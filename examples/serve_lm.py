"""Batched LM serving demo: continuous-batched prefill+decode over synthetic
requests (reduced config on CPU; production mesh uses the same steps).

    PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-1.2b]
"""

import argparse

from repro.launch.serve import serve_demo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    args = ap.parse_args()
    out = serve_demo(args.arch, n_requests=args.requests,
                     n_lanes=args.lanes)
    print(f"served {out['requests']} requests, "
          f"{out['tokens']} tokens in {out['wall_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s, reduced config on CPU)")


if __name__ == "__main__":
    main()
