"""End-to-end LM training driver: train a ~small LM for a few hundred steps
with the full production stack (data pipeline, optimizer, checkpointing,
fault-tolerant loop) — the same code path the dry-run proves at 405B/512
chips.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen2-7b] [--steps 200]
"""

import argparse

from repro.launch.train import build_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    loop = build_trainer(args.arch, use_reduced=True, seq_len=args.seq,
                         global_batch=args.batch, total_steps=args.steps,
                         ckpt_every=max(args.steps // 4, 1),
                         ckpt_dir=args.ckpt_dir)
    state = loop.run()
    n = len(state.losses)
    print(f"steps: {state.step} (resumed_from={state.resumed_from})")
    print(f"loss: {state.losses[0]:.4f} → {state.losses[-1]:.4f} "
          f"(min {min(state.losses):.4f})")
    head = sum(state.losses[: n // 5]) / (n // 5)
    tail = sum(state.losses[-n // 5:]) / (n // 5)
    assert tail < head, "loss did not decrease"
    print("loss decreased ✓ — checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
