"""Quickstart: stratum in ~40 lines.

Build two agent-style ML pipelines against the same table, hand the batch
to a :class:`repro.client.StratumClient`, and watch fusion + CSE +
operator selection + caching do their job.  Swap ``"local"`` for
``"service"`` or ``"fabric"`` and nothing else changes — that is the
point of the unified surface.

    PYTHONPATH=src python examples/quickstart.py [--rows 20000]
"""

import argparse

import numpy as np

from repro.client import StratumConfig, SubmitOptions, connect
from repro.core import PipelineBatch
import repro.tabular as T
from repro.data.tabular import feature_target_indices, schema_dict

args = argparse.ArgumentParser()
args.add_argument("--rows", type=int, default=20_000)
args = args.parse_args()

feats, tgt = feature_target_indices()

# --- two pipelines an agent might emit (shared preprocessing prefix) -----
raw = T.read("uk_housing", n_rows=args.rows, seed=0)
y = T.project(raw, [tgt])
X = T.table_vectorizer(T.project(raw, feats), schema_dict(), feats)

ridge = T.cv_score(X, y, {"name": "ridge_fit", "alpha": 1.0}, k=3, seed=7)
gbt = T.cv_score(X, y, {"name": "gbt_fit", "n_trees": 20}, k=3, seed=7)

# --- run the batch through a stratum client -------------------------------
client = connect("local", StratumConfig.make(memory_budget_bytes=4 << 30))
results, report = client.run_batch(
    PipelineBatch([ridge, gbt], ["ridge", "gbt"]),
    SubmitOptions(deadline_s=600, tags=("quickstart",)))

print("scores:", {k: round(float(np.asarray(v)), 4)
                  for k, v in results.items()})
print(report.summary())

# --- run it again: the intermediate cache kicks in -------------------------
results2, report2 = client.run_batch(
    PipelineBatch([ridge, gbt], ["ridge", "gbt"]))
print(f"\nsecond run: {report2.run.ops_from_cache} ops served from cache, "
      f"wall {report2.run.wall_time_s:.3f}s "
      f"(first run {report.run.wall_time_s:.3f}s)")
assert results2["ridge"] == results["ridge"]
