"""Quickstart: stratum in ~40 lines.

Build two agent-style ML pipelines against the same table, hand the batch to
stratum, and watch fusion + CSE + operator selection + caching do their job.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import PipelineBatch, Stratum
import repro.tabular as T
from repro.data.tabular import feature_target_indices, schema_dict

feats, tgt = feature_target_indices()

# --- two pipelines an agent might emit (shared preprocessing prefix) -----
raw = T.read("uk_housing", n_rows=20_000, seed=0)
y = T.project(raw, [tgt])
X = T.table_vectorizer(T.project(raw, feats), schema_dict(), feats)

ridge = T.cv_score(X, y, {"name": "ridge_fit", "alpha": 1.0}, k=3, seed=7)
gbt = T.cv_score(X, y, {"name": "gbt_fit", "n_trees": 20}, k=3, seed=7)

# --- run the batch through stratum ----------------------------------------
session = Stratum(memory_budget_bytes=4 << 30)
results, report = session.run_batch(
    PipelineBatch([ridge, gbt], ["ridge", "gbt"]))

print("scores:", {k: round(float(np.asarray(v)), 4)
                  for k, v in results.items()})
print(report.summary())

# --- run it again: the intermediate cache kicks in -------------------------
results2, report2 = session.run_batch(
    PipelineBatch([ridge, gbt], ["ridge", "gbt"]))
print(f"\nsecond run: {report2.run.ops_from_cache} ops served from cache, "
      f"wall {report2.run.wall_time_s:.3f}s "
      f"(first run {report.run.wall_time_s:.3f}s)")
assert results2["ridge"] == results["ridge"]
