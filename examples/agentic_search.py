"""End-to-end agentic pipeline search (the paper's §6 use case).

A deterministic AIDE-like agent explores preprocessing × model combinations
and then fine-tunes the winner with a grid search.

Two modes:

* default — the original synchronous path: one ``Stratum`` session, the
  agent blocks on each ``run_batch``.
* ``--service`` — the multi-tenant execution service: ``--agents N``
  concurrent AIDE agents connect via non-blocking ``Session`` handles and
  run :class:`AsyncAIDESearch`, which keeps drafting the next tree nodes
  while earlier batches are still executing.  Concurrent submissions are
  coalesced, cross-agent duplicates execute once, and all agents share one
  intermediate cache.  Add ``--shards K`` to run the agents against the
  sharded fabric instead (``ShardedStratum``): submissions cross the
  serializable envelope boundary and each search tree is pinned to one
  consistent-hash shard via ``shard_affinity``.

    PYTHONPATH=src python examples/agentic_search.py [--rows 20000]
    PYTHONPATH=src python examples/agentic_search.py --service --agents 4
    PYTHONPATH=src python examples/agentic_search.py --service --shards 2
"""

import argparse
import threading
import time

import numpy as np

from repro.agents import AIDEAgent, AsyncAIDESearch, paper_workload_batches
from repro.agents.aide import second_iteration_batch
from repro.core import Stratum
from repro.service import ShardedStratum, StratumService


def run_sync(args) -> None:
    session = Stratum(memory_budget_bytes=4 << 30)

    # ---- iteration 1: 2 preprocessing strategies × 4 models --------------
    name, batch, ctx = next(iter(paper_workload_batches(
        n_rows=args.rows, cv_k=args.cv)))
    t0 = time.time()
    results, report = session.run_batch(batch)
    t1 = time.time() - t0
    print(f"iteration 1 ({len(results)} pipelines) in {t1:.2f}s")
    for k, v in sorted(results.items(), key=lambda kv: float(kv[1])):
        print(f"   rmse={float(np.asarray(v)):.4f}  {k}")
    print(f"   CSE merged {report.rewrites.cse_merged} ops, "
          f"read sharing x{report.rewrites.reads_shared + 1}")

    # ---- iteration 2: grid search on the winner ---------------------------
    best = min(results, key=lambda k: float(np.asarray(results[k])))
    print(f"\nbest: {best} → grid search")
    batch2, specs2 = second_iteration_batch(ctx["specs"][best])
    t0 = time.time()
    results2, report2 = session.run_batch(batch2)
    t2 = time.time() - t0
    best2 = min(results2, key=lambda k: float(np.asarray(results2[k])))
    print(f"iteration 2 ({len(results2)} grid points) in {t2:.2f}s "
          f"— {report2.run.ops_from_cache} ops from cache")
    print(f"   winner: {best2} rmse={float(np.asarray(results2[best2])):.4f}"
          f" (params {specs2[int(best2.split('_')[1])].params_dict()})")


def run_service(args) -> None:
    t0 = time.time()
    if args.shards:
        svc = ShardedStratum(n_shards=args.shards,
                             memory_budget_bytes=4 << 30,
                             coalesce_window_s=0.05)
    else:
        svc = StratumService(memory_budget_bytes=4 << 30,
                             coalesce_window_s=0.05)
    with svc:
        bests = [None] * args.agents

        def agent_main(i: int) -> None:
            agent = AIDEAgent(n_rows=args.rows, cv_k=args.cv, seed=i)
            search = AsyncAIDESearch(svc.session(f"agent-{i}"), agent,
                                     batch_size=4, max_inflight=2,
                                     shard_affinity=bool(args.shards))
            bests[i] = search.run(n_rounds=args.rounds)

        threads = [threading.Thread(target=agent_main, args=(i,))
                   for i in range(args.agents)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        dt = time.time() - t0
        print(f"{args.agents} agents × {args.rounds} rounds in {dt:.2f}s "
              f"(async, overlapped planning/execution)")
        for i, node in enumerate(bests):
            if node is not None:
                print(f"   agent-{i}: best rmse={node.score:.4f} "
                      f"({node.spec.preproc}+{node.spec.model})")
        print(svc.telemetry.report())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--cv", type=int, default=3)
    ap.add_argument("--service", action="store_true",
                    help="run N concurrent agents through StratumService")
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3,
                    help="AIDE search rounds per agent (service mode)")
    ap.add_argument("--shards", type=int, default=0,
                    help="service mode: run agents against a ShardedStratum"
                         " fabric with this many shards")
    args = ap.parse_args()
    if args.service:
        run_service(args)
    else:
        run_sync(args)


if __name__ == "__main__":
    main()
