"""End-to-end agentic pipeline search (the paper's §6 use case).

A deterministic AIDE-like agent explores preprocessing × model combinations
and then fine-tunes the winner with a grid search — all execution flows
through one stratum session, so fused batches share work and iteration 2
reuses iteration 1's preprocessing from the cache.

    PYTHONPATH=src python examples/agentic_search.py [--rows 20000]
"""

import argparse
import time

import numpy as np

from repro.agents import paper_workload_batches
from repro.agents.aide import second_iteration_batch
from repro.core import Stratum


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--cv", type=int, default=3)
    args = ap.parse_args()

    session = Stratum(memory_budget_bytes=4 << 30)

    # ---- iteration 1: 2 preprocessing strategies × 4 models --------------
    name, batch, ctx = next(iter(paper_workload_batches(
        n_rows=args.rows, cv_k=args.cv)))
    t0 = time.time()
    results, report = session.run_batch(batch)
    t1 = time.time() - t0
    print(f"iteration 1 ({len(results)} pipelines) in {t1:.2f}s")
    for k, v in sorted(results.items(), key=lambda kv: float(kv[1])):
        print(f"   rmse={float(np.asarray(v)):.4f}  {k}")
    print(f"   CSE merged {report.rewrites.cse_merged} ops, "
          f"read sharing x{report.rewrites.reads_shared + 1}")

    # ---- iteration 2: grid search on the winner ---------------------------
    best = min(results, key=lambda k: float(np.asarray(results[k])))
    print(f"\nbest: {best} → grid search")
    batch2, specs2 = second_iteration_batch(ctx["specs"][best])
    t0 = time.time()
    results2, report2 = session.run_batch(batch2)
    t2 = time.time() - t0
    best2 = min(results2, key=lambda k: float(np.asarray(results2[k])))
    print(f"iteration 2 ({len(results2)} grid points) in {t2:.2f}s "
          f"— {report2.run.ops_from_cache} ops from cache")
    print(f"   winner: {best2} rmse={float(np.asarray(results2[best2])):.4f}"
          f" (params {specs2[int(best2.split('_')[1])].params_dict()})")


if __name__ == "__main__":
    main()
