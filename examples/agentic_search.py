"""End-to-end agentic pipeline search (the paper's §6 use case).

A deterministic AIDE-like agent explores preprocessing × model combinations
and then fine-tunes the winner with a grid search.

All modes drive the SAME unified submission surface
(:class:`repro.client.StratumClient`): pick a target with ``--target``.

* ``local`` (default) — the original synchronous path: one in-process
  optimizing session; the agent blocks on each batch.
* ``service`` — the multi-tenant execution service: ``--agents N``
  concurrent AIDE agents connect via tenant-scoped client sessions and
  run :class:`AsyncAIDESearch`, which keeps drafting the next tree nodes
  while earlier batches are still executing.  Concurrent submissions are
  coalesced, cross-agent duplicates execute once, and all agents share one
  intermediate cache.
* ``fabric`` — the same agents against the sharded fabric (``--shards K``
  consistent-hash shards): submissions cross the serializable envelope
  boundary and each search tree is pinned to one shard via
  ``shard_affinity``.

``--deadline-ms D`` attaches a deadline SLO to every *refinement*
submission (the work the search frontier is blocked on): on a
deadline-aware backend, refinements are scheduled EDF within their band
and shed with ``DeadlineExceeded`` if the SLO expires — the run prints
the attainment rate from telemetry afterwards.

    PYTHONPATH=src python examples/agentic_search.py [--rows 20000]
    PYTHONPATH=src python examples/agentic_search.py --target service --agents 4
    PYTHONPATH=src python examples/agentic_search.py --target fabric --shards 2 \
        --deadline-ms 2000
    PYTHONPATH=src python examples/agentic_search.py --processes --shards 2
"""

import argparse
import threading
import time

import numpy as np

from repro.agents import AIDEAgent, AsyncAIDESearch, paper_workload_batches
from repro.agents.aide import second_iteration_batch
from repro.client import StratumConfig, connect


def run_sync(args) -> None:
    client = connect("local", StratumConfig.make(
        memory_budget_bytes=4 << 30))

    # ---- iteration 1: 2 preprocessing strategies × 4 models --------------
    name, batch, ctx = next(iter(paper_workload_batches(
        n_rows=args.rows, cv_k=args.cv)))
    t0 = time.time()
    results, report = client.run_batch(batch)
    t1 = time.time() - t0
    print(f"iteration 1 ({len(results)} pipelines) in {t1:.2f}s")
    for k, v in sorted(results.items(), key=lambda kv: float(kv[1])):
        print(f"   rmse={float(np.asarray(v)):.4f}  {k}")
    print(f"   CSE merged {report.rewrites.cse_merged} ops, "
          f"read sharing x{report.rewrites.reads_shared + 1}")

    # ---- iteration 2: grid search on the winner ---------------------------
    best = min(results, key=lambda k: float(np.asarray(results[k])))
    print(f"\nbest: {best} → grid search")
    batch2, specs2 = second_iteration_batch(ctx["specs"][best])
    t0 = time.time()
    results2, report2 = client.run_batch(batch2)
    t2 = time.time() - t0
    best2 = min(results2, key=lambda k: float(np.asarray(results2[k])))
    print(f"iteration 2 ({len(results2)} grid points) in {t2:.2f}s "
          f"— {report2.run.ops_from_cache} ops from cache")
    print(f"   winner: {best2} rmse={float(np.asarray(results2[best2])):.4f}"
          f" (params {specs2[int(best2.split('_')[1])].params_dict()})")


def run_async(args) -> None:
    t0 = time.time()
    cfg = StratumConfig.make(memory_budget_bytes=4 << 30,
                             coalesce_window_s=0.05,
                             n_shards=args.shards,
                             processes=args.processes,
                             trace=args.live)
    deadline_s = args.deadline_ms / 1000 if args.deadline_ms else None
    with connect(args.target, cfg) as client:
        bests = [None] * args.agents
        live_stop = threading.Event()
        if args.live:
            # periodic text dashboard over the same telemetry snapshots
            # `python -m repro.service.observability.top` renders offline
            from repro.service.observability import top

            def live_view() -> None:
                while not live_stop.wait(1.0):
                    frame = top.render(client.telemetry.global_snapshot())
                    print(f"\n{frame}\n", flush=True)

            threading.Thread(target=live_view, name="live-view",
                             daemon=True).start()

        def agent_main(i: int) -> None:
            agent = AIDEAgent(n_rows=args.rows, cv_k=args.cv, seed=i)
            search = AsyncAIDESearch(
                client.session(f"agent-{i}"), agent,
                batch_size=4, max_inflight=2,
                shard_affinity=args.target == "fabric",
                deadline_s=deadline_s)
            bests[i] = search.run(n_rounds=args.rounds)

        threads = [threading.Thread(target=agent_main, args=(i,))
                   for i in range(args.agents)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        live_stop.set()
        dt = time.time() - t0
        print(f"{args.agents} agents × {args.rounds} rounds in {dt:.2f}s "
              f"(async, overlapped planning/execution)")
        for i, node in enumerate(bests):
            if node is not None:
                print(f"   agent-{i}: best rmse={node.score:.4f} "
                      f"({node.spec.preproc}+{node.spec.model})")
        if deadline_s is not None:
            d = client.telemetry.global_snapshot()["deadline"]
            print(f"refinement SLO ({args.deadline_ms}ms): "
                  f"{d['met']}/{d['jobs']} met "
                  f"(attainment {d['attainment']:.2f}, shed {d['shed']})")
        print(client.telemetry.report())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--cv", type=int, default=3)
    ap.add_argument("--target", choices=("local", "service", "fabric"),
                    default="local",
                    help="which StratumClient target runs the search")
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3,
                    help="AIDE search rounds per agent (async targets)")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard count (implies --target fabric; "
                         "default 2 when --target fabric is given alone)")
    ap.add_argument("--processes", action="store_true",
                    help="run each fabric shard in its own OS process "
                         "(implies --target fabric)")
    ap.add_argument("--deadline-ms", type=int, default=0,
                    help="SLO for refinement submissions (async targets); "
                         "late refinements are shed with DeadlineExceeded")
    ap.add_argument("--live", action="store_true",
                    help="render a live text dashboard (per-shard depth, "
                         "plan-cache hit rate, windowed attainment) while "
                         "the search runs; async targets only")
    # legacy spelling kept working: --service == --target service, and
    # --service --shards K (the PR-3 invocation) still means the fabric
    ap.add_argument("--service", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.target == "local" and (args.service or args.shards):
        args.target = "fabric" if args.shards else "service"
    if (args.shards or args.processes) and args.target != "fabric":
        args.target = "fabric"
    if args.target == "fabric" and not args.shards:
        args.shards = 2
    if args.target == "local":
        run_sync(args)
    else:
        run_async(args)


if __name__ == "__main__":
    main()
