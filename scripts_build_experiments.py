"""Regenerate EXPERIMENTS.md from results/*.jsonl + benchmark CSVs.

Usage: PYTHONPATH=src python scripts_build_experiments.py
Reads:  results/dryrun_single.jsonl, results/dryrun_multi.jsonl,
        results/bench_e2e.txt (optional), results/perf_log.md (optional)
"""

import json
import os
import sys

sys.path.insert(0, "src")
from benchmarks.roofline import load_cells, model_flops, table  # noqa: E402

OUT = "EXPERIMENTS.md"


def dryrun_section() -> str:
    lines = ["## §Dry-run", ""]
    for mesh, path in [("16x16 (256 chips, single pod)",
                        "results/dryrun_single.jsonl"),
                       ("2x16x16 (512 chips, multi-pod)",
                        "results/dryrun_multi.jsonl")]:
        recs = [json.loads(l) for l in open(path)]
        ok = [r for r in recs if r["status"] == "ok"]
        skip = [r for r in recs if r["status"] == "skip"]
        err = [r for r in recs if r["status"] == "error"]
        lines.append(f"### Mesh {mesh}: {len(ok)} compiled OK, "
                     f"{len(skip)} documented skips, {len(err)} errors")
        lines.append("")
        lines.append("| arch | shape | per-dev mem arg/temp (GB) | "
                     "HLO GFLOPs/dev | collective GB/dev | policy |")
        lines.append("|---|---|---|---|---|---|")
        for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
            if r["status"] == "ok":
                mem = r["per_device_mem_bytes"]
                pol = r["policy"]
                pol_s = (f"tp={int(pol['tp'])} fsdp={int(pol['fsdp'])} "
                         f"sp={int(pol['sp'])} ep={pol['ep'] or '-'} "
                         f"M={pol['microbatches']}")
                lines.append(
                    f"| {r['arch']} | {r['shape']} | "
                    f"{mem['argument']/1e9:.1f}/{mem['temp']/1e9:.1f} | "
                    f"{r['flops']/1e9:.0f} | "
                    f"{r['collective_bytes']/1e9:.1f} | {pol_s} |")
            elif r["status"] == "skip":
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                             f"SKIP: {r['reason'][:48]} |")
            else:
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                             f"ERROR |")
        lines.append("")
    if os.path.exists("results/multipod_note.md"):
        lines.append(open("results/multipod_note.md").read())
    return "\n".join(lines)


def roofline_section() -> str:
    lines = ["## §Roofline", "",
             "Terms per (arch × shape) on the single-pod 16×16 mesh "
             "(TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI/link):",
             "",
             "* **compute term** = per-device loop-aware HLO dot-FLOPs / "
             "peak  (`cost_analysis()` omits while-loop trip counts — "
             "verified — so FLOPs come from the custom pass in "
             "`launch/hlo_cost.py`, validated against unrolled modules)",
             "* **memory term** = analytic fused-backend HBM traffic / BW "
             "(the CPU-lowered HLO materializes tensors that live in VMEM "
             "inside the Pallas kernels on the TPU target; the analytic "
             "model in `launch/analysis.py` counts weight/activation/cache "
             "streams; HLO-derived bytes are recorded in the jsonl as a "
             "bracket)",
             "* **collective term** = per-device collective operand bytes "
             "(loop-aware HLO parse) / ICI link BW",
             "* **MODEL/HLO** = useful FLOPs (6·N_active·D train, 2·N·D "
             "prefill, per-token decode) / global HLO FLOPs — catches "
             "remat and replication waste.",
             "",
             table("16x16"), ""]
    return "\n".join(lines)


def main():
    parts = [open("EXPERIMENTS.header.md").read()
             if os.path.exists("EXPERIMENTS.header.md") else
             "# EXPERIMENTS\n"]
    parts.append(dryrun_section())
    parts.append(roofline_section())
    if os.path.exists("results/perf_log.md"):
        parts.append(open("results/perf_log.md").read())
    if os.path.exists("results/paper_validation.md"):
        parts.append(open("results/paper_validation.md").read())
    with open(OUT, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
