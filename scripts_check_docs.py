#!/usr/bin/env python
"""Docs hygiene checker (run by the CI ``docs`` job).

Fails (exit 1) when:

* a relative markdown link in ``README.md`` or ``docs/*.md`` points at a
  file or directory that does not exist, or
* an ``examples/*.py`` script is never referenced from the docs tree
  (README or ``docs/``) — examples that nothing points at rot silently.

Absolute URLs (http/https) are ignored: CI must not depend on the network.
"""

from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.abspath(__file__))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files() -> list:
    docs = [os.path.join(ROOT, "README.md")]
    docs_dir = os.path.join(ROOT, "docs")
    if os.path.isdir(docs_dir):
        docs += sorted(os.path.join(docs_dir, n)
                       for n in os.listdir(docs_dir) if n.endswith(".md"))
    return docs


def check_links(paths) -> list:
    errors = []
    for path in paths:
        base = os.path.dirname(path)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:          # pure in-page anchor
                continue
            resolved = os.path.normpath(os.path.join(base, rel))
            if not os.path.exists(resolved):
                errors.append(f"{os.path.relpath(path, ROOT)}: "
                              f"broken link -> {target}")
    return errors


def check_examples_referenced(paths) -> list:
    corpus = ""
    for path in paths:
        with open(path, encoding="utf-8") as f:
            corpus += f.read()
    errors = []
    ex_dir = os.path.join(ROOT, "examples")
    for name in sorted(os.listdir(ex_dir)):
        if not name.endswith(".py") or name.startswith("_"):
            continue
        if f"examples/{name}" not in corpus:
            errors.append(f"examples/{name} is not referenced from "
                          f"README.md or docs/")
    return errors


def main() -> int:
    paths = doc_files()
    missing = [p for p in ("docs/ARCHITECTURE.md", "docs/SCHEDULING.md",
                           "docs/API.md")
               if not os.path.exists(os.path.join(ROOT, p))]
    errors = [f"missing doc: {p}" for p in missing]
    errors += check_links(paths)
    errors += check_examples_referenced(paths)
    if errors:
        for e in errors:
            print(f"FAIL {e}")
        return 1
    print(f"docs OK: {len(paths)} files, links resolve, "
          f"all examples referenced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
