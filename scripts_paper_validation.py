"""Build results/paper_validation.md from the tee'd benchmark CSV.

Usage: python scripts_paper_validation.py bench_output.txt
"""

import sys


def parse(path: str) -> dict:
    rows = {}
    for line in open(path):
        parts = line.strip().split(",", 2)
        if len(parts) == 3 and parts[0] != "name":
            rows[parts[0]] = (parts[1], parts[2])
    return rows


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "bench_output.txt"
    r = parse(path)

    def sec(name):
        return float(r[name][0]) / 1e6 if name in r else float("nan")

    def derived(name):
        return r.get(name, ("", ""))[1]

    lines = [
        "## §Paper-validation",
        "",
        "Measured on this container (1 CPU core — the paper used 48; see "
        "notes).  Full CSV: bench_output.txt.",
        "",
        "### Fig. 2 — workload characterization",
        "",
        f"* median iteration diff: {sec('characterize_median_diff')*100:.1f}%"
        f" of pipeline lines; {derived('characterize_median_diff')}"
        " — paper: 50% of iterations change ≤16% of lines.",
        f"* operator redundancy across the fused batch: "
        f"{sec('characterize_redundancy')*100:.1f}% of submitted ops are "
        f"duplicates ({derived('characterize_redundancy')}).",
        "",
        "### Fig. 6(a) — end-to-end agentic search (2 iterations)",
        "",
        "| mode | wall (s) | speedup |",
        "|---|---|---|",
        f"| Base (sequential AIDE, interpreted tier) | {sec('e2e_base'):.1f}"
        " | 1.0× |",
        f"| Base_par (naive thread-parallel) | {sec('e2e_base_par'):.1f} | "
        f"{sec('e2e_base')/max(sec('e2e_base_par'),1e-9):.1f}× |",
        f"| **stratum** (all optimizations) | {sec('e2e_stratum'):.1f} | "
        f"**{sec('e2e_base')/max(sec('e2e_stratum'),1e-9):.1f}×** |",
        "",
        f"Paper: 16.6× over Base, 7.8× over Base_par on a 48-core node.  "
        f"Score agreement across modes: rel. diff "
        f"{sec('e2e_score_agreement')*1e6:.1f}e-6 (semantic equivalence).",
        "",
        "Interpretation: the paper's gains decompose into redundancy "
        "elimination (ours reproduces), native-backend selection (ours "
        "reproduces at 1-core scale), and 48-way parallelism of the Rust "
        "backend (not reproducible on 1 core — the paper itself attributes "
        "only +10% to inter-op parallelism because its operators already "
        "saturate cores; the multithreading win is inside its *intra*-op "
        "kernels, which a single-core container cannot express).",
        "",
        "### Fig. 6(b) — ablation (cumulative, full 2-iteration workload)",
        "",
        "| level | wall (s) | speedup | paper |",
        "|---|---|---|---|",
        f"| none (fused graph, interpreted ops) | {sec('ablation_none'):.1f}"
        " | 1.0× | 1.0× |",
        f"| +logical (CSE, sharing, rewrites) | "
        f"{sec('ablation_+logical'):.1f} | "
        f"{derived('ablation_+logical').split()[0].replace('speedup=','')} "
        "| 2.2× |",
        f"| +operator selection | {sec('ablation_+selection'):.1f} | "
        f"{derived('ablation_+selection').split()[0].replace('speedup=','')}"
        " | ×4.5 further |",
        f"| +inter-op parallelism | {sec('ablation_+parallel'):.1f} | "
        f"{derived('ablation_+parallel').split()[0].replace('speedup=','')} "
        "| +10% |",
        f"| +cache (cross-iteration reuse) | {sec('ablation_+cache'):.1f} | "
        f"{derived('ablation_+cache').split()[0].replace('speedup=','')} "
        "| n/a (included in 16.6×) |",
        "",
    ]
    with open("results/paper_validation.md", "w") as f:
        f.write("\n".join(lines) + "\n")
    print("wrote results/paper_validation.md")


if __name__ == "__main__":
    main()
