"""Distributed correctness tests — run in subprocesses with a forced
8-device host platform (the main test process must keep 1 device).

``repro.distributed.compat`` bridges the jax version gap (shard_map /
make_mesh spellings), so these run on both modern jax and the 0.4.37
floor."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    line = out.stdout.strip().splitlines()[-1]
    return json.loads(line)


PREAMBLE = """
import json
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_config, reduced
from repro.models.config import ShapeConfig
from repro.models.model import param_specs, init_params
from repro.distributed.policy import (make_policy, param_pspecs,
                                      tree_shardings, input_pspecs)
from repro.distributed.context import use_context
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh((2, 4), ("data", "model"))
"""


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    """Same seeds, same batch: sharded loss == single-device loss."""
    code = PREAMBLE + textwrap.dedent("""
    from repro.train.step import make_train_step
    from repro.optim import adamw
    from repro.launch.specs import train_input_specs
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b")),
                              d_model=128, n_heads=4, n_kv_heads=2,
                              vocab=512, dtype="float32")
    shape = ShapeConfig("t", 64, 8, "train")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 4, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 512, (2, 4, 64)), jnp.int32)}
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3)

    # single-device reference
    step0 = make_train_step(cfg, opt, policy=None)
    o0 = step0.init_opt_state(params)
    p0, _, m0 = jax.jit(step0)(params, o0, batch)

    # sharded
    pol = make_policy(cfg, shape, mesh, tp=True, fsdp=True, microbatches=2)
    with use_context(pol.context()):
        step1 = make_train_step(cfg, opt, policy=pol)
        pshard = tree_shardings(param_pspecs(params, pol, cfg), pol)
        o1 = step1.init_opt_state(params)
        oshard = tree_shardings(param_pspecs(o1, pol, cfg), pol)
        bshard = tree_shardings(input_pspecs(batch, pol, "train"), pol)
        fn = jax.jit(step1, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None))
        p1, _, m1 = fn(jax.device_put(params, pshard),
                       jax.device_put(o1, oshard),
                       jax.device_put(batch, bshard))
    d_loss = abs(float(m0["loss"]) - float(m1["loss"]))
    d_par = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)))
    print(json.dumps({"d_loss": d_loss, "d_par": d_par}))
    """)
    out = _run(code)
    assert out["d_loss"] < 2e-4, out
    assert out["d_par"] < 2e-3, out


@pytest.mark.slow
def test_vocab_parallel_ce_matches_fused():
    code = PREAMBLE + textwrap.dedent("""
    from repro.distributed.vocab_ce import vocab_parallel_ce
    from repro.kernels import fused_cross_entropy
    from repro.distributed.context import use_context, ShardingContext
    rng = np.random.default_rng(1)
    T, D, V = 64, 32, 512
    x = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, V)) * 0.1, jnp.float32)
    lab = jnp.asarray(rng.integers(0, 500, T), jnp.int32)
    val = jnp.ones((T,), bool)
    ref = float(fused_cross_entropy(x, w, lab, valid=val, n_valid=500))
    ctx = ShardingContext(mesh=mesh, rules={})
    with use_context(ctx):
        got = float(vocab_parallel_ce(x, w, lab, val, n_valid=500))
        # grads too
        g1 = jax.grad(lambda x: fused_cross_entropy(x, w, lab, valid=val,
                                                    n_valid=500))(x)
        g2 = jax.grad(lambda x: vocab_parallel_ce(x, w, lab, val,
                                                  n_valid=500))(x)
    d_g = float(jnp.max(jnp.abs(g1 - g2)))
    print(json.dumps({"ref": ref, "got": got, "d_g": d_g}))
    """)
    out = _run(code)
    assert abs(out["ref"] - out["got"]) < 1e-4, out
    assert out["d_g"] < 1e-4, out


@pytest.mark.slow
def test_moe_ep_matches_local():
    code = PREAMBLE + textwrap.dedent("""
    from repro.models.moe import moe_ffn
    from repro.distributed.context import use_context, ShardingContext
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = dataclasses.replace(reduced(get_config("granite-moe-3b-a800m")),
                              d_model=64, n_heads=4, n_kv_heads=2,
                              n_experts=8, top_k=2, d_ff_expert=32,
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])["moe"]
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 16, 64)), jnp.float32)
    y_local = moe_ffn(lp, x, cfg)
    ctx = ShardingContext(mesh=mesh, rules={}, ep_axis="model")
    with use_context(ctx):
        y_ep = jax.jit(lambda lp, x: moe_ffn(lp, x, cfg))(lp, x)
    d = float(jnp.max(jnp.abs(y_local - y_ep)))
    print(json.dumps({"d": d}))
    """)
    out = _run(code)
    assert out["d"] < 2e-4, out


@pytest.mark.slow
def test_compressed_psum_matches_psum():
    code = PREAMBLE + textwrap.dedent("""
    from functools import partial
    from repro.optim.compress import compressed_psum
    from jax.sharding import PartitionSpec as P
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(8, 512)), jnp.float32)

    from repro.distributed.compat import shard_map

    @partial(shard_map, mesh=mesh, in_specs=P(("data", "model")),
             out_specs=P(("data", "model")), check_vma=False)
    def exact(g):
        return jax.lax.psum(g, ("data", "model")) / 8 + 0 * g

    @partial(shard_map, mesh=mesh, in_specs=P(("data", "model")),
             out_specs=P(("data", "model")), check_vma=False)
    def compressed(g):
        return compressed_psum(g, ("data", "model")) / 8 + 0 * g

    a = exact(g)
    b = compressed(g)
    rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
    print(json.dumps({"rel": rel}))
    """)
    out = _run(code)
    assert out["rel"] < 0.02, out       # int8 quantization error bound


@pytest.mark.slow
def test_elastic_restart_across_meshes():
    """Checkpoint on a (2,4) mesh, restore onto (1,4) with 4 devices."""
    import tempfile
    tmp = tempfile.mkdtemp()
    save_code = PREAMBLE + textwrap.dedent(f"""
    from repro.ckpt import save_checkpoint
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b")),
                              d_model=128, n_heads=4, n_kv_heads=2,
                              vocab=512, dtype="float32")
    shape = ShapeConfig("t", 32, 8, "train")
    pol = make_policy(cfg, shape, mesh, tp=True, fsdp=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pshard = tree_shardings(param_pspecs(params, pol, cfg), pol)
    params = jax.device_put(params, pshard)
    save_checkpoint({tmp!r}, 1, params)
    print(json.dumps({{"sum": float(sum(jnp.sum(jnp.abs(l))
                                        for l in jax.tree.leaves(params)))}}))
    """)
    a = _run(save_code)

    restore_code = textwrap.dedent(f"""
    import json
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs import get_config, reduced
    from repro.models.config import ShapeConfig
    from repro.models.model import param_specs
    from repro.distributed.policy import make_policy, param_pspecs, tree_shardings
    from repro.launch.mesh import make_debug_mesh
    from repro.ckpt import load_checkpoint
    mesh = make_debug_mesh((1, 4), ("data", "model"))   # DIFFERENT mesh
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b")),
                              d_model=128, n_heads=4, n_kv_heads=2,
                              vocab=512, dtype="float32")
    shape = ShapeConfig("t", 32, 8, "train")
    pol = make_policy(cfg, shape, mesh, tp=True, fsdp=True)
    pstruct = param_specs(cfg)
    pshard = tree_shardings(param_pspecs(pstruct, pol, cfg), pol)
    tree, _ = load_checkpoint({tmp!r}, 1, pstruct, shardings=pshard)
    print(json.dumps({{"sum": float(sum(jnp.sum(jnp.abs(l))
                                        for l in jax.tree.leaves(tree)))}}))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", restore_code], env=env,
                         capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    b = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(a["sum"] - b["sum"]) / a["sum"] < 1e-5


@pytest.mark.slow
def test_seq_sharded_flash_decode_matches_single_device():
    """§Perf H4: distributed flash-decode (LSE merge over a seq-sharded
    cache) must reproduce single-device decode logits."""
    code = PREAMBLE + textwrap.dedent("""
    from repro.models.model import (init_params, prefill, decode_step,
                                    init_decode_state)
    from repro.distributed.policy import decode_state_pspecs
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b")),
                              d_model=128, n_heads=4, n_kv_heads=2,
                              vocab=512, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S, MAX = 8, 12, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    _, st0 = prefill(params, {"tokens": toks[:, :S]}, cfg, max_len=MAX)
    ref, _ = decode_step(params, st0, toks[:, S:S + 1], cfg)

    shape = ShapeConfig("dec", MAX, B, "decode")
    pol = make_policy(cfg, shape, mesh, tp=True)
    with use_context(pol.context()):
        pshard = tree_shardings(param_pspecs(params, pol, cfg), pol)
        sstruct = jax.eval_shape(lambda: init_decode_state(cfg, B, MAX))
        sshard = tree_shardings(decode_state_pspecs(sstruct, pol, B), pol)
        pf = jax.jit(lambda p, i: prefill(p, i, cfg, max_len=MAX),
                     out_shardings=(None, sshard))
        _, st1 = pf(jax.device_put(params, pshard), {"tokens": toks[:, :S]})
        dec = jax.jit(lambda p, s, t: decode_step(p, s, t, cfg),
                      in_shardings=(pshard, sshard, None),
                      out_shardings=(None, sshard))
        got, _ = dec(jax.device_put(params, pshard), st1, toks[:, S:S + 1])
    err = float(jnp.max(jnp.abs(ref - got)))
    print(json.dumps({"err": err}))
    """)
    out = _run(code)
    assert out["err"] < 2e-3, out
