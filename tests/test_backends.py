"""Compiled plan-segment backends: structural signatures, the plan cache,
whole-segment jit execution, segment-boundary preemption salvage, and the
tenant-aware cache probe in vmap variant batching."""

import numpy as np
import pytest

import repro.tabular as T
from repro.core import (PipelineBatch, PlanCache, Stratum,
                        structural_signature)
from repro.core.cache import IntermediateCache
from repro.core.runtime import ExecutionPreempted, Runtime
from repro.core.scheduler import partition_segments


def _variant_sink(alpha, cols=(10, 11, 12, 13), n_rows=2000):
    """A jax-heavy pipeline; alpha is a tunable constant."""
    x = T.read("uk_housing", n_rows, seed=0)
    y = T.project(x, [0])
    Xv = T.scale(T.impute(T.project(x, list(cols))))
    w = T.ridge_fit(Xv, y, alpha=alpha)
    return T.metric(y, T.predict(w, Xv), kind="rmse")


def _compiled_sessions(**kw):
    on = Stratum(memory_budget_bytes=1 << 30, **kw)
    off = Stratum(memory_budget_bytes=1 << 30, compiled_segments=False,
                  **kw)
    return on, off


# ---------------------------------------------------------------------------
# structural signatures
# ---------------------------------------------------------------------------

def test_structural_signature_shared_across_constants():
    """Pipelines differing only in tunable constants share one structural
    signature; differing in topology (or non-tunable spec) don't."""
    a = _variant_sink(alpha=0.1)
    b = _variant_sink(alpha=42.0)
    c = _variant_sink(alpha=0.1, cols=(10, 11))          # topology change
    assert structural_signature([a]) == structural_signature([b])
    assert structural_signature([a]) != structural_signature([c])
    # content signatures still differ (they hash the constants)
    assert a.op.signature != b.op.signature


def test_structural_signature_nontunable_spec_is_structural():
    x = T.read("uk_housing", 1000, seed=0)
    y = T.project(x, [0])
    Xv = T.impute(T.project(x, [10, 11]))
    m1 = T.metric(y, T.project(Xv, [0]), kind="rmse")
    m2 = T.metric(y, T.project(Xv, [0]), kind="mae")     # kind: not tunable
    assert structural_signature([m1]) != structural_signature([m2])


def test_structural_signature_seed_value_excluded():
    """Seed values are payload (runtime-side), presence is structural."""
    w1 = T.ridge_fit(T.project(T.read("uk_housing", 1000, seed=0), [1, 2]),
                     T.project(T.read("uk_housing", 1000, seed=0), [0]),
                     alpha=1.0, seed=3)
    w2 = T.ridge_fit(T.project(T.read("uk_housing", 1000, seed=0), [1, 2]),
                     T.project(T.read("uk_housing", 1000, seed=0), [0]),
                     alpha=1.0, seed=9)
    assert w1.op.structural_signature == w2.op.structural_signature
    # seed *absence* is structural (it flips cacheability semantics)
    from repro.core import ESTIMATOR, LazyOp
    w3 = LazyOp("ridge_fit", ESTIMATOR, spec={"alpha": 1.0},
                inputs=tuple(w1.op.inputs), seed=None).out()
    assert w1.op.structural_signature != w3.op.structural_signature


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_lru_eviction_and_telemetry():
    pc = PlanCache(capacity=2)
    pc.put("a", 1)
    pc.put("b", 2)
    assert pc.get("a") == 1                  # refresh a: b is now LRU
    pc.put("c", 3)                           # evicts b
    assert "b" not in pc and "a" in pc and "c" in pc
    assert pc.get("b") is None
    snap = pc.snapshot()
    assert snap["entries"] == 2
    assert snap["evictions"] == 1
    assert snap["compiles"] == 3
    assert snap["hits"] == 1 and snap["misses"] == 1
    assert snap["hit_rate"] == 0.5
    # re-put of a live key is not a new compile
    pc.put("a", 10)
    assert pc.snapshot()["compiles"] == 3
    with pytest.raises(ValueError):
        PlanCache(capacity=0)


def test_plan_cache_reused_across_hyperparameter_variants():
    """The same structure with different constants compiles once; later
    variants are pure plan-cache hits (no retraces)."""
    # no intermediate cache: isolate compiled-plan reuse from value reuse
    s = Stratum(memory_budget_bytes=1 << 30,
                enable=("logical", "lowering", "selection", "parallel"))
    scores = []
    for alpha in (0.1, 1.0, 10.0):
        r, rep = s.run(_variant_sink(alpha))
        scores.append(float(np.asarray(r)))
    snap = s.plan_cache.snapshot()
    assert snap["compiles"] > 0
    assert snap["hits"] >= snap["compiles"]  # variants 2..3 all hit
    first_compiles = snap["compiles"]
    s.run(_variant_sink(123.0))
    assert s.plan_cache.snapshot()["compiles"] == first_compiles
    assert len(set(scores)) == 3             # different alphas, real work


# ---------------------------------------------------------------------------
# compiled execution equivalence
# ---------------------------------------------------------------------------

def test_compiled_segments_match_per_op_dispatch():
    on, off = _compiled_sessions()
    sink = _variant_sink(alpha=2.0)
    r_on, rep_on = on.run(sink)
    r_off, rep_off = off.run(sink)
    assert rep_on.run.per_backend.get("jax-seg", 0) > 0
    assert "jax-seg" not in rep_off.run.per_backend
    np.testing.assert_allclose(float(np.asarray(r_on)),
                               float(np.asarray(r_off)), rtol=1e-6)


def test_plan_has_backend_homogeneous_segments():
    s = Stratum(memory_budget_bytes=1 << 30)
    sinks, sel, plan, *_ = s.compile_batch(
        PipelineBatch([_variant_sink(1.0)], ["p"]))
    kinds = [seg.kind for seg in plan.segments]
    assert "jax" in kinds and "python" in kinds
    # segments tile the wave list exactly, in order
    assert sum(len(seg.waves) for seg in plan.segments) == len(plan.waves)
    # maximality: no two adjacent segments share a kind
    assert all(a != b for a, b in zip(kinds, kinds[1:]))
    # every op of a jax segment selected a traceable jax impl
    for seg in plan.segments:
        if seg.kind != "jax":
            continue
        for wave in seg.waves:
            for op in wave.ops:
                impl = sel[op.signature]
                assert impl.backend == "jax" and impl.traceable


def test_one_op_jax_runs_demoted_to_python():
    """A single traceable op gains nothing from whole-segment tracing, so
    1-op jax runs stay per-op; ≥2 contiguous traceable ops segment."""
    from repro.core.scheduler import Wave
    from repro.core.selection import impls_for
    impl = next(i for i in impls_for("project") if i.backend == "jax")
    x = T.read("uk_housing", 500, seed=0)
    a, b = T.project(x, [1, 2]).op, T.project(x, [3, 4]).op
    sel = {a.signature: impl, b.signature: impl}
    assert [s.kind for s in
            partition_segments([Wave(ops=[a])], sel)] == ["python"]
    assert [s.kind for s in
            partition_segments([Wave(ops=[a]), Wave(ops=[b])], sel)] \
        == ["jax"]


def test_uncompilable_segment_falls_back_to_per_op(monkeypatch):
    """An impl wrongly declared traceable must not break execution: the
    segment falls back to per-op dispatch, the plan-cache entry is
    poisoned, and results match the per-op path."""
    from repro.core.selection import impls_for
    impl = next(i for i in impls_for("string_encode") if i.backend == "jax")
    monkeypatch.setattr(impl, "traceable", True)    # lie: it uses np.unique
    x = T.read("uk_housing", 1500, seed=0)
    y = T.project(x, [0])
    enc = T.string_encode(T.project(x, [5]), dim=4, seed=1)
    sink = T.metric(y, T.predict(
        T.ridge_fit(T.scale(T.impute(enc)), y, alpha=1.0),
        T.scale(T.impute(enc))), kind="rmse")
    on, off = _compiled_sessions()
    r_on, rep_on = on.run(sink)
    r_off, _ = off.run(sink)
    np.testing.assert_allclose(float(np.asarray(r_on)),
                               float(np.asarray(r_off)), rtol=1e-6)
    # second run goes straight to the poisoned-entry fallback (no retrace)
    r_on2, _ = on.run(sink)
    np.testing.assert_allclose(float(np.asarray(r_on2)),
                               float(np.asarray(r_off)), rtol=1e-6)


# ---------------------------------------------------------------------------
# segment-boundary preemption: salvage exactness
# ---------------------------------------------------------------------------

def test_segment_boundary_preemption_salvage_exact():
    """Preempting between segments and resuming with the salvage executes
    every op exactly once across the two dispatches."""
    s = Stratum(memory_budget_bytes=1 << 30,
                enable=("logical", "lowering", "selection", "parallel"))
    sink = _variant_sink(alpha=3.0)
    sinks, sel, plan, cands, *_ = s.compile_batch(
        PipelineBatch([sink], ["p"]))
    n_unique = len({op.signature for w in plan.waves for op in w.ops})

    fired = []

    def preempt_once():
        if not fired:
            fired.append(True)
            return True
        return False

    rt1 = Runtime(parallel=False, preempt_check=preempt_once,
                  backends=s._backends)
    with pytest.raises(ExecutionPreempted) as ei:
        rt1.execute(sinks, plan, sel)
    salvage = ei.value.salvage
    assert salvage                            # something completed pre-yield

    rt2 = Runtime(parallel=False, preloaded=salvage, backends=s._backends)
    results, rep2 = rt2.execute(sinks, plan, sel)
    # exactness: nothing executed twice, nothing skipped
    assert ei.value.waves_done <= len(plan.waves)
    assert rep2.ops_executed + rep2.ops_salvaged == n_unique
    assert rep2.ops_executed < n_unique       # the resume reused salvage
    # and the result is correct
    r_ref, _ = Stratum(memory_budget_bytes=1 << 30).run(sink)
    np.testing.assert_allclose(float(np.asarray(results[0])),
                               float(np.asarray(r_ref)), rtol=1e-6)


# ---------------------------------------------------------------------------
# vmap variant batching: tenant-aware cache probe (PR satellite)
# ---------------------------------------------------------------------------

def test_batch_variants_cache_hits_attribute_cross_tenant():
    """vmap-grouped ops served from the shared cache must go through the
    tenant-aware get: cross-tenant hits are attributed, and the fetched
    value is the one used (no membership-probe/eviction race window)."""
    x = T.read("uk_housing", 1500, seed=0)
    y = T.project(x, [0])
    Xv = T.scale(T.impute(T.project(x, [10, 11, 12])))
    fits = [T.ridge_fit(Xv, y, alpha=a) for a in (0.5, 5.0)]
    batch = PipelineBatch(fits, ["w0", "w1"])

    cache = IntermediateCache(budget_bytes=64 << 20)
    # per-op path so _batch_variants is exercised
    s = Stratum(memory_budget_bytes=1 << 30, cache=cache,
                compiled_segments=False)
    sinks, sel, plan, cands, *_ = s.compile_batch(batch)
    fit_sigs = [op.signature for w in plan.waves for op in w.ops
                if op.op_name == "ridge_fit"]
    assert len(fit_sigs) == 2

    # tenant A materializes everything (including the fits)
    rt_a = Runtime(cache=cache, cache_candidates=set(
        cands | set(fit_sigs)), parallel=False, compiled_segments=False,
        sig_tenant={sig: "A" for w in plan.waves for op in w.ops
                    for sig in [op.signature]})
    rt_a.execute(sinks, plan, sel)
    assert all(sig in cache for sig in fit_sigs)

    before = cache.stats.cross_tenant_hits
    # tenant B re-runs the same structure: the vmap group probe must be a
    # tenant-aware get and count both fits as cross-tenant hits
    rt_b = Runtime(cache=cache, cache_candidates=cands, parallel=False,
                   compiled_segments=False,
                   sig_tenant={sig: "B" for w in plan.waves for op in w.ops
                               for sig in [op.signature]})
    _, rep_b = rt_b.execute(sinks, plan, sel)
    assert all(rep_b.sig_source[sig] == "cache" for sig in fit_sigs)
    assert cache.stats.cross_tenant_hits >= before + 2
    assert rep_b.per_backend.get("jax-vmap", 0) == 0   # nothing re-fit


# ---------------------------------------------------------------------------
# service + fabric telemetry surface
# ---------------------------------------------------------------------------

def test_plan_cache_hit_rate_in_service_and_fabric_snapshots():
    from repro.service import StratumService
    from repro.service.fabric import ShardedStratum

    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         autostart=True)
    try:
        ses = svc.session("t")
        for alpha in (0.2, 2.0):
            ses.submit(PipelineBatch([_variant_sink(alpha)], ["p"])
                       ).result(timeout=120)
        g = svc.telemetry.global_snapshot()
        assert "plan_cache" in g
        assert g["plan_cache"]["hits"] + g["plan_cache"]["misses"] > 0
        assert "hit_rate" in g["plan_cache"]
    finally:
        svc.stop()

    fab = ShardedStratum(n_shards=2, memory_budget_bytes=1 << 30,
                         n_executors=1)
    try:
        ses = fab.session("t")
        for alpha in (0.2, 2.0):
            ses.submit(PipelineBatch([_variant_sink(alpha)], ["p"])
                       ).result(timeout=120)
        g = fab.telemetry.global_snapshot()
        assert "plan_cache_hit_rate" in g
        assert g["plan_cache_hits"] + g["plan_cache_misses"] > 0
        assert any("plan_cache" in row for row in g["per_shard"].values())
    finally:
        fab.stop()


# ---------------------------------------------------------------------------
# custom register_backend kinds get their own segments (ROADMAP item)
# ---------------------------------------------------------------------------

class _ToyBackend:
    """Minimal custom ExecutionBackend: executes per-op through the
    runtime helpers and stamps its own name into sig_source."""

    name = "toy"

    def __init__(self, plan_cache=None):
        self.plan_cache = plan_cache
        self.segments_executed = 0

    def execute_segment(self, rt, segment, selection, report):
        self.segments_executed += 1
        report.waves += len(segment.waves)
        for wave in segment.waves:
            for op in wave.ops:
                rt._run_op(op, selection, report)
            rt._free_wave(wave)


def test_partition_emits_segments_for_registered_custom_kind(monkeypatch):
    from repro.core.backends.base import _FACTORIES
    from repro.core.scheduler import Wave
    from repro.core.selection import PhysicalImpl
    monkeypatch.setitem(_FACTORIES, "toy", _ToyBackend)

    def _ident(op, inputs):
        return (inputs[0],)

    toy_impl = PhysicalImpl(op_name="noop", backend="toy", fn=_ident)
    x = T.read("uk_housing", 500, seed=0)
    a, b = T.project(x, [1, 2]).op, T.project(x, [3, 4]).op
    sel = {a.signature: toy_impl, b.signature: toy_impl}
    segs = partition_segments([Wave(ops=[a]), Wave(ops=[b])], sel)
    assert [s.kind for s in segs] == ["toy"]
    # unregistered custom backends still flatten onto the python path
    monkeypatch.delitem(_FACTORIES, "toy")
    segs = partition_segments([Wave(ops=[a]), Wave(ops=[b])], sel)
    assert [s.kind for s in segs] == ["python"]


def test_custom_backend_executes_its_segments_end_to_end(monkeypatch):
    """register_backend("toy") + a selection picking backend="toy" runs
    the toy backend for whole segments through the ordinary Runtime."""
    from repro.core import GENERIC, LazyOp
    from repro.core.backends.base import _FACTORIES, make_backends
    from repro.core.scheduler import SchedulerConfig, plan as make_plan
    from repro.core.selection import BACKENDS, BackendProfile, PhysicalImpl
    monkeypatch.setitem(_FACTORIES, "toy", _ToyBackend)
    monkeypatch.setitem(BACKENDS, "toy",
                        BackendProfile("toy", 1e9, 1e9, 1e-6, 1.0))

    def _add_one(op, inputs):
        return (np.asarray(inputs[0]) + 1.0,)

    a = LazyOp("toy_add", GENERIC, spec={"fn": lambda v: v + 1.0},
               inputs=(LazyOp("const0", GENERIC,
                              spec={"fn": lambda: np.zeros(4)}).out(),))
    sink = LazyOp("toy_add2", GENERIC, spec={"fn": lambda v: v + 1.0},
                  inputs=(a.out(),)).out()
    toy = PhysicalImpl(op_name="toy_add", backend="toy", fn=_add_one)
    sel = {a.signature: toy, sink.op.signature: toy}
    p = make_plan([sink], sel, SchedulerConfig())
    assert "toy" in {seg.kind for seg in p.segments}
    backends = make_backends(None, compiled=True)
    assert "toy" in backends            # registry factory picked up
    rt = Runtime(backends=backends)
    results, report = rt.execute([sink], p, sel)
    np.testing.assert_allclose(np.asarray(results[0]), np.full(4, 2.0))
    assert backends["toy"].segments_executed >= 1
    assert report.per_backend.get("toy", 0) == 2


# ---------------------------------------------------------------------------
# segment est_time budget bounds compiled-segment preempt latency
# ---------------------------------------------------------------------------

def test_segment_time_budget_splits_jax_segments():
    budget = 1e-9           # below any wave's est_time → one wave each
    s_nb = Stratum(memory_budget_bytes=1 << 30)
    s_b = Stratum(memory_budget_bytes=1 << 30,
                  segment_time_budget_s=budget)
    batch = PipelineBatch([_variant_sink(1.0)], ["p"])
    _, _, plan_nb, *_ = s_nb.compile_batch(batch)
    _, _, plan_b, *_ = s_b.compile_batch(batch)
    n_jax_nb = sum(1 for seg in plan_nb.segments if seg.kind == "jax")
    n_jax_b = sum(1 for seg in plan_b.segments if seg.kind == "jax")
    assert n_jax_b > n_jax_nb          # the cap split the big segment
    for seg in plan_b.segments:
        if seg.kind == "jax":
            assert len(seg.waves) == 1
    # splitting changes dispatch granularity, never results
    r_b, _ = s_b.run_batch(batch)
    r_nb, _ = s_nb.run_batch(batch)
    np.testing.assert_allclose(float(np.asarray(r_b["p"])),
                               float(np.asarray(r_nb["p"])), rtol=1e-6)


def test_segment_pieces_respect_the_budget():
    from repro.core.scheduler import partition_segments as ps
    s = Stratum(memory_budget_bytes=1 << 30)
    sinks, sel, plan, *_ = s.compile_batch(
        PipelineBatch([_variant_sink(1.0)], ["p"]))
    base = [seg for seg in ps(plan.waves, sel) if seg.kind == "jax"]
    assert base, "workload must produce a jax segment"
    times = [w.est_time for seg in base for w in seg.waves]
    budget = max(times) * 1.5          # forces a split mid-segment
    for seg in ps(plan.waves, sel, time_budget_s=budget):
        if seg.kind != "jax" or len(seg.waves) == 1:
            continue                   # single waves may overshoot alone
        assert sum(w.est_time for w in seg.waves) <= budget


def test_budget_bounds_preempt_latency_at_segment_boundaries():
    """With the cap, a preempt check fires BETWEEN pieces of what would
    have been one monolithic compiled segment: the yield arrives with
    partial salvage instead of after the whole segment."""
    s = Stratum(memory_budget_bytes=1 << 30, segment_time_budget_s=1e-9)
    batch = PipelineBatch([_variant_sink(1.0)], ["p"])
    sinks, sel, plan, candidates, *_ = s.compile_batch(batch)
    n_ops = sum(len(w.ops) for w in plan.waves)
    fired = {"n": 0}

    def preempt_after_first_progress():
        fired["n"] += 1
        return fired["n"] > 2          # let the first segments run

    rt = Runtime(preempt_check=preempt_after_first_progress,
                 backends=s._backends)
    with pytest.raises(ExecutionPreempted) as exc:
        rt.execute(sinks, plan, sel)
    salvage = exc.value.salvage
    assert 0 < len(salvage) < n_ops    # a bounded slice ran, not the lot
    # the salvage resumes losslessly (preemption semantics preserved)
    rt2 = Runtime(preloaded=salvage, backends=s._backends)
    results, report = rt2.execute(sinks, plan, sel)
    ref, _ = Stratum(memory_budget_bytes=1 << 30).run_batch(batch)
    np.testing.assert_allclose(float(np.asarray(results[0])),
                               float(np.asarray(ref["p"])), rtol=1e-6)
    # every salvaged value is honored; completed-then-freed ops the
    # reverse-topo sweep skips count as salvaged too, hence >=
    assert report.ops_salvaged >= len(salvage)
