"""Per-architecture smoke tests (MANDATED): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs.  Plus
decode-vs-forward consistency and param-count sanity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import decode_step, forward, init_params, loss_fn, prefill

RNG = np.random.default_rng(0)


def _inputs(cfg, B=2, S=32):
    labels = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.frontend == "none":
        return {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32), "labels": labels}
    return {"embeds": jnp.asarray(RNG.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32), "labels": labels}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    inputs = _inputs(cfg, B, S)

    hidden, _ = forward(params, inputs, cfg)
    assert hidden.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()

    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, inputs, cfg))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    inputs = _inputs(cfg, B, S)
    inputs.pop("labels")
    logits, state = prefill(params, inputs, cfg, max_len=S + 8)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    if cfg.frontend != "none":
        tok = jnp.asarray(RNG.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    l2, state2 = decode_step(params, state, tok, cfg)
    assert l2.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(l2)).all()
    assert int(state2["len"][0]) == S + 1


@pytest.mark.parametrize("arch", ["qwen2-7b", "zamba2-1.2b", "xlstm-1.3b",
                                  "granite-moe-3b-a800m"])
def test_decode_consistency_with_forward(arch):
    """Teacher-forced decode must reproduce the full forward's next-token
    logits (prefill S tokens, decode token S ≡ forward over S+1 tokens)."""
    cfg = reduced(get_config(arch))
    if cfg.family == "moe":
        # top_k == n_experts → routing is drop-free, so prefill and decode
        # see identical expert assignments (GShard capacity dropping is
        # otherwise batch-size dependent by design)
        cfg = dataclasses.replace(cfg, n_experts=4, top_k=4)
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)

    hidden, _ = forward(params, {"tokens": toks}, cfg)
    full_logits = (hidden[:, -1] @ params["lm_head"]).astype(jnp.float32)

    logits_p, state = prefill(params, {"tokens": toks[:, :S]}, cfg,
                              max_len=S + 4)
    dec_logits, _ = decode_step(params, state, toks[:, S:S + 1], cfg)

    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               atol=2e-2, rtol=2e-2)


def test_param_counts_match_published_scale():
    """Analytic parameter counts land near the published sizes."""
    expect = {
        "llama3-405b": 405e9, "qwen2-7b": 7.6e9, "nemotron-4-340b": 340e9,
        "starcoder2-15b": 15e9, "arctic-480b": 480e9,
        "internvl2-76b": 70e9, "zamba2-1.2b": 1.2e9, "xlstm-1.3b": 1.3e9,
        "musicgen-medium": 1.5e9, "granite-moe-3b-a800m": 3.3e9,
    }
    for arch, target in expect.items():
        n = get_config(arch).params_count()
        assert 0.5 * target < n < 1.8 * target, (arch, n, target)


def test_moe_active_params_smaller():
    cfg = get_config("arctic-480b")
    assert cfg.active_params_count() < 0.2 * cfg.params_count()


def test_vocab_padding_shardable():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        assert cfg.vocab_padded % 128 == 0
        assert cfg.vocab_padded % 16 == 0
        assert cfg.vocab_padded >= cfg.vocab
