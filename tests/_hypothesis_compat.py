"""Optional-hypothesis shim for the tier-1 suite.

``hypothesis`` is an *extra* (see pyproject ``[test]``); the tier-1 suite
must collect and run without it.  When it is installed we re-export the real
``given``/``settings``/``st``.  When it is missing, ``@given`` degrades to a
``pytest.mark.parametrize`` over a small deterministic sample of each
strategy's domain (bounds + midpoint), so the property tests still execute
as fixed-example tests instead of erroring at import time.

Only the strategy surface the suite actually uses (``st.integers``) is
shimmed; grow it as tests need more.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import itertools

    import pytest

    HAVE_HYPOTHESIS = False

    class _Integers:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = int(lo), int(hi)

        def samples(self) -> list:
            mid = (self.lo + self.hi) // 2
            return sorted({self.lo, mid, self.hi})

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Integers:
            return _Integers(min_value, max_value)

    st = _Strategies()

    def settings(**_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            names = fn.__code__.co_varnames[:fn.__code__.co_argcount]
            argnames = ",".join(names[-len(strategies):])
            cases = list(itertools.product(
                *(s.samples() for s in strategies)))
            if len(strategies) == 1:      # 1-tuples would reach the test
                cases = [c[0] for c in cases]
            return pytest.mark.parametrize(argnames, cases)(fn)
        return deco
