"""Structural validation of the sharding policy across all 40 cells —
every parameter/optimizer/state/input PartitionSpec must divide its dim and
never duplicate a mesh axis.  Catches config/policy regressions without a
single compile (the compile-level proof is the dry-run grid)."""

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.distributed.policy import (decode_state_pspecs,
                                      make_policy,
                                      param_pspecs)
from repro.models.config import SHAPES, shape_applicable
from repro.models.model import init_decode_state, param_specs


def _mesh_like_production():
    """Same axis names/proportions as production, host-size (1 device ok —
    specs are validated structurally against the production axis sizes)."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    return FakeMesh()


AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _check_spec_tree(tree, spec_tree, where):
    leaves = jax.tree_util.tree_leaves(tree)
    spec_leaves = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves), where
    for leaf, spec in zip(leaves, spec_leaves):
        assert isinstance(spec, P), (where, spec)
        used = []
        for dim, entry in zip(leaf.shape, tuple(spec)):
            axes = () if entry is None else (
                entry if isinstance(entry, tuple) else (entry,))
            shards = 1
            for a in axes:
                assert a not in used, f"{where}: duplicate axis {a} in {spec}"
                used.append(a)
                shards *= AXIS_SIZES[a]
            assert dim % shards == 0, \
                f"{where}: dim {dim} not divisible by {shards} ({spec})"


class ProdMesh:
    """Duck-typed mesh carrying production axis sizes (policy only reads
    .shape)."""
    def __init__(self, multi_pod=False):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                      else {"data": 16, "model": 16})


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape_name", list(SHAPES))
@pytest.mark.parametrize("multi_pod", [False, True])
def test_all_cell_policies_are_structurally_valid(arch, shape_name,
                                                  multi_pod):
    cfg = get_config(arch)
    ok, _ = shape_applicable(cfg, shape_name)
    if not ok:
        pytest.skip("documented shape skip")
    shape = SHAPES[shape_name]
    pol = make_policy(cfg, shape, ProdMesh(multi_pod))

    pstruct = param_specs(cfg)
    _check_spec_tree(pstruct, param_pspecs(pstruct, pol, cfg),
                     f"{arch}/{shape_name}/params")

    if shape.kind == "decode":
        sstruct = jax.eval_shape(
            lambda: init_decode_state(cfg, shape.global_batch,
                                      shape.seq_len))
        _check_spec_tree(
            sstruct, decode_state_pspecs(sstruct, pol, shape.global_batch),
            f"{arch}/{shape_name}/state")


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "musicgen-medium",
                                  "zamba2-1.2b", "xlstm-1.3b"])
def test_dp_over_model_policies_valid(arch):
    """§Perf H2 remesh must produce valid specs for every small arch."""
    cfg = get_config(arch)
    pol = make_policy(cfg, SHAPES["train_4k"], ProdMesh(False),
                      dp_over_model=True)
    assert pol.ep_axis is None
    pstruct = param_specs(cfg)
    _check_spec_tree(pstruct, param_pspecs(pstruct, pol, cfg),
                     f"{arch}/remesh/params")


def test_policy_flags_follow_scale():
    big = make_policy(get_config("llama3-405b"), SHAPES["train_4k"],
                      ProdMesh(False))
    small = make_policy(get_config("musicgen-medium"), SHAPES["train_4k"],
                        ProdMesh(False))
    assert big.tp and big.fsdp and big.sp
    assert not small.tp and not small.fsdp
    assert make_policy(get_config("granite-moe-3b-a800m"),
                       SHAPES["train_4k"], ProdMesh(False)).ep_axis == "model"
    # long_500k decode with batch 1 must not shard the batch dim
    lp = make_policy(get_config("xlstm-1.3b"), SHAPES["long_500k"],
                     ProdMesh(False))
    assert lp.batch_dp is None
