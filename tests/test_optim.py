"""Optimizers, schedules, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adafactor, adamw, cosine_schedule,
                         int8_compress_decompress, linear_warmup,
                         make_error_feedback)


def _optimize(opt, steps=200):
    """Minimize ||Wx - y||² over a small linear model."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    w_true = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    Y = X @ w_true
    params = {"w": jnp.zeros((8, 4), jnp.float32),
              "b": jnp.zeros((4,), jnp.float32)}

    def loss(p):
        return jnp.mean((X @ p["w"] + p["b"] - Y) ** 2)

    state = opt.init(params)
    step = jax.jit(lambda p, s: opt.update(jax.grad(loss)(p), s, p))
    for _ in range(steps):
        params, state, _ = step(params, state)
    return float(loss(params))


def test_adamw_converges():
    assert _optimize(adamw(lr=0.05)) < 1e-3


def test_adafactor_converges():
    # adafactor's RMS-clipped updates need a conservative lr on tiny problems
    assert _optimize(adafactor(lr=0.02), steps=600) < 1e-2


def test_grad_clip_bounds_update():
    opt = adamw(lr=1.0, grad_clip=1e-6)
    params = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 1e6)}
    state = opt.init(params)
    new, _, gnorm = opt.update(g, state, params)
    assert float(gnorm) > 1e5              # reported pre-clip norm
    assert float(jnp.max(jnp.abs(new["w"] - params["w"]))) < 1.1


def test_schedules_shape():
    s = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    steps = jnp.arange(0, 100)
    lrs = jax.vmap(s)(steps)
    assert float(lrs[0]) < 1e-4            # warmup start
    assert abs(float(lrs[10]) - 1e-3) < 1e-4
    assert float(lrs[99]) < float(lrs[10])
    w = linear_warmup(1e-3, 10)
    assert abs(float(w(jnp.asarray(20))) - 1e-3) < 1e-9


# ---------------------------------------------------------------------------
# int8 compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    g_hat, resid = int8_compress_decompress(g)
    # per-block max / 127 quantization error bound
    assert float(jnp.max(jnp.abs(resid))) <= float(jnp.max(jnp.abs(g))) / 127 + 1e-6
    np.testing.assert_allclose(np.asarray(g_hat + resid), np.asarray(g),
                               atol=1e-6)


def test_error_feedback_preserves_convergence():
    """SGD with int8+EF must converge like exact SGD on a quadratic."""
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.normal(size=(16, 16)) / 4, jnp.float32)
    A = A @ A.T + 0.5 * jnp.eye(16)
    b = jnp.asarray(rng.normal(size=(16,)), jnp.float32)

    def grad(x):
        return A @ x - b

    ef_init, ef_apply = make_error_feedback()
    x = jnp.zeros(16)
    x_ef = jnp.zeros(16)
    ef = ef_init({"x": x})
    lr = 0.1
    for _ in range(300):
        x = x - lr * grad(x)
        g_hat, ef2 = ef_apply({"x": grad(x_ef)}, ef)
        ef = ef2
        x_ef = x_ef - lr * g_hat["x"]
    x_star = jnp.linalg.solve(A, b)
    assert float(jnp.linalg.norm(x - x_star)) < 1e-3
    assert float(jnp.linalg.norm(x_ef - x_star)) < 1e-2
