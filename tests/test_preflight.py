"""Pre-flight static analysis: wiring/shape/lint verdicts, admission-time
rejection on every client target, picklable AnalysisError, coalesced
blast-radius isolation, the AIDE repair loop and the concurrency lint.

The property tests ride ``tests/_hypothesis_compat`` so the suite runs
with or without hypothesis installed.
"""

import pickle
import subprocess
import sys
from pathlib import Path

import pytest

import repro.tabular as T
from repro.agents.aide import AIDEAgent, AsyncAIDESearch
from repro.client import (StratumConfig, SubmitOptions, connect)
from repro.core import PipelineBatch, Stratum
from repro.core.analysis import (AnalysisError, analyze, validate_wiring)
from repro.core.dag import TRANSFORM, LazyOp
from repro.service import StratumService

from _hypothesis_compat import given, settings, st

REPO = Path(__file__).resolve().parent.parent


def _pipeline(n_rows=2000, cols=(10, 11, 12)):
    x = T.read("uk_housing", n_rows, seed=0)
    xs = T.scale(T.impute(T.project(x, list(cols))))
    return T.metric(T.project(xs, [0]), T.project(x, [0]), kind="mae")


def _valid_batch(name="p"):
    return PipelineBatch([_pipeline()], [name])


def _invalid_batch(name="bad", op="no_such_op"):
    t = T.read("uk_housing", 2000, seed=0)
    return PipelineBatch([LazyOp(op, TRANSFORM, inputs=(t,)).out()], [name])


def _config(**overrides):
    base = dict(memory_budget_bytes=1 << 30, n_executors=1, n_shards=2,
                coalesce_window_s=0.0)
    base.update(overrides)
    return StratumConfig.make(**base)


# ---------------------------------------------------------------------------
# verdict correctness: no false positives, and OK verdicts really execute
# ---------------------------------------------------------------------------

def test_zero_false_positives_on_paper_corpus():
    """Every pipeline the repo's own workloads build must analyze clean."""
    from repro.agents import paper_workload_batches
    from repro.agents.aide import PipelineSpec, second_iteration_batch
    batches = [b for _name, b, _ctx in paper_workload_batches(n_rows=2000)]
    grid_batch, _specs = second_iteration_batch(PipelineSpec(n_rows=2000))
    batches.append(grid_batch)
    assert batches
    for batch in batches:
        report = analyze(batch)
        assert report.ok, [str(f) for f in report.errors]


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=40))
def test_analyzer_ok_implies_executable(seed):
    """Property: any AIDE-space batch the analyzer passes must execute.
    (The converse is not required — jnp index clamping lets some invalid
    pipelines 'execute' silently, which is exactly what the analyzer is
    for.)"""
    agent = AIDEAgent(n_rows=2000, seed=seed)
    specs = agent.propose(2)
    batch = PipelineBatch([s.build() for s in specs],
                          [f"v{i}" for i in range(len(specs))])
    report = analyze(batch)
    assert report.ok, [str(f) for f in report.errors]
    st_ = Stratum(memory_budget_bytes=1 << 30)
    results, _ = st_.run_batch(batch)
    assert len(results) == len(specs)


def test_invalid_batch_findings_have_provenance():
    report = analyze(_invalid_batch())
    assert not report.ok
    assert any(f.rule == "unknown-op" and f.op_name == "no_such_op"
               for f in report.errors)
    with pytest.raises(AnalysisError) as ei:
        report.raise_if_invalid()
    assert "unknown-op" in ei.value.rules


# ---------------------------------------------------------------------------
# admission-time rejection, uniform across the three client targets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("target", ["local", "service", "fabric"])
def test_verify_rejects_at_submit_on_every_target(target):
    with connect(target, _config()) as client:
        report = client.analyze(_invalid_batch())
        assert not report.ok and "unknown-op" in {f.rule
                                                  for f in report.errors}
        with pytest.raises(AnalysisError):
            client.submit(_invalid_batch(),
                          options=SubmitOptions(verify=True))
        # valid traffic is untouched by verification
        value, _ = client.run(_pipeline(),
                              options=SubmitOptions(verify=True))
        assert float(value) == float(value)        # finite, not NaN-check


def test_verify_rejects_at_submit_processes_true():
    cfg = _config(processes=True)
    with connect("fabric", cfg) as client:
        with pytest.raises(AnalysisError) as ei:
            client.submit(_invalid_batch(),
                          options=SubmitOptions(verify=True))
        assert "unknown-op" in ei.value.rules


def test_admission_analysis_config_default_and_telemetry():
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, admission_analysis=True)
    try:
        ses = svc.session("t")
        ses.submit(_valid_batch()).result(timeout=120)
        ses.submit(_valid_batch()).result(timeout=120)   # cached verdict
        with pytest.raises(AnalysisError):
            ses.submit(_invalid_batch())
        snap = svc.telemetry.global_snapshot()["analysis"]
        assert snap["analyzed"] == 3
        assert snap["rejected"] == 1
        assert snap["cached_verdicts"] >= 1
        assert snap["by_rule"].get("unknown-op", 0) >= 1
    finally:
        svc.stop()


def test_submit_options_verify_must_be_bool():
    with pytest.raises(ValueError):
        SubmitOptions(verify="yes")


# ---------------------------------------------------------------------------
# the error is structured and survives every wire it can cross
# ---------------------------------------------------------------------------

def test_analysis_error_pickle_roundtrip():
    err = pytest.raises(AnalysisError,
                        analyze(_invalid_batch()).raise_if_invalid).value
    clone = pickle.loads(pickle.dumps(err))
    assert isinstance(clone, AnalysisError)
    assert clone.rules == err.rules
    assert clone.findings == err.findings


def test_analysis_error_crosses_envelope_codec():
    from repro.service.fabric.envelope import (ResultEnvelope,
                                               decode_result, encode_result)
    err = pytest.raises(AnalysisError,
                        analyze(_invalid_batch()).raise_if_invalid).value
    env = ResultEnvelope(envelope_id="e1", tenant="t", shard_id="s0",
                         ok=False, results=None, report=None, error=err)
    back = decode_result(encode_result(env))
    assert isinstance(back.error, AnalysisError)
    assert back.error.rules == err.rules


# ---------------------------------------------------------------------------
# without verification, wiring errors still fail deterministically —
# and a poisoned coalesced batch only takes down its own job
# ---------------------------------------------------------------------------

def test_wiring_error_is_structured_without_analysis():
    st_ = Stratum(memory_budget_bytes=1 << 30)
    with pytest.raises(AnalysisError) as ei:
        st_.run_batch(_invalid_batch())
    assert "unknown-op" in ei.value.rules


def test_coalesced_blast_radius_is_isolated():
    """An invalid job merged into a super-batch fails alone, with its own
    findings; coalesced valid bystanders still complete."""
    want, _ = Stratum(memory_budget_bytes=1 << 30).run_batch(_valid_batch())
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                        coalesce_window_s=0.05)
    try:
        ses = svc.session("agent")
        bad_ses = svc.session("adversary")
        # the executor picks up this head-of-line job first; everything
        # submitted behind it queues up and coalesces
        head = ses.submit(_valid_batch("head"))
        good = [ses.submit(_valid_batch(f"g{i}")) for i in range(3)]
        bad = bad_ses.submit(_invalid_batch())
        with pytest.raises(AnalysisError) as ei:
            bad.result(timeout=120)
        assert "unknown-op" in ei.value.rules
        head.result(timeout=120)
        for f in good:
            results, _ = f.result(timeout=120)
            for v in results.values():
                assert float(v) == pytest.approx(float(want["p"]))
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# feasibility classification pre-verifies compiled segments
# ---------------------------------------------------------------------------

def test_preverified_segments_recorded_and_results_unchanged():
    st_ = Stratum(memory_budget_bytes=1 << 30)
    batch = _valid_batch()
    report = st_.analyze_batch(batch)
    assert report.ok
    assert report.segments                 # feasibility classification ran
    if any(s.get("kind") == "jax" for s in report.segments):
        assert report.preverified_segments >= 1
    results, _ = st_.run_batch(batch)
    ref, _ = Stratum(memory_budget_bytes=1 << 30).run_batch(_valid_batch())
    assert float(results["p"]) == pytest.approx(float(ref["p"]))


# ---------------------------------------------------------------------------
# the agent reads the verdict and repairs instead of resubmitting blind
# ---------------------------------------------------------------------------

def test_aide_agent_never_reproposes_rejected_spec():
    agent = AIDEAgent(n_rows=2000, seed=3)
    first = agent.propose(4)
    err = pytest.raises(AnalysisError,
                        analyze(_invalid_batch()).raise_if_invalid).value
    agent.observe_rejection(first[:2], err)
    assert agent.rejection_rules.get("unknown-op", 0) >= 1
    for _ in range(6):
        for spec in agent.propose(4):
            assert spec not in agent.rejected_specs


def test_async_search_survives_admission_analysis():
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, admission_analysis=True)
    try:
        agent = AIDEAgent(n_rows=2000, seed=1)
        search = AsyncAIDESearch(svc.session("aide"), agent, batch_size=2,
                                 max_inflight=2)
        best = search.run(n_rounds=2)
        assert best is not None and best.score is not None
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# the runtime's own concurrency lint
# ---------------------------------------------------------------------------

_LINT = REPO / "scripts_check_concurrency.py"

_BAD_MODULE = '''\
import threading
import time

class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self.jobs = []          # guarded-by: _lock

    def slow(self):
        with self._lock:
            time.sleep(0.1)

    def unguarded(self):
        self.jobs = []
'''


def test_concurrency_lint_flags_synthetic_violations(tmp_path):
    mod = tmp_path / "bad.py"
    mod.write_text(_BAD_MODULE)
    out = subprocess.run([sys.executable, str(_LINT), str(mod)],
                         capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 1
    assert "blocking" in out.stdout       # time.sleep under _lock
    assert "guarded-by" in out.stdout     # self.jobs written without _lock


def test_concurrency_lint_clean_on_runtime():
    out = subprocess.run([sys.executable, str(_LINT)],
                         capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# lint findings (warnings) don't reject, and reach the report
# ---------------------------------------------------------------------------

def test_lint_warnings_do_not_reject():
    x = T.read("uk_housing", 2000, seed=0)
    dead = T.scale(T.project(x, [1]))     # never reaches a sink
    sink = T.metric(T.project(x, [0]), T.project(x, [0]), kind="mae")
    report = analyze(PipelineBatch([sink], ["p"]), extra_roots=(dead,))
    assert report.ok                       # warnings never reject
    report2 = analyze(PipelineBatch([sink], ["p"]))
    assert report2.ok


def test_validate_wiring_is_the_always_on_subset():
    findings = validate_wiring(_invalid_batch().fused_sinks())
    assert any(f.rule == "unknown-op" for f in findings)
    assert not [f for f in
                validate_wiring(_valid_batch().fused_sinks())
                if f.severity == "error"]
