"""Core stratum invariants: DAG hashing, CSE soundness, rewrites, scheduler,
cache — unit + hypothesis property tests."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import CONST, LazyOp, Stratum, TRANSFORM, count_ops, toposort
from repro.core.cache import IntermediateCache, mark_cache_candidates
from repro.core.metadata import collect_metadata
from repro.core.rewrites import cse, optimize_logical, project_pushdown
from repro.core.runtime import execute_reference
from repro.core.scheduler import SchedulerConfig, plan as make_plan
from repro.core.selection import SelectionConfig, select
import repro.tabular as T  # registers impls/meta/lowerings


# ---------------------------------------------------------------------------
# signatures / CSE
# ---------------------------------------------------------------------------

def _const(v):
    return LazyOp("const", CONST, spec={"value": np.asarray(v)}).out()


def test_signature_deterministic_across_instances():
    a1 = _const([1.0, 2.0])
    a2 = _const([1.0, 2.0])
    assert a1.op.signature == a2.op.signature
    assert _const([1.0, 3.0]).op.signature != a1.op.signature


def test_signature_includes_seed_and_spec():
    x = _const([1.0])
    f1 = LazyOp("string_encode", TRANSFORM, spec={"dim": 4}, inputs=(x,),
                seed=1)
    f2 = LazyOp("string_encode", TRANSFORM, spec={"dim": 4}, inputs=(x,),
                seed=2)
    f3 = LazyOp("string_encode", TRANSFORM, spec={"dim": 8}, inputs=(x,),
                seed=1)
    assert len({f1.signature, f2.signature, f3.signature}) == 3


def test_unseeded_nondeterministic_never_merged():
    x = _const([1.0])
    n1 = LazyOp("udf", "generic", inputs=(x,), deterministic=False)
    n2 = LazyOp("udf", "generic", inputs=(x,), deterministic=False)
    assert n1.signature != n2.signature
    merged = cse([n1.out(), n2.out()])
    assert merged[0].op is not merged[1].op


def test_cse_merges_identical_subgraphs():
    def pipeline():
        x = T.read("uk_housing", 500, seed=0)
        return T.scale(T.project(x, [10, 11]))
    a, b = pipeline(), pipeline()
    assert a.op is not b.op
    out = cse([a, b])
    assert out[0].op is out[1].op
    assert count_ops(out) < count_ops([a, b])


@given(st.integers(0, 5), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_cse_preserves_results(seed_a, seed_b):
    """Fusing two pipelines never changes their outputs."""
    x = T.read("uk_housing", 200, seed=0)
    pa = T.metric(T.project(x, [0]),
                  T.project(x, [10 + seed_a % 3]), kind="mae")
    pb = T.metric(T.project(x, [0]),
                  T.project(x, [10 + seed_b % 3]), kind="mae")

    def run(sinks):
        vals = {}
        for op in toposort(sinks):
            ins = [vals[r.signature] for r in op.inputs]
            outs = execute_reference(op, ins)
            for i, v in enumerate(outs):
                vals[f"{op.signature}:{i}"] = v
        return [vals[r.signature] for r in sinks]

    plain = run([pa, pb])
    fused = run(cse([pa, pb]))
    np.testing.assert_allclose(plain, fused)


# ---------------------------------------------------------------------------
# rewrites
# ---------------------------------------------------------------------------

def test_projection_pushdown_commutes():
    x = T.read("uk_housing", 300, seed=1)
    clipped = LazyOp("clip_outliers", TRANSFORM, spec={"q": 0.05},
                     inputs=(x,)).out()
    proj = T.project(clipped, [2, 3])
    pushed = project_pushdown([proj])

    def run(sink):
        vals = {}
        for op in toposort([sink]):
            ins = [vals[r.signature] for r in op.inputs]
            for i, v in enumerate(execute_reference(op, ins)):
                vals[f"{op.signature}:{i}"] = v
        return vals[sink.signature]

    np.testing.assert_allclose(run(proj), run(pushed[0]))
    # and the projection actually moved below the transform
    assert pushed[0].op.op_name == "clip_outliers"


def test_constant_folding():
    a = _const(np.ones((4, 4)))
    s = LazyOp("metric", "eval", spec={"kind": "mae"},
               inputs=(a, a)).out()
    collect_metadata([s])
    out, stats = optimize_logical(
        [s], lambda op, ins: execute_reference(op, ins))
    assert stats.constants_folded >= 1
    assert out[0].op.op_class == CONST
    assert float(np.asarray(out[0].op.spec["value"])) == 0.0


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _random_dag(rng, n_ops: int):
    nodes = [_const(rng.normal(size=(8,)))]
    for i in range(n_ops):
        k = 1 + int(rng.integers(0, min(2, len(nodes))))
        ins = tuple(nodes[int(rng.integers(0, len(nodes)))] for _ in range(k))
        nodes.append(LazyOp("mean_scalars", "eval", inputs=ins).out())
    return nodes[-1]


@given(st.integers(0, 10_000), st.integers(2, 30))
@settings(max_examples=25, deadline=None)
def test_scheduler_schedules_every_op_once(seed, n_ops):
    rng = np.random.default_rng(seed)
    sink = _random_dag(rng, n_ops)
    collect_metadata([sink])
    sel = select([sink], SelectionConfig())
    p = make_plan([sink], sel, SchedulerConfig())
    planned = [op.uid for w in p.waves for op in w.ops]
    assert sorted(planned) == sorted(o.uid for o in toposort([sink]))
    # topological: every input appears in an earlier wave
    seen = set()
    for w in p.waves:
        for op in w.ops:
            for r in op.inputs:
                assert r.op.uid in seen
        seen.update(op.uid for op in w.ops)


def test_scheduler_respects_memory_budget_estimates():
    x = T.read("uk_housing", 5000, seed=0)
    sinks = [T.scale(T.project(x, [10 + i])) for i in range(4)]
    collect_metadata(sinks)
    sel = select(sinks, SelectionConfig())
    tight = make_plan(sinks, sel, SchedulerConfig(
        memory_budget_bytes=1 << 20))
    loose = make_plan(sinks, sel, SchedulerConfig(
        memory_budget_bytes=1 << 34))
    assert len(tight.waves) >= len(loose.waves)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_cache_lru_and_disk_spill(tmp_path):
    c = IntermediateCache(budget_bytes=3000, spill_dir=str(tmp_path))
    big = np.zeros(256)  # 2 KB
    c.put("a", (big,))
    c.put("b", (big,))   # evicts "a" from RAM → disk
    assert c.get("a") is not None          # reload from disk
    assert c.stats.disk_hits >= 1

    # persistence across "restart"
    c2 = IntermediateCache(budget_bytes=3000, spill_dir=str(tmp_path))
    assert c2.get("b") is not None


def test_cache_candidates_exclude_cheap_ops():
    x = T.read("uk_housing", 50_000, seed=0)
    scaled = T.scale(T.project(x, [10, 11, 12]))
    tiny = T.mean_of([T.metric(T.project(x, [0]), T.project(x, [0]))])
    collect_metadata([scaled, tiny])
    cands = mark_cache_candidates([scaled, tiny], min_cost_s=1e-4)
    assert x.op.signature in cands or scaled.op.signature in cands
    assert tiny.op.signature not in cands


def test_runtime_cache_hits_are_exact(tmp_path):
    x = T.read("uk_housing", 2000, seed=3)
    y = T.project(x, [0])
    Xv = T.scale(T.impute(T.project(x, [10, 11, 12, 13])))
    sink = T.cv_score(Xv, y, {"name": "ridge_fit", "alpha": 1.0}, k=2,
                      seed=1)
    s = Stratum(memory_budget_bytes=1 << 30, spill_dir=str(tmp_path))
    r1, rep1 = s.run(sink)
    r2, rep2 = s.run(sink)
    assert rep2.run.ops_from_cache > 0
    np.testing.assert_allclose(np.asarray(r1, dtype=np.float64),
                               np.asarray(r2, dtype=np.float64))
