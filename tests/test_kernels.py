"""Pallas kernel sweeps: shapes × dtypes, assert_allclose vs ref.py oracles
(interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cross_entropy.kernel import ce_forward_pallas
from repro.kernels.cross_entropy.ops import (_forward_chunked,
                                             fused_cross_entropy)
from repro.kernels.cross_entropy.ref import cross_entropy_ref
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import (attention_chunked,
                                               attention_ref)
from repro.kernels.moe_gmm.kernel import moe_gmm_pallas
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd.kernel import ssd_scan_pallas
from repro.kernels.ssd.ops import ssd_step
from repro.kernels.ssd.ref import ssd_ref

RNG = np.random.default_rng(0)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype=dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 2, 256, 64), (2, 2, 2, 128, 128), (1, 8, 2, 384, 64),
    (1, 4, 1, 300, 64),                      # non-divisible seq, MQA
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, causal, window, dtype):
    q = _arr((B, Hq, S, D), dtype)
    k = _arr((B, Hkv, S, D), dtype)
    v = _arr((B, Hkv, S, D), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_chunked_reference_matches_exact():
    q = _arr((1, 4, 333, 64))
    k = _arr((1, 2, 333, 64))
    v = _arr((1, 2, 333, 64))
    ref = attention_ref(q, k, v, causal=True)
    chk = attention_chunked(q, k, v, causal=True, block_k=128)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref), atol=2e-5)


def test_chunked_reference_grads_match():
    q = _arr((1, 2, 96, 32))
    k = _arr((1, 2, 96, 32))
    v = _arr((1, 2, 96, 32))

    def loss_exact(q, k, v):
        return attention_ref(q, k, v, causal=True).sum()

    def loss_chunk(q, k, v):
        return attention_chunked(q, k, v, causal=True, block_k=32).sum()

    g1 = jax.grad(loss_exact, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_chunk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 8, 2, 1024, 64), (1, 4, 4, 700, 128), (2, 16, 8, 300, 64),
])
def test_decode_attention_sweep(B, Hq, Hkv, S, D):
    q = _arr((B, Hq, D))
    k = _arr((B, S, Hkv, D))
    v = _arr((B, S, Hkv, D))
    lens = jnp.asarray(RNG.integers(S // 2, S, B), jnp.int32)
    ref = decode_attention_ref(q, k, v, lens)
    out = decode_attention_pallas(q, k, v, lens, interpret=True,
                                  block_s=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_attention_lse_merge():
    """Sharded-cache LSE merge (flash-decode): splitting the cache and
    merging partial (out, m, l) must equal the unsharded result."""
    B, Hq, Hkv, S, D = 1, 4, 2, 512, 64
    q = _arr((B, Hq, D))
    k = _arr((B, S, Hkv, D))
    v = _arr((B, S, Hkv, D))
    lens = jnp.asarray([S], jnp.int32)
    ref = decode_attention_ref(q, k, v, lens)

    halves = []
    for piece in (slice(0, S // 2), slice(S // 2, S)):
        out, m, l = decode_attention_pallas(
            q, k[:, piece], v[:, piece],
            jnp.asarray([S // 2], jnp.int32), interpret=True,
            block_s=128, return_lse=True)
        halves.append((out.astype(jnp.float32), m, l))
    (o1, m1, l1), (o2, m2, l2) = halves
    m = jnp.maximum(m1, m2)
    w1 = jnp.exp(m1 - m) * l1
    w2 = jnp.exp(m2 - m) * l2
    merged = (o1 * w1[..., None] + o2 * w2[..., None]) / (w1 + w2)[..., None]
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 37, 256), (1, 128), (3, 5, 7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _arr(shape, dtype)
    w = _arr((shape[-1],))
    ref = rmsnorm_ref(x, w)
    out = rmsnorm_pallas(x, w, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,N,P,chunk", [
    (1, 2, 256, 16, 32, 64), (2, 1, 128, 8, 16, 32), (1, 3, 192, 64, 64, 64),
])
def test_ssd_sweep(B, H, S, N, P, chunk):
    c = _arr((B, H, S, N))
    b = _arr((B, H, S, N), scale=0.3)
    x = _arr((B, H, S, P))
    la = -jnp.abs(_arr((B, H, S), scale=0.1))
    g = jnp.abs(_arr((B, H, S), scale=0.5))
    yr, sr = ssd_ref(c, b, x, la, g)
    yp, sp = ssd_scan_pallas(c, b, x, la, g, interpret=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=5e-4)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=5e-4)


def test_ssd_step_matches_scan():
    """Decode step recurrence == scan, position by position."""
    B, H, S, N, P = 1, 2, 16, 8, 8
    c = _arr((B, H, S, N))
    b = _arr((B, H, S, N), scale=0.3)
    x = _arr((B, H, S, P))
    la = -jnp.abs(_arr((B, H, S), scale=0.1))
    g = jnp.abs(_arr((B, H, S), scale=0.5))
    y_ref, s_ref = ssd_ref(c, b, x, la, g)
    s = jnp.zeros((B, H, N, P), jnp.float32)
    ys = []
    for t in range(S):
        y, s = ssd_step(s, c[:, :, t], b[:, :, t], x[:, :, t],
                        la[:, :, t], g[:, :, t])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 2)),
                               np.asarray(y_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=5e-4)


# ---------------------------------------------------------------------------
# moe gmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,D,E,F,bt,bf", [
    (512, 64, 8, 128, 128, 64), (256, 32, 4, 64, 64, 64),
    (130, 32, 5, 48, 64, 48),                # ragged sizes
])
def test_moe_gmm_sweep(T, D, E, F, bt, bf):
    sizes = RNG.multinomial(T, [1 / E] * E)
    x = _arr((T, D))
    w = _arr((E, D, F))
    ref = moe_gmm_ref(x, w, jnp.asarray(sizes))
    out = moe_gmm_pallas(x, w, jnp.asarray(sizes), interpret=True,
                         block_t=bt, block_f=bf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_moe_gmm_empty_experts():
    sizes = np.array([0, 100, 0, 28], np.int32)
    x = _arr((128, 32))
    w = _arr((4, 32, 64))
    ref = moe_gmm_ref(x, w, jnp.asarray(sizes))
    out = moe_gmm_pallas(x, w, jnp.asarray(sizes), interpret=True,
                         block_t=64, block_f=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


# ---------------------------------------------------------------------------
# fused cross entropy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,D,V", [(128, 64, 1000), (64, 32, 513)])
def test_ce_forward_paths_agree(T, D, V):
    x = _arr((T, D), scale=0.5)
    w = _arr((D, V), scale=0.1)
    lab = jnp.asarray(RNG.integers(0, V, T), jnp.int32)
    ref = cross_entropy_ref(x, w, lab)
    fused = fused_cross_entropy(x, w, lab)
    assert abs(float(ref) - float(fused)) < 1e-4
    lse_p, ll_p = ce_forward_pallas(x, w, lab, interpret=True,
                                    block_t=64, block_v=256)
    lse_c, ll_c = _forward_chunked(x, w, lab, V)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_c),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(ll_p), np.asarray(ll_c),
                               atol=1e-4)


def test_ce_padded_vocab_masking():
    """n_valid < V: padded columns must not affect the loss."""
    T, D, V = 32, 16, 256
    x = _arr((T, D), scale=0.5)
    w = _arr((D, V), scale=0.1)
    lab = jnp.asarray(RNG.integers(0, 200, T), jnp.int32)
    ref = cross_entropy_ref(x, w[:, :200], lab)
    # poison the padding columns — must be masked out exactly
    w_pad = w.at[:, 200:].set(100.0)
    fused = fused_cross_entropy(x, w_pad, lab, n_valid=200)
    assert abs(float(ref) - float(fused)) < 1e-4


def test_ce_grads_vs_autodiff():
    T, D, V = 64, 32, 500
    x = _arr((T, D), scale=0.5)
    w = _arr((D, V), scale=0.1)
    lab = jnp.asarray(RNG.integers(0, V, T), jnp.int32)
    gref = jax.grad(lambda x, w: cross_entropy_ref(x, w, lab),
                    argnums=(0, 1))(x, w)
    gfus = jax.grad(lambda x, w: fused_cross_entropy(x, w, lab),
                    argnums=(0, 1))(x, w)
    for a, b in zip(gref, gfus):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
