"""Observability layer: per-job lifecycle traces (hop completeness,
monotonicity, survival across preemption and failover), the windowed
throughput collector, JSONL event-log replay round-trips, the live text
view, plus direct unit coverage backfill for the coalescer and tenant
snapshot merging."""

import json
import os
import time
from collections import Counter
from types import SimpleNamespace

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import GENERIC, LazyOp, PipelineBatch
from repro.service import (DeadlineExceeded, Priority, ShardedStratum,
                           StratumService, ThroughputCollector, TraceSink,
                           coalesce, cross_agent_dedup,
                           merge_tenant_snapshots, merge_window_snapshots)
from repro.service.coalesce import _SEP, reachable_sigs
from repro.service.fabric import JobEnvelope, decode_job, encode_job
from repro.service.observability import (ADMITTED, CANCELLED, COALESCED,
                                         COMPLETED, DISPATCHED, EVENTS,
                                         FAILED, FAILOVER, JobTrace,
                                         MAX_SAMPLES, PREEMPTED, QUEUED,
                                         REQUEUED, ROUTED, SHED, SUBMITTED,
                                         TERMINAL, hop_record, make_hop,
                                         percentile, record_hop)
from repro.service.observability import replay, top
from repro.service.observability.events import COMPLETED_RING, TraceLog
import repro.tabular as T


def _pipeline(n_rows=2000, cols=(10, 11, 12), kind="mae", data_seed=0):
    x = T.read("uk_housing", n_rows, seed=data_seed)
    xs = T.scale(T.impute(T.project(x, list(cols))))
    y = T.project(x, [0])
    return T.metric(T.project(xs, [0]), y, kind=kind)


def _batch(name="p", **kw):
    return PipelineBatch([_pipeline(**kw)], [name])


def _events(hops):
    return [h[0] for h in hops]


def _assert_monotone(hops):
    ts = [h[1] for h in hops]
    assert ts == sorted(ts), ts


def _assert_slack_non_increasing(hops, eps=0.05):
    slacks = [h[3] for h in hops if h[3] is not None]
    for a, b in zip(slacks, slacks[1:]):
        assert b <= a + eps, slacks


# ---------------------------------------------------------------------------
# hop tuples + JobTrace invariants
# ---------------------------------------------------------------------------

def test_event_constants_are_unique_and_terminal_is_subset():
    assert len(set(EVENTS)) == len(EVENTS)
    assert set(TERMINAL) <= set(EVENTS)
    assert all(e == e.lower() for e in EVENTS)


def test_make_hop_shape_and_types():
    hop = make_hop(DISPATCHED, shard="shard-1", slack=1.5, t=100.0,
                   wait_s=0.25, resume=False)
    assert hop == (DISPATCHED, 100.0, "shard-1", 1.5,
                   {"wait_s": 0.25, "resume": False})
    # deadline-free: slack stays None (not coerced to 0.0)
    ev, t, shard, slack, detail = make_hop(QUEUED)
    assert slack is None and shard == "" and detail == {}
    assert isinstance(t, float) and abs(t - time.time()) < 5.0


def test_jobtrace_stamp_clamps_clock_jitter_monotone():
    # seed hop stamped "in the future" (e.g. another host's wall clock):
    # subsequent local stamps must never order before it
    future_t = time.time() + 120.0
    tr = JobTrace("k", "t", hops=[make_hop(SUBMITTED, t=future_t)])
    hop = tr.stamp(QUEUED, slack=3.0)
    assert hop[1] == future_t            # clamped, not before the seed
    assert hop[0] == QUEUED and hop[3] == 3.0
    _assert_monotone(tr.hops)


def test_jobtrace_terminal_property_and_len():
    tr = JobTrace("k", "t")
    assert tr.terminal is None and len(tr) == 0
    tr.stamp(SUBMITTED)
    tr.stamp(DISPATCHED, shard="s0")
    assert tr.terminal is None
    tr.stamp(COMPLETED, shard="s0")
    assert tr.terminal == COMPLETED and len(tr) == 3
    assert tr.as_hops() == tuple(tr.hops)
    assert all(isinstance(h, tuple) for h in tr.as_hops())


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=3600),
       st.integers(min_value=0, max_value=3600))
def test_property_stamps_stay_monotone_after_any_seed(off_a, off_b):
    # property: whatever (possibly skewed) history seeds a trace, every
    # stamp keeps the hop log sorted by time
    now = time.time()
    seed = [make_hop(SUBMITTED, t=now + off_a),
            make_hop(ROUTED, shard="s1", t=now + off_a + off_b)]
    tr = JobTrace("k", "t", hops=seed)
    for ev in (ADMITTED, QUEUED, DISPATCHED, COMPLETED):
        tr.stamp(ev, shard="s1")
    _assert_monotone(tr.hops)
    assert _events(tr.hops)[:2] == [SUBMITTED, ROUTED]
    assert tr.terminal == COMPLETED


# ---------------------------------------------------------------------------
# windowed throughput collector
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_percentile_nearest_rank():
    assert percentile([], 99) == 0.0
    xs = list(range(1, 101))            # 1..100
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 99) == 99.0
    assert percentile(xs, 100) == 100.0
    assert percentile([7.0], 99) == 7.0
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0   # sorts first


def test_collector_counts_throughput_and_attainment():
    clk = _Clock()
    c = ThroughputCollector(window_s=1.0, n_windows=4, clock=clk)
    for _ in range(6):
        c.record_submit()
    for _ in range(4):
        c.record_completion()
    c.record_deadline_outcome(True)
    c.record_deadline_outcome(True)
    c.record_deadline_outcome(False)
    snap = c.snapshot()
    assert snap["submitted"] == 6 and snap["completed"] == 4
    assert snap["deadline_jobs"] == 3 and snap["deadline_met"] == 2
    assert snap["attainment"] == pytest.approx(2 / 3)
    # only the open window exists: span is one window
    assert snap["span_s"] == 1.0
    assert snap["throughput_per_s"] == pytest.approx(4.0)


def test_collector_window_rollover_places_counts_in_order():
    clk = _Clock()
    c = ThroughputCollector(window_s=1.0, n_windows=8, clock=clk)
    c.record_completion(2)
    clk.t = 1.1                          # roll into window 1
    c.record_completion(3)
    clk.t = 2.2                          # roll into window 2
    snap = c.snapshot()
    per = snap["per_window"]
    assert [w["completed"] for w in per] == [2, 3, 0]
    assert snap["completed"] == 5
    assert snap["span_s"] == pytest.approx(3.0)


def test_collector_ring_is_bounded():
    clk = _Clock()
    c = ThroughputCollector(window_s=1.0, n_windows=4, clock=clk)
    for i in range(20):
        clk.t = float(i)
        c.record_completion()
    snap = c.snapshot()
    # at most n_windows closed + the open one
    assert snap["n_windows"] <= 5
    assert len(snap["per_window"]) <= 5
    # old windows fell off: only the ring's worth of completions remain
    assert snap["completed"] <= 5


def test_collector_idle_gap_blanks_the_ring_without_spinning():
    clk = _Clock()
    c = ThroughputCollector(window_s=1.0, n_windows=4, clock=clk)
    c.record_completion(5)
    clk.t = 1e9                          # an hour+ of idle: clamped catch-up
    snap = c.snapshot()
    assert snap["completed"] == 0        # stale activity fell off the ring
    assert snap["throughput_per_s"] == 0.0
    c.record_completion()                # and the ring still works after
    assert c.snapshot()["completed"] == 1


def test_collector_p50_p99_against_known_latencies():
    clk = _Clock()
    c = ThroughputCollector(window_s=60.0, n_windows=2, clock=clk)
    for ms in range(1, 101):             # 1ms .. 100ms
        c.record_dispatch(ms / 1000.0)
    snap = c.snapshot()
    assert snap["dispatch_p50_s"] == pytest.approx(0.050)
    assert snap["dispatch_p99_s"] == pytest.approx(0.099)
    assert snap["per_window"][-1]["dispatch_p99_s"] == pytest.approx(0.099)


def test_collector_queue_depth_max_and_sample_cap():
    clk = _Clock()
    c = ThroughputCollector(window_s=60.0, n_windows=2, clock=clk)
    c.record_dispatch(0.01, queue_depth=3)
    c.record_dispatch(0.01, queue_depth=9)
    c.record_dispatch(0.01, queue_depth=1)
    for _ in range(MAX_SAMPLES + 50):
        c.record_dispatch(0.001)
    snap = c.snapshot()
    assert snap["queue_depth_max"] == 9
    assert len(snap["latency_samples"]) <= MAX_SAMPLES


def test_collector_rejects_bad_config():
    with pytest.raises(ValueError):
        ThroughputCollector(window_s=0.0)
    with pytest.raises(ValueError):
        ThroughputCollector(n_windows=0)


def test_merge_window_snapshots_sums_maxes_and_recomputes():
    clk = _Clock()
    a = ThroughputCollector(window_s=1.0, n_windows=4, clock=clk)
    b = ThroughputCollector(window_s=1.0, n_windows=4, clock=clk)
    a.record_completion(3)
    a.record_dispatch(0.010, queue_depth=2)
    a.record_deadline_outcome(True)
    b.record_completion(1)
    b.record_dispatch(0.090, queue_depth=7)
    b.record_deadline_outcome(False)
    m = merge_window_snapshots([a.snapshot(), b.snapshot()])
    assert m["completed"] == 4
    assert m["queue_depth_max"] == 7
    assert m["attainment"] == pytest.approx(0.5)
    assert m["throughput_per_s"] == pytest.approx(4.0)
    # percentiles recomputed over the union, not averaged
    assert m["dispatch_p99_s"] == pytest.approx(0.090)
    assert sorted(m["latency_samples"]) == [0.010, 0.090]
    # None/absent snapshots are skipped; all-absent merges to None
    assert merge_window_snapshots([None, a.snapshot()])["completed"] == 3
    assert merge_window_snapshots([None, {}]) is None


def test_merge_tenant_snapshots_merges_windows_blocks():
    clk = _Clock()
    a = ThroughputCollector(window_s=1.0, n_windows=4, clock=clk)
    b = ThroughputCollector(window_s=1.0, n_windows=4, clock=clk)
    a.record_completion(2)
    a.record_dispatch(0.02)
    b.record_completion(3)
    b.record_dispatch(0.08)
    shard_a = {"t": {"jobs": 2, "wait_max_s": 0.5,
                     "per_backend": {"jax": 2}, "windows": a.snapshot()}}
    shard_b = {"t": {"jobs": 3, "wait_max_s": 0.9,
                     "per_backend": {"jax": 1}, "windows": b.snapshot()}}
    merged = merge_tenant_snapshots([shard_a, shard_b])["t"]
    assert merged["jobs"] == 5                       # counters sum
    assert merged["wait_max_s"] == 0.9               # maxes max
    assert merged["per_backend"] == {"jax": 3}       # nested dicts sum
    w = merged["windows"]                            # windows recombine
    assert w["completed"] == 5
    assert w["dispatch_p99_s"] == pytest.approx(0.08)
    # one-sided windows survive the merge unchanged
    one = merge_tenant_snapshots(
        [shard_a, {"t": {"jobs": 1, "wait_max_s": 0.1,
                         "per_backend": {}}}])["t"]
    assert one["windows"]["completed"] == 2


# ---------------------------------------------------------------------------
# JSONL event log + trace sink
# ---------------------------------------------------------------------------

def test_hop_record_round_trips_the_hop_tuple():
    hop = make_hop(COMPLETED, shard="shard-3", slack=0.75, t=42.0,
                   backends={"jax-seg": 4}, deadline_met=True)
    rec = hop_record("e-1", "agent-0", hop)
    assert rec["job"] == "e-1" and rec["tenant"] == "agent-0"
    assert record_hop(rec) == hop
    # via JSON (the on-disk form)
    assert record_hop(json.loads(json.dumps(rec))) == hop


def test_tracelog_lines_are_flushed_and_close_is_idempotent(tmp_path):
    log = TraceLog(str(tmp_path), "service")
    rec = hop_record("j1", "t", make_hop(SUBMITTED, t=1.0))
    log.append(rec)
    # flushed per line: readable while the writer is still open
    lines = open(log.path, encoding="utf-8").read().splitlines()
    assert len(lines) == 1 and json.loads(lines[0])["event"] == SUBMITTED
    log.close()
    log.append(rec)                      # after close: dropped, no raise
    log.close()
    assert len(open(log.path, encoding="utf-8").read().splitlines()) == 1


def test_disabled_sink_is_a_no_op():
    sink = TraceSink()
    assert sink.enabled is False
    assert sink.begin("k", "t") is None
    assert sink.store("k", "t", (make_hop(COMPLETED),)) is None
    sink.finish(None)                    # tolerated
    sink.emit_hop("k", "t", make_hop(SUBMITTED))   # no log: no-op
    assert sink.get("k") is None
    sink.close()


def test_sink_lifecycle_get_recent_and_completed_ring():
    sink = TraceSink(enabled=True)
    tr = sink.begin("k0", "t")
    tr.stamp(SUBMITTED)
    assert sink.get("k0") is tr          # live
    tr.stamp(COMPLETED)
    sink.finish(tr)
    assert sink.get("k0") is tr          # finished, still addressable
    for i in range(COMPLETED_RING + 40):
        t2 = sink.begin(f"k{i + 1}", "t")
        t2.stamp(COMPLETED)
        sink.finish(t2)
    assert len(sink._done) <= COMPLETED_RING
    assert sink.get("k0") is None        # oldest fell off the ring
    recent = sink.recent(5)
    assert len(recent) == 5
    assert recent[-1].key == f"k{COMPLETED_RING + 40}"


def test_seed_hops_are_not_reemitted_to_jsonl(tmp_path):
    sink = TraceSink(trace_dir=str(tmp_path), component="shard-1")
    seed = (make_hop(SUBMITTED, t=1.0), make_hop(ROUTED, shard="s1", t=2.0))
    tr = sink.begin("e-1", "t", hops=seed)
    lines = open(sink.log.path, encoding="utf-8").read().splitlines()
    assert lines == []                   # history was logged at origin
    tr.stamp(ADMITTED, shard="s1")
    lines = open(sink.log.path, encoding="utf-8").read().splitlines()
    assert len(lines) == 1 and json.loads(lines[0])["event"] == ADMITTED
    sink.close()


# ---------------------------------------------------------------------------
# replay: JSONL → timelines → gantt
# ---------------------------------------------------------------------------

def _emit_trace(sink, key, hops):
    for hop in hops:
        sink.emit_hop(key, "t", hop)


def test_replay_round_trips_emitted_hops(tmp_path):
    sink = TraceSink(trace_dir=str(tmp_path), component="service")
    hops_a = [make_hop(SUBMITTED, t=1.0, slack=5.0),
              make_hop(DISPATCHED, shard="s0", t=2.0, slack=4.0),
              make_hop(COMPLETED, shard="s0", t=3.0, slack=3.0,
                       backends={"jax": 2})]
    hops_b = [make_hop(SUBMITTED, t=1.5),
              make_hop(FAILED, shard="s0", t=2.5, reason="boom")]
    _emit_trace(sink, "ja", hops_a)
    _emit_trace(sink, "jb", hops_b)
    sink.close()
    timelines = replay.reassemble(replay.load_events(str(tmp_path)))
    assert set(timelines) == {"ja", "jb"}
    # exact round-trip: every reassembled record rebuilds the source hop
    assert [record_hop(r) for r in timelines["ja"]] == hops_a
    assert [record_hop(r) for r in timelines["jb"]] == hops_b
    assert replay.job_timeline(timelines, "nope") == []


def test_replay_dedups_identical_hops_across_files(tmp_path):
    # the same hop logged by two components (client + shard) counts once
    hop = make_hop(ROUTED, shard="s1", t=5.0)
    for comp in ("client-f0", "shard-1"):
        sink = TraceSink(trace_dir=str(tmp_path), component=comp)
        # distinct files even in one process: component is in the name
        _emit_trace(sink, "e-1", [hop])
        sink.close()
    records = replay.load_events(str(tmp_path))
    assert len(records) == 2
    assert len({r["source"] for r in records}) == 2
    timelines = replay.reassemble(records)
    assert len(timelines["e-1"]) == 1


def test_replay_skips_torn_tail_and_junk_lines(tmp_path):
    path = os.path.join(str(tmp_path), "events-shard-9-123.jsonl")
    good = json.dumps(hop_record("j1", "t", make_hop(SUBMITTED, t=1.0)))
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(good + "\n")
        fh.write("\n")                               # blank
        fh.write('{"job": "j1", "event": "disp')     # torn by kill -9
    records = replay.load_events(str(tmp_path))
    assert len(records) == 1
    assert records[0]["event"] == SUBMITTED


def test_shard_gantt_spans_preemption_and_lost_workers(tmp_path):
    timelines = replay.reassemble([
        hop_record("j1", "t", h) for h in (
            make_hop(DISPATCHED, shard="s0", t=1.0),
            make_hop(PREEMPTED, shard="s0", t=2.0),
            make_hop(DISPATCHED, shard="s0", t=4.0),
            make_hop(COMPLETED, shard="s0", t=5.0))
    ] + [
        hop_record("j2", "t", h) for h in (
            make_hop(DISPATCHED, shard="s1", t=1.0),)   # never finished
    ])
    gantt = replay.shard_gantt(timelines)
    assert [(j, t0, t1, o) for j, t0, t1, o in gantt["s0"]] == \
        [("j1", 1.0, 2.0, PREEMPTED), ("j1", 4.0, 5.0, COMPLETED)]
    # the killed worker's open span closes at last-known-stamp as "lost"
    assert gantt["s1"] == [("j2", 1.0, 1.0, "lost")]


def test_summarize_counts_outcomes_and_failovers():
    timelines = replay.reassemble(
        [hop_record("j1", "t", h) for h in (
            make_hop(SUBMITTED, t=1.0),
            make_hop(FAILOVER, shard="s0", t=2.0),
            make_hop(COMPLETED, shard="s1", t=3.0))] +
        [hop_record("j2", "t", make_hop(SUBMITTED, t=1.0))])
    s = replay.summarize(timelines)
    assert s == {"jobs": 2, "outcomes": {COMPLETED: 1, "open": 1},
                 "failovers": 1}


def test_replay_cli_prints_timelines_and_gantt(tmp_path, capsys):
    sink = TraceSink(trace_dir=str(tmp_path), component="service")
    _emit_trace(sink, "j1", [make_hop(SUBMITTED, t=1.0, slack=2.0),
                             make_hop(DISPATCHED, shard="s0", t=2.0,
                                      slack=1.0, wait_s=1.0),
                             make_hop(COMPLETED, shard="s0", t=3.0)])
    sink.close()
    assert replay.main([str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "1 jobs" in out and "submitted→dispatched→completed" in out
    assert replay.main([str(tmp_path), "--job", "j1"]) == 0
    out = capsys.readouterr().out
    assert "dispatched" in out and "@s0" in out and "slack=" in out
    assert replay.main([str(tmp_path), "--gantt"]) == 0
    out = capsys.readouterr().out
    assert "shard s0" in out and "→ completed" in out


def test_top_renders_synthetic_snapshot():
    frame = top.render(top.demo_snapshot())
    assert "stratum" in frame
    assert "thr" in frame and "p99" in frame
    # degenerate snapshot renders too (empty service, no windows yet)
    assert top.render({}) != ""


# ---------------------------------------------------------------------------
# service integration: traces are truthful
# ---------------------------------------------------------------------------

def _svc(**kw):
    kw.setdefault("memory_budget_bytes", 1 << 30)
    kw.setdefault("n_executors", 1)
    kw.setdefault("coalesce_window_s", 0.0)
    return StratumService(**kw)


def test_tracing_is_off_by_default_and_free():
    svc = _svc()
    try:
        assert svc.traces.enabled is False
        _, rep = svc.session("t").submit(
            _batch(n_rows=1000)).result(timeout=120)
        assert rep.trace == ()
    finally:
        svc.stop()


def test_basic_lifecycle_trace_is_complete_monotone_and_slack_shrinks():
    svc = _svc(trace=True)
    try:
        _, rep = svc.session("t").submit(
            _batch(n_rows=1000), deadline_s=300.0).result(timeout=120)
        ev = _events(rep.trace)
        assert ev == [SUBMITTED, ADMITTED, QUEUED, DISPATCHED, COMPLETED]
        _assert_monotone(rep.trace)
        _assert_slack_non_increasing(rep.trace)
        # every hop carries real slack against the 300s SLO
        assert all(h[3] is not None and 0 < h[3] <= 300.0
                   for h in rep.trace)
        done = rep.trace[-1]
        assert done[4]["deadline_met"] is True
        assert done[4]["backends"]                 # backend mix recorded
        assert "plan_cache_hits" in done[4]
        assert "plan_cache_misses" in done[4]
        disp = rep.trace[3]
        assert disp[4]["wait_s"] >= 0.0 and disp[4]["resume"] is False
    finally:
        svc.stop()


def test_deadline_free_job_traces_with_none_slack():
    svc = _svc(trace=True)
    try:
        _, rep = svc.session("t").submit(
            _batch(n_rows=1000)).result(timeout=120)
        assert all(h[3] is None for h in rep.trace)
        assert rep.trace[-1][0] == COMPLETED
    finally:
        svc.stop()


def test_trace_dir_jsonl_replays_to_the_reported_trace(tmp_path):
    svc = _svc(trace=True, trace_dir=str(tmp_path))
    try:
        fut = svc.session("t").submit(_batch(n_rows=1000))
        _, rep = fut.result(timeout=120)
        svc.stop()
        timelines = replay.reassemble(replay.load_events(str(tmp_path)))
        key = f"j{fut.job_id}"
        assert tuple(record_hop(r) for r in timelines[key]) == rep.trace
    finally:
        svc.stop()


def _slow_identity(x, delay=0.05):
    time.sleep(delay)
    return x


def test_preempted_job_trace_has_one_dispatch_preempt_requeue_chain():
    svc = _svc(trace=True, aging_s=None, autostart=False)
    try:
        tag = f"obs{time.monotonic_ns()}"
        x = T.read("uk_housing", 1000, seed=0)
        ref = T.project(x, [0])
        for d in range(8):
            ref = LazyOp(f"slow_{tag}_{d}", GENERIC,
                         spec={"fn": _slow_identity,
                               "kwargs": {"delay": 0.1}},
                         inputs=(ref,)).out()
        chain_fut = svc.session("bulk").submit(
            PipelineBatch([ref], ["chain"]), priority=Priority.SCAVENGER)
        svc.start()
        time.sleep(0.45)                 # let a few waves complete
        probe_fut = svc.session("probe").submit(
            _batch(n_rows=1000), priority=Priority.INTERACTIVE)
        probe_fut.result(timeout=120)
        _, rep = chain_fut.result(timeout=120)
        assert rep.preemptions == 1
        ev = _events(rep.trace)
        # exactly one preemption chain, in order, nothing lost/duplicated
        assert ev == [SUBMITTED, ADMITTED, QUEUED, DISPATCHED, PREEMPTED,
                      REQUEUED, DISPATCHED, COMPLETED], ev
        _assert_monotone(rep.trace)
        by_event = Counter(ev)
        assert by_event[DISPATCHED] == 2
        assert by_event[PREEMPTED] == by_event[REQUEUED] == 1
        first_disp, second_disp = [h for h in rep.trace
                                   if h[0] == DISPATCHED]
        assert first_disp[4]["resume"] is False
        assert second_disp[4]["resume"] is True
        # the re-dispatch does not re-measure queue wait
        assert second_disp[4]["wait_s"] == first_disp[4]["wait_s"]
        preempt = next(h for h in rep.trace if h[0] == PREEMPTED)
        requeue = next(h for h in rep.trace if h[0] == REQUEUED)
        assert preempt[4]["salvaged"] > 0
        assert requeue[4]["preemptions"] == 1
        # salvage is counted once, on the terminal hop, matching the report
        assert rep.trace[-1][4]["salvaged"] == rep.ops_salvaged > 0
    finally:
        svc.stop()


def test_shed_job_trace_terminates_in_shed():
    svc = _svc(trace=True)
    try:
        ses = svc.session("t")
        fut = ses.submit(_batch(n_rows=1000), deadline_s=1e-9)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=120)
        tr = svc.traces.get(f"j{fut.job_id}")
        assert tr is not None and tr.terminal == SHED
        ev = _events(tr.hops)
        assert ev[:3] == [SUBMITTED, ADMITTED, QUEUED]
        assert DISPATCHED not in ev              # shed before any dispatch
        assert tr.hops[-1][3] is not None and tr.hops[-1][3] <= 0
        # the shed fed the windowed collector
        w = svc.telemetry.global_snapshot()["windows"]
        assert w["shed"] >= 1 and w["deadline_jobs"] >= 1
    finally:
        svc.stop()


def test_cancelled_job_trace_terminates_in_cancelled():
    svc = _svc(trace=True, autostart=False)
    try:
        fut = svc.session("t").submit(_batch(n_rows=1000))
        assert fut.cancel() is True
        tr = svc.traces.get(f"j{fut.job_id}")
        assert tr.terminal == CANCELLED
        assert _events(tr.hops) == [SUBMITTED, ADMITTED, QUEUED, CANCELLED]
    finally:
        svc.stop()


def test_failed_job_trace_carries_the_error():
    def _boom(*_a, **_k):
        raise ValueError("poisoned op")

    svc = _svc(trace=True)
    try:
        bad = LazyOp("boom_obs", GENERIC, spec={"fn": _boom},
                     inputs=(T.read("uk_housing", 1000, seed=0),)).out()
        fut = svc.session("t").submit(PipelineBatch([bad], ["bad"]))
        with pytest.raises(Exception):
            fut.result(timeout=120)
        tr = svc.traces.get(f"j{fut.job_id}")
        assert tr.terminal == FAILED
        assert tr.hops[-1][4]["error"]        # exception type recorded
        _assert_monotone(tr.hops)
    finally:
        svc.stop()


def test_coalesced_jobs_both_carry_the_merge_hop():
    svc = _svc(trace=True, autostart=False)
    try:
        f1 = svc.session("a").submit(_batch(n_rows=1000))
        f2 = svc.session("b").submit(_batch("q", n_rows=1000,
                                            cols=(10, 11, 13)))
        svc.start()
        reps = [f.result(timeout=120)[1] for f in (f1, f2)]
        for rep in reps:
            ev = _events(rep.trace)
            assert ev == [SUBMITTED, ADMITTED, QUEUED, COALESCED,
                          DISPATCHED, COMPLETED], ev
            merge_hop = next(h for h in rep.trace if h[0] == COALESCED)
            assert merge_hop[4]["n_jobs"] == 2
            assert rep.coalesced_with == 1
    finally:
        svc.stop()


def test_windowed_collector_feeds_service_global_snapshot():
    svc = _svc()                          # windows are on even untraced
    try:
        svc.session("t").submit(_batch(n_rows=1000),
                                deadline_s=300.0).result(timeout=120)
        w = svc.telemetry.global_snapshot()["windows"]
        assert w["submitted"] >= 1 and w["completed"] >= 1
        assert w["deadline_jobs"] == 1 and w["deadline_met"] == 1
        assert w["attainment"] == 1.0
        assert w["dispatch_p99_s"] >= 0.0
        assert len(w["per_window"]) >= 1
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# fabric: traces survive the wire and failover
# ---------------------------------------------------------------------------

def _fabric(n_shards=2, **kw):
    kw.setdefault("memory_budget_bytes", 1 << 30)
    kw.setdefault("n_executors", 1)
    kw.setdefault("coalesce_window_s", 0.0)
    return ShardedStratum(n_shards=n_shards, **kw)


def _key_for_shard(fab, shard_id, tag="k"):
    for i in range(10_000):
        key = f"{tag}-{i}"
        if fab.router._ring.route(key) == shard_id:
            return key
    raise AssertionError("no key found")  # pragma: no cover


def test_envelope_hops_survive_the_wire_codec():
    hops = (make_hop(SUBMITTED, t=1.0, slack=9.5, tenant="t",
                     priority="BATCH"),
            make_hop(ROUTED, shard="shard-1", t=2.0, slack=8.5, attempt=0,
                     requeue=False))
    env = JobEnvelope(envelope_id="e-7", tenant="t", priority=1,
                      routing_key="k", batch=_batch(n_rows=1000),
                      deadline_s=9.0, hops=hops)
    out = decode_job(encode_job(env))
    assert out.hops == hops
    # untraced envelopes stay hop-free through the codec
    bare = decode_job(encode_job(JobEnvelope(
        envelope_id="e-8", tenant="t", priority=1, routing_key="k",
        batch=_batch(n_rows=1000))))
    assert bare.hops == ()


def test_fabric_trace_reassembles_client_and_shard_hops():
    fab = _fabric(trace=True)
    try:
        _, rep = fab.session("t").submit(
            _batch(n_rows=1000), deadline_s=300.0).result(timeout=120)
        ev = _events(rep.hops)
        assert ev == [SUBMITTED, ROUTED, ADMITTED, QUEUED, DISPATCHED,
                      COMPLETED], ev
        _assert_monotone(rep.hops)
        _assert_slack_non_increasing(rep.hops, eps=0.25)
        routed = rep.hops[1]
        assert routed[2] == rep.shard_id          # placement recorded
        assert rep.hops[-1][2] == rep.shard_id
        # the client sink adopted the reassembled trace
        tr = fab.traces.get(rep.envelope_id)
        assert tr is not None and tr.as_hops() == rep.hops
    finally:
        fab.stop()


def test_fabric_untraced_reports_have_no_hops():
    fab = _fabric()
    try:
        _, rep = fab.session("t").submit(
            _batch(n_rows=1000)).result(timeout=120)
        assert rep.hops == ()
        assert fab.traces.enabled is False
    finally:
        fab.stop()


def test_failover_trace_continuity_under_fail_shard():
    fab = _fabric(n_shards=2, autostart=False, trace=True)
    try:
        victim, survivor = fab.shard_ids()
        fut = fab.session("t").submit(
            _batch(n_rows=1000), deadline_s=300.0,
            affinity=_key_for_shard(fab, victim))
        assert fab.router.pending_count(victim) == 1
        assert fab.fail_shard(victim) == 1
        fab.start()
        _, rep = fut.result(timeout=180)
        assert rep.shard_id == survivor
        ev = _events(rep.hops)
        # the trace crosses the failover without losing the pre-crash hops
        assert ev[:2] == [SUBMITTED, ROUTED]
        assert FAILOVER in ev
        fo = ev.index(FAILOVER)
        assert ev[fo + 1:] == [ROUTED, ADMITTED, QUEUED, DISPATCHED,
                               COMPLETED], ev
        hop_fo = rep.hops[fo]
        assert hop_fo[2] == victim                # who died
        assert rep.hops[1][2] == victim           # first placement
        assert rep.hops[fo + 1][2] == survivor    # re-placement
        assert rep.hops[-1][2] == survivor
        _assert_monotone(rep.hops)
        _assert_slack_non_increasing(rep.hops, eps=0.25)
    finally:
        fab.stop()


def test_retired_shard_freezes_its_windows_snapshot():
    fab = _fabric(n_shards=2)
    try:
        victim = fab.shard_ids()[0]
        fut = fab.session("t").submit(_batch(n_rows=1000),
                                      affinity=_key_for_shard(fab, victim))
        fut.result(timeout=120)
        fab.drain_shard(victim)
        per = fab.telemetry.per_shard()
        assert per[victim]["retired"] is True
        frozen = per[victim]["windows"]
        assert frozen["completed"] >= 1           # history preserved
        g = fab.telemetry.global_snapshot()
        # fabric-wide windows still merge retired + live shards
        assert g["windows"]["completed"] >= 1
    finally:
        fab.stop()


# ---------------------------------------------------------------------------
# backfill: coalescer unit coverage
# ---------------------------------------------------------------------------

def _fake_job(jid, tenant, batch):
    return SimpleNamespace(id=jid, tenant=tenant, batch=batch)


def test_coalesce_namespaces_and_split_results_round_trips():
    a = _fake_job(7, "a", _batch("p", n_rows=1000))
    b = _fake_job(9, "b", PipelineBatch(
        [_pipeline(n_rows=1000), _pipeline(n_rows=1000, cols=(10, 11, 13))],
        ["p", "q"]))
    sb = coalesce([a, b])
    assert sb.batch.names == [f"j7{_SEP}p", f"j9{_SEP}p", f"j9{_SEP}q"]
    assert sb.spans == [(0, 1), (1, 3)]
    named = {f"j7{_SEP}p": 1.0, f"j9{_SEP}p": 2.0, f"j9{_SEP}q": 3.0}
    assert sb.split_results(named) == [{"p": 1.0}, {"p": 2.0, "q": 3.0}]
    # a job sharing a sink NAME with another tenant never collides:
    # the namespace prefix keys on job id, not pipeline name
    assert len(set(sb.batch.names)) == 3


def test_coalesce_job_sinks_follow_spans():
    a = _fake_job(1, "a", _batch("p", n_rows=1000))
    b = _fake_job(2, "b", _batch("q", n_rows=1000, cols=(10, 11, 13)))
    sb = coalesce([a, b])
    final = list(sb.batch.sinks)          # pre-rewrite order is preserved
    assert sb.job_sinks(final, 0) == final[0:1]
    assert sb.job_sinks(final, 1) == final[1:2]


def test_reachable_sigs_and_cross_agent_dedup_accounting():
    shared = _pipeline(n_rows=1000)
    only_b = _pipeline(n_rows=1000, cols=(10, 11, 13))
    sigs_a = reachable_sigs([shared])
    sigs_b = reachable_sigs([shared, only_b])
    assert sigs_a and sigs_a <= sigs_b
    saved, per_tenant = cross_agent_dedup([sigs_a, sigs_b], ["a", "b"])
    # every op of A's pipeline also appears in B's job: each saved once
    assert saved == len(sigs_a)
    assert per_tenant["a"] == per_tenant["b"] == len(sigs_a)
    # same-tenant overlap is NOT cross-agent dedup
    saved_same, per_same = cross_agent_dedup([sigs_a, sigs_a], ["a", "a"])
    assert saved_same == 0 and per_same == {}
