"""Tabular operator library: python-tier vs jax-tier equivalence, GBT
cross-implementation agreement, estimator sanity."""

import numpy as np
import pytest

from repro.core.dag import LazyOp, TRANSFORM
from repro.core.selection import impls_for
from repro.data.tabular import generate_uk_housing
from repro.tabular import gbt


def _table(n=400, seed=0):
    return np.asarray(generate_uk_housing(n, seed=seed))


def _run_both(op_name, spec, inputs, seed=None, atol=2e-3):
    op = LazyOp(op_name, TRANSFORM, spec=spec,
                inputs=(), seed=seed)
    impls = {i.backend: i for i in impls_for(op_name) if i.fidelity == "exact"}
    assert "python" in impls and "jax" in impls, op_name
    py = impls["python"].fn(op, inputs)
    jx = impls["jax"].fn(op, inputs)
    for a, b in zip(py, jx):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   atol=atol, rtol=2e-3)


@pytest.mark.parametrize("op_name,spec,make_inputs", [
    ("project", {"cols": (1, 3, 5)}, lambda X: [X]),
    ("cleaner", {}, lambda X: [X]),
    ("log1p", {}, lambda X: [np.abs(np.nan_to_num(X))]),
    ("impute_fit", {"strategy": "mean"}, lambda X: [X[:, 10:14]]),
    ("scaler_fit", {}, lambda X: [np.nan_to_num(X[:, 10:14])]),
    ("datetime_encode", {}, lambda X: [X[:, 1:2]]),
    ("onehot", {"cards": (5, 2)}, lambda X: [X[:, 2:4]]),
    ("string_encode", {"dim": 8}, lambda X: [X[:, 5:6]]),
])
def test_tier_equivalence(op_name, spec, make_inputs):
    X = _table()
    _run_both(op_name, spec, make_inputs(X),
              seed=0 if op_name == "string_encode" else None)


def test_scaler_apply_tiers():
    X = np.nan_to_num(_table()[:, 10:14])
    stats = np.stack([X.mean(0), X.std(0) + 1e-9])
    _run_both("scaler_apply", {}, [stats, X])


def test_target_encode_tiers():
    X = _table()
    col, y = X[:, 5:6], X[:, 0]
    op = LazyOp("target_encode_fit", TRANSFORM,
                spec={"card": 1100, "smoothing": 20.0}, seed=0)
    impls = {i.backend: i for i in impls_for("target_encode_fit")}
    t_py = impls["python"].fn(op, [col, y])[0]
    t_jx = impls["jax"].fn(op, [col, y])[0]
    np.testing.assert_allclose(np.asarray(t_py), np.asarray(t_jx),
                               rtol=2e-3, atol=2e-1)


def test_ridge_tiers_and_quality():
    X = np.nan_to_num(_table(1000)[:, 1:])
    y = np.log1p(_table(1000)[:, 0])
    op = LazyOp("ridge_fit", "estimator", spec={"alpha": 1.0}, seed=0)
    impls = {i.backend: i for i in impls_for("ridge_fit")}
    w_py = np.asarray(impls["python"].fn(op, [X, y])[0], np.float64)
    w_jx = np.asarray(impls["jax"].fn(op, [X, y])[0], np.float64)
    pred_py = X @ w_py[:-1] + w_py[-1]
    pred_jx = X @ w_jx[:-1] + w_jx[-1]
    # float32 solve differs in weights; predictions must agree closely
    np.testing.assert_allclose(pred_py, pred_jx, rtol=0.05, atol=0.05)
    ss_res = np.sum((y - pred_py) ** 2)
    ss_tot = np.sum((y - y.mean()) ** 2)
    assert 1 - ss_res / ss_tot > 0.3     # learns something real


def test_elasticnet_tiers_agree_in_loss():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(300, 8))
    w_true = np.array([2.0, -1.0, 0, 0, 0.5, 0, 0, 0])
    y = X @ w_true + 0.01 * rng.normal(size=300)
    op = LazyOp("elasticnet_fit", "estimator",
                spec={"alpha": 0.001, "l1_ratio": 0.5, "iters": 300}, seed=0)
    impls = {i.backend: i for i in impls_for("elasticnet_fit")}
    losses = {}
    for name, impl in impls.items():
        if impl.fidelity != "exact":
            continue
        w = np.asarray(impl.fn(op, [X, y])[0], np.float64)
        pred = X @ w[:-1] + w[-1]
        losses[name] = np.mean((pred - y) ** 2)
    assert losses["python"] < 0.01 and losses["jax"] < 0.01


def test_gbt_numpy_vs_jax_same_trees():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(500, 6))
    y = X[:, 0] * 2 + (X[:, 1] > 0) * 3 + 0.01 * rng.normal(size=500)
    m_np = gbt.fit_numpy(X, y, n_trees=10, depth=3, subsample=1.0)
    m_jx = gbt.fit_jax(X, y, n_trees=10, depth=3, subsample=1.0)
    p_np = gbt.predict_numpy(m_np, X)
    p_jx = gbt.predict_jax(m_jx, X)
    # same algorithm, same bins — predictions nearly identical
    np.testing.assert_allclose(p_np, p_jx, rtol=1e-3, atol=1e-2)
    # and it learns
    assert np.mean((p_np - y) ** 2) < np.var(y) * 0.4


def test_kfold_split_partition_properties():
    X = _table(333)
    y = X[:, 0]
    op = LazyOp("kfold_split", TRANSFORM, spec={"k": 3, "fold": 1}, seed=9)
    impl = {i.backend: i for i in impls_for("kfold_split")}["python"]
    xtr, ytr, xte, yte = impl.fn(op, [X, y])
    assert len(xte) == 333 // 3
    assert len(xtr) + len(xte) == 333 - (333 - 3 * (333 // 3)) + (333 - 333 // 3 * 3)
    # folds are disjoint across fold ids (check via target values multiset)
    op2 = LazyOp("kfold_split", TRANSFORM, spec={"k": 3, "fold": 2}, seed=9)
    _, _, xte2, _ = impl.fn(op2, [X, y])
    rows1 = {tuple(np.round(r, 6)) for r in np.nan_to_num(xte)}
    rows2 = {tuple(np.round(r, 6)) for r in np.nan_to_num(xte2)}
    assert not (rows1 & rows2)
