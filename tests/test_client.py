"""The unified submission surface: StratumClient targets, SubmitOptions,
StratumConfig, and deadline semantics uniform across local/service/fabric.

The parametrized suite runs the SAME submission code (priority + affinity
+ deadline + tags via SubmitOptions) against all three targets and
requires identical results — the api_redesign acceptance criterion.
"""

import warnings

import numpy as np
import pytest

import repro
from repro.client import (CacheConfig,
                          OptimizerConfig,
                          RuntimeConfig,
                          ServiceTuning,
                          StratumConfig,
                          SubmitOptions,
                          connect)
from repro.core import PipelineBatch, Stratum
from repro.service import DeadlineExceeded, Priority
import repro.tabular as T


def _pipeline(n_rows=3000, cols=(10, 11, 12), kind="mae"):
    x = T.read("uk_housing", n_rows, seed=0)
    xs = T.scale(T.impute(T.project(x, list(cols))))
    y = T.project(x, [0])
    return T.metric(T.project(xs, [0]), y, kind=kind)


def _batch(name="p", **kw):
    return PipelineBatch([_pipeline(**kw)], [name])


def _config(**overrides):
    base = dict(memory_budget_bytes=1 << 30, n_executors=1, n_shards=2,
                coalesce_window_s=0.01)
    base.update(overrides)
    return StratumConfig.make(**base)


@pytest.fixture(params=["local", "service", "fabric"])
def client(request):
    with connect(request.param, _config()) as c:
        yield c


# ---------------------------------------------------------------------------
# the acceptance suite: one submission path, three targets, same answers
# ---------------------------------------------------------------------------

def test_same_submission_code_identical_results(client):
    """priority + affinity + deadline + tags via SubmitOptions against
    every target; values must match the bare-Stratum reference."""
    ref, _ = Stratum(memory_budget_bytes=1 << 30).run_batch(_batch())
    opts = SubmitOptions(priority=Priority.INTERACTIVE, affinity="pin",
                         deadline_s=120, tenant="agent-0",
                         tags=("probe", "r0"))
    results, report = client.submit(_batch(), opts).result(timeout=120)
    assert set(results) == {"p"}
    np.testing.assert_allclose(np.asarray(results["p"]),
                               np.asarray(ref["p"]), rtol=1e-9)
    # the submitting tenant is attributed in telemetry on every target
    assert "agent-0" in client.telemetry.snapshot()


def test_run_single_sink(client):
    value, _ = client.run(_pipeline(), options=SubmitOptions(deadline_s=120))
    ref, _ = Stratum(memory_budget_bytes=1 << 30).run_batch(_batch())
    np.testing.assert_allclose(np.asarray(value), np.asarray(ref["p"]),
                               rtol=1e-9)


def test_expired_deadline_resolves_deadline_exceeded(client):
    """A hopeless deadline fails with DeadlineExceeded on EVERY target —
    queued targets shed, the local target detects the late finish —
    and attainment telemetry records the miss uniformly."""
    with pytest.raises(DeadlineExceeded):
        client.submit(_batch(), SubmitOptions(deadline_s=1e-9)
                      ).result(timeout=60)
    d = client.telemetry.global_snapshot()["deadline"]
    assert d["jobs"] >= 1 and d["met"] < d["jobs"]


def test_met_deadline_counts_in_attainment(client):
    client.submit(_batch(), SubmitOptions(deadline_s=120)).result(timeout=120)
    d = client.telemetry.global_snapshot()["deadline"]
    assert d["jobs"] == d["met"] == 1
    assert d["attainment"] == 1.0


def test_tenant_scoped_session(client):
    ses = client.session("agent-7")
    results, _ = ses.submit(_batch()).result(timeout=120)
    assert set(results) == {"p"}
    assert "agent-7" in client.telemetry.snapshot()


def test_closed_client_rejects_submissions(client):
    client.close()
    with pytest.raises(RuntimeError):
        client.submit(_batch())


# ---------------------------------------------------------------------------
# SubmitOptions semantics
# ---------------------------------------------------------------------------

def test_submit_options_validation():
    with pytest.raises(ValueError):
        SubmitOptions(deadline_s=0)
    with pytest.raises(ValueError):
        SubmitOptions(deadline_s=-1.0)
    opts = SubmitOptions(priority=1, tags=["a", "b"])   # coercions
    assert opts.priority is Priority.BATCH
    assert opts.tags == ("a", "b")
    assert opts.with_(deadline_s=2.0).deadline_s == 2.0
    assert opts.deadline_s is None                      # frozen original


def test_tags_echoed_on_service_and_fabric_reports():
    for target in ("service", "fabric"):
        with connect(target, _config()) as c:
            _, report = c.submit(
                _batch(), SubmitOptions(deadline_s=120, tags=("x", "y"))
                ).result(timeout=120)
            assert tuple(report.tags) == ("x", "y")
            assert report.deadline_met is True


# ---------------------------------------------------------------------------
# StratumConfig: layered sections, flat constructor, bridges
# ---------------------------------------------------------------------------

def test_config_make_routes_flat_kwargs_to_sections():
    cfg = StratumConfig.make(memory_budget_bytes=123, enable=("logical",),
                             fraction=0.2, n_shards=5, aging_s=None)
    assert cfg.runtime.memory_budget_bytes == 123
    assert cfg.optimizer.enable == ("logical",)
    assert cfg.cache.fraction == 0.2
    assert cfg.service.n_shards == 5
    assert cfg.service.aging_s is None
    with pytest.raises(TypeError):
        StratumConfig.make(not_a_field=1)


def test_config_accepts_section_objects():
    cfg = StratumConfig.make(
        optimizer=OptimizerConfig(enable=("logical",)),
        runtime=RuntimeConfig(memory_budget_bytes=77),
        cache=CacheConfig(fraction=0.3),
        service=ServiceTuning(n_executors=3))
    assert cfg.runtime.memory_budget_bytes == 77
    assert cfg.service.n_executors == 3


def test_config_bridges_to_legacy_constructors():
    cfg = StratumConfig.make(memory_budget_bytes=1 << 28, n_executors=3,
                             deadline_tight_slack_s=0.5,
                             segment_time_budget_s=0.1)
    sc = cfg.service_config()
    assert sc.memory_budget_bytes == 1 << 28
    assert sc.n_executors == 3
    assert sc.deadline_tight_slack_s == 0.5
    assert sc.segment_time_budget_s == 0.1
    s = Stratum(**cfg.stratum_kwargs())
    assert s.memory_budget_bytes == 1 << 28
    assert s.segment_time_budget_s == 0.1


def test_connect_rejects_unknown_target():
    with pytest.raises(ValueError):
        connect("cloud")


def test_package_level_lazy_exports():
    assert repro.StratumClient is not None
    assert repro.SubmitOptions is SubmitOptions
    assert repro.connect is connect
    with pytest.raises(AttributeError):
        repro.not_a_thing        # noqa: B018


# ---------------------------------------------------------------------------
# Stratum constructor validation (satellite: no silently-dead kwargs)
# ---------------------------------------------------------------------------

def test_stratum_warns_on_cache_kwargs_with_cache_disabled():
    import repro.core.api as api
    api._warned_once.clear()
    with pytest.warns(UserWarning, match="cache_fraction"):
        Stratum(enable=("logical",), cache_fraction=0.2)
    with pytest.warns(UserWarning, match="spill_dir"):
        Stratum(enable=("logical",), spill_dir="/tmp/nowhere")


def test_stratum_warns_on_plan_cache_kwargs_without_compiled_segments():
    import repro.core.api as api
    api._warned_once.clear()
    with pytest.warns(UserWarning, match="plan_cache_entries"):
        Stratum(compiled_segments=False, plan_cache_entries=7)


def test_stratum_warns_once_per_process():
    import repro.core.api as api
    api._warned_once.clear()
    with pytest.warns(UserWarning):
        Stratum(enable=("logical",), cache_fraction=0.2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # a repeat would now raise
        Stratum(enable=("logical",), cache_fraction=0.2)


def test_stratum_defaults_unchanged_without_warned_kwargs():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s = Stratum(memory_budget_bytes=1 << 30)
    assert s.cache is not None               # default cache still built
    assert s.plan_cache is not None


# ---------------------------------------------------------------------------
# target-agnostic AsyncAIDESearch (tentpole: the driver over a client)
# ---------------------------------------------------------------------------

def test_async_aide_search_runs_on_every_client_target():
    from repro.agents import AIDEAgent, AsyncAIDESearch
    bests = {}
    for target in ("service", "fabric"):
        with connect(target, _config()) as c:
            agent = AIDEAgent(n_rows=1500, cv_k=2, seed=3)
            search = AsyncAIDESearch(c.session("agent-0"), agent,
                                     batch_size=2, max_inflight=2,
                                     shard_affinity=True, deadline_s=300)
            node = search.run(n_rounds=2)
            assert node is not None and node.score is not None
            bests[target] = node.score
    assert bests["service"] == pytest.approx(bests["fabric"], rel=1e-9)
