"""End-to-end behaviour tests for stratum on the paper's workload (§6):
fusion + CSE + lowering + selection + caching over the two-iteration
agentic search, plus agent–system co-design hooks."""

import numpy as np

from repro.agents import paper_workload_batches
from repro.agents.aide import AIDEAgent, diff_fraction, second_iteration_batch
from repro.core import ALL_FEATURES, PipelineBatch, Stratum, annotate
import repro.tabular as T

N_ROWS = 6000


def _iteration1(enable=ALL_FEATURES, spill_dir=None):
    s = Stratum(memory_budget_bytes=2 << 30, enable=enable,
                spill_dir=spill_dir)
    name, batch, ctx = next(iter(paper_workload_batches(
        n_rows=N_ROWS, cv_k=2)))
    results, report = s.run_batch(batch)
    return s, results, report, ctx


def test_paper_workload_iteration1_all_models_score():
    _, results, report, _ = _iteration1()
    assert len(results) == 8                       # 2 preproc × 4 models
    for name, score in results.items():
        assert np.isfinite(float(np.asarray(score))), name
        assert 0.05 < float(np.asarray(score)) < 5.0, (name, score)
    # fusion+CSE actually deduplicated shared stages
    assert report.rewrites.cse_merged > 20
    assert report.rewrites.reads_shared >= 7       # 8 pipelines share 1 read


def test_iteration2_reuses_iteration1_preprocessing(tmp_path):
    s, results, _, ctx = _iteration1(spill_dir=str(tmp_path))
    best = min(results, key=lambda k: float(np.asarray(results[k])))
    batch2, specs2 = second_iteration_batch(ctx["specs"][best])
    r2, rep2 = s.run_batch(batch2)
    assert rep2.run.ops_from_cache > 0             # cross-iteration reuse
    assert all(np.isfinite(float(np.asarray(v))) for v in r2.values())


def test_ablation_features_produce_identical_scores():
    """Every optimization level computes the same pipeline scores (within
    backend dtype differences) — the paper's semantic-equivalence claim."""
    base = None
    for enable in [(), ("logical",), ("logical", "lowering"),
                   ALL_FEATURES]:
        en = tuple(enable) + (("lowering",) if "lowering" not in enable
                              else ())
        s = Stratum(memory_budget_bytes=2 << 30, enable=en)
        x = T.read("uk_housing", 3000, seed=0)
        y = T.project(x, [0])
        Xv = T.scale(T.impute(T.project(x, [10, 11, 12, 13])))
        sink = T.cv_score(Xv, y, {"name": "ridge_fit", "alpha": 1.0},
                          k=2, seed=5)
        out, _ = s.run(sink)
        val = float(np.asarray(out))
        if base is None:
            base = val
        assert abs(val - base) / base < 5e-3, (en, val, base)


def test_grid_search_shares_folds_across_grid_points():
    x = T.read("uk_housing", 4000, seed=2)
    y = T.project(x, [0])
    Xv = T.scale(T.impute(T.project(x, [10, 11, 12, 13])))
    best_score, best_idx = T.grid_search(
        x=Xv, y=y, estimator_name="ridge_fit",
        grid=[{"alpha": a} for a in (0.1, 1.0, 10.0)], k=3, seed=4)
    s = Stratum(memory_budget_bytes=2 << 30)
    batch = PipelineBatch([best_score, best_idx], ["score", "idx"])
    results, report = s.run_batch(batch)
    # 3 grid points × 3 folds, but only 3 kfold_split ops must execute
    kfolds = [op for w in report.plan.waves for op in w.ops
              if op.op_name == "kfold_split"]
    assert len(kfolds) == 3
    assert 0 <= int(np.asarray(results["idx"])) < 3


def test_fidelity_annotation_selects_approx_impl():
    x = T.read("uk_housing", 2000, seed=0)
    Xv = T.scale(T.impute(T.project(x, [10, 11, 12, 13])))
    red = T.svd_reduce(Xv, k=2, seed=0)
    annotate(red, stage="explore")
    s = Stratum(memory_budget_bytes=2 << 30)
    sinks, sel, plan, _, _, _, _ = s.compile_batch(
        PipelineBatch([red], ["p"]))
    from repro.core.dag import toposort
    svd_ops = [op for op in toposort(sinks) if op.op_name == "svd_reduce"]
    assert svd_ops and sel[svd_ops[0].signature].fidelity == "approx"


def test_agent_diff_statistics_match_paper_characterization():
    """Fig 2a: ~50% of iterations change ≤16% of the pipeline code."""
    agent = AIDEAgent(seed=3)
    specs = agent.propose(4)
    agent.observe(specs, [1.0, 0.9, 1.1, 0.95])
    prev = agent.best().spec
    fracs = []
    for i in range(60):
        new = agent.propose(1)[0]
        fracs.append(diff_fraction(prev, new))
        agent.observe([new], [0.9 + 0.001 * i])
        prev = new
    frac_small = float(np.mean(np.asarray(fracs) <= 0.17))
    assert 0.35 <= frac_small <= 0.9


def test_agent_search_improves_over_drafts():
    agent = AIDEAgent(seed=1, n_rows=3000, cv_k=2)
    s = Stratum(memory_budget_bytes=2 << 30)
    for _ in range(3):
        specs = agent.propose(2)
        batch = PipelineBatch([sp.build() for sp in specs],
                              [f"s{i}" for i in range(len(specs))])
        results, _ = s.run_batch(batch)
        agent.observe(specs, [float(np.asarray(results[f"s{i}"]))
                              for i in range(len(specs))])
    assert agent.best() is not None
    assert np.isfinite(agent.best().score)
