"""Loop-aware HLO cost pass + serving batcher + data-lake tiers."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze, parse_module
from repro.serve.batcher import Batcher, Request


# ---------------------------------------------------------------------------
# hlo_cost: the roofline's data source must stay trustworthy
# ---------------------------------------------------------------------------

def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_flops_match_unrolled():
    A = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def scan_mm(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    def unroll_mm(x):
        for _ in range(7):
            x = x @ x
        return x

    fs = analyze(_compile(scan_mm, A).as_text()).flops
    fu = analyze(_compile(unroll_mm, A).as_text()).flops
    assert fs == fu == 7 * 2 * 256 ** 3


def test_nested_scan_multiplicity():
    A = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    f = analyze(_compile(nested, A).as_text()).flops
    assert f == 12 * 2 * 128 ** 3


def test_collective_trip_weighting():
    """A psum inside a scan must count once per iteration."""
    import os
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
sys_path = %r
import sys; sys.path.insert(0, sys_path)
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_debug_mesh
from repro.distributed.compat import shard_map
mesh = make_debug_mesh((1, 4), ("data", "model"))

@partial(shard_map, mesh=mesh, in_specs=P(None, "model"),
         out_specs=P(None, "model"), check_vma=False)
def inner(x):
    def body(c, _):
        return jax.lax.psum(c, "model") * 0.5 + c, None
    out, _ = jax.lax.scan(body, x, None, length=5)
    return out

co = jax.jit(inner).lower(
    jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
c = analyze(co.as_text())
n = sum(c.collective_count_by_kind.values())
print("COUNT", int(n))
""" % (str(jax.__file__ and __import__("os").path.join(
        __import__("os").path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr[-2000:]
    count = int(out.stdout.strip().split()[-1])
    assert count == 5, out.stdout


def test_parse_module_shapes():
    co = _compile(lambda x: (x @ x).sum(),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
    comps, shapes = parse_module(co.as_text())
    assert comps and shapes
    assert any("64,64" in s for s in shapes.values())


# ---------------------------------------------------------------------------
# serving batcher
# ---------------------------------------------------------------------------

def test_batcher_lifecycle():
    b = Batcher(n_lanes=2, max_len=32)
    for rid in range(5):
        b.submit(Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                         max_new_tokens=2))
    admitted = b.admit()
    assert len(admitted) == 2
    assert b.active_lanes() == [0, 1]
    b.record_tokens(np.array([7, 8]))
    b.record_tokens(np.array([9, 10]))       # both lanes hit max_new → retire
    assert b.active_lanes() == []
    assert len(b.finished) == 2
    assert b.finished[0].generated == [7, 9]
    # next wave admits from the queue
    assert len(b.admit()) == 2
    assert not b.idle


def test_batcher_eos_retires_early():
    b = Batcher(n_lanes=1, max_len=32, eos_id=0)
    b.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                     max_new_tokens=10))
    b.admit()
    b.record_tokens(np.array([5]))
    b.record_tokens(np.array([0]))            # EOS
    assert b.finished and b.finished[0].generated == [5, 0]


# ---------------------------------------------------------------------------
# data lake tiers
# ---------------------------------------------------------------------------

def test_csv_and_binary_tiers_agree():
    from repro.data.tabular import ensure_files, load_binary, load_csv
    ensure_files("uk_housing", 500, 0)
    a = load_csv("uk_housing", 500, 0)
    b = load_binary("uk_housing", 500, 0)
    np.testing.assert_allclose(np.nan_to_num(a), np.nan_to_num(b),
                               rtol=1e-6)
