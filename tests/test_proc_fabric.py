"""Out-of-process shard fabric: stream framing under adversity, wire
exception fidelity across real process boundaries, supervised failover
(``kill -9`` loses zero jobs), graceful shutdown with no orphans, the
synchronous admission window, warm cache hand-off on scale-down, and
elastic autoscaling."""

import base64
import os
import pickle
import signal
import subprocess
import sys
import time
from concurrent.futures import CancelledError

import pytest

from repro.core import PipelineBatch
from repro.service.fabric import (CodecError, JobEnvelope, ProcConfig,
                                  ProcStratumFabric, ShardedStratum,
                                  decode_job, encode_job, encode_result,
                                  ResultEnvelope)
from repro.service.fabric.proc.frames import (BYE, CONFIG, DRAIN,
                                              HANDOFF_DATA, HANDOFF_PUT,
                                              HANDOFF_REQ, HEARTBEAT, HELLO,
                                              FrameDecoder, FrameError,
                                              decode_control, encode_control)
from repro.service.queue import AdmissionError, DeadlineExceeded
import repro.tabular as T

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

N_ROWS = 1200


def _pipeline(data_seed=0, cols=(10, 11, 12), kind="mae"):
    x = T.read("uk_housing", N_ROWS, seed=data_seed)
    xs = T.scale(T.impute(T.project(x, list(cols))))
    y = T.project(x, [0])
    return T.metric(T.project(xs, [0]), y, kind=kind)


def _batch(name="p", **kw):
    return PipelineBatch([_pipeline(**kw)], [name])


@pytest.fixture(scope="module", autouse=True)
def _datasets():
    # workers read the shared data lake; generate every seed up front so
    # no worker ever races the atomic-write path mid-test
    from repro.data.tabular import ensure_files
    for seed in range(16):
        ensure_files("uk_housing", N_ROWS, seed=seed)


def _proc_fabric(n_shards=2, proc=None, **kw):
    kw.setdefault("memory_budget_bytes", 1 << 30)
    kw.setdefault("n_executors", 1)
    kw.setdefault("coalesce_window_s", 0.0)
    proc = proc or ProcConfig(heartbeat_s=0.1, heartbeat_timeout_s=3.0,
                              reconnect_grace_s=0.5)
    return ProcStratumFabric(n_shards=n_shards, proc=proc, **kw)


def _frames_with_prefix(frames):
    out = bytearray()
    for f in frames:
        out += len(f).to_bytes(4, "big") + f
    return bytes(out)


# ---------------------------------------------------------------------------
# stream framing under adversity
# ---------------------------------------------------------------------------

def test_frame_decoder_reassembles_one_byte_feeds():
    frame = encode_control(HEARTBEAT, {"queue_depth": 3})
    stream = _frames_with_prefix([frame])
    dec = FrameDecoder()
    got = []
    for i in range(len(stream)):
        got += dec.feed(stream[i:i + 1])
    assert got == [frame]
    assert dec.pending_bytes() == 0


def test_frame_decoder_interleaved_kinds_in_one_chunk():
    job = encode_job(JobEnvelope(envelope_id="e-0", tenant="t",
                                 priority=1, routing_key="k",
                                 batch=_batch()))
    result = encode_result(ResultEnvelope(envelope_id="e-0", tenant="t",
                                          shard_id="s", ok=False,
                                          error=RuntimeError("x")))
    beat = encode_control(HEARTBEAT, {"inflight": 1})
    stream = _frames_with_prefix([job, beat, result])
    dec = FrameDecoder()
    # split at an arbitrary unaligned point: partial tail carries over
    got = dec.feed(stream[:len(stream) // 3])
    got += dec.feed(stream[len(stream) // 3:])
    assert got == [job, beat, result]


def test_frame_decoder_oversize_length_word_raises():
    dec = FrameDecoder(max_frame_bytes=1024)
    with pytest.raises(FrameError):
        dec.feed((1 << 20).to_bytes(4, "big") + b"xxxx")


def test_checksum_corruption_poisons_one_frame_not_the_stream():
    a = encode_control(HEARTBEAT, {"n": 1})
    b = encode_control(HEARTBEAT, {"n": 2})
    corrupted = a[:-1] + bytes([a[-1] ^ 0xFF])   # flip payload byte
    dec = FrameDecoder()
    frames = dec.feed(_frames_with_prefix([corrupted, b]))
    assert len(frames) == 2                      # framing stays in sync
    with pytest.raises(CodecError):
        decode_control(frames[0])                # poisoned alone
    assert decode_control(frames[1]) == (HEARTBEAT, {"n": 2})


def test_control_codec_round_trip_every_kind():
    for kind in (HELLO, CONFIG, HEARTBEAT, DRAIN, BYE,
                 HANDOFF_REQ, HANDOFF_DATA, HANDOFF_PUT):
        obj = {"kind": kind, "blob": b"\x00\xff" * 8}
        assert decode_control(encode_control(kind, obj)) == (kind, obj)
    with pytest.raises(ValueError):
        encode_control(0x01, {})                 # data-plane kind refused
    with pytest.raises(CodecError):
        decode_control(encode_job(JobEnvelope(
            envelope_id="e", tenant="t", priority=1, routing_key="k",
            batch=_batch())))


# ---------------------------------------------------------------------------
# wire-crossing exceptions survive a REAL process boundary
# ---------------------------------------------------------------------------

def _unpickles_in_fresh_process(obj, check: str) -> None:
    """Pickle here, unpickle in a clean interpreter, run ``check`` there."""
    blob = base64.b64encode(pickle.dumps(obj)).decode()
    code = (f"import base64, pickle\n"
            f"e = pickle.loads(base64.b64decode('{blob}'))\n"
            f"{check}\n")
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr


def test_execution_error_crosses_process_with_op_and_cause():
    from repro.core.runtime import ExecutionError
    op = _pipeline().op
    err = ExecutionError(op, ValueError("original cause"))
    _unpickles_in_fresh_process(
        err,
        "assert type(e).__name__ == 'ExecutionError'\n"
        "assert e.op is not None and e.op.op_name\n"
        "assert isinstance(e.cause, ValueError)")


def test_execution_preempted_crosses_process_with_payload():
    from repro.core.runtime import ExecutionPreempted
    p = ExecutionPreempted(salvage={"sig": (1, 2)}, waves_done=3)
    _unpickles_in_fresh_process(
        p,
        "assert e.salvage == {'sig': (1, 2)} and e.waves_done == 3")


def test_admission_and_deadline_errors_cross_process():
    _unpickles_in_fresh_process(
        AdmissionError("queue full"),
        "assert type(e).__name__ == 'AdmissionError'\n"
        "assert 'queue full' in str(e)")
    _unpickles_in_fresh_process(
        DeadlineExceeded("too late"),
        "assert type(e).__name__ == 'DeadlineExceeded'")


def test_execution_error_with_unpicklable_cause_degrades_not_drops():
    from repro.core.runtime import ExecutionError
    from repro.service.fabric.envelope import decode_result

    class Unpicklable(Exception):
        def __reduce__(self):
            raise TypeError("nope")

    err = ExecutionError(_pipeline().op, Unpicklable("device handle"))
    data = encode_result(ResultEnvelope(envelope_id="e", tenant="t",
                                        shard_id="s", ok=False, error=err))
    out = decode_result(data).error
    # .op and .cause survive; the unpicklable cause is stringified
    assert type(out).__name__ == "ExecutionError"
    assert out.op.op_name == err.op.op_name
    assert "device handle" in repr(out.cause)


# ---------------------------------------------------------------------------
# end-to-end over real worker processes
# ---------------------------------------------------------------------------

def test_proc_fabric_matches_in_process_fabric():
    local = ShardedStratum(n_shards=1, memory_budget_bytes=1 << 30,
                           n_executors=1, coalesce_window_s=0.0)
    try:
        want, _ = local.session("t").submit(_batch()).result(timeout=120)
    finally:
        local.stop()
    fab = _proc_fabric(n_shards=2)
    try:
        got, report = fab.session("t").submit(_batch()).result(timeout=120)
        assert float(got["p"]) == pytest.approx(float(want["p"]))
        assert report.shard_id in fab.shard_ids()
    finally:
        fab.stop()


def test_client_processes_true_is_the_same_surface():
    from repro.client import StratumConfig, SubmitOptions, connect
    cfg = StratumConfig.make(memory_budget_bytes=1 << 30, n_shards=2,
                             processes=True, n_executors=1,
                             coalesce_window_s=0.0)
    with connect("fabric", cfg) as client:
        value, report = client.run(_pipeline(),
                                   options=SubmitOptions(deadline_s=120.0))
        assert report.deadline_met is True
        snap = client.telemetry.global_snapshot()
        assert len(snap["proc"]["workers"]) == 2
        for pid in snap["proc"]["workers"].values():
            os.kill(pid, 0)                     # live worker processes


def test_sigkill_mid_flood_loses_zero_jobs_and_keeps_deadlines():
    fab = _proc_fabric(n_shards=2)
    try:
        sess = fab.session("agent-0")
        futs = [sess.submit(_batch(data_seed=s), deadline_s=300.0)
                for s in range(10)]
        victim = fab.shard_ids()[-1]
        os.kill(fab.supervisor.live_workers()[victim], signal.SIGKILL)
        reports = [f.result(timeout=300)[1] for f in futs]
        assert len(reports) == 10               # zero loss
        g = fab.telemetry.global_snapshot()
        assert g["shards_failed"] == 1
        assert g["failover_requeues"] > 0
        retried = [r for r in reports if r.attempt > 0]
        assert retried, "the killed shard's jobs must have been requeued"
        for r in retried:
            # deadline budgets shrink across failover, never reset: the
            # requeued attempt saw strictly less than the original SLO
            assert r.deadline_s is not None and r.deadline_s < 300.0
        assert all(r.deadline_met for r in reports)
    finally:
        fab.stop()


def test_hung_worker_detected_by_heartbeat_timeout_and_failed_over():
    proc = ProcConfig(heartbeat_s=0.1, heartbeat_timeout_s=1.0,
                      reconnect_grace_s=0.5)
    fab = _proc_fabric(n_shards=2, proc=proc)
    try:
        sess = fab.session("agent-0")
        futs = [sess.submit(_batch(data_seed=s)) for s in range(6)]
        victim = fab.shard_ids()[-1]
        pid = fab.supervisor.live_workers()[victim]
        os.kill(pid, signal.SIGSTOP)            # alive but silent
        try:
            for f in futs:
                f.result(timeout=300)           # zero loss despite the hang
        finally:
            try:
                os.kill(pid, signal.SIGCONT)
            except ProcessLookupError:
                pass                            # supervisor already killed it
        assert any(sid == victim for sid, _ in fab.supervisor.failures)
        assert victim not in fab.shard_ids()
    finally:
        fab.stop()


def test_graceful_stop_exits_zero_and_leaves_no_orphans():
    fab = _proc_fabric(n_shards=2)
    sess = fab.session("t")
    for s in range(3):
        sess.submit(_batch(data_seed=s)).result(timeout=120)
    pids = dict(fab.supervisor.live_workers())
    fab.stop()
    assert set(fab.supervisor.reaped) == set(pids)
    for sid, rc in fab.supervisor.reaped.items():
        assert rc == 0, f"worker {sid} exited {rc}, not a clean drain"
    for pid in pids.values():                   # process-table check
        with pytest.raises(ProcessLookupError):
            os.kill(pid, 0)


def test_admission_window_raises_synchronously_at_submit():
    proc = ProcConfig(heartbeat_s=0.1, heartbeat_timeout_s=3.0, window=1)
    fab = _proc_fabric(n_shards=1, proc=proc)
    try:
        sess = fab.session("t")
        futs, rejected = [], 0
        for s in range(8):
            try:
                futs.append(sess.submit(_batch(data_seed=s)))
            except AdmissionError:
                rejected += 1                   # raised AT THE CALL SITE
        assert rejected > 0, "window=1 must push back synchronously"
        for f in futs:
            f.result(timeout=120)               # admitted work completes
    finally:
        fab.stop()


def test_scale_down_hands_hot_cache_to_ring_successor():
    fab = _proc_fabric(n_shards=2)
    try:
        sess = fab.session("t")
        victim = fab.newest_shard()
        victim_seeds = []
        for s in range(8):
            _, rep = sess.submit(_batch(data_seed=s)).result(timeout=120)
            if rep.shard_id == victim:
                victim_seeds.append(s)
        assert victim_seeds, "hash spread should hit both shards"
        fab.scale_down(victim)
        assert fab.supervisor.handoff_entries_shipped > 0
        assert fab.shard_ids() == [s for s in fab.shard_ids()
                                   if s != victim]
        # a pipeline only the departed shard ever computed now hits warm
        # cache on the survivor — the hand-off carried the entries over
        _, rep = sess.submit(
            _batch(data_seed=victim_seeds[0])).result(timeout=120)
        assert rep.shard_id != victim
        assert rep.cache_hits > 0
    finally:
        fab.stop()


def test_autoscaler_grows_under_backlog_and_drains_idle():
    fab = ProcStratumFabric(
        n_shards=1, memory_budget_bytes=1 << 30, n_executors=1,
        coalesce_window_s=0.0, autoscale=(1, 2),
        proc=ProcConfig(heartbeat_s=0.1, heartbeat_timeout_s=3.0))
    try:
        fab.autoscaler.policy.scale_up_backlog_per_shard = 2.0
        fab.autoscaler.policy.scale_down_idle_s = 1.0
        sess = fab.session("t")
        futs = [sess.submit(_batch(data_seed=s)) for s in range(10)]
        for f in futs:
            f.result(timeout=300)
        assert fab.autoscaler.scale_ups >= 1
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline and (
                len(fab.shard_ids()) > 1 or fab.autoscaler.scale_downs < 1):
            time.sleep(0.2)
        assert fab.shard_ids() == ["shard-0"]   # drained back to min
        assert fab.autoscaler.scale_downs >= 1
    finally:
        fab.stop()


def test_cancel_crosses_the_wire_to_the_owning_worker():
    fab = _proc_fabric(n_shards=1, coalesce_max_jobs=1,
                       max_jobs_per_tenant_per_round=1)
    try:
        sess = fab.session("t")
        futs = [sess.submit(_batch(data_seed=s)) for s in range(6)]
        futs[-1].cancel()       # remote: confirmation is asynchronous
        for f in futs[:-1]:
            f.result(timeout=120)
        assert futs[-1]._event.wait(timeout=60)
        assert futs[-1].cancelled()
        with pytest.raises(CancelledError):
            futs[-1].result(timeout=1)
        assert fab.router.cancels_sent == 1
    finally:
        fab.stop()


# ---------------------------------------------------------------------------
# worker entrypoint hygiene
# ---------------------------------------------------------------------------

def test_worker_entrypoint_help_runs():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.service.fabric.proc.worker",
         "--help"], env=env, capture_output=True, text=True, timeout=120)
    assert r.returncode == 0
    assert "shard" in r.stdout


def test_worker_exits_nonzero_when_supervisor_is_gone():
    import socket as _socket
    s = _socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()                                   # nothing listens here
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.service.fabric.proc.worker",
         "--port", str(port), "--shard-id", "s0"],
        env=env, capture_output=True, text=True, timeout=120)
    assert r.returncode != 0                    # never a silent orphan


# ---------------------------------------------------------------------------
# observability under chaos: heartbeat windows, traced frames, kill -9 traces
# ---------------------------------------------------------------------------

def test_heartbeat_with_windowed_stats_survives_byte_feeds():
    from repro.service.observability import ThroughputCollector
    col = ThroughputCollector(window_s=0.5, n_windows=8)
    col.record_submit()
    col.record_dispatch(0.012, queue_depth=4)
    col.record_completion()
    # the exact payload shape the worker heartbeat thread ships
    beat = {"shard_id": "shard-0", "pid": 4242, "t": 1.0,
            "queue_depth": 0, "inflight": 1, "tenants": {},
            "global": {"windows": col.snapshot()}}
    frame = encode_control(HEARTBEAT, beat)
    stream = _frames_with_prefix([frame])
    dec = FrameDecoder()
    got = []
    for i in range(len(stream)):
        got += dec.feed(stream[i:i + 1])
    assert got == [frame] and dec.pending_bytes() == 0
    kind, payload = decode_control(got[0])
    assert kind == HEARTBEAT
    win = payload["global"]["windows"]
    assert win["submitted"] == 1 and win["completed"] == 1
    assert win["dispatch_p99_s"] == pytest.approx(0.012)
    assert win["queue_depth_max"] == 4
    assert win["per_window"]                    # ring detail survives too


def test_traced_job_frame_corruption_poisons_one_frame_not_stream():
    from repro.service.observability import ROUTED, SUBMITTED, make_hop
    env = JobEnvelope(envelope_id="e-t", tenant="t", priority=1,
                      routing_key="k", batch=_batch(),
                      hops=(make_hop(SUBMITTED, t=1.0, slack=5.0),
                            make_hop(ROUTED, shard="shard-0", t=2.0,
                                     attempt=0)))
    job = encode_job(env)
    beat = encode_control(HEARTBEAT, {"n": 1})
    corrupted = job[:-1] + bytes([job[-1] ^ 0xFF])   # flip payload byte
    dec = FrameDecoder()
    frames = dec.feed(_frames_with_prefix([corrupted, beat]))
    assert len(frames) == 2                     # framing stays in sync
    with pytest.raises(CodecError):
        decode_job(frames[0])                   # poisoned alone
    assert decode_control(frames[1]) == (HEARTBEAT, {"n": 1})
    # the uncorrupted frame round-trips the hop log byte-exactly
    assert decode_job(job).hops == env.hops


def test_live_view_renders_synthetic_proc_snapshot():
    from repro.service.observability import top
    frame = top.render(top.demo_snapshot())
    assert "proc:" in frame and "autoscale" in frame
    assert "shard0" in frame and "retired" in frame
    assert "p99" in frame


def _key_for_shard(fab, shard_id, tag="k"):
    for i in range(10_000):
        key = f"{tag}-{i}"
        if fab.router._ring.route(key) == shard_id:
            return key
    raise AssertionError("no key found")  # pragma: no cover


def _chaos_trace_dir(tmp_path):
    """Trace dir for kill -9 tests; CI sets STRATUM_TEST_TRACE_DIR so the
    JSONL logs survive the run and upload as a failure artifact."""
    base = os.environ.get("STRATUM_TEST_TRACE_DIR")
    if base:
        import tempfile
        os.makedirs(base, exist_ok=True)
        return tempfile.mkdtemp(prefix="trace-", dir=base)
    return str(tmp_path)


def test_sigkill_mid_dispatch_trace_survives_and_replays(tmp_path):
    from repro.service.observability import replay
    from repro.service.observability import (COMPLETED, DISPATCHED,
                                             FAILOVER)
    tdir = _chaos_trace_dir(tmp_path)
    fab = _proc_fabric(n_shards=2, trace=True, trace_dir=tdir)
    try:
        victim = fab.shard_ids()[0]
        sess = fab.session("agent-0")
        futs = [sess.submit(_batch(data_seed=s), deadline_s=600.0,
                            affinity=_key_for_shard(fab, victim, f"v{s}"))
                for s in range(6)]
        # sensor: the victim worker flushes every hop to its JSONL, so
        # poll the trace dir for a dispatched-but-not-completed job and
        # SIGKILL the worker while it holds that job
        deadline = time.monotonic() + 120.0
        armed = False
        while time.monotonic() < deadline and not armed:
            recs = replay.load_events(tdir)
            done = {r["job"] for r in recs if r["event"] == COMPLETED}
            armed = any(r["event"] == DISPATCHED and r["shard"] == victim
                        and r["job"] not in done for r in recs)
            if not armed:
                time.sleep(0.02)
        assert armed, "victim never dispatched a job"
        os.kill(fab.supervisor.live_workers()[victim], signal.SIGKILL)
        reports = [f.result(timeout=300)[1] for f in futs]
        assert len(reports) == 6                # zero loss, as ever
        survivor = fab.shard_ids()[0]
        assert survivor != victim
    finally:
        fab.stop()

    # postmortem: the killed worker's flushed hops + the survivor's hops
    # reassemble into full timelines
    timelines = replay.reassemble(replay.load_events(tdir))
    crossed = []
    for key, hops in timelines.items():
        ev = [r["event"] for r in hops]
        disp_shards = [r["shard"] for r in hops if r["event"] == DISPATCHED]
        if FAILOVER in ev and victim in disp_shards:
            crossed.append((key, hops))
    assert crossed, \
        "no job was dispatched on the victim and failed over"
    for key, hops in crossed:
        ev = [r["event"] for r in hops]
        # dispatch on the victim, then failover, then completion on the
        # ring successor — nothing lost, nothing duplicated out of order
        assert ev[-1] == COMPLETED, (key, ev)
        i_disp = next(i for i, r in enumerate(hops)
                      if r["event"] == DISPATCHED and r["shard"] == victim)
        i_fo = next(i for i, r in enumerate(hops)
                    if r["event"] == FAILOVER)
        assert i_disp < i_fo < len(hops) - 1, (key, ev)
        last_disp = [r for r in hops if r["event"] == DISPATCHED][-1]
        assert last_disp["shard"] == survivor
        assert hops[-1]["shard"] == survivor
        ts = [r["t"] for r in hops]
        assert ts == sorted(ts), (key, ts)      # monotone timestamps
        slacks = [r["slack"] for r in hops if r["slack"] is not None]
        for a, b in zip(slacks, slacks[1:]):    # budget shrinks, never grows
            assert b <= a + 0.25, (key, slacks)
    # the gantt view attributes the victim's cut-short span as lost work
    gantt = replay.shard_gantt(timelines)
    assert victim in gantt and survivor in gantt
    summary = replay.summarize(timelines)
    assert summary["failovers"] >= 1
    assert summary["outcomes"].get(COMPLETED, 0) >= 6
