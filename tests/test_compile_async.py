"""Async/speculative plan compilation and batched variant solves.

Covers the compile executor (single-flight dedup under a thread hammer,
lane bounds, clean shutdown), score equality across per-op / compiled /
variant-batched execution, the async first-touch contract (fall back this
round, hit the next), speculative warm-up via ``precompile``, the bounded
uncompilable set, and the AIDE driver's speculation hook.
"""

import threading
import time

import numpy as np

import repro.tabular as T
from repro.core import PipelineBatch, PlanCache, Stratum
from repro.core.backends.jax_segment import JaxSegmentBackend
from repro.core.plan_cache import CompileExecutor, PlanCacheStats
from repro.service import StratumService


def _variant_batch(alphas, log1p=False, n_rows=2000):
    """AIDE-style refinement fan: identical structure, tunable alphas.
    ``log1p=True`` inserts one extra stage — a *structural* neighbor of
    the base fan (the shape the speculation predictor enumerates)."""
    x = T.read("uk_housing", n_rows, seed=0)
    y = T.project(x, [0])
    Xs = T.scale(T.impute(T.project(x, [10, 11, 12, 13])))
    if log1p:
        Xs = T.log1p(Xs)
    sinks = [T.metric(y, T.predict(T.ridge_fit(Xs, y, alpha=a), Xs),
                      kind="rmse") for a in alphas]
    return PipelineBatch(sinks, [f"v{i}" for i in range(len(alphas))])


def _scores(res, batch):
    return [float(np.asarray(res[n])) for n in batch.names]


# ---------------------------------------------------------------------------
# CompileExecutor: single-flight, bounds, shutdown
# ---------------------------------------------------------------------------

def test_executor_single_flight_under_thread_hammer():
    """N threads racing M keys: each key's job runs exactly once and the
    stats stay consistent — the contract that lets N tenants miss on the
    same new signature without N traces."""
    pc = PlanCache(capacity=64, compile_async=True)
    ex = pc.executor
    runs: dict = {}
    mu = threading.Lock()

    def job_for(key):
        def job():
            time.sleep(0.002)        # widen the race window
            with mu:
                runs[key] = runs.get(key, 0) + 1
            pc.put(key, f"compiled-{key}")
        return job

    keys = [f"sig{i}" for i in range(8)]
    accepted = []

    def hammer():
        for key in keys:
            accepted.append(ex.submit(key, job_for(key)))

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ex.drain(timeout=30)
    # every key ran exactly once, and exactly as many submits were
    # accepted as jobs ran (the rest were deduped as inflight/cached)
    assert runs == {k: 1 for k in keys}
    assert sum(accepted) == len(keys)
    snap = pc.snapshot()
    assert snap["async"] is True
    assert snap["async_compiles"] == len(keys)
    assert snap["async_failures"] == 0
    assert snap["inflight"] == 0
    assert snap["compile_time_s"] > 0
    for k in keys:
        assert pc.get(k) == f"compiled-{k}"
    pc.close()


def test_executor_lanes_are_bounded_and_speculative_drops_count():
    stats, lock = PlanCacheStats(), threading.Lock()
    ex = CompileExecutor(stats, lock, lambda k: False,
                         max_pending=2, speculative_depth=1)
    gate = threading.Event()
    assert ex.submit("busy", gate.wait)      # occupies the worker
    time.sleep(0.05)                         # let the worker dequeue it
    assert ex.submit("n1", lambda: None)
    assert ex.submit("n2", lambda: None)
    assert not ex.submit("n3", lambda: None)          # normal lane full
    assert stats.speculative_dropped == 0             # not a warm-up drop
    assert ex.submit("s1", lambda: None, speculative=True)
    assert not ex.submit("s2", lambda: None, speculative=True)
    assert stats.speculative_dropped == 1
    # single-flight also rejects a key already queued
    assert not ex.submit("n1", lambda: None)
    gate.set()
    assert ex.drain(timeout=30)
    assert stats.inflight == 0
    assert stats.async_compiles == 4          # busy, n1, n2, s1
    ex.close()


def test_executor_close_is_idempotent_and_drops_queued_work():
    stats, lock = PlanCacheStats(), threading.Lock()
    ex = CompileExecutor(stats, lock, lambda k: False, max_pending=8)
    gate = threading.Event()
    ran = []
    ex.submit("busy", gate.wait)
    time.sleep(0.05)
    ex.submit("queued", lambda: ran.append(1))
    gate.set()
    ex.close(timeout=10)
    ex.close(timeout=10)                      # idempotent
    assert not ex.submit("after", lambda: ran.append(2))
    assert ran == []                          # queued job was dropped
    assert stats.inflight == 0
    assert ex._worker is not None and not ex._worker.is_alive()


def test_executor_counts_failures_without_dying():
    pc = PlanCache(capacity=8, compile_async=True)

    def boom():
        raise RuntimeError("trace failed")

    assert pc.executor.submit("bad", boom)
    assert pc.executor.submit("good", lambda: pc.put("good", 1))
    assert pc.executor.drain(timeout=30)
    snap = pc.snapshot()
    assert snap["async_failures"] == 1
    assert snap["async_compiles"] == 1
    assert pc.get("good") == 1
    pc.close()


def test_plan_cache_speculative_hit_accounting():
    pc = PlanCache(capacity=8, compile_async=True, speculative_depth=2)
    pc.put("warm", "program", speculative=True)
    snap = pc.snapshot()
    assert snap["speculative_compiles"] == 1
    assert snap["speculative_hits"] == 0
    assert pc.get("warm") == "program"
    assert pc.snapshot()["speculative_hits"] == 1
    pc.get("warm")                            # only the FIRST demand hit
    assert pc.snapshot()["speculative_hits"] == 1
    pc.close()


# ---------------------------------------------------------------------------
# batched variant solves: one vmapped program, identical scores
# ---------------------------------------------------------------------------

def test_batched_variants_match_per_op_and_compiled():
    alphas = (0.5, 1.0, 2.0, 4.0)
    per_op = Stratum(memory_budget_bytes=1 << 30, compiled_segments=False)
    comp = Stratum(memory_budget_bytes=1 << 30)
    vb = Stratum(memory_budget_bytes=1 << 30, batch_variants=True)
    batch = _variant_batch(alphas)
    ref = _scores(per_op.run_batch(batch)[0], batch)
    got_c = _scores(comp.run_batch(_variant_batch(alphas))[0], batch)
    res_vb, rep_vb = vb.run_batch(_variant_batch(alphas))
    got_vb = _scores(res_vb, batch)
    assert rep_vb.run.per_backend.get("jax-seg", 0) > 0
    np.testing.assert_allclose(got_c, ref, rtol=1e-6)
    np.testing.assert_allclose(got_vb, ref, rtol=1e-6)
    assert len(set(ref)) == len(alphas)       # distinct alphas, real work
    # batched programs key under their own tag — the caches never mix
    assert vb._backends["jax"]._key_tag == "jax-seg-vb"
    assert comp._backends["jax"]._key_tag == "jax-seg"


def test_batched_variants_reuse_one_compiled_program():
    vb = Stratum(memory_budget_bytes=1 << 30, batch_variants=True,
                 enable=("logical", "lowering", "selection", "parallel"))
    vb.run_batch(_variant_batch((0.5, 1.0, 2.0)))
    compiles = vb.plan_cache.snapshot()["compiles"]
    assert compiles > 0
    vb.run_batch(_variant_batch((3.0, 5.0, 7.0)))
    snap = vb.plan_cache.snapshot()
    assert snap["compiles"] == compiles       # second fan: pure hits
    assert snap["hits"] > 0


def test_variant_group_planning_is_safe_and_pure():
    """Groups form per (structural signature, impl); a group whose
    deferral would starve an intermediate consumer is dropped."""
    plan = JaxSegmentBackend._plan_groups
    # three members of one class, hoisted tunables, no internal edges
    assert plan(("s", "s", "s"), (1, 1, 1),
                ((), (), ()), (("a",), ("a",), ("a",))) == ((0, 1, 2),)
    # mixed classes: only same-(ssig, impl) runs group
    assert plan(("s", "t", "s"), (1, 1, 1),
                ((), (), ()), (("a",), ("a",), ("a",))) == ((0, 2),)
    # no hoisted tunables still groups: differing inputs are the batched
    # axis (chain ops downstream of a tunable fan)
    assert plan(("s", "s"), (1, 1), ((), ()), ((), ())) == ((0, 1),)
    # op 1 consumes member 0's output: deferring 0 to position 2 would
    # starve it, so the group is dropped
    assert plan(("s", "x", "s"), (1, 2, 1),
                ((), ((1, 0, 0),), ()), (("a",), (), ("a",))) == ()


# ---------------------------------------------------------------------------
# async compilation: first touch falls back, next round runs compiled
# ---------------------------------------------------------------------------

def test_async_first_touch_falls_back_then_hits_warm():
    ref_s = Stratum(memory_budget_bytes=1 << 30, compiled_segments=False)
    s = Stratum(memory_budget_bytes=1 << 30, compile_async=True)
    try:
        batch = _variant_batch((0.5, 1.5))
        res1, rep1 = s.run_batch(batch)
        # the miss went to the background lane; this round ran per-op
        assert rep1.run.plan_cache_fallback_rounds >= 1
        assert rep1.run.per_backend.get("jax-seg", 0) == 0
        assert s.plan_cache.executor.drain(timeout=120)
        # same structure, fresh constants: compiled program is warm now
        batch2 = _variant_batch((2.5, 3.5))
        res2, rep2 = s.run_batch(batch2)
        assert rep2.run.plan_cache_fallback_rounds == 0
        assert rep2.run.per_backend.get("jax-seg", 0) > 0
        ref = _scores(ref_s.run_batch(_variant_batch((2.5, 3.5)))[0],
                      batch2)
        np.testing.assert_allclose(_scores(res2, batch2), ref, rtol=1e-6)
        snap = s.plan_cache.snapshot()
        assert snap["async_compiles"] >= 1
        assert snap["async_failures"] == 0
    finally:
        s.close()


def test_speculative_precompile_warms_future_structure():
    """precompile_batch on a structure the tenant has NOT submitted:
    after the background build, the first real submission is a
    speculative hit with zero fallback rounds."""
    s = Stratum(memory_budget_bytes=1 << 30, compile_async=True,
                speculative_depth=4)
    try:
        # two real rounds of the rmse structure: the second runs with the
        # shared prefix served from the intermediate cache, which is the
        # cut future plans will see — and records the observed input avals
        # the speculative build warms with
        s.run_batch(_variant_batch((0.5, 1.5)))
        assert s.plan_cache.executor.drain(timeout=120)
        s.run_batch(_variant_batch((2.0, 3.0)))
        assert s.plan_cache.executor.drain(timeout=120)
        # predict a STRUCTURAL neighbor (one extra traced stage)
        counts = s.precompile_batch(_variant_batch((4.0, 5.0), log1p=True))
        assert counts.get("enqueued", 0) >= 1
        assert s.plan_cache.executor.drain(timeout=120)
        base = s.plan_cache.snapshot()
        assert base["speculative_compiles"] >= 1
        batch = _variant_batch((6.0, 7.0), log1p=True)
        res, rep = s.run_batch(batch)
        snap = s.plan_cache.snapshot()
        assert snap["speculative_hits"] >= 1
        assert rep.run.per_backend.get("jax-seg", 0) > 0
        ref_s = Stratum(memory_budget_bytes=1 << 30,
                        compiled_segments=False)
        ref = _scores(
            ref_s.run_batch(_variant_batch((6.0, 7.0), log1p=True))[0],
            batch)
        np.testing.assert_allclose(_scores(res, batch), ref, rtol=1e-6)
    finally:
        s.close()


def test_uncompilable_set_is_lru_bounded_and_gauged():
    pc = PlanCache(capacity=8)
    be = JaxSegmentBackend(pc, uncompilable_max=8)
    for i in range(20):
        be._mark_uncompilable(("sig", i))
    assert len(be._uncompilable) == 8
    assert pc.snapshot()["uncompilable"] == 8
    assert be._is_uncompilable(("sig", 19))
    assert not be._is_uncompilable(("sig", 0))        # LRU-evicted


# ---------------------------------------------------------------------------
# service integration: lifecycle, telemetry, the AIDE speculation hook
# ---------------------------------------------------------------------------

def test_service_stop_closes_compile_executor():
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, compile_async=True,
                         speculative_depth=2)
    ses = svc.session("t0")
    ses.submit(_variant_batch((0.5, 1.5))).result(timeout=300)
    ex = svc.plan_cache.executor
    assert ex is not None
    assert ex.drain(timeout=120)
    g = svc.telemetry.global_snapshot()
    assert g["plan_cache"]["async"] is True
    assert g["plan_cache"]["async_compiles"] >= 1
    svc.stop()
    assert ex._closed
    assert ex._worker is None or not ex._worker.is_alive()
    assert not ex.submit("late", lambda: None)


def test_session_precompile_surface_and_compat():
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, compile_async=True,
                         speculative_depth=4)
    try:
        ses = svc.session("t0")
        ses.submit(_variant_batch((0.5, 1.5))).result(timeout=300)
        assert svc.plan_cache.executor.drain(timeout=120)
        counts = ses.precompile(_variant_batch((1.0, 2.0), log1p=True))
        assert isinstance(counts, dict) and counts
    finally:
        svc.stop()
    # a session over a backend without the hook degrades to {}
    class _Bare:
        telemetry = None
    from repro.service.session import Session
    assert Session(_Bare(), "t").precompile(
        _variant_batch((1.0,))) == {}


def test_async_aide_search_sends_speculative_hints():
    from repro.agents import AIDEAgent, AsyncAIDESearch
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, compile_async=True,
                         speculative_depth=4)
    try:
        agent = AIDEAgent(n_rows=1500, cv_k=2, seed=3)
        search = AsyncAIDESearch(svc.session("aide"), agent,
                                 batch_size=2, max_inflight=1,
                                 speculate=True)
        best = search.run(n_rounds=3)
        assert best is not None and best.score is not None
        # refinement rounds fired precompile hints for structural
        # neighbors of the incumbent
        assert search.speculative_batches >= 1
    finally:
        svc.stop()


def test_async_aide_search_speculative_hint_scores_a_hit():
    """Warm-up end to end THROUGH the driver: the precompile hint fired
    while refining must cover a later round that submits the predicted
    structures.  The plan key is pipeline-name independent, so hint
    batches (named ``speculative_i``) warm demand batches (``r{k}_i``).
    The agent is scripted to make the hit deterministic: rounds 1-2 stay
    on the base structure (which ``speculate()`` never predicts — both
    neighbors are structural mutations), round 3 submits exactly the
    predicted neighbor pair."""
    from repro.agents import AIDEAgent, AsyncAIDESearch

    class ScriptedAgent(AIDEAgent):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.proposals = 0

        def speculate(self, max_specs: int = 2):
            # frozen on the base spec (incumbent ignored), so the hint
            # fired at round 2 and the demand at round 3 agree exactly
            saved, self.nodes = self.nodes, []
            try:
                return super().speculate(max_specs)
            finally:
                self.nodes = saved

        def propose(self, batch_size: int):
            self.proposals += 1
            if self.proposals < 3:
                return [self.base] * batch_size
            return self.speculate(batch_size)

    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, compile_async=True,
                         speculative_depth=8)
    try:
        agent = ScriptedAgent(n_rows=1200, cv_k=2, seed=3)
        search = AsyncAIDESearch(svc.session("aide"), agent,
                                 batch_size=2, max_inflight=1,
                                 speculate=True)
        search.run(n_rounds=2)      # round 2 refines → hint fires
        assert search.speculative_batches >= 1
        svc.plan_cache.executor.drain(timeout=180.0)
        warmed = svc.plan_cache.snapshot()
        # the neighbors compiled on the speculative lane and nothing has
        # touched them yet
        assert warmed["speculative_compiles"] >= 1
        assert warmed["speculative_hits"] == 0
        best = search.run(n_rounds=1)   # round 3 = predicted neighbors
        assert best is not None
        assert svc.plan_cache.snapshot()["speculative_hits"] >= 1
    finally:
        svc.stop()


def test_scheduler_clusters_variant_fans_deterministically():
    """Equal-cost ready ops tie-break on structural signature, so variant
    fans land adjacent within a wave (minimal group deferral) and wave
    layout is reproducible."""
    s = Stratum(memory_budget_bytes=1 << 30)
    batch = _variant_batch((0.5, 1.0, 2.0, 4.0))
    _, _, p1, _, _, _, _ = s.compile_batch(batch)
    _, _, p2, _, _, _, _ = s.compile_batch(_variant_batch(
        (0.5, 1.0, 2.0, 4.0)))
    lay1 = [[op.structural_signature for op in w.ops] for w in p1.waves]
    lay2 = [[op.structural_signature for op in w.ops] for w in p2.waves]
    assert lay1 == lay2
    for wave in lay1:
        # same-signature runs are contiguous within each wave
        seen = []
        for sig in wave:
            if sig in seen:
                assert sig == seen[-1], f"non-contiguous fan in {wave}"
            else:
                seen.append(sig)
