"""GBT prefix-sharing rewrite (beyond-paper, §Perf H3.2): exactness and
structure, plus the MoE equal-groups gmm path."""

import numpy as np
import pytest

from repro.core import PipelineBatch, Stratum
from repro.core.dag import toposort
import repro.tabular as T


def _pair(seed=1, n=3000):
    x = T.read("uk_housing", n, seed=0)
    y = T.project(x, [0])
    Xv = T.scale(T.impute(T.project(x, [10, 11, 12, 13])))
    s20 = T.cv_score(Xv, y, {"name": "gbt_fit", "n_trees": 20, "depth": 3},
                     k=2, seed=seed)
    s40 = T.cv_score(Xv, y, {"name": "gbt_fit", "n_trees": 40, "depth": 3},
                     k=2, seed=seed)
    return s20, s40


def test_prefix_rewrite_fires_and_is_exact():
    s20, s40 = _pair()
    sess = Stratum(memory_budget_bytes=1 << 30)
    sinks, sel, plan, _, rw, _, _ = sess.compile_batch(
        PipelineBatch([s20, s40], ["a", "b"]))
    ops_ = toposort(sinks)
    fits = [o for o in ops_ if o.op_name == "gbt_fit"]
    prefixes = [o for o in ops_ if o.op_name == "gbt_prefix"]
    assert len(fits) == 2          # one 40-tree fit per fold
    assert len(prefixes) == 2      # 20-tree models extracted
    assert all(o.spec["n_trees"] == 40 for o in fits)

    res, _ = sess.run_batch(PipelineBatch([s20, s40], ["a", "b"]))
    plain = Stratum(memory_budget_bytes=1 << 30,
                    enable=("lowering", "selection"))
    res0, _ = plain.run_batch(PipelineBatch([s20, s40], ["a0", "b0"]))
    assert float(res["a"]) == pytest.approx(float(res0["a0"]), abs=0)
    assert float(res["b"]) == pytest.approx(float(res0["b0"]), abs=0)


def test_prefix_rewrite_respects_differing_hyperparams():
    """Different depth/lr must NOT be merged."""
    x = T.read("uk_housing", 2000, seed=0)
    y = T.project(x, [0])
    Xv = T.scale(T.impute(T.project(x, [10, 11])))
    a = T.cv_score(Xv, y, {"name": "gbt_fit", "n_trees": 20, "depth": 2},
                   k=2, seed=3)
    b = T.cv_score(Xv, y, {"name": "gbt_fit", "n_trees": 40, "depth": 3},
                   k=2, seed=3)
    sess = Stratum(memory_budget_bytes=1 << 30)
    sinks, *_ = sess.compile_batch(PipelineBatch([a, b], ["a", "b"]))
    fits = [o for o in toposort(sinks) if o.op_name == "gbt_fit"]
    assert len(fits) == 4          # 2 folds × 2 distinct configs — no merge


def test_moe_equal_groups_matches_ref():
    import jax.numpy as jnp
    from repro.kernels.moe_gmm.ops import moe_gmm
    from repro.kernels.moe_gmm.ref import moe_gmm_ref
    rng = np.random.default_rng(0)
    E, C, D, F = 4, 16, 8, 12
    x = jnp.asarray(rng.normal(size=(E * C, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, D, F)), jnp.float32)
    sizes = jnp.full((E,), C, jnp.int32)
    ref = moe_gmm_ref(x, w, sizes)
    fast = moe_gmm(x, w, sizes, equal_groups=C)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                               atol=1e-5)


def test_variant_batching_exact():
    """H3.4: vmapped hyperparameter groups produce identical results to
    individual execution.  Variant vmap-batching lives on the per-op
    python backend, so both runs pin ``compiled_segments=False``; a third
    run through the compiled segment backend must agree too."""
    import repro.core.selection as sel
    x = T.read("uk_housing", 4000, seed=0)
    y = T.project(x, [0])
    Xv = T.scale(T.impute(T.project(x, [10, 11, 12, 13])))
    score, idx = T.grid_search(
        Xv, y, "ridge_fit",
        [{"alpha": a} for a in (0.1, 1.0, 10.0)], k=2, seed=4)

    saved = dict(sel._VMAP_GROUPS)
    try:
        sel._VMAP_GROUPS.clear()
        r0, rep0 = Stratum(memory_budget_bytes=1 << 30,
                           compiled_segments=False).run_batch(
            PipelineBatch([score, idx], ["s", "i"]))
        assert "jax-vmap" not in rep0.run.per_backend
    finally:
        sel._VMAP_GROUPS.update(saved)
    r1, rep1 = Stratum(memory_budget_bytes=1 << 30,
                       compiled_segments=False).run_batch(
        PipelineBatch([score, idx], ["s", "i"]))
    assert rep1.run.per_backend.get("jax-vmap", 0) >= 6
    np.testing.assert_allclose(float(np.asarray(r0["s"])),
                               float(np.asarray(r1["s"])), atol=1e-5)
    assert int(np.asarray(r0["i"])) == int(np.asarray(r1["i"]))

    r2, rep2 = Stratum(memory_budget_bytes=1 << 30).run_batch(
        PipelineBatch([score, idx], ["s", "i"]))
    assert rep2.run.per_backend.get("jax-seg", 0) > 0
    np.testing.assert_allclose(float(np.asarray(r0["s"])),
                               float(np.asarray(r2["s"])), atol=1e-5)
    assert int(np.asarray(r0["i"])) == int(np.asarray(r2["i"]))
