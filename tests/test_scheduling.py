"""Priority-aware scheduling: WFQ bands, starvation aging, cooperative
preemption (no lost intermediates), and cross-tenant cache arbitration."""

import threading
import time
from collections import Counter

import numpy as np
import pytest

from repro.core import GENERIC, LazyOp, PipelineBatch
from repro.core.cache import IntermediateCache
from repro.service import FairQueue, Priority, StratumService
from repro.service.queue import Job as QJob
from repro.service.session import PipelineFuture
import repro.tabular as T


def _job(i, tenant="t", priority=Priority.BATCH, batch=None):
    return QJob(id=i, tenant=tenant, batch=batch,
                future=PipelineFuture(i, tenant, priority),
                priority=priority)


def _pipeline(n_rows=4000, cols=(10, 11, 12), kind="mae"):
    x = T.read("uk_housing", n_rows, seed=0)
    xs = T.scale(T.impute(T.project(x, list(cols))))
    y = T.project(x, [0])
    return T.metric(T.project(xs, [0]), y, kind=kind)


def _batch(name="p", **kw):
    return PipelineBatch([_pipeline(**kw)], [name])


# ---------------------------------------------------------------------------
# weighted fair queuing across priority bands
# ---------------------------------------------------------------------------

def test_interactive_band_served_first():
    q = FairQueue()
    for i in range(5):
        q.push(_job(i, tenant="bulk", priority=Priority.BATCH))
    q.push(_job(99, tenant="probe", priority=Priority.INTERACTIVE))
    round1 = q.pop_round(max_jobs=4, max_per_tenant=4)
    # rounds are single-band: the interactive probe comes out alone, first
    assert [j.id for j in round1] == [99]


def test_wfq_gives_lower_bands_proportional_share():
    q = FairQueue(weights={Priority.INTERACTIVE: 3, Priority.BATCH: 1,
                           Priority.SCAVENGER: 0}, aging_s=None)
    for i in range(20):
        q.push(_job(i, tenant="i", priority=Priority.INTERACTIVE))
        q.push(_job(100 + i, tenant="b", priority=Priority.BATCH))
    served = Counter()
    for _ in range(8):
        jobs = q.pop_round(max_jobs=1)
        assert len(jobs) == 1
        served[jobs[0].priority] += 1
    # 3:1 weights → 6 interactive rounds, 2 batch rounds out of 8
    assert served[Priority.INTERACTIVE] == 6
    assert served[Priority.BATCH] == 2


def test_weight_zero_band_served_only_when_weighted_bands_empty():
    q = FairQueue(weights={Priority.INTERACTIVE: 1, Priority.BATCH: 0,
                           Priority.SCAVENGER: 0}, aging_s=None)
    q.push(_job(0, tenant="s", priority=Priority.SCAVENGER))
    q.push(_job(1, tenant="i", priority=Priority.INTERACTIVE))
    assert [j.id for j in q.pop_round(max_jobs=1)] == [1]
    # interactive drained → the background band finally runs
    assert [j.id for j in q.pop_round(max_jobs=1)] == [0]


def test_priority_blind_mode_ignores_bands():
    q = FairQueue(priority_aware=False)
    q.push(_job(0, tenant="a", priority=Priority.SCAVENGER))
    q.push(_job(1, tenant="b", priority=Priority.INTERACTIVE))
    jobs = q.pop_round(max_jobs=2, max_per_tenant=1)
    # both collapse into one band: plain round-robin over tenants
    assert {j.id for j in jobs} == {0, 1}


def test_has_work_above():
    q = FairQueue()
    q.push(_job(0, priority=Priority.SCAVENGER))
    assert not q.has_work_above(int(Priority.SCAVENGER))
    q.push(_job(1, priority=Priority.BATCH))
    assert q.has_work_above(int(Priority.SCAVENGER))
    assert not q.has_work_above(int(Priority.BATCH))
    q.push(_job(2, priority=Priority.INTERACTIVE))
    assert q.has_work_above(int(Priority.BATCH))


def test_requeue_goes_to_front_of_band():
    q = FairQueue(aging_s=None)
    first = _job(0, tenant="a")
    q.push(first)
    q.push(_job(1, tenant="a"))
    popped = q.pop_round(max_jobs=1, max_per_tenant=1)
    assert popped == [first]
    q.requeue(popped)
    assert q.pop_round(max_jobs=1, max_per_tenant=1) == [first]


# ---------------------------------------------------------------------------
# starvation aging
# ---------------------------------------------------------------------------

def test_aging_promotes_scavenger_under_sustained_interactive_load():
    # strict-priority weights: without aging the scavenger job would never
    # run while interactive work exists
    q = FairQueue(weights={Priority.INTERACTIVE: 1, Priority.BATCH: 0,
                           Priority.SCAVENGER: 0}, aging_s=0.05)
    scav = _job(999, tenant="s", priority=Priority.SCAVENGER)
    q.push(scav)
    next_id = 0
    served_scav_at = None
    deadline = time.perf_counter() + 5.0
    while time.perf_counter() < deadline:
        # sustained interactive load: keep the top band non-empty
        while q.pending_by_band()[int(Priority.INTERACTIVE)] < 2:
            q.push(_job(next_id, tenant="i",
                        priority=Priority.INTERACTIVE))
            next_id += 1
        jobs = q.pop_round(max_jobs=1)
        if any(j.id == 999 for j in jobs):
            served_scav_at = time.perf_counter()
            break
        time.sleep(0.005)
    assert served_scav_at is not None, \
        "scavenger job starved despite aging"
    # it was served from the top band, i.e. genuinely promoted twice
    assert scav.band == int(Priority.INTERACTIVE)


def test_service_scavenger_completes_under_interactive_flood():
    svc = StratumService(
        memory_budget_bytes=1 << 30, n_executors=1,
        coalesce_window_s=0.0,
        priority_weights={Priority.INTERACTIVE: 1, Priority.BATCH: 0,
                          Priority.SCAVENGER: 0},
        aging_s=0.1, autostart=False)
    try:
        scav_fut = svc.session("scav").submit(
            _batch(cols=(3, 4)), priority=Priority.SCAVENGER)
        flood = svc.session("flood")
        flood_futs = [flood.submit(_batch(name=f"f{i}", cols=(10, 11)),
                                   priority=Priority.INTERACTIVE)
                      for i in range(8)]
        svc.start()
        res, rep = scav_fut.result(timeout=120)
        assert "p" in res
        for f in flood_futs:
            f.result(timeout=120)
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# cooperative preemption: completed intermediates are never lost
# ---------------------------------------------------------------------------

EXEC_COUNTS: Counter = Counter()
_EXEC_LOCK = threading.Lock()


def _slow_identity(x, tag="", delay=0.05):
    with _EXEC_LOCK:
        EXEC_COUNTS[tag] += 1
    time.sleep(delay)
    return x


def _chain_batch(name: str, depth: int, delay: float,
                 tag_prefix: str) -> PipelineBatch:
    """``depth`` sequential slow ops → ``depth`` waves with yield points."""
    x = T.read("uk_housing", 1000, seed=0)
    ref = T.project(x, [0])
    for d in range(depth):
        ref = LazyOp(f"slow_{tag_prefix}_{d}", GENERIC,
                     spec={"fn": _slow_identity,
                           "kwargs": {"tag": f"{tag_prefix}{d}",
                                      "delay": delay}},
                     inputs=(ref,)).out()
    return PipelineBatch([ref], [name])


def test_preempted_superbatch_loses_no_completed_intermediates():
    EXEC_COUNTS.clear()
    tag = f"pre{time.monotonic_ns()}"   # unique sigs per test run
    done_order: list = []
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, aging_s=None,
                         autostart=False)
    try:
        chain_fut = svc.session("bulk").submit(
            _chain_batch("chain", depth=8, delay=0.1, tag_prefix=tag),
            priority=Priority.SCAVENGER)
        chain_fut.add_done_callback(lambda _f: done_order.append("chain"))
        svc.start()
        time.sleep(0.45)                # a few waves complete
        probe_fut = svc.session("probe").submit(
            _batch(n_rows=1000), priority=Priority.INTERACTIVE)
        probe_fut.add_done_callback(lambda _f: done_order.append("probe"))
        probe_res, _ = probe_fut.result(timeout=120)
        assert "p" in probe_res
        chain_res, chain_rep = chain_fut.result(timeout=120)
        assert "chain" in chain_res
        # the probe overtook the running scavenger super-batch
        assert done_order[0] == "probe", done_order
        # the chain really yielded and resumed from salvage
        assert chain_rep.preemptions >= 1
        assert chain_rep.ops_salvaged > 0
        # no completed intermediate was recomputed: every slow op ran once
        counts = {k: v for k, v in EXEC_COUNTS.items()
                  if k.startswith(tag)}
        assert counts and all(v == 1 for v in counts.values()), counts
        snap = svc.telemetry.snapshot()
        assert snap["bulk"]["preemptions"] >= 1
        assert svc.telemetry.global_snapshot()["preemptions"] >= 1
    finally:
        svc.stop()


def test_interactive_superbatch_is_never_preempted():
    EXEC_COUNTS.clear()
    tag = f"top{time.monotonic_ns()}"
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, aging_s=None,
                         autostart=False)
    try:
        chain_fut = svc.session("a").submit(
            _chain_batch("chain", depth=5, delay=0.05, tag_prefix=tag),
            priority=Priority.INTERACTIVE)
        svc.start()
        time.sleep(0.1)
        other_fut = svc.session("b").submit(
            _batch(n_rows=1000), priority=Priority.INTERACTIVE)
        _, rep = chain_fut.result(timeout=120)
        assert rep.preemptions == 0
        other_fut.result(timeout=120)
    finally:
        svc.stop()


def test_preemption_cap_lets_scavenger_finish():
    """A job yields at most max_preemptions_per_job times, then runs to
    completion even under continued interactive pressure."""
    EXEC_COUNTS.clear()
    tag = f"cap{time.monotonic_ns()}"
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0, aging_s=None,
                         max_preemptions_per_job=1, autostart=False)
    try:
        chain_fut = svc.session("bulk").submit(
            _chain_batch("chain", depth=6, delay=0.08, tag_prefix=tag),
            priority=Priority.SCAVENGER)
        svc.start()
        probe = svc.session("probe")
        time.sleep(0.25)
        futs = [probe.submit(_batch(name=f"q{i}", n_rows=1000),
                             priority=Priority.INTERACTIVE)
                for i in range(4)]
        _, rep = chain_fut.result(timeout=120)
        assert rep.preemptions <= 1
        for f in futs:
            f.result(timeout=120)
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# cross-tenant cache arbitration
# ---------------------------------------------------------------------------

def _val(n_f64: int):
    return (np.zeros(n_f64),)          # n_f64 * 8 bytes


def test_quota_evicts_over_quota_tenant_first():
    cache = IntermediateCache(budget_bytes=1000, arbitration="quota",
                              tenant_quota_fraction=0.3)   # quota: 300 B
    cache.put("b1", _val(25), spill=False, tenant="B")     # B: 200 B, under
    for i in range(4):                                     # A: 800 B, over
        cache.put(f"a{i}", _val(25), spill=False, tenant="A")
    assert cache.stats.evictions == 0
    # pressure: next insert must evict — and the victim must be A's LRU,
    # not B's older (globally-LRU) entry
    cache.put("a4", _val(25), spill=False, tenant="A")
    assert "b1" in cache
    assert "a0" not in cache
    assert cache.stats.evictions_by_tenant == {"A": 1}
    # keep pushing A: B stays resident while an over-quota victim exists
    for i in range(5, 10):
        cache.put(f"a{i}", _val(25), spill=False, tenant="A")
    assert "b1" in cache
    assert all(t == "A" for t in cache.stats.evictions_by_tenant)


def test_quota_falls_back_to_global_lru_when_nobody_over():
    cache = IntermediateCache(budget_bytes=1000, arbitration="quota",
                              tenant_quota_fraction=0.5)   # quota: 500 B
    cache.put("a1", _val(50), spill=False, tenant="A")     # 400 B
    cache.put("b1", _val(50), spill=False, tenant="B")     # 400 B
    cache.put("c1", _val(50), spill=False, tenant="C")     # overflow
    # nobody exceeds 500 B → plain LRU: the oldest entry (a1) goes
    assert "a1" not in cache
    assert "b1" in cache and "c1" in cache


def test_lru_policy_ignores_quotas():
    cache = IntermediateCache(budget_bytes=1000, arbitration="lru",
                              tenant_quota_fraction=0.1)
    cache.put("b1", _val(25), spill=False, tenant="B")
    for i in range(5):
        cache.put(f"a{i}", _val(25), spill=False, tenant="A")
    assert "b1" not in cache           # global LRU evicted B regardless


def test_cross_tenant_hit_attribution():
    cache = IntermediateCache(budget_bytes=1 << 20, arbitration="quota")
    cache.put("s", _val(8), spill=False, tenant="A")
    assert cache.get("s", tenant="A") is not None
    assert cache.stats.cross_tenant_hits == 0
    assert cache.get("s", tenant="B") is not None
    assert cache.stats.cross_tenant_hits == 1
    assert cache.stats.hits_by_tenant == {"A": 1, "B": 1}
    assert cache.tenant_bytes() == {"A": 64}


def test_attribution_survives_eviction_and_disk_reload(tmp_path):
    """The first materializer keeps both the quota charge and the
    cross-tenant hit credit even after its entry was evicted to disk."""
    cache = IntermediateCache(budget_bytes=800, arbitration="quota",
                              tenant_quota_fraction=0.9,
                              spill_dir=str(tmp_path))
    cache.put("a1", _val(50), tenant="A")          # 400 B, spilled
    cache.put("a2", _val(50), tenant="A")          # 800 B total
    cache.put("a3", _val(50), tenant="A")          # evicts a1 (LRU)
    assert cache.stats.evictions == 1
    # B reloads A's evicted entry from disk: it is a cross-tenant hit and
    # the RAM charge goes back to A, not to B
    assert cache.get("a1", tenant="B") is not None
    assert cache.stats.disk_hits == 1
    assert cache.stats.cross_tenant_hits == 1
    assert "B" not in cache.tenant_bytes()
    snap = cache.arbitration_snapshot()
    assert snap["cross_tenant_hits"] == 1
    assert snap["evictions_by_tenant"] == {"A": 2}  # a1 + one more on reload


def test_unknown_arbitration_rejected():
    with pytest.raises(ValueError):
        IntermediateCache(budget_bytes=1, arbitration="lifo")


# ---------------------------------------------------------------------------
# telemetry surfaces the new dimensions
# ---------------------------------------------------------------------------

def test_telemetry_reports_priority_and_cache_state():
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0)
    try:
        svc.session("t").submit(_batch(n_rows=1000),
                                priority=Priority.INTERACTIVE
                                ).result(timeout=60)
        snap = svc.telemetry.snapshot()["t"]
        assert snap["submitted_by_priority"] == {"INTERACTIVE": 1}
        assert "INTERACTIVE" in snap["queue_wait_by_priority"]
        g = svc.telemetry.global_snapshot()
        assert "preemptions" in g
        assert "cache_cross_tenant_hits" in g
        assert "preemptions:" in svc.telemetry.report()
        import json
        json.dumps(snap), json.dumps(g)   # JSON-serializable surfaces
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# deadline-aware scheduling: EDF tie-break, shedding, tight-slack dispatch
# ---------------------------------------------------------------------------

def _djob(i, tenant="t", deadline_s=None, priority=Priority.BATCH):
    return QJob(id=i, tenant=tenant, batch=None,
                future=PipelineFuture(i, tenant, priority),
                priority=priority, deadline_s=deadline_s)


def test_edf_serves_deadline_tenant_before_round_robin():
    """Within the WFQ-chosen band, the tenant holding the earliest
    deadline is served first; deadline-free tenants keep RR order."""
    q = FairQueue()
    q.push(_djob(0, "bulk-a"))
    q.push(_djob(1, "bulk-b"))
    q.push(_djob(2, "slo-loose", deadline_s=60.0))
    q.push(_djob(3, "slo-tight", deadline_s=10.0))
    out = q.pop_round(max_jobs=4, max_per_tenant=1)
    assert [j.tenant for j in out] == \
        ["slo-tight", "slo-loose", "bulk-a", "bulk-b"]


def test_edf_orders_within_one_tenant_fifo():
    q = FairQueue()
    q.push(_djob(0, "t", deadline_s=60.0))
    q.push(_djob(1, "t", deadline_s=5.0))
    q.push(_djob(2, "t"))
    out = q.pop_round(max_jobs=3, max_per_tenant=3)
    assert [j.id for j in out] == [1, 0, 2]


def test_expired_job_is_shed_with_deadline_exceeded():
    from repro.service import DeadlineExceeded
    q = FairQueue()
    shed_seen = []
    q.on_shed = shed_seen.append
    job = _djob(0, "t", deadline_s=1e-9)
    q.push(_djob(1, "t"))
    q.push(job)
    time.sleep(0.002)
    out = q.pop_round(max_jobs=4, max_per_tenant=4)
    assert [j.id for j in out] == [1]          # survivor still served
    assert [j.id for j in shed_seen] == [0]
    assert q.pending() == 0
    with pytest.raises(DeadlineExceeded):
        job.future.result(timeout=0)


def test_tight_slack_job_pops_alone_never_into_a_merge():
    q = FairQueue()
    q.push(_djob(0, "bulk-a"))
    q.push(_djob(1, "bulk-b"))
    q.push(_djob(2, "slo", deadline_s=0.2))
    out = q.pop_round(max_jobs=4, max_per_tenant=1, tight_slack_s=1.0)
    assert [j.tenant for j in out] == ["slo"]  # solo: refuses the merge
    assert q.pending() == 2
    # an extension pop (band=...) must leave a tight job queued
    q.push(_djob(3, "slo", deadline_s=0.2))
    more = q.pop_round(max_jobs=4, max_per_tenant=1,
                       band=int(Priority.BATCH), tight_slack_s=1.0)
    assert all(j.deadline_s is None for j in more)
    assert q.pending() == 1


def test_deadline_blind_queue_records_but_ignores_deadlines():
    q = FairQueue(deadline_aware=False)
    q.push(_djob(0, "bulk"))
    q.push(_djob(1, "slo", deadline_s=1e-9))
    time.sleep(0.002)
    out = q.pop_round(max_jobs=4, max_per_tenant=1, tight_slack_s=1.0)
    assert [j.id for j in out] == [0, 1]       # RR order, nothing shed
    assert out[1].deadline_t is not None       # deadline still recorded


def test_deadline_free_jobs_schedule_exactly_as_before():
    q = FairQueue()
    for i, tenant in enumerate(("a", "b", "a", "c")):
        q.push(_djob(i, tenant))
    out = q.pop_round(max_jobs=3, max_per_tenant=1, tight_slack_s=0.25)
    assert [j.tenant for j in out] == ["a", "b", "c"]
    assert q.pending() == 1


def test_service_deadline_attainment_telemetry_and_shed():
    from repro.service import DeadlineExceeded
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0)
    try:
        ses = svc.session("t")
        _, rep = ses.submit(_batch(n_rows=1000), deadline_s=120,
                            tags=("probe",)).result(timeout=60)
        assert rep.deadline_met is True
        assert rep.deadline_s == 120
        assert rep.tags == ("probe",)
        with pytest.raises(DeadlineExceeded):
            ses.submit(_batch(n_rows=1000), deadline_s=1e-9
                       ).result(timeout=60)
        snap = svc.telemetry.snapshot()["t"]
        assert snap["deadline_jobs"] == 2
        assert snap["deadline_met"] == 1
        assert snap["deadline_shed"] == 1
        g = svc.telemetry.global_snapshot()
        assert g["deadline"] == {"jobs": 2, "met": 1, "shed": 1,
                                 "attainment": 0.5}
        assert "deadlines:" in svc.telemetry.report()
    finally:
        svc.stop()


def test_jobs_without_deadlines_leave_attainment_at_one():
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0)
    try:
        svc.session("t").submit(_batch(n_rows=1000)).result(timeout=60)
        g = svc.telemetry.global_snapshot()
        assert g["deadline"]["jobs"] == 0
        assert g["deadline"]["attainment"] == 1.0
    finally:
        svc.stop()


def test_deadline_total_accounting_across_operations():
    """The O(0)-when-unused fast path depends on the deadline-job counter
    staying exact across push/pop/cancel/shed/requeue/close."""
    q = FairQueue()
    assert q._deadline_total == 0
    jobs = [_djob(0, "t", deadline_s=60.0), _djob(1, "t"),
            _djob(2, "u", deadline_s=1e-9), _djob(3, "u", deadline_s=60.0)]
    for j in jobs:
        q.push(j)
    assert q._deadline_total == 3
    time.sleep(0.002)
    out = q.pop_round(max_jobs=1, max_per_tenant=1)   # sheds #2, takes #0
    assert [j.id for j in out] == [0]
    assert q._deadline_total == 1
    q.requeue(out)
    assert q._deadline_total == 2
    assert q.cancel(3) is True
    assert q._deadline_total == 1
    q.close()
    assert q._deadline_total == 0
    q.reopen()


def test_session_options_tenant_override_attributes_correctly():
    from repro.client import SubmitOptions
    svc = StratumService(memory_budget_bytes=1 << 30, n_executors=1,
                         coalesce_window_s=0.0)
    try:
        ses = svc.session("default-tenant")
        ses.submit(_batch(n_rows=1000),
                   options=SubmitOptions(tenant="override-tenant")
                   ).result(timeout=60)
        snap = svc.telemetry.snapshot()
        assert "override-tenant" in snap
        assert "default-tenant" not in snap
    finally:
        svc.stop()
