"""Fault tolerance: atomic checkpoints, auto-resume, preemption, straggler
detection, deterministic data sharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, find_latest, load_checkpoint,
                        save_checkpoint)
from repro.data.lm import DataConfig, global_batch_at, shard_batch_at
from repro.launch.train import build_trainer
from repro.train.loop import PreemptionError


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extras={"note": "hi"})
    assert find_latest(str(tmp_path)) == 7
    restored, manifest = load_checkpoint(str(tmp_path), 7, t)
    assert manifest["extras"]["note"] == "hi"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    # simulate a crash mid-write: later step without COMMIT
    d = tmp_path / "step_00000009"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert find_latest(str(tmp_path)) == 3


def test_manager_gc_keeps_last_k(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        m.save(s, _tree())
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_async_checkpoint(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    m.save(5, _tree())
    m.wait()
    assert m.latest() == 5


# ---------------------------------------------------------------------------
# training loop fault tolerance (end-to-end, single device, reduced model)
# ---------------------------------------------------------------------------

def test_preemption_then_resume(tmp_path):
    kwargs = dict(use_reduced=True, seq_len=16, global_batch=4,
                  total_steps=10, ckpt_every=3, ckpt_dir=str(tmp_path))
    loop = build_trainer("qwen2-7b", inject_preemption_at=5, **kwargs)
    with pytest.raises(PreemptionError):
        loop.run()
    assert find_latest(str(tmp_path)) == 5

    loop2 = build_trainer("qwen2-7b", **kwargs)
    state = loop2.run()
    assert state.resumed_from == 5
    assert state.step == 10
    assert all(np.isfinite(state.losses))


def test_straggler_detection(tmp_path):
    import time
    loop = build_trainer("qwen2-7b", use_reduced=True, seq_len=16,
                         global_batch=4, total_steps=8, ckpt_every=100,
                         ckpt_dir=str(tmp_path))
    events = []
    loop.on_straggler = lambda step, dt: events.append(step)
    orig = loop.batch_fn

    def slow_batch(step):
        if step == 6:
            time.sleep(1.5)          # inject a straggling step
        return orig(step)

    loop.batch_fn = slow_batch
    state = loop.run()
    assert any(s == 6 for s, _ in state.stragglers) or events


# ---------------------------------------------------------------------------
# deterministic step-indexed data sharding
# ---------------------------------------------------------------------------

def test_data_is_step_indexed_and_shardable():
    cfg = DataConfig(vocab=256, seq_len=16, global_batch=8, microbatches=2)
    b1 = global_batch_at(cfg, step=4)
    b2 = global_batch_at(cfg, step=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = global_batch_at(cfg, step=5)
    assert not np.array_equal(b1["tokens"], b3["tokens"])

    # shards partition the global batch exactly
    shards = [shard_batch_at(cfg, 4, s, 4) for s in range(4)]
    reassembled = np.concatenate([s["tokens"] for s in shards], axis=1)
    np.testing.assert_array_equal(reassembled, b1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    b = global_batch_at(cfg, 0)
    assert b["tokens"].shape == (1, 2, 8)
    # same underlying stream: labels[t] == tokens[t+1]
    np.testing.assert_array_equal(b["tokens"][..., 1:], b["labels"][..., :-1])
