import os

# Tests must see exactly ONE device (the dry-run sets its own flags in a
# separate process; see src/repro/launch/dryrun.py).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "test process must not force a device count"

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
