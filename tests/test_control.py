"""Closed-loop control: the feedback controller over the windowed
collector (control/), its queue actuation surface, the windowed per-band
attainment plumbing, the autoscaler's windowed-attainment trend, and the
observability of every actuation (telemetry block, top row, JSONL)."""

import json
import pickle

import pytest

from repro.service import (ControlPolicy, FairQueue, Priority,
                           ServiceController, StratumService,
                           merge_control_snapshots)
from repro.service.control.controller import CONTROL_TRACE_KEY
from repro.service.observability import (RETUNED, ThroughputCollector,
                                         TraceSink, merge_window_snapshots)
from repro.service.observability.replay import load_events, reassemble
from repro.service.observability.top import render
from repro.service.priority import DEFAULT_WEIGHTS
from repro.service.queue import AdmissionError, Job
from repro.service.session import PipelineFuture


class FakeClock:
    def __init__(self, t0=1000.0):
        self.t = t0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _job(i, tenant="t", priority=Priority.BATCH):
    return Job(id=i, tenant=tenant, batch=None,
               future=PipelineFuture(i, tenant, priority),
               priority=priority)


def _rig(clk=None, policy=None, **queue_kw):
    """A controller wired to a real queue + collector on a fake clock."""
    clk = clk or FakeClock()
    policy = policy or ControlPolicy()
    queue_kw.setdefault("max_queued_total", 128)
    queue = FairQueue(**queue_kw)
    windows = ThroughputCollector(window_s=1.0, n_windows=8, clock=clk)
    ctl = ServiceController(policy, queue, windows, clock=clk)
    return clk, policy, queue, windows, ctl


def _breach(windows, policy, n=None):
    """Feed enough slow dispatch samples to evidence a p99 breach."""
    for _ in range(n or policy.min_window_jobs):
        windows.record_dispatch(policy.dispatch_p99_target_s * 5,
                                queue_depth=50)


# ---------------------------------------------------------------------------
# policy hygiene
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        ControlPolicy(admission_decrease=1.5)
    with pytest.raises(ValueError):
        ControlPolicy(tick_interval_s=0)
    with pytest.raises(ValueError):
        ControlPolicy(weight_gain=0.5)
    with pytest.raises(ValueError):
        ControlPolicy(max_weight_factor=1.5, weight_gain=2.0)


def test_policy_is_picklable():
    # the policy crosses the proc-fabric CONFIG frame inside ServiceConfig
    p = ControlPolicy(dispatch_p99_target_s=0.25, interactive_reserve=4)
    assert pickle.loads(pickle.dumps(p)) == p


# ---------------------------------------------------------------------------
# gain / cooldown clamping
# ---------------------------------------------------------------------------

def test_shrink_is_multiplicative_and_cooldown_limited():
    clk, pol, queue, windows, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=1.0, cooldown_s=10.0,
                             dispatch_p99_target_s=0.1))
    _breach(windows, pol)
    assert ctl.maybe_tick()
    assert queue.max_queued_total == int(128 * pol.admission_decrease)
    assert ctl.admission_shrinks == 1
    # the breach persists, but the cooldown suppresses a second shrink
    clk.advance(1.0)
    _breach(windows, pol)
    ctl.maybe_tick()
    assert ctl.admission_shrinks == 1
    assert queue.max_queued_total == 64
    # past the cooldown the next shrink lands
    clk.advance(10.0)
    _breach(windows, pol)
    ctl.maybe_tick()
    assert ctl.admission_shrinks == 2
    assert queue.max_queued_total == 32


def test_tick_interval_rate_limits():
    clk, pol, _q, _w, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=5.0))
    assert ctl.maybe_tick()
    assert not ctl.maybe_tick()     # same instant: rate-limited
    clk.advance(4.9)
    assert not ctl.maybe_tick()
    clk.advance(0.2)
    assert ctl.maybe_tick()


def test_shrink_floor_never_crossed():
    clk, pol, queue, windows, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=1.0, cooldown_s=0.0,
                             dispatch_p99_target_s=0.1,
                             min_queued_total=8))
    for _ in range(30):
        _breach(windows, pol)
        ctl.maybe_tick()
        clk.advance(1.0)
    assert queue.max_queued_total == 8
    # once floored, further breaches are not counted as actuations
    shrinks = ctl.admission_shrinks
    _breach(windows, pol)
    ctl.maybe_tick()
    assert ctl.admission_shrinks == shrinks


def test_weight_boost_capped_at_max_factor():
    clk, pol, queue, windows, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=1.0, cooldown_s=0.0,
                             weight_gain=2.0, max_weight_factor=8.0))
    base = queue.weights[Priority.SCAVENGER]
    for _ in range(10):
        windows.record_deadline_outcome(False,
                                        band=int(Priority.SCAVENGER))
        ctl.maybe_tick()
        clk.advance(1.0)
    assert queue.weights[Priority.SCAVENGER] == base * 8.0
    boosts = ctl.weight_boosts
    windows.record_deadline_outcome(False, band=int(Priority.SCAVENGER))
    ctl.maybe_tick()
    assert ctl.weight_boosts == boosts      # capped: no further actuation


# ---------------------------------------------------------------------------
# floor clamp: INTERACTIVE is never starved of admission
# ---------------------------------------------------------------------------

def test_interactive_reserve_bypasses_full_queue():
    _clk, pol, queue, _w, _ctl = _rig(
        policy=ControlPolicy(interactive_reserve=4),
        max_queued_total=16, aging_s=None)
    for i in range(16):
        queue.push(_job(i, tenant=f"bulk{i % 3}"))
    with pytest.raises(AdmissionError):
        queue.push(_job(100, tenant="bulk0"))       # BATCH: queue full
    for i in range(4):                              # reserve admits these
        queue.push(_job(200 + i, tenant="probe",
                        priority=Priority.INTERACTIVE))
    with pytest.raises(AdmissionError):             # reserve itself full
        queue.push(_job(300, tenant="probe",
                        priority=Priority.INTERACTIVE))
    # serving the probes frees the reserve again
    served = queue.pop_round(max_jobs=4, max_per_tenant=4)
    assert all(j.priority == Priority.INTERACTIVE for j in served)
    queue.push(_job(301, tenant="probe", priority=Priority.INTERACTIVE))


def test_reserve_respects_tenant_quota():
    _clk, pol, queue, _w, _ctl = _rig(
        policy=ControlPolicy(interactive_reserve=8),
        max_queued_total=4, max_queued_per_tenant=2)
    for i in range(4):
        queue.push(_job(i, tenant=f"bulk{i}"))
    queue.push(_job(10, tenant="p", priority=Priority.INTERACTIVE))
    queue.push(_job(11, tenant="p", priority=Priority.INTERACTIVE))
    with pytest.raises(AdmissionError):     # reserve never overrides quota
        queue.push(_job(12, tenant="p", priority=Priority.INTERACTIVE))


def test_band_limits_gate_bulk_only():
    queue = FairQueue(max_queued_total=64)
    queue.set_limits(band_limits={int(Priority.BATCH): 2},
                     reserve_interactive=2)
    queue.push(_job(0, tenant="b"))
    queue.push(_job(1, tenant="b2"))
    with pytest.raises(AdmissionError) as ei:
        queue.push(_job(2, tenant="b3"))
    assert "gated" in str(ei.value)
    # INTERACTIVE is not band-limited
    queue.push(_job(3, tenant="p", priority=Priority.INTERACTIVE))


# ---------------------------------------------------------------------------
# decay back to defaults when pressure clears
# ---------------------------------------------------------------------------

def test_admission_regrows_additively_to_configured_default():
    clk, pol, queue, windows, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=1.0, cooldown_s=0.0,
                             dispatch_p99_target_s=0.5,
                             admission_increase=16))
    _breach(windows, pol)
    ctl.maybe_tick()
    assert queue.max_queued_total == 64
    assert queue.band_limits        # bulk bands gated while shrunk
    # pressure clears: the breach samples age out of the 8-window ring
    clk.advance(20.0)
    regrown = []
    for _ in range(8):
        clk.advance(1.0)
        ctl.maybe_tick()
        regrown.append(queue.max_queued_total)
    assert regrown == [80, 96, 112, 128, 128, 128, 128, 128]
    assert ctl.admission_regrows == 4   # stops actuating at the default
    assert queue.band_limits == {}      # gate lifted with the limits
    assert queue.reserve_interactive == pol.interactive_reserve


def test_weights_decay_back_to_defaults():
    clk, pol, queue, windows, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=1.0, cooldown_s=0.0))
    base = dict(queue.weights)
    windows.record_deadline_outcome(False, band=int(Priority.BATCH))
    ctl.maybe_tick()
    assert queue.weights[Priority.BATCH] == base[Priority.BATCH] * 2.0
    clk.advance(20.0)                   # sag evidence ages out of the ring
    for _ in range(20):
        clk.advance(1.0)
        ctl.maybe_tick()
    assert queue.weights == {k: float(v) for k, v in base.items()}
    snap = ctl.snapshot()
    assert snap["weights"]["factors"] == {}     # nothing boosted anymore


# ---------------------------------------------------------------------------
# idle-gap windows never cause spurious retunes
# ---------------------------------------------------------------------------

def test_idle_windows_trigger_no_retunes():
    clk, pol, queue, windows, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=1.0))
    for _ in range(50):                 # a long idle stretch of empty ticks
        clk.advance(1.0)
        ctl.maybe_tick()
    assert ctl.retunes == 0
    assert queue.max_queued_total == 128
    assert queue.weights == dict(DEFAULT_WEIGHTS)


def test_thin_window_is_no_breach_evidence():
    # fewer than min_window_jobs samples — even arbitrarily slow ones —
    # must not shrink the gate
    clk, pol, queue, windows, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=1.0, min_window_jobs=4))
    for _ in range(3):
        windows.record_dispatch(100.0)
    ctl.maybe_tick()
    assert ctl.admission_shrinks == 0
    assert queue.max_queued_total == 128


# ---------------------------------------------------------------------------
# every actuation is observable
# ---------------------------------------------------------------------------

def test_actuations_emit_retuned_hops_to_jsonl(tmp_path):
    clk = FakeClock()
    pol = ControlPolicy(tick_interval_s=1.0, cooldown_s=0.0,
                        dispatch_p99_target_s=0.1)
    queue = FairQueue(max_queued_total=128)
    windows = ThroughputCollector(window_s=1.0, n_windows=8, clock=clk)
    sink = TraceSink(trace_dir=str(tmp_path), component="ctl-test",
                     enabled=True)
    ctl = ServiceController(pol, queue, windows, trace_sink=sink,
                            shard_id="s0", clock=clk)
    _breach(windows, pol)
    ctl.maybe_tick()
    windows.record_deadline_outcome(False, band=int(Priority.BATCH))
    clk.advance(1.0)
    ctl.maybe_tick()
    sink.close()
    recs = load_events(str(tmp_path))
    retuned = [r for r in recs if r["event"] == RETUNED]
    assert retuned and all(r["job"] == CONTROL_TRACE_KEY for r in retuned)
    knobs = {r["detail"]["knob"] for r in retuned}
    assert "admission" in knobs and "weights" in knobs
    assert all(r["shard"] == "s0" for r in retuned)
    # and the JSONL replays: the control timeline reassembles like a job's
    timelines = reassemble(recs)
    events = {r["event"] for r in timelines[CONTROL_TRACE_KEY]}
    assert events == {RETUNED}


def test_snapshot_and_top_render_show_control_state():
    clk, pol, queue, windows, ctl = _rig(
        policy=ControlPolicy(tick_interval_s=1.0, cooldown_s=0.0,
                             dispatch_p99_target_s=0.1))
    _breach(windows, pol)
    ctl.maybe_tick()
    snap = ctl.snapshot()
    assert snap["retunes"] == 1
    assert snap["admission"]["gated"]
    assert snap["admission"]["max_queued_total"] == 64
    assert snap["last_actions"][-1]["knob"] == "admission"
    frame = render({"jobs_submitted": 1, "control": snap})
    assert "control:" in frame and "GATED" in frame
    # the fabric-merged form renders too
    merged = merge_control_snapshots([snap, snap])
    assert merged["retunes"] == 2 and merged["gated_shards"] == 2
    assert "shards gated" in render({"control": merged})


def test_service_global_snapshot_carries_control_block():
    svc = StratumService(memory_budget_bytes=1 << 28,
                         control=ControlPolicy(), autostart=False)
    try:
        g = svc.telemetry.global_snapshot()
        assert g["control"]["admission"]["configured_max_queued_total"] \
            == svc.config.max_queued_total
        assert svc.queue.reserve_interactive \
            == svc.config.control.interactive_reserve
    finally:
        svc.stop(drain=False)


def test_control_off_means_no_controller_and_no_block():
    svc = StratumService(memory_budget_bytes=1 << 28, autostart=False)
    try:
        assert svc.controller is None
        assert "control" not in svc.telemetry.global_snapshot()
        assert svc.queue.reserve_interactive == 0
    finally:
        svc.stop(drain=False)


# ---------------------------------------------------------------------------
# per-band windowed attainment (the rebalancer's sensor)
# ---------------------------------------------------------------------------

def test_windows_by_band_accumulates_and_merges():
    clk = FakeClock()
    w = ThroughputCollector(window_s=1.0, n_windows=4, clock=clk)
    w.record_deadline_outcome(True, band=0)
    w.record_deadline_outcome(False, band=1)
    w.record_deadline_outcome(False, band=1)
    snap = w.snapshot()
    assert snap["by_band"][0] == {"deadline_jobs": 1, "deadline_met": 1,
                                  "attainment": 1.0}
    assert snap["by_band"][1]["attainment"] == 0.0
    # merge normalizes string band keys (JSON/heartbeat round-trips)
    other = json.loads(json.dumps(snap))
    merged = merge_window_snapshots([snap, other])
    assert merged["by_band"][1]["deadline_jobs"] == 4
    assert set(merged["by_band"]) == {0, 1}


def test_bandless_outcomes_skip_by_band():
    w = ThroughputCollector(window_s=1.0, n_windows=4)
    w.record_deadline_outcome(True)
    assert "by_band" not in w.snapshot()


# ---------------------------------------------------------------------------
# autoscaler: windowed attainment trend, not instantaneous whipsaw
# ---------------------------------------------------------------------------

class _FakeFabric:
    """Just enough fabric surface for Autoscaler._tick."""

    def __init__(self, windows_seq):
        self._windows_seq = list(windows_seq)
        self.added = []
        self.router = type("R", (), {"pending_count": lambda *a: 3})()
        self.telemetry = self

    def shard_ids(self):
        return ["s0"]

    def shards(self):
        return {}

    def global_snapshot(self):
        win = (self._windows_seq.pop(0) if self._windows_seq
               else {"deadline_jobs": 0})
        return {"windows": win}

    def add_shard(self, sid):
        self.added.append(sid)

    def newest_shard(self):
        return None


def _scaler(fabric, trend_len=3):
    from repro.service.fabric.proc.autoscale import (Autoscaler,
                                                     AutoscalePolicy)
    pol = AutoscalePolicy(min_shards=1, max_shards=4,
                          scale_up_backlog_per_shard=100.0,
                          attainment_floor=0.9,
                          attainment_trend_len=trend_len,
                          scale_up_cooldown_s=0.0)
    return Autoscaler(fabric, pol)     # never start()ed: we call _tick


def test_autoscaler_needs_a_sustained_windowed_sag():
    sag = {"deadline_jobs": 5, "attainment": 0.5}
    fab = _FakeFabric([sag, sag, sag, sag])
    sc = _scaler(fab, trend_len=3)
    sc._tick()
    sc._tick()
    assert fab.added == []          # two sags: trend not established yet
    sc._tick()
    assert fab.added == ["auto-1"]  # third consecutive sag scales up
    # trend restarts after the spawn — the next single sag is not enough
    sc._tick()
    assert fab.added == ["auto-1"]


def test_autoscaler_ignores_single_window_whipsaw():
    # one bad window between good ones — the classic between-heartbeats
    # burst — must not spawn a worker
    good = {"deadline_jobs": 5, "attainment": 1.0}
    bad = {"deadline_jobs": 5, "attainment": 0.2}
    fab = _FakeFabric([good, bad, good, bad, good, bad])
    sc = _scaler(fab, trend_len=3)
    for _ in range(6):
        sc._tick()
    assert fab.added == []


def test_autoscaler_trend_clears_without_slo_evidence():
    sag = {"deadline_jobs": 5, "attainment": 0.5}
    idle = {"deadline_jobs": 0}
    fab = _FakeFabric([sag, sag, idle, sag])
    sc = _scaler(fab, trend_len=3)
    for _ in range(4):
        sc._tick()
    assert fab.added == []          # the idle window reset the trend


# ---------------------------------------------------------------------------
# controlled-vs-static equivalence when no target is ever crossed
# ---------------------------------------------------------------------------

def test_controlled_equals_static_when_targets_never_crossed():
    import repro.tabular as T
    from repro.core import PipelineBatch
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", 2000, 0)

    def _batch(i):
        x = T.read("uk_housing", 2000, seed=0)
        xs = T.scale(T.impute(T.project(x, [10, 11, 12 + (i % 3)])))
        sink = T.metric(T.project(xs, [0]), T.project(x, [0]), kind="mae")
        return PipelineBatch([sink], [f"p{i}"])

    # targets far beyond anything this workload can reach
    calm = ControlPolicy(dispatch_p99_target_s=1e6, attainment_floor=0.01)
    results = {}
    for label, control in (("static", None), ("controlled", calm)):
        svc = StratumService(memory_budget_bytes=1 << 28, control=control)
        try:
            ses = svc.session("a")
            futs = [ses.submit(_batch(i)) for i in range(6)]
            results[label] = [float(list(f.result(timeout=60)[0]
                                         .values())[0]) for f in futs]
        finally:
            svc.stop()
        if control is not None:
            assert svc.controller.retunes == 0      # nothing to retune
            assert svc.queue.max_queued_total \
                == svc.config.max_queued_total
            assert dict(svc.queue.weights) == dict(DEFAULT_WEIGHTS)
    assert results["controlled"] == results["static"]
