"""Benchmark harness: exit-code propagation and the CI regression gate.

The CI bench job can only be trusted if ``benchmarks.run`` reliably exits
nonzero when a section fails — including the sneaky case of a section
raising ``SystemExit(0)`` mid-run, which ``except Exception`` would let
sail through as success."""

import sys

import pytest

from benchmarks import run as bench_run
from benchmarks.check_regression import check


# ---------------------------------------------------------------------------
# benchmarks.run exit codes
# ---------------------------------------------------------------------------

def test_unknown_section_exits_nonzero(capsys):
    assert bench_run.main(["--sections", "no_such_section"]) == 1
    out = capsys.readouterr().out
    assert "no_such_section,ERROR" in out


def test_crashing_section_exits_nonzero_but_others_still_run(capsys,
                                                            monkeypatch):
    monkeypatch.setitem(bench_run.SECTIONS, "boom",
                        lambda args: (_ for _ in ()).throw(RuntimeError("x")))
    monkeypatch.setitem(bench_run.SECTIONS, "fine",
                        lambda args: [("ok_row", 1.0, "d")])
    assert bench_run.main(["--sections", "boom,fine"]) == 1
    out = capsys.readouterr().out
    assert "boom,ERROR" in out
    assert "ok_row,1.0,d" in out          # later sections still executed


def test_section_calling_sys_exit_zero_is_a_failure(capsys, monkeypatch):
    def exits(args):
        sys.exit(0)                       # must NOT vouch for the harness
    monkeypatch.setitem(bench_run.SECTIONS, "exiter", exits)
    assert bench_run.main(["--sections", "exiter"]) == 1
    assert "exiter,ERROR" in capsys.readouterr().out


def test_all_sections_ok_exits_zero(monkeypatch):
    monkeypatch.setitem(bench_run.SECTIONS, "fine",
                        lambda args: [("row", 1.0, "")])
    assert bench_run.main(["--sections", "fine"]) == 0


# ---------------------------------------------------------------------------
# check_regression gate logic
# ---------------------------------------------------------------------------

BASE = {"service_smoke": {"speedup": 2.0}, "sharded_smoke": {"speedup": 3.0}}


def test_gate_passes_within_tolerance_and_on_improvement():
    fresh = {"service_smoke": {"speedup": 1.7},   # -15% < 20% tolerance
             "sharded_smoke": {"speedup": 4.0}}   # improvement
    assert check(BASE, fresh, 0.20) == []


def test_gate_fails_on_regression_beyond_tolerance():
    fresh = {"service_smoke": {"speedup": 1.5},   # -25%
             "sharded_smoke": {"speedup": 3.0}}
    failures = check(BASE, fresh, 0.20)
    assert len(failures) == 1 and "service_smoke.speedup" in failures[0]


def test_gate_fails_when_fresh_metric_missing():
    fresh = {"service_smoke": {"speedup": 2.0}}   # sharded crashed/skipped
    failures = check(BASE, fresh, 0.20)
    assert any("missing from fresh" in f for f in failures)


def test_gate_skips_metrics_absent_from_baseline():
    base = {"sharded_smoke": {"speedup": 3.0}}    # no service baseline yet
    fresh = {"sharded_smoke": {"speedup": 2.9}}
    assert check(base, fresh, 0.20) == []


def test_gate_refuses_empty_baseline():
    failures = check({}, {}, 0.20)
    assert any("nothing" in f for f in failures)


def test_committed_baseline_contains_gated_smoke_metrics():
    """The CI gate is only meaningful if the repo ships the baselines."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_service.json")
    with open(path) as f:
        baseline = json.load(f)
    assert baseline["sharded_smoke"]["speedup"] > 0
    assert baseline["service_smoke"]["speedup"] > 0
    assert baseline["compiled_smoke"]["speedup"] > 0
    # the tentpole acceptance datapoint: >=2x aggregate throughput at
    # 4 shards / 16 agents with identical pipeline scores
    assert baseline["sharded"]["speedup"] >= 2.0
    assert baseline["sharded"]["scores_identical"] is True
    assert baseline["sharded"]["agents"] == 16
    # compiled plan-segment acceptance: >=2x over per-op dispatch on the
    # repeated-structure workload, identical scores, warm plan cache
    assert baseline["compiled"]["speedup"] >= 2.0
    assert baseline["compiled"]["scores_identical"] is True
    assert baseline["compiled"]["plan_cache_hit_rate"] > 0.5


@pytest.mark.parametrize("argv_exit", [(["--sections", "nope"], 1)])
def test_module_entrypoint_propagates_exit_code(argv_exit):
    import subprocess
    argv, expected = argv_exit
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *argv],
        capture_output=True, text=True, timeout=120,
        cwd=str(__import__("pathlib").Path(__file__).resolve().parent.parent),
        env={**__import__("os").environ,
             "PYTHONPATH": "src"})
    assert proc.returncode == expected
