"""Sharded execution fabric: consistent-hash ring properties, the
serializable envelope codec, routing/locality, failover (zero job loss),
rebalancing, telemetry aggregation, and the async AIDE driver on shards."""

import pickle

import numpy as np
import pytest

from repro.core import GENERIC, LazyOp, PipelineBatch
from repro.core.dag import toposort
from repro.core.runtime import ExecutionError
from repro.service import merge_tenant_snapshots
from repro.service.fabric import (CodecError, ConsistentHashRing,
                                  JobEnvelope, NoShardsError, ResultEnvelope,
                                  ShardedStratum, decode_job, decode_result,
                                  encode_job, encode_result, routing_key_for)
import repro.tabular as T


def _pipeline(n_rows=2000, cols=(10, 11, 12), kind="mae", data_seed=0):
    x = T.read("uk_housing", n_rows, seed=data_seed)
    xs = T.scale(T.impute(T.project(x, list(cols))))
    y = T.project(x, [0])
    return T.metric(T.project(xs, [0]), y, kind=kind)


def _batch(name="p", **kw):
    return PipelineBatch([_pipeline(**kw)], [name])


def _boom(*_a, **_k):
    raise ValueError("poisoned op")


def _fabric(n_shards=2, **kw):
    kw.setdefault("memory_budget_bytes", 1 << 30)
    kw.setdefault("n_executors", 1)
    kw.setdefault("coalesce_window_s", 0.0)
    return ShardedStratum(n_shards=n_shards, **kw)


# ---------------------------------------------------------------------------
# consistent-hash ring
# ---------------------------------------------------------------------------

KEYS = [f"key-{i}" for i in range(2000)]


def test_ring_routing_is_deterministic_across_instances():
    a = ConsistentHashRing(["s0", "s1", "s2"], vnodes=32)
    b = ConsistentHashRing(["s0", "s1", "s2"], vnodes=32)
    assert [a.route(k) for k in KEYS] == [b.route(k) for k in KEYS]
    # every shard owns a nontrivial share (vnodes spread the arcs)
    counts = {n: 0 for n in a.nodes()}
    for k in KEYS:
        counts[a.route(k)] += 1
    assert min(counts.values()) > len(KEYS) * 0.1


def test_ring_add_moves_at_most_bounded_fraction_and_only_to_new_node():
    ring = ConsistentHashRing([f"s{i}" for i in range(4)], vnodes=64)
    before = {k: ring.route(k) for k in KEYS}
    ring.add("s4")
    moved = {k for k in KEYS if ring.route(k) != before[k]}
    # expected K/N = K/5; generous 2x slack for hash variance
    assert len(moved) <= 2 * len(KEYS) / 5
    assert moved, "a new shard must take over some keys"
    # consistent hashing's defining property: keys only move TO the joiner
    assert all(ring.route(k) == "s4" for k in moved)


def test_ring_remove_remaps_only_the_removed_nodes_keys():
    ring = ConsistentHashRing([f"s{i}" for i in range(4)], vnodes=64)
    before = {k: ring.route(k) for k in KEYS}
    ring.remove("s2")
    for k in KEYS:
        if before[k] == "s2":
            assert ring.route(k) != "s2"
        else:
            assert ring.route(k) == before[k]


def test_ring_successors_distinct_and_respect_exclusion():
    ring = ConsistentHashRing([f"s{i}" for i in range(5)], vnodes=16)
    succ = list(ring.successors("some-key"))
    assert sorted(succ) == sorted(ring.nodes())     # all, each once
    assert succ[0] == ring.route("some-key")
    excl = list(ring.successors("some-key", exclude={"s1", "s3"}))
    assert "s1" not in excl and "s3" not in excl and len(excl) == 3


def test_ring_membership_errors():
    ring = ConsistentHashRing(["s0"])
    with pytest.raises(ValueError):
        ring.add("s0")
    with pytest.raises(KeyError):
        ring.remove("nope")
    ring.remove("s0")
    with pytest.raises(LookupError):
        ring.route("k")


# ---------------------------------------------------------------------------
# envelope codec — the serializable submission boundary
# ---------------------------------------------------------------------------

def test_job_envelope_round_trip_preserves_signatures_with_fresh_uids():
    batch = _batch()
    env = JobEnvelope(envelope_id="e-1", tenant="t", priority=0,
                      routing_key=routing_key_for(batch), batch=batch)
    out = decode_job(encode_job(env))
    assert (out.envelope_id, out.tenant, out.priority, out.routing_key) \
        == ("e-1", "t", 0, env.routing_key)
    assert out.batch.names == batch.names
    # content signatures survive bit-exactly (CSE/cache keys intact) ...
    assert [r.signature for r in out.batch.sinks] \
        == [r.signature for r in batch.sinks]
    # ... but every op is re-identified: no uid crosses the boundary, so
    # envelopes from different origin processes can't collide on a shard
    old_uids = {op.uid for op in toposort(batch.sinks)}
    new_uids = {op.uid for op in toposort(out.batch.sinks)}
    assert old_uids.isdisjoint(new_uids)


def test_codec_rejects_corruption_and_wrong_kind():
    data = encode_job(JobEnvelope("e", "t", 1, "rk", _batch()))
    flipped = data[:30] + bytes([data[30] ^ 0xFF]) + data[31:]
    with pytest.raises(CodecError):
        decode_job(flipped)
    with pytest.raises(CodecError):
        decode_result(data)          # job frame fed to the result decoder
    with pytest.raises(CodecError):
        decode_job(b"not a frame at all")


def test_result_envelope_round_trip_hosts_arrays_and_carries_errors():
    import jax.numpy as jnp
    ok = ResultEnvelope(envelope_id="e", tenant="t", shard_id="s", ok=True,
                        results={"p": jnp.arange(4.0)})
    out = decode_result(encode_result(ok))
    assert isinstance(out.results["p"], np.ndarray)
    np.testing.assert_allclose(out.results["p"], [0.0, 1.0, 2.0, 3.0])

    op = LazyOp("boom", GENERIC, spec={"fn": _boom})
    err = ExecutionError(op, ValueError("poisoned op"))
    bad = decode_result(encode_result(ResultEnvelope(
        envelope_id="e", tenant="t", shard_id="s", ok=False, error=err)))
    assert isinstance(bad.error, ExecutionError)
    assert isinstance(bad.error.cause, ValueError)
    assert bad.error.op.op_name == "boom"


def test_execution_error_pickles_directly():
    op = LazyOp("boom", GENERIC, spec={"fn": _boom})
    e = pickle.loads(pickle.dumps(ExecutionError(op, ValueError("x"))))
    assert isinstance(e.cause, ValueError) and e.op.op_name == "boom"


def test_routing_key_groups_by_source_not_by_sink():
    a = _batch(kind="mae")
    b = _batch(kind="rmse")            # same dataset, different pipeline
    c = _batch(data_seed=3)            # different dataset read
    assert routing_key_for(a) == routing_key_for(b)
    assert routing_key_for(a) != routing_key_for(c)
    # "batch" policy keys on the full sink set instead
    assert routing_key_for(a, "batch") != routing_key_for(b, "batch")
    with pytest.raises(ValueError):
        routing_key_for(a, "bogus")


# ---------------------------------------------------------------------------
# fabric end-to-end
# ---------------------------------------------------------------------------

def test_fabric_executes_and_all_traffic_crosses_the_codec():
    fab = _fabric(n_shards=3)
    try:
        from repro.core import Stratum
        ref, _ = Stratum(memory_budget_bytes=1 << 30).run_batch(_batch())
        ref_val = float(np.asarray(ref["p"]))

        futs = [fab.session(f"t{i}").submit(_batch()) for i in range(3)]
        for f in futs:
            results, report = f.result(timeout=120)
            assert float(np.asarray(results["p"])) \
                == pytest.approx(ref_val, rel=1e-6)
            assert report.shard_id.startswith("shard-")
            # the wire gave us host arrays, not device buffers
            assert isinstance(results["p"], np.ndarray)
        # every submission and every reply crossed the byte codec
        transports = fab.router._transports.values()
        assert sum(t.jobs_received for t in transports) == 3
        assert sum(t.results_sent for t in transports) == 3
        assert all(t.bytes_in > 0 or t.jobs_received == 0
                   for t in transports)
    finally:
        fab.stop()


def test_identical_sources_land_on_one_shard_and_share_work():
    fab = _fabric(n_shards=4, coalesce_window_s=0.05, autostart=False)
    try:
        f1 = fab.session("a").submit(_batch())
        f2 = fab.session("b").submit(_batch(kind="rmse"))
        fab.start()
        f1.result(timeout=120), f2.result(timeout=120)
        g = fab.telemetry.global_snapshot()
        routed = [s["envelopes_routed"] for s in g["per_shard"].values()]
        assert sorted(routed) == [0, 0, 0, 2]      # co-located by source
        assert g["ops_deduped_cross_agent"] > 0    # per-shard CSE survived
        # locality is measured over repeat keys only: the second
        # occurrence landed where the first did, and a stable ring is 1.0
        assert g["signature_locality_hit_rate"] == pytest.approx(1.0)
    finally:
        fab.stop()


def test_affinity_overrides_content_routing():
    fab = _fabric(n_shards=4, autostart=False)
    try:
        # different datasets would normally spread; affinity pins them
        f1 = fab.session("a").submit(_batch(data_seed=1), affinity="pin-me")
        f2 = fab.session("a").submit(_batch(data_seed=2), affinity="pin-me")
        fab.start()
        f1.result(timeout=120), f2.result(timeout=120)
        routed = [s["envelopes_routed"] for s in
                  fab.telemetry.per_shard().values()]
        assert sorted(routed) == [0, 0, 0, 2]
    finally:
        fab.stop()


def test_admission_backpressure_raises_synchronously_from_submit():
    from repro.service import AdmissionError
    fab = _fabric(n_shards=1, autostart=False, max_queued_total=2)
    try:
        ses = fab.session("t")
        ses.submit(_batch())
        ses.submit(_batch(kind="rmse"))
        with pytest.raises(AdmissionError):    # Session.submit contract
            ses.submit(_batch(data_seed=9))
        assert fab.router.pending_count() == 2   # no leaked pending entry
    finally:
        fab.start()
        fab.stop()


def test_unencodable_batch_fails_future_without_leaking_pending():
    fab = _fabric(n_shards=1)
    try:
        bad = LazyOp("boom", GENERIC,
                     spec={"fn": lambda: None},     # lambdas don't pickle
                     inputs=(_pipeline(n_rows=500),)).out()
        fut = fab.session("t").submit(PipelineBatch([bad], ["bad"]))
        with pytest.raises(Exception):
            fut.result(timeout=10)
        assert fab.router.pending_count() == 0
    finally:
        fab.stop()


def test_execution_error_crosses_the_boundary_with_cause():
    fab = _fabric(n_shards=2)
    try:
        sink = LazyOp("boom", GENERIC, spec={"fn": _boom},
                      inputs=(_pipeline(n_rows=500),)).out()
        fut = fab.session("t").submit(PipelineBatch([sink], ["bad"]))
        with pytest.raises(ExecutionError) as ei:
            fut.result(timeout=120)
        assert isinstance(ei.value.cause, ValueError)
    finally:
        fab.stop()


# ---------------------------------------------------------------------------
# failover + rebalancing
# ---------------------------------------------------------------------------

def _key_for_shard(fab, shard_id: str, tag="k") -> str:
    """An affinity key that routes to ``shard_id`` on the current ring."""
    for i in range(10_000):
        key = f"{tag}-{i}"
        if fab.router._ring.route(key) == shard_id:
            return key
    raise AssertionError("no key found")  # pragma: no cover


def test_failover_requeues_all_inflight_zero_loss():
    fab = _fabric(n_shards=2, autostart=False)
    try:
        shard_ids = fab.shard_ids()
        victim, survivor = shard_ids[0], shard_ids[1]
        # queue jobs on BOTH shards (none running yet: autostart=False)
        n_victim, n_survivor = 3, 2
        futs = []
        for i in range(n_victim):
            futs.append(fab.session("t").submit(
                _batch(name="p", cols=(10 + i, 11, 12)),
                affinity=_key_for_shard(fab, victim, f"v{i}")))
        for i in range(n_survivor):
            futs.append(fab.session("t").submit(
                _batch(name="p", cols=(10, 11 + i, 13)),
                affinity=_key_for_shard(fab, survivor, f"s{i}")))
        assert fab.router.pending_count(victim) == n_victim
        requeued = fab.fail_shard(victim)
        assert requeued == n_victim
        fab.start()
        # ZERO jobs lost: every future resolves with a real result
        for f in futs:
            results, report = f.result(timeout=180)
            assert "p" in results
            assert report.shard_id == survivor
        g = fab.telemetry.global_snapshot()
        assert g["failover_requeues"] == n_victim
        assert g["shards_failed"] == 1
        assert fab.shard_ids() == [survivor]
        # the dead shard's history is retired, not erased: fabric-wide
        # counters stay monotone and include its routed envelopes
        assert g["per_shard"][victim]["retired"] is True
        assert g["envelopes_routed"] == n_victim + n_survivor + n_victim
        assert g["n_shards"] == 1
    finally:
        fab.stop()


def test_dead_transport_detected_on_send_and_fails_over():
    fab = _fabric(n_shards=2)
    try:
        victim = fab.shard_ids()[0]
        key = _key_for_shard(fab, victim)
        fab.router._transports[victim].kill()   # crash without notice
        fut = fab.session("t").submit(_batch(), affinity=key)
        results, report = fut.result(timeout=120)
        assert "p" in results and report.shard_id != victim
        assert fab.router.shards_failed == 1
    finally:
        fab.stop()


def test_router_fail_shard_alone_silences_transport():
    # the crash model lives in the ROUTER: failing a shard through the
    # public router API (not the fabric wrapper) must silence its
    # transport so a still-running host can't answer for requeued work
    fab = _fabric(n_shards=2, autostart=False)
    try:
        victim = fab.shard_ids()[0]
        fut = fab.session("t").submit(
            _batch(), affinity=_key_for_shard(fab, victim))
        transport = fab.router._transports[victim]
        assert fab.router.fail_shard(victim) == 1
        assert transport._dead            # silenced by the router itself
        fab.start()
        results, report = fut.result(timeout=120)
        assert "p" in results and report.shard_id != victim
    finally:
        fab.stop()


def test_corrupted_reply_frame_is_counted_not_raised():
    fab = _fabric(n_shards=1)
    try:
        fab.router._on_result(b"garbage frame")      # must not raise
        assert fab.router.reply_codec_errors == 1
        g = fab.telemetry.global_snapshot()
        assert g["reply_codec_errors"] == 1
        # the fabric still serves normally afterwards
        r, _ = fab.session("t").submit(_batch()).result(timeout=120)
        assert "p" in r
    finally:
        fab.stop()


def test_all_shards_dead_raises_no_shards():
    fab = _fabric(n_shards=1, autostart=False)
    try:
        victim = fab.shard_ids()[0]
        fut = fab.session("t").submit(_batch())
        fab.fail_shard(victim)
        with pytest.raises(NoShardsError):
            fut.result(timeout=10)
    finally:
        fab.stop()


def test_drain_shard_reroutes_new_work_and_keeps_results():
    fab = _fabric(n_shards=2)
    try:
        first = fab.session("t").submit(_batch())
        first.result(timeout=120)
        victim = fab.shard_ids()[0]
        fab.drain_shard(victim, timeout=30)
        assert victim not in fab.shard_ids()
        # fabric still serves everything after the drain
        r, rep = fab.session("t").submit(_batch(kind="rmse")).result(
            timeout=120)
        assert "p" in r and rep.shard_id == fab.shard_ids()[0]
        g = fab.telemetry.global_snapshot()
        assert g["shards_drained"] == 1
        # drained shard's tenant history survives in the merged view
        assert fab.telemetry.snapshot()["t"]["jobs_completed"] == 2
    finally:
        fab.stop()


def test_add_shard_extends_ring_and_serves():
    fab = _fabric(n_shards=1)
    try:
        new = fab.add_shard()
        assert len(fab.shard_ids()) == 2
        key = _key_for_shard(fab, new)
        r, rep = fab.session("t").submit(_batch(), affinity=key).result(
            timeout=120)
        assert "p" in r and rep.shard_id == new
    finally:
        fab.stop()


# ---------------------------------------------------------------------------
# telemetry aggregation + drivers
# ---------------------------------------------------------------------------

def test_merge_tenant_snapshots_sums_and_maxes():
    a = {"t": {"jobs_completed": 1, "queue_wait_s": 0.5,
               "queue_wait_max_s": 0.5, "per_backend": {"jax": 2}}}
    b = {"t": {"jobs_completed": 2, "queue_wait_s": 0.25,
               "queue_wait_max_s": 0.75, "per_backend": {"jax": 1,
                                                         "python": 4}},
         "u": {"jobs_completed": 1, "queue_wait_s": 0.0,
               "queue_wait_max_s": 0.0, "per_backend": {}}}
    m = merge_tenant_snapshots([a, b])
    assert m["t"]["jobs_completed"] == 3
    assert m["t"]["queue_wait_s"] == pytest.approx(0.75)
    assert m["t"]["queue_wait_max_s"] == pytest.approx(0.75)
    assert m["t"]["per_backend"] == {"jax": 3, "python": 4}
    assert m["u"]["jobs_completed"] == 1


def test_session_telemetry_merges_across_shards():
    fab = _fabric(n_shards=3)
    try:
        ses = fab.session("t")
        ses.submit(_batch()).result(timeout=120)
        ses.submit(_batch(data_seed=5)).result(timeout=120)
        snap = ses.telemetry
        assert snap["jobs_completed"] == 2
        assert snap["jobs_submitted"] == 2
    finally:
        fab.stop()


def test_async_aide_search_on_fabric_with_shard_affinity():
    from repro.agents import AIDEAgent, AsyncAIDESearch
    fab = _fabric(n_shards=3, coalesce_window_s=0.02)
    try:
        agent = AIDEAgent(n_rows=2000, cv_k=2, seed=0)
        search = AsyncAIDESearch(fab.session("aide"), agent,
                                 batch_size=2, max_inflight=2,
                                 shard_affinity=True)
        best = search.run(n_rounds=2)
        assert best is not None and best.score is not None
        assert len(agent.nodes) == 4
        # affinity pinned the whole search to exactly one shard
        routed = [s["envelopes_routed"] for s in
                  fab.telemetry.per_shard().values()]
        assert sorted(routed) == [0, 0, 2]
        assert fab.telemetry.snapshot()["aide"]["jobs_completed"] == 2
    finally:
        fab.stop()


# ---------------------------------------------------------------------------
# shard-aware cancellation (CancelEnvelope through the codec)
# ---------------------------------------------------------------------------

def test_cancel_envelope_codec_round_trip():
    from repro.service.fabric import (CancelEnvelope, decode_cancel,
                                      encode_cancel)
    env = CancelEnvelope(envelope_id="c-7", tenant="t", attempt=2)
    out = decode_cancel(encode_cancel(env))
    assert (out.envelope_id, out.tenant, out.attempt) == ("c-7", "t", 2)
    with pytest.raises(CodecError):           # wrong kind
        decode_cancel(encode_job(JobEnvelope(
            envelope_id="x", tenant="t", priority=1, routing_key="k",
            batch=_batch())))


def test_fabric_cancel_removes_queued_work_on_owning_shard():
    from concurrent.futures import CancelledError
    fab = _fabric(n_shards=2, autostart=False)
    try:
        ses = fab.session("t")
        futs = [ses.submit(_batch(name=f"p{i}", data_seed=i),
                           affinity="pin") for i in range(3)]
        shard_depths = {sid: row["queue_depth"] for sid, row in
                        fab.telemetry.per_shard().items()}
        owner = max(shard_depths, key=shard_depths.get)
        assert shard_depths[owner] == 3       # all pinned to one shard
        assert futs[1].cancel() is True       # still queued: removed
        assert futs[1].cancelled()
        with pytest.raises(CancelledError):
            futs[1].result(timeout=5)
        # the job is gone from the OWNING SHARD's queue, not just local
        assert fab.telemetry.per_shard()[owner]["queue_depth"] == 2
        assert fab.router.pending_count() == 2   # no leaked pending entry
        g = fab.telemetry.global_snapshot()
        assert g["cancels_sent"] == 1 and g["cancels_confirmed"] == 1
        assert fab.telemetry.snapshot()["t"]["jobs_cancelled"] == 1
        fab.start()
        for f in (futs[0], futs[2]):          # survivors run to completion
            res, _ = f.result(timeout=120)
            assert all(np.isfinite(float(np.asarray(v)))
                       for v in res.values())
    finally:
        fab.start()
        fab.stop()


def test_fabric_cancel_after_completion_returns_false():
    fab = _fabric(n_shards=1)
    try:
        fut = fab.session("t").submit(_batch())
        fut.result(timeout=120)
        assert fut.cancel() is False          # nothing queued to remove
        assert not fut.cancelled()
        assert fab.router.cancel("no-such-envelope") is False
    finally:
        fab.stop()


# ---------------------------------------------------------------------------
# SubmitOptions on the wire: deadline/tags through the envelope codec
# ---------------------------------------------------------------------------

def test_job_envelope_carries_deadline_and_tags_through_the_codec():
    batch = _batch()
    env = JobEnvelope(envelope_id="e-1", tenant="t", priority=1,
                      routing_key=routing_key_for(batch), batch=batch,
                      deadline_s=1.5, tags=("probe", "r3"))
    out = decode_job(encode_job(env))
    assert out.deadline_s == 1.5
    assert out.tags == ("probe", "r3")
    # deadline_t is client-local state and must NOT cross the wire
    assert out.deadline_t is None
    # deadline-free envelopes stay deadline-free
    bare = decode_job(encode_job(JobEnvelope(
        envelope_id="e-2", tenant="t", priority=1,
        routing_key=env.routing_key, batch=batch)))
    assert bare.deadline_s is None and bare.tags == ()


def test_deadline_envelope_corruption_still_raises_codec_error():
    env = JobEnvelope("e", "t", 1, "rk", _batch(), deadline_s=2.0,
                      tags=("x",))
    data = encode_job(env)
    flipped = data[:40] + bytes([data[40] ^ 0xFF]) + data[41:]
    with pytest.raises(CodecError):
        decode_job(flipped)


def test_stale_attempt_reply_dropped_for_deadline_job():
    """A failover bumps the attempt; a stale reply from the dead shard
    must not resolve a deadline-carrying future."""
    from repro.service.fabric.envelope import FabricJobReport
    fab = _fabric(n_shards=1, autostart=False)
    try:
        fut = fab.session("t").submit(_batch(), deadline_s=300.0,
                                      tags=("slo",))
        (eid, pending), = fab.router._pending.items()
        assert pending.envelope.deadline_s is not None
        pending.envelope.attempt += 1            # as a failover would
        stale = encode_result(ResultEnvelope(
            envelope_id=eid, tenant="t", shard_id="s", ok=True,
            results={"p": np.zeros(1)},
            report=FabricJobReport(tenant="t", envelope_id=eid,
                                   shard_id="s"),
            attempt=0))                          # pre-failover attempt
        fab.router._on_result(stale)
        assert not fut.done()                    # stale reply dropped
        assert fab.router.pending_count() == 1   # still owed an answer
    finally:
        fab.stop()


def test_fabric_future_resolves_deadline_exceeded_like_an_error():
    """An expired deadline sheds ON THE SHARD; the DeadlineExceeded
    travels back through the result codec and resolves the future."""
    from repro.service import DeadlineExceeded
    fab = _fabric(n_shards=2)
    try:
        ses = fab.session("t")
        with pytest.raises(DeadlineExceeded):
            ses.submit(_batch(), deadline_s=1e-9).result(timeout=60)
        # ... exactly like a normal error: done, not cancelled, and the
        # exception is also readable without raising
        fut = ses.submit(_batch(), deadline_s=1e-9)
        assert isinstance(fut.exception(timeout=60), DeadlineExceeded)
        assert fut.done() and not fut.cancelled()
        # attainment aggregates fabric-wide from the shard ledgers
        d = fab.telemetry.global_snapshot()["deadline"]
        assert d["jobs"] == 2 and d["shed"] == 2 and d["met"] == 0
    finally:
        fab.stop()


def test_remaining_deadline_shrinks_at_reencode_on_failover():
    """Failover re-encodes the envelope; the deadline budget that crossed
    the wire must be the REMAINING budget, not the original SLO."""
    import time as _time
    fab = _fabric(n_shards=2, autostart=False)
    try:
        victim = fab.shard_ids()[0]
        fut = fab.session("t").submit(
            _batch(), deadline_s=300.0,
            affinity=_key_for_shard(fab, victim))
        (eid, pending), = fab.router._pending.items()
        sent_first = pending.envelope.deadline_s
        _time.sleep(0.05)
        assert fab.fail_shard(victim) == 1       # re-routes + re-encodes
        sent_second = fab.router._pending[eid].envelope.deadline_s
        assert sent_second < sent_first <= 300.0
        fab.start()
        results, report = fut.result(timeout=180)
        assert "p" in results and report.deadline_met is True
    finally:
        fab.stop()
