"""Multi-tenant execution service: shared cache, cross-agent dedup,
fairness, admission control, cancellation and error propagation."""

from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.core import GENERIC, LazyOp, PipelineBatch
from repro.core.runtime import ExecutionError
from repro.service import (AdmissionError, FairQueue, StratumService,
                           cross_agent_dedup)
from repro.service.queue import Job as QJob
from repro.service.session import PipelineFuture
import repro.tabular as T


def _pipeline(n_rows=6000, cols=(10, 11, 12), kind="mae", data_seed=0):
    x = T.read("uk_housing", n_rows, seed=data_seed)
    xs = T.scale(T.impute(T.project(x, list(cols))))
    y = T.project(x, [0])
    return T.metric(T.project(xs, [0]), y, kind=kind)


def _batch(name="p", **kw):
    return PipelineBatch([_pipeline(**kw)], [name])


def _boom(*_a, **_k):
    raise ValueError("poisoned op")


def _poison_batch():
    sink = LazyOp("boom", GENERIC, spec={"fn": _boom},
                  inputs=(_pipeline(n_rows=500),)).out()
    return PipelineBatch([sink], ["bad"])


def _service(**kw):
    kw.setdefault("memory_budget_bytes", 1 << 30)
    kw.setdefault("n_executors", 2)
    return StratumService(**kw)


# ---------------------------------------------------------------------------
# shared cache across concurrent sessions
# ---------------------------------------------------------------------------

def test_concurrent_sessions_share_cache_no_corruption():
    svc = _service(coalesce_window_s=0.0)
    try:
        # reference result from a plain single-tenant session
        from repro.core import Stratum
        ref, _ = Stratum(memory_budget_bytes=1 << 30).run_batch(_batch())
        ref_val = float(np.asarray(ref["p"]))

        # tenant 1 populates the shared cache
        s1 = svc.session("t1")
        r1, rep1 = s1.submit(_batch()).result(timeout=60)
        assert float(np.asarray(r1["p"])) == pytest.approx(ref_val, rel=1e-6)

        # tenant 2 submits the same work later: served from shared cache
        s2 = svc.session("t2")
        r2, rep2 = s2.submit(_batch()).result(timeout=60)
        assert float(np.asarray(r2["p"])) == pytest.approx(ref_val, rel=1e-6)
        assert rep2.cache_hits > 0
        # hits are attributed to the tenant that benefited
        snap = svc.telemetry.snapshot()
        assert snap["t2"]["cache_hits"] > 0
        assert snap["t1"]["jobs_completed"] == 1
    finally:
        svc.stop()


def test_many_concurrent_tenants_results_stay_isolated():
    svc = _service(coalesce_window_s=0.05)
    try:
        # distinct pipelines per tenant → distinct results, one shared run
        sessions = [svc.session(f"t{i}") for i in range(4)]
        kinds = ["mae", "rmse", "mae", "rmse"]
        cols = [(10, 11), (10, 11), (11, 12), (11, 12)]
        futs = [s.submit(_batch(kind=k, cols=c))
                for s, k, c in zip(sessions, kinds, cols)]
        vals = [float(np.asarray(f.result(timeout=60)[0]["p"]))
                for f in futs]
        # same (kind, cols) must agree; different kinds must differ
        assert vals[0] != vals[1]
        # every tenant got exactly its own single named result
        for f in futs:
            results, _ = f.result()
            assert set(results) == {"p"}
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# cross-agent dedup
# ---------------------------------------------------------------------------

def test_cross_agent_dedup_identical_subdags_execute_once():
    # autostart=False: both jobs are queued before dispatch begins, so they
    # land in the same super-batch deterministically
    svc = _service(autostart=False, coalesce_window_s=0.05)
    try:
        f1 = svc.session("a").submit(_batch())
        f2 = svc.session("b").submit(_batch())
        svc.start()
        (r1, rep1), (r2, rep2) = (f1.result(timeout=60),
                                  f2.result(timeout=60))
        assert rep1.coalesced_with == 1 and rep2.coalesced_with == 1
        g = svc.telemetry.global_snapshot()
        assert g["super_batches"] == 1
        assert g["ops_deduped_cross_agent"] > 0
        # identical DAGs → the merged run executed each op once: both
        # tenants' attributed op sets are the same signatures
        assert rep1.ops_shared_cross_agent == rep2.ops_shared_cross_agent > 0
        np.testing.assert_allclose(np.asarray(r1["p"]), np.asarray(r2["p"]))
    finally:
        svc.stop()


def test_cross_agent_dedup_accounting_unit():
    sigs = [{"s1", "s2", "shared"}, {"s3", "shared"}]
    total, per_tenant = cross_agent_dedup(sigs, ["a", "b"])
    assert total == 1
    assert per_tenant == {"a": 1, "b": 1}
    # same tenant twice → intra-agent, not cross-agent
    total, per_tenant = cross_agent_dedup(sigs, ["a", "a"])
    assert total == 0 and per_tenant == {}


# ---------------------------------------------------------------------------
# fairness
# ---------------------------------------------------------------------------

def test_fair_queue_round_robin_caps_flooding_tenant():
    q = FairQueue()
    for i in range(10):
        q.push(QJob(id=i, tenant="flood", batch=_batch(),
                    future=PipelineFuture(i, "flood")))
    q.push(QJob(id=100, tenant="small", batch=_batch(),
                future=PipelineFuture(100, "small")))
    round1 = q.pop_round(max_jobs=4, max_per_tenant=2)
    tenants = [j.tenant for j in round1]
    # the small tenant is served in the very first round despite the flood
    assert "small" in tenants
    assert tenants.count("flood") <= 2


def test_flooding_tenant_cannot_starve_another():
    svc = _service(autostart=False, coalesce_window_s=0.0,
                   coalesce_max_jobs=2, max_jobs_per_tenant_per_round=1,
                   n_executors=1)
    try:
        done_order = []
        flood = svc.session("flood")
        futs = [flood.submit(_batch(name=f"f{i}", n_rows=2000))
                for i in range(6)]
        victim_fut = svc.session("victim").submit(_batch(n_rows=2000))
        for i, f in enumerate(futs):
            f.add_done_callback(
                lambda _f, i=i: done_order.append(f"flood{i}"))
        victim_fut.add_done_callback(lambda _f: done_order.append("victim"))
        svc.start()
        victim_fut.result(timeout=120)
        for f in futs:
            f.result(timeout=120)
        # the victim's single job completed well before the flood drained
        assert "victim" in done_order[:4], done_order
        snap = svc.telemetry.snapshot()
        assert snap["victim"]["queue_wait_max_s"] \
            <= snap["flood"]["queue_wait_max_s"]
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_control_rejects_over_quota():
    svc = _service(autostart=False, max_queued_total=3,
                   max_queued_per_tenant=2)
    try:
        s = svc.session("greedy")
        s.submit(_batch())
        s.submit(_batch())
        with pytest.raises(AdmissionError):
            s.submit(_batch())                     # per-tenant quota
        svc.session("other").submit(_batch())
        with pytest.raises(AdmissionError):
            svc.session("third").submit(_batch())  # global depth
    finally:
        svc.start()
        svc.stop()


# ---------------------------------------------------------------------------
# cancellation + error propagation
# ---------------------------------------------------------------------------

def test_future_cancellation_while_queued():
    svc = _service(autostart=False)
    try:
        fut = svc.session("t").submit(_batch())
        assert fut.cancel()
        assert fut.cancelled()
        with pytest.raises(CancelledError):
            fut.result(timeout=5)
        assert svc.telemetry.snapshot()["t"]["jobs_cancelled"] == 1
        svc.start()
        # a later job on the same tenant still works
        r, _ = svc.session("t").submit(_batch()).result(timeout=60)
        assert "p" in r
    finally:
        svc.stop()


def test_execution_error_propagates_wrapped():
    svc = _service()
    try:
        fut = svc.session("t").submit(_poison_batch())
        with pytest.raises(ExecutionError) as ei:
            fut.result(timeout=60)
        assert isinstance(ei.value.cause, ValueError)
        assert svc.telemetry.snapshot()["t"]["jobs_failed"] == 1
    finally:
        svc.stop()


def test_poisoned_peer_does_not_fail_innocent_coalesced_job():
    svc = _service(autostart=False, coalesce_window_s=0.05)
    try:
        bad_fut = svc.session("bad").submit(_poison_batch())
        good_fut = svc.session("good").submit(_batch())
        svc.start()
        with pytest.raises(ExecutionError):
            bad_fut.result(timeout=60)
        # the innocent job was re-executed without the poisoned peer
        results, _ = good_fut.result(timeout=60)
        assert "p" in results
        snap = svc.telemetry.snapshot()
        assert snap["good"]["jobs_completed"] == 1
        assert snap["bad"]["jobs_failed"] == 1
    finally:
        svc.stop()


def test_cancel_after_dispatch_returns_false():
    svc = _service()
    try:
        fut = svc.session("t").submit(_batch(n_rows=1000))
        fut.result(timeout=60)
        assert not fut.cancel()
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_service_restart_accepts_new_jobs():
    svc = _service()
    try:
        svc.session("t").submit(_batch(n_rows=1000)).result(timeout=60)
        svc.stop()
        with pytest.raises(AdmissionError):
            svc.session("t").submit(_batch(n_rows=1000))
        svc.start()
        r, _ = svc.session("t").submit(_batch(n_rows=1000)).result(timeout=60)
        assert "p" in r
    finally:
        svc.stop()


def test_stop_without_start_fails_queued_jobs_without_hanging():
    svc = _service(autostart=False)
    fut = svc.session("t").submit(_batch(n_rows=1000))
    svc.stop()                      # must not spin waiting for a dispatcher
    with pytest.raises(AdmissionError):
        fut.result(timeout=5)


def test_retry_does_not_double_count_telemetry():
    svc = _service(autostart=False, coalesce_window_s=0.05)
    try:
        svc.session("bad").submit(_poison_batch())
        good_fut = svc.session("good").submit(_batch())
        svc.start()
        good_fut.result(timeout=60)
        g = svc.telemetry.global_snapshot()
        assert g["super_batches"] == 1       # the retry is not a new batch
        assert g["jobs_coalesced"] == 2
        snap = svc.telemetry.snapshot()
        # queue wait recorded once, at first dispatch (not inflated by the
        # failed run's execution time)
        assert snap["good"]["jobs_completed"] == 1
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# async AIDE driver through the service
# ---------------------------------------------------------------------------

def test_async_aide_rejects_nonpositive_inflight():
    from repro.agents import AIDEAgent, AsyncAIDESearch
    with pytest.raises(ValueError):
        AsyncAIDESearch(None, AIDEAgent(), max_inflight=0)


def test_async_aide_search_runs_through_service():
    from repro.agents import AIDEAgent, AsyncAIDESearch
    svc = _service(coalesce_window_s=0.02)
    try:
        agent = AIDEAgent(n_rows=2000, cv_k=2, seed=0)
        search = AsyncAIDESearch(svc.session("aide"), agent,
                                 batch_size=2, max_inflight=2)
        best = search.run(n_rounds=2)
        assert best is not None and best.score is not None
        assert len(agent.nodes) == 4
        assert svc.telemetry.snapshot()["aide"]["jobs_completed"] == 2
    finally:
        svc.stop()
