"""Bench regression gate (run by the CI ``bench`` job).

Compares a freshly produced smoke benchmark artifact against the
committed ``BENCH_service.json`` baseline and fails (exit 1) when an
agent-scaling *speedup* regressed by more than ``--max-regression``
(default 20%).  Speedups are dimensionless ratios measured within one
machine and one run, so they transfer across runner generations far
better than absolute latencies; the tolerance absorbs normal CI noise.

Gated metrics (checked when present in the baseline):

* ``service_smoke.speedup`` — N concurrent agents through one service vs
  N isolated sequential sessions;
* ``sharded_smoke.speedup`` — aggregate fabric throughput at K shards vs
  1 shard;
* ``compiled_smoke.speedup`` — compiled plan-segment backends (warm
  structural plan cache) vs per-op dispatch on the repeated-structure
  workload;
* ``deadline_smoke.attainment_aware`` — fraction of deadline-carrying
  probes meeting their SLO under mixed load with the deadline-aware
  scheduler (a dimensionless rate, gated like the speedups);
* ``observability_smoke.traced_over_untraced`` — throughput with full
  lifecycle tracing + JSONL event log relative to tracing off.  Its
  committed baseline is pinned at 1.0 (parity) and its gate carries a
  per-gate 5% tolerance, so this is an absolute overhead budget: traced
  throughput must stay within 5% of untraced.

A metric present in the baseline but missing from the fresh artifact is a
failure (the bench crashed or was skipped); a metric missing from the
baseline is skipped (lets a PR introduce the baseline it is adding).

    python -m benchmarks.check_regression \
        --baseline BENCH_service.json --fresh /tmp/bench_smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys

# (section, metric) or (section, metric, max_regression): an explicit
# third element overrides the CLI-wide --max-regression for that gate.
# observability_smoke's baseline pins traced_over_untraced at 1.0
# (parity), so its 0.05 tolerance IS the tracing-overhead budget: the
# traced run must stay within 5% of untraced throughput.
GATES = (
    ("service_smoke", "speedup"),
    ("sharded_smoke", "speedup"),
    ("compiled_smoke", "speedup"),
    ("deadline_smoke", "attainment_aware"),
    ("fabric_proc_smoke", "completed_frac"),
    ("observability_smoke", "traced_over_untraced", 0.05),
)


def check(baseline: dict, fresh: dict, max_regression: float) -> list:
    """Returns a list of failure strings (empty = gate passes)."""
    failures = []
    gated = 0
    for section, metric, *tol in GATES:
        base = baseline.get(section, {}).get(metric)
        if base is None:
            continue                      # no committed baseline yet
        gated += 1
        new = fresh.get(section, {}).get(metric)
        if new is None:
            failures.append(f"{section}.{metric}: missing from fresh "
                            f"artifact (bench crashed or skipped?)")
            continue
        allowed = tol[0] if tol else max_regression
        floor = base * (1.0 - allowed)
        if new < floor:
            failures.append(
                f"{section}.{metric}: {new:.2f} < allowed floor "
                f"{floor:.2f} (baseline {base:.2f}, "
                f"max regression {allowed:.0%})")
    if not gated:
        failures.append("no gated metrics found in baseline — nothing "
                        "was checked; commit a *_smoke baseline first")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_service.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="allowed fractional speedup loss (default 0.20)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = check(baseline, fresh, args.max_regression)
    for section, metric, *_tol in GATES:
        base = baseline.get(section, {}).get(metric)
        new = fresh.get(section, {}).get(metric)
        if base is not None and new is not None:
            print(f"{section}.{metric}: baseline {base:.2f} -> "
                  f"fresh {new:.2f}")
    if failures:
        for msg in failures:
            print(f"REGRESSION {msg}")
        return 1
    print("bench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
