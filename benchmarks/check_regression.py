"""Bench regression gate (run by the CI ``bench`` job).

Compares a freshly produced smoke benchmark artifact against the
committed ``BENCH_service.json`` baseline and fails (exit 1) when an
agent-scaling *speedup* regressed by more than ``--max-regression``
(default 20%).  Speedups are dimensionless ratios measured within one
machine and one run, so they transfer across runner generations far
better than absolute latencies; the tolerance absorbs normal CI noise.

Gated metrics (checked when present in the baseline):

* ``service_smoke.speedup`` — N concurrent agents through one service vs
  N isolated sequential sessions;
* ``sharded_smoke.speedup`` — aggregate fabric throughput at K shards vs
  1 shard;
* ``compiled_smoke.speedup`` — compiled plan-segment backends (warm
  structural plan cache) vs per-op dispatch on the repeated-structure
  workload;
* ``compiled_batched_smoke.speedup`` — batched variant solves (one
  vmapped trace per homogeneous refinement fan) vs per-op dispatch on
  the same workload, as a ratio of per-round medians (makespans flake
  on straggler rounds);
* ``compiled_cold_smoke.speculative_hits`` — every structure on the
  changing-structure ladder must take its first measured touch on a
  speculatively compiled program (deterministic count, one per
  structure);
* ``compiled_cold_smoke.cold_p50_speedup`` — blocking first-touch
  median over async+speculative first-touch median on that ladder.
  Compile cost swings severalfold with process warmth, so its gate
  carries a 70% per-gate tolerance — it guards the order-of-magnitude
  claim, not the exact ratio;
* ``deadline_smoke.attainment_aware`` — fraction of deadline-carrying
  probes meeting their SLO under mixed load with the deadline-aware
  scheduler (a dimensionless rate, gated like the speedups);
* ``observability_smoke.traced_over_untraced`` — throughput with full
  lifecycle tracing + JSONL event log relative to tracing off.  Its
  committed baseline is pinned at 1.0 (parity) and its gate carries a
  per-gate 5% tolerance, so this is an absolute overhead budget: traced
  throughput must stay within 5% of untraced;
* ``control_smoke.attainment_controlled`` — tight-deadline probe
  attainment under a batch flood with the closed-loop controller on
  (the static mode collapses to edge rejections by design, so only the
  controlled rate is gated);
* ``analysis_smoke.reject_speedup`` — how much sooner a statically
  invalid submission learns its fate when the admission analyzer
  rejects it at ``submit`` instead of letting it fail at the executor
  behind the queue.  Queue-depth dependent, so its gate carries a 90%
  per-gate tolerance (order-of-magnitude claim, like the cold-compile
  gate);
* ``analysis_smoke.valid_work_frac`` — 1 minus the fraction of the
  admission-analysis run's makespan spent inside the analyzer.  Like
  the observability gate, its committed baseline is pinned at 1.0 with
  a 5% per-gate tolerance, so it is an absolute analyzer-overhead
  budget on valid traffic.

A metric present in the baseline but missing from the fresh artifact is a
failure (the bench crashed or was skipped); a metric missing from the
baseline is skipped (lets a PR introduce the baseline it is adding).

Each failure also prints one machine-readable ``DIFF {...}`` JSON line
per gate (section, metric, baseline, fresh, floor, status), and
``--markdown-summary PATH`` appends a baseline-vs-fresh comparison table
in GitHub-flavored markdown (the CI jobs point it at
``$GITHUB_STEP_SUMMARY``).

``--write-baseline`` regenerates the gated sections of the baseline file
from the fresh artifact instead of checking.  It REFUSES to touch the
baseline unless ``--yes`` is also passed — rewriting the committed
numbers is how a regression gets laundered into the gate, so it must be
an explicit two-flag act.

    python -m benchmarks.check_regression \
        --baseline BENCH_service.json --fresh /tmp/bench_smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys

# (section, metric) or (section, metric, max_regression): an explicit
# third element overrides the CLI-wide --max-regression for that gate.
# observability_smoke's baseline pins traced_over_untraced at 1.0
# (parity), so its 0.05 tolerance IS the tracing-overhead budget: the
# traced run must stay within 5% of untraced throughput.
GATES = (
    ("service_smoke", "speedup"),
    ("sharded_smoke", "speedup"),
    ("compiled_smoke", "speedup"),
    ("compiled_batched_smoke", "speedup"),
    ("compiled_cold_smoke", "speculative_hits"),
    ("compiled_cold_smoke", "cold_p50_speedup", 0.7),
    ("deadline_smoke", "attainment_aware"),
    ("fabric_proc_smoke", "completed_frac"),
    ("observability_smoke", "traced_over_untraced", 0.05),
    ("control_smoke", "attainment_controlled"),
    # analysis_smoke.valid_work_frac follows the observability idiom:
    # its committed baseline is pinned at 1.0, so the 0.05 tolerance IS
    # the admission-analyzer overhead budget (≤5% of valid wall time).
    # reject_speedup swings with queue depth and machine speed, so like
    # cold_p50_speedup it gets a wide tolerance guarding the
    # order-of-magnitude claim, not the exact ratio.
    ("analysis_smoke", "reject_speedup", 0.9),
    ("analysis_smoke", "valid_work_frac", 0.05),
)


def gate_rows(baseline: dict, fresh: dict, max_regression: float) -> list:
    """Per-gate comparison rows: the single source for failures, the
    printed diff lines and the markdown summary table.

    ``status`` is one of ``ok`` / ``regression`` / ``missing_fresh`` /
    ``no_baseline`` (skipped — the PR is introducing this baseline)."""
    rows = []
    for section, metric, *tol in GATES:
        base = baseline.get(section, {}).get(metric)
        new = fresh.get(section, {}).get(metric)
        allowed = tol[0] if tol else max_regression
        row = {"section": section, "metric": metric, "baseline": base,
               "fresh": new, "max_regression": allowed, "floor": None,
               "status": "ok"}
        if base is None:
            row["status"] = "no_baseline"
        elif new is None:
            row["status"] = "missing_fresh"
        else:
            row["floor"] = base * (1.0 - allowed)
            if new < row["floor"]:
                row["status"] = "regression"
        rows.append(row)
    return rows


def check(baseline: dict, fresh: dict, max_regression: float) -> list:
    """Returns a list of failure strings (empty = gate passes)."""
    failures = []
    gated = 0
    for row in gate_rows(baseline, fresh, max_regression):
        name = f"{row['section']}.{row['metric']}"
        if row["status"] == "no_baseline":
            continue                      # no committed baseline yet
        gated += 1
        if row["status"] == "missing_fresh":
            failures.append(f"{name}: missing from fresh "
                            f"artifact (bench crashed or skipped?)")
        elif row["status"] == "regression":
            failures.append(
                f"{name}: {row['fresh']:.2f} < allowed floor "
                f"{row['floor']:.2f} (baseline {row['baseline']:.2f}, "
                f"max regression {row['max_regression']:.0%})")
    if not gated:
        failures.append("no gated metrics found in baseline — nothing "
                        "was checked; commit a *_smoke baseline first")
    return failures


def markdown_summary(rows: list, title: str = "Bench gate") -> str:
    """Baseline-vs-fresh comparison as a GitHub-flavored markdown table."""
    icon = {"ok": "✅", "regression": "❌", "missing_fresh": "❌",
            "no_baseline": "⏭️"}

    def fmt(v):
        return f"{v:.3f}" if isinstance(v, (int, float)) else "—"

    lines = [f"### {title}", "",
             "| gate | baseline | fresh | allowed floor | status |",
             "|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| `{r['section']}.{r['metric']}` | {fmt(r['baseline'])} "
            f"| {fmt(r['fresh'])} | {fmt(r['floor'])} "
            f"| {icon[r['status']]} {r['status']} |")
    return "\n".join(lines) + "\n"


def write_baseline(baseline_path: str, baseline: dict, fresh: dict) -> list:
    """Merge the fresh artifact's GATED sections into the baseline file.

    Only sections named in ``GATES`` move — a full-bench artifact may
    carry extra sections the baseline doesn't gate.  Returns the list of
    section names updated."""
    updated = []
    for section, _metric, *_tol in GATES:
        if section in fresh:
            baseline[section] = fresh[section]
            updated.append(section)
    with open(baseline_path, "w") as f:
        json.dump(baseline, f, indent=2, sort_keys=True)
        f.write("\n")
    return updated


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_service.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="allowed fractional speedup loss (default 0.20)")
    ap.add_argument("--markdown-summary", metavar="PATH",
                    help="append a baseline-vs-fresh markdown table here "
                         "(point at $GITHUB_STEP_SUMMARY in CI)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline's gated sections from "
                         "--fresh instead of checking (requires --yes)")
    ap.add_argument("--yes", action="store_true",
                    help="confirm --write-baseline (refused without it)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    if args.write_baseline:
        if not args.yes:
            print("refusing to rewrite the committed baseline without "
                  "--yes (this is how a regression gets laundered into "
                  "the gate)")
            return 1
        updated = write_baseline(args.baseline, baseline, fresh)
        print(f"baseline {args.baseline}: "
              f"regenerated {', '.join(updated) or 'nothing'} "
              f"from {args.fresh}")
        return 0

    rows = gate_rows(baseline, fresh, args.max_regression)
    failures = check(baseline, fresh, args.max_regression)
    for row in rows:
        if row["baseline"] is not None and row["fresh"] is not None:
            print(f"{row['section']}.{row['metric']}: "
                  f"baseline {row['baseline']:.2f} -> "
                  f"fresh {row['fresh']:.2f}")
    if args.markdown_summary:
        with open(args.markdown_summary, "a") as f:
            f.write(markdown_summary(rows))
    if failures:
        for msg in failures:
            print(f"REGRESSION {msg}")
        for row in rows:
            if row["status"] not in ("ok", "no_baseline"):
                print("DIFF " + json.dumps(row, sort_keys=True))
        return 1
    print("bench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
