"""Workload characterization (paper Fig. 2): distribution of code-diff
sizes across agent iterations + cross-pipeline operator redundancy."""

from __future__ import annotations

import numpy as np

from repro.agents.aide import AIDEAgent, diff_fraction
from repro.agents import paper_workload_batches
from repro.core import count_ops
from repro.core.lowering import lower
from repro.core.rewrites import cse


def diff_stats(n_iters: int = 80, seed: int = 3) -> dict:
    agent = AIDEAgent(seed=seed)
    specs = agent.propose(4)
    agent.observe(specs, [1.0, 0.9, 1.1, 0.95])
    prev = agent.best().spec
    fracs = []
    for i in range(n_iters):
        new = agent.propose(1)[0]
        fracs.append(diff_fraction(prev, new))
        agent.observe([new], [0.9 + 0.001 * i])
        prev = new
    f = np.asarray(fracs)
    return {"median_diff_frac": float(np.median(f)),
            "frac_leq_16pct": float(np.mean(f <= 0.165)),
            "p90_diff_frac": float(np.quantile(f, 0.9))}


def redundancy_stats(n_rows: int = 5000) -> dict:
    """Operator redundancy across the fused batch: how much of the submitted
    work is duplicated (the headroom stratum exploits)."""
    _, batch, _ = next(iter(paper_workload_batches(n_rows=n_rows, cv_k=3)))
    sinks = lower(batch.fused_sinks())
    before = count_ops(sinks)
    after = count_ops(cse(sinks))
    return {"ops_submitted": before, "ops_unique": after,
            "redundancy_frac": 1.0 - after / before}


def rows() -> list:
    d = diff_stats()
    r = redundancy_stats()
    return [
        ("characterize_median_diff", d["median_diff_frac"] * 1e6,
         f"frac<=16pct={d['frac_leq_16pct']:.2f} (paper: 0.50)"),
        ("characterize_redundancy", r["redundancy_frac"] * 1e6,
         f"ops {r['ops_submitted']}->{r['ops_unique']}"),
    ]
