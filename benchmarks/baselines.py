"""Paper baselines (Fig. 6a): Base and Base_par execution modes.

* ``Base``     — how AIDE actually executes: each pipeline is run start to
  finish in isolation, sequentially, on the interpreted ("python") operator
  tier; no fusion, no CSE across pipelines, no cache, fresh data load per
  pipeline.
* ``Base_par`` — AIDE triggering pipelines concurrently: same isolated
  execution, thread pool across pipelines.  (The paper's Base_par uses
  multiprocessing on 48 cores with 8× memory blow-up; this container has one
  core, so Base_par measures the overhead side of naive parallelism —
  reported as such in EXPERIMENTS.md.)

Both run each pipeline's DAG after lowering (a CV score still needs its
folds), but with per-pipeline isolation: shared prefixes are re-executed per
pipeline, exactly like stateless agent-generated scripts.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

from repro.core.dag import LazyRef
from repro.core.lowering import lower
from repro.core.metadata import collect_metadata
from repro.core.runtime import Runtime
from repro.core.scheduler import SchedulerConfig, plan as make_plan
from repro.core.selection import SelectionConfig, select


def run_pipeline_isolated(sink: LazyRef, backends=("python",)):
    """One pipeline, no sharing with anything else."""
    sinks = lower([sink])
    collect_metadata(sinks)
    sel = select(sinks, SelectionConfig(allowed_backends=backends))
    plan = make_plan(sinks, sel, SchedulerConfig(enable_inter_op=False))
    rt = Runtime(cache=None, parallel=False)
    results, report = rt.execute(sinks, plan, sel)
    return results[0], report


def run_base(sinks, backends=("python",)):
    t0 = time.perf_counter()
    results = [run_pipeline_isolated(s, backends)[0] for s in sinks]
    return results, time.perf_counter() - t0


def run_base_par(sinks, backends=("python",), max_workers: int = 4):
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=max_workers) as pool:
        futures = [pool.submit(run_pipeline_isolated, s, backends)
                   for s in sinks]
        results = [f.result()[0] for f in futures]
    return results, time.perf_counter() - t0
