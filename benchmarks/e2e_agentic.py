"""End-to-end agentic pipeline search (paper Fig. 6a) + the N-concurrent-
agent scaling benchmark for the multi-tenant execution service.

Workload (paper §6, verbatim structure): iteration 1 = 2 preprocessing
strategies × 4 models over UK-housing-like data; iteration 2 = grid search
on the winner.  Modes: Base (sequential AIDE), Base_par (naively parallel
AIDE), stratum (all optimizations), service (N agents multiplexed over one
StratumService — emitted to ``BENCH_service.json``).

    PYTHONPATH=src python benchmarks/e2e_agentic.py --agents 4
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import replace

import numpy as np

from repro.agents import paper_workload_batches
from repro.agents.aide import second_iteration_batch
from repro.core import Stratum
from repro.service import StratumService

try:
    from .baselines import run_base, run_base_par
except ImportError:          # executed as a script, not a package module
    from baselines import run_base, run_base_par


def _workload(n_rows: int, cv_k: int):
    name, batch, ctx = next(iter(paper_workload_batches(
        n_rows=n_rows, cv_k=cv_k)))
    return batch, ctx


def run(n_rows: int = 20_000, cv_k: int = 3, spill_dir: str | None = None,
        include_base_par: bool = True) -> dict:
    out = {}
    # materialize the data lake files once (setup, not measured)
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)

    # ---- Base ------------------------------------------------------------
    batch, ctx = _workload(n_rows, cv_k)
    res_base, t_base = run_base(batch.sinks)
    scores = {n: float(np.asarray(r)) for n, r in zip(batch.names, res_base)}
    best = min(scores, key=scores.get)
    b2, _ = second_iteration_batch(ctx["specs"][best])
    res2, t2 = run_base(b2.sinks)
    out["base_s"] = t_base + t2

    # ---- Base_par ----------------------------------------------------------
    if include_base_par:
        batch, ctx = _workload(n_rows, cv_k)
        _, tp1 = run_base_par(batch.sinks)
        _, tp2 = run_base_par(b2.sinks)
        out["base_par_s"] = tp1 + tp2

    # ---- stratum -----------------------------------------------------------
    batch, ctx = _workload(n_rows, cv_k)
    s = Stratum(memory_budget_bytes=4 << 30, spill_dir=spill_dir,
                jit_cache_dir="/tmp/repro_jit_cache")
    t0 = time.perf_counter()
    res1, rep1 = s.run_batch(batch)
    best = min(res1, key=lambda k: float(np.asarray(res1[k])))
    b2s, _ = second_iteration_batch(ctx["specs"][best])
    res2s, rep2 = s.run_batch(b2s)
    out["stratum_s"] = time.perf_counter() - t0
    out["stratum_cold"] = not getattr(run, "_warmed", False)
    run._warmed = True

    out["speedup_vs_base"] = out["base_s"] / out["stratum_s"]
    if include_base_par:
        out["speedup_vs_base_par"] = out["base_par_s"] / out["stratum_s"]
    out["stratum_cache_hits"] = rep2.run.ops_from_cache
    out["stratum_cse_merged"] = rep1.rewrites.cse_merged

    # scores must agree across modes (same seeds; dtype tolerance)
    s_base = float(np.asarray(scores[best]))
    s_strat = float(np.asarray(res1[best]))
    out["score_rel_diff"] = abs(s_base - s_strat) / abs(s_base)
    return out


def rows() -> list:
    r = run()
    out = [("e2e_base", r["base_s"] * 1e6, ""),
           ("e2e_stratum", r["stratum_s"] * 1e6,
            f"speedup={r['speedup_vs_base']:.1f}x"),
           ("e2e_score_agreement", r["score_rel_diff"] * 1e6,
            "rel_diff_x1e-6")]
    if "base_par_s" in r:
        out.insert(1, ("e2e_base_par", r["base_par_s"] * 1e6,
                       f"speedup={r.get('speedup_vs_base_par', 0):.1f}x"))
    return out


# ---------------------------------------------------------------------------
# N-concurrent-agents scaling through the multi-tenant service
# ---------------------------------------------------------------------------

def _agent_iterations(n_rows: int, cv_k: int, agent_seed: int):
    """One agent's two-iteration AIDE workload.  Iteration 1 is the paper's
    8-pipeline sweep (identical across agents — the multi-tenant sharing
    scenario: every agent profiles the same dataset); iteration 2 is the
    grid on that agent's winner, re-seeded per agent so the model-fit work
    is tenant-unique while reads/preprocessing stay shareable."""
    _, batch, ctx = next(iter(paper_workload_batches(
        n_rows=n_rows, cv_k=cv_k)))

    def second(best_name: str):
        spec = replace(ctx["specs"][best_name], seed=7 + agent_seed)
        return second_iteration_batch(spec)[0]

    return batch, second


def _run_one_agent(run_batch, n_rows: int, cv_k: int, agent_seed: int
                   ) -> float:
    """Drive the two-iteration workload through ``run_batch`` (a callable
    with the Stratum/Session signature); returns the winning score."""
    batch, second = _agent_iterations(n_rows, cv_k, agent_seed)
    res1, _ = run_batch(batch)
    best = min(res1, key=lambda k: float(np.asarray(res1[k])))
    res2, _ = run_batch(second(best))
    return min(float(np.asarray(v)) for v in res2.values())


def run_service(n_agents: int = 4, n_rows: int = 20_000, cv_k: int = 3,
                warmup: bool = True) -> dict:
    """4-sequential-sessions baseline vs N agents through one service."""
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"

    if warmup:  # warm the XLA jit cache so neither mode pays compile time
        _run_one_agent(
            Stratum(memory_budget_bytes=4 << 30,
                    jit_cache_dir=jit_dir).run_batch, n_rows, cv_k, 0)

    # ---- baseline: N isolated, sequential Stratum sessions ---------------
    t0 = time.perf_counter()
    seq_scores = []
    for i in range(n_agents):
        session = Stratum(memory_budget_bytes=4 << 30, jit_cache_dir=jit_dir)
        seq_scores.append(
            _run_one_agent(session.run_batch, n_rows, cv_k, i))
    sequential_s = time.perf_counter() - t0

    # ---- service: N concurrent agents over one optimizing runtime --------
    svc = StratumService(memory_budget_bytes=4 << 30,
                         jit_cache_dir=jit_dir,
                         coalesce_window_s=0.05,
                         n_executors=2)
    svc_scores = [None] * n_agents
    errors: list = []
    barrier = threading.Barrier(n_agents)

    def agent_main(i: int) -> None:
        try:
            session = svc.session(f"agent-{i}")
            barrier.wait()
            svc_scores[i] = _run_one_agent(
                session.run_batch, n_rows, cv_k, i)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=agent_main, args=(i,))
               for i in range(n_agents)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    service_s = time.perf_counter() - t0
    telemetry = {"global": svc.telemetry.global_snapshot(),
                 "per_tenant": svc.telemetry.snapshot()}
    report_text = svc.telemetry.report()
    svc.stop()
    if errors:
        raise errors[0]

    rel = max(abs(a - b) / max(abs(a), 1e-12)
              for a, b in zip(seq_scores, svc_scores))
    return {
        "agents": n_agents,
        "rows": n_rows,
        "sequential_s": sequential_s,
        "service_s": service_s,
        "speedup": sequential_s / service_s,
        "score_rel_diff": rel,
        "ops_deduped_cross_agent":
            telemetry["global"]["ops_deduped_cross_agent"],
        "shared_cache_hits": sum(t["cache_hits"]
                                 for t in telemetry["per_tenant"].values()),
        "telemetry": telemetry,
        "telemetry_report": report_text,
    }


def write_service_json(result: dict, path: str = "BENCH_service.json"
                       ) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2, default=str)


def service_rows(n_agents: int = 4, n_rows: int = 20_000) -> list:
    r = run_service(n_agents=n_agents, n_rows=n_rows)
    write_service_json(r)
    return [
        ("service_sequential", r["sequential_s"] * 1e6,
         f"{r['agents']}_isolated_sessions"),
        ("service_concurrent", r["service_s"] * 1e6,
         f"speedup={r['speedup']:.1f}x"),
        ("service_deduped_ops", float(r["ops_deduped_cross_agent"]),
         "cross_agent"),
        ("service_cache_hits", float(r["shared_cache_hits"]),
         "shared_cache"),
        ("service_score_agreement", r["score_rel_diff"] * 1e6,
         "rel_diff_x1e-6"),
    ]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--cv", type=int, default=3)
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args()
    r = run_service(n_agents=args.agents, n_rows=args.rows, cv_k=args.cv)
    write_service_json(r, args.out)
    print(f"{args.agents} sequential sessions: {r['sequential_s']:.2f}s")
    print(f"{args.agents} agents via service:  {r['service_s']:.2f}s "
          f"({r['speedup']:.1f}x)")
    print(f"cross-agent ops deduped: {r['ops_deduped_cross_agent']}  "
          f"shared-cache hits: {r['shared_cache_hits']}")
    print(r["telemetry_report"])
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
