"""End-to-end agentic pipeline search (paper Fig. 6a) + the N-concurrent-
agent scaling benchmark for the multi-tenant execution service.

Workload (paper §6, verbatim structure): iteration 1 = 2 preprocessing
strategies × 4 models over UK-housing-like data; iteration 2 = grid search
on the winner.  Modes: Base (sequential AIDE), Base_par (naively parallel
AIDE), stratum (all optimizations), service (N agents multiplexed over one
StratumService — emitted to ``BENCH_service.json``).

``--mixed-priority`` measures the priority scheduler instead: an
interactive tenant issues sequential latency-sensitive probes while batch
tenants flood the service with bulk sweeps, once with the priority-aware
scheduler (WFQ bands + cooperative preemption) and once priority-blind
(plain round-robin).  Interactive p50/p99 latency for both modes is merged
into ``BENCH_service.json`` under ``"mixed_priority"``.

``--deadline`` measures deadline-aware scheduling instead: one tenant's
sequential probes carry a ``deadline_s`` SLO while bulk tenants flood the
SAME priority band with deadline-free sweeps; EDF tie-breaks, tight-slack
solo dispatch and shedding (aware) vs deadline-blind round-robin.  p99
attainment and batch-throughput parity land in ``BENCH_service.json``
under ``"deadline"``.

``--shards K`` measures the sharded fabric: agent cohorts over distinct
datasets submit open-loop sweeps through ``ShardedStratum`` at 1 shard vs
K shards; consistent-hash placement keeps each shard's intermediate cache
and cross-agent CSE hot for its cohorts, where a single shard LRU-thrashes
across all of them.  Aggregate throughput, signature-locality hit rate and
score agreement land in ``BENCH_service.json`` under ``"sharded"``.

    PYTHONPATH=src python benchmarks/e2e_agentic.py --agents 4
    PYTHONPATH=src python benchmarks/e2e_agentic.py --mixed-priority
    PYTHONPATH=src python benchmarks/e2e_agentic.py --shards 4 --agents 16
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import replace

import numpy as np

from repro.agents import paper_workload_batches
from repro.agents.aide import PipelineSpec, second_iteration_batch
from repro.core import PipelineBatch, Stratum
from repro.service import (AdmissionError, ControlPolicy, DeadlineExceeded,
                           Priority, StratumService)
import repro.tabular as T

try:
    from .baselines import run_base, run_base_par
except ImportError:          # executed as a script, not a package module
    from baselines import run_base, run_base_par


def _workload(n_rows: int, cv_k: int):
    name, batch, ctx = next(iter(paper_workload_batches(
        n_rows=n_rows, cv_k=cv_k)))
    return batch, ctx


def run(n_rows: int = 20_000, cv_k: int = 3, spill_dir: str | None = None,
        include_base_par: bool = True) -> dict:
    out = {}
    # materialize the data lake files once (setup, not measured)
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)

    # ---- Base ------------------------------------------------------------
    batch, ctx = _workload(n_rows, cv_k)
    res_base, t_base = run_base(batch.sinks)
    scores = {n: float(np.asarray(r)) for n, r in zip(batch.names, res_base)}
    best = min(scores, key=scores.get)
    b2, _ = second_iteration_batch(ctx["specs"][best])
    res2, t2 = run_base(b2.sinks)
    out["base_s"] = t_base + t2

    # ---- Base_par ----------------------------------------------------------
    if include_base_par:
        batch, ctx = _workload(n_rows, cv_k)
        _, tp1 = run_base_par(batch.sinks)
        _, tp2 = run_base_par(b2.sinks)
        out["base_par_s"] = tp1 + tp2

    # ---- stratum -----------------------------------------------------------
    batch, ctx = _workload(n_rows, cv_k)
    s = Stratum(memory_budget_bytes=4 << 30, spill_dir=spill_dir,
                jit_cache_dir="/tmp/repro_jit_cache")
    t0 = time.perf_counter()
    res1, rep1 = s.run_batch(batch)
    best = min(res1, key=lambda k: float(np.asarray(res1[k])))
    b2s, _ = second_iteration_batch(ctx["specs"][best])
    res2s, rep2 = s.run_batch(b2s)
    out["stratum_s"] = time.perf_counter() - t0
    out["stratum_cold"] = not getattr(run, "_warmed", False)
    run._warmed = True

    out["speedup_vs_base"] = out["base_s"] / out["stratum_s"]
    if include_base_par:
        out["speedup_vs_base_par"] = out["base_par_s"] / out["stratum_s"]
    out["stratum_cache_hits"] = rep2.run.ops_from_cache
    out["stratum_cse_merged"] = rep1.rewrites.cse_merged

    # scores must agree across modes (same seeds; dtype tolerance)
    s_base = float(np.asarray(scores[best]))
    s_strat = float(np.asarray(res1[best]))
    out["score_rel_diff"] = abs(s_base - s_strat) / abs(s_base)
    return out


def rows() -> list:
    r = run()
    out = [("e2e_base", r["base_s"] * 1e6, ""),
           ("e2e_stratum", r["stratum_s"] * 1e6,
            f"speedup={r['speedup_vs_base']:.1f}x"),
           ("e2e_score_agreement", r["score_rel_diff"] * 1e6,
            "rel_diff_x1e-6")]
    if "base_par_s" in r:
        out.insert(1, ("e2e_base_par", r["base_par_s"] * 1e6,
                       f"speedup={r.get('speedup_vs_base_par', 0):.1f}x"))
    return out


# ---------------------------------------------------------------------------
# N-concurrent-agents scaling through the multi-tenant service
# ---------------------------------------------------------------------------

def _agent_iterations(n_rows: int, cv_k: int, agent_seed: int):
    """One agent's two-iteration AIDE workload.  Iteration 1 is the paper's
    8-pipeline sweep (identical across agents — the multi-tenant sharing
    scenario: every agent profiles the same dataset); iteration 2 is the
    grid on that agent's winner, re-seeded per agent so the model-fit work
    is tenant-unique while reads/preprocessing stay shareable."""
    _, batch, ctx = next(iter(paper_workload_batches(
        n_rows=n_rows, cv_k=cv_k)))

    def second(best_name: str):
        spec = replace(ctx["specs"][best_name], seed=7 + agent_seed)
        return second_iteration_batch(spec)[0]

    return batch, second


def _run_one_agent(run_batch, n_rows: int, cv_k: int, agent_seed: int
                   ) -> float:
    """Drive the two-iteration workload through ``run_batch`` (a callable
    with the Stratum/Session signature); returns the winning score."""
    batch, second = _agent_iterations(n_rows, cv_k, agent_seed)
    res1, _ = run_batch(batch)
    best = min(res1, key=lambda k: float(np.asarray(res1[k])))
    res2, _ = run_batch(second(best))
    return min(float(np.asarray(v)) for v in res2.values())


def run_service(n_agents: int = 4, n_rows: int = 20_000, cv_k: int = 3,
                warmup: bool = True) -> dict:
    """4-sequential-sessions baseline vs N agents through one service."""
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"

    if warmup:  # warm the XLA jit cache so neither mode pays compile time
        _run_one_agent(
            Stratum(memory_budget_bytes=4 << 30,
                    jit_cache_dir=jit_dir).run_batch, n_rows, cv_k, 0)

    # ---- baseline: N isolated, sequential Stratum sessions ---------------
    t0 = time.perf_counter()
    seq_scores = []
    for i in range(n_agents):
        session = Stratum(memory_budget_bytes=4 << 30, jit_cache_dir=jit_dir)
        seq_scores.append(
            _run_one_agent(session.run_batch, n_rows, cv_k, i))
    sequential_s = time.perf_counter() - t0

    # ---- service: N concurrent agents over one optimizing runtime --------
    svc = StratumService(memory_budget_bytes=4 << 30,
                         jit_cache_dir=jit_dir,
                         coalesce_window_s=0.05,
                         n_executors=2)
    svc_scores = [None] * n_agents
    errors: list = []
    barrier = threading.Barrier(n_agents)

    def agent_main(i: int) -> None:
        try:
            session = svc.session(f"agent-{i}")
            barrier.wait()
            svc_scores[i] = _run_one_agent(
                session.run_batch, n_rows, cv_k, i)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=agent_main, args=(i,))
               for i in range(n_agents)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    service_s = time.perf_counter() - t0
    telemetry = {"global": svc.telemetry.global_snapshot(),
                 "per_tenant": svc.telemetry.snapshot()}
    report_text = svc.telemetry.report()
    svc.stop()
    if errors:
        raise errors[0]

    rel = max(abs(a - b) / max(abs(a), 1e-12)
              for a, b in zip(seq_scores, svc_scores))
    return {
        "agents": n_agents,
        "rows": n_rows,
        "sequential_s": sequential_s,
        "service_s": service_s,
        "speedup": sequential_s / service_s,
        "score_rel_diff": rel,
        "ops_deduped_cross_agent":
            telemetry["global"]["ops_deduped_cross_agent"],
        "shared_cache_hits": sum(t["cache_hits"]
                                 for t in telemetry["per_tenant"].values()),
        "telemetry": telemetry,
        "telemetry_report": report_text,
    }


def write_service_json(result: dict, path: str = "BENCH_service.json",
                       merge: bool = False) -> None:
    if merge and os.path.exists(path):
        with open(path) as f:
            prev = json.load(f)
        prev.update(result)
        result = prev
    with open(path, "w") as f:
        json.dump(result, f, indent=2, default=str)


# ---------------------------------------------------------------------------
# sharded-fabric scaling: N agents over 1 vs K consistent-hash shards
# ---------------------------------------------------------------------------

def _cohort_job(cohort_seed: int, n_rows: int, tail_idx: int
                ) -> PipelineBatch:
    """One agent-round job: an expensive preprocessing *prefix* shared by
    the whole cohort (read → TableVectorizer fit over the cohort's
    dataset — real encoder compute, not just IO) and a cheap
    per-(agent, round) unique *tail* (a metric on one vectorized column)
    — the regime where shard-local cache/CSE locality decides
    throughput."""
    from repro.data.tabular import feature_target_indices, schema_dict
    feats, tgt = feature_target_indices()
    x = T.read("uk_housing", n_rows, seed=cohort_seed)
    Xv = T.table_vectorizer(T.project(x, feats), schema_dict(), feats)
    y = T.project(x, [tgt])
    col = tail_idx % len(feats)
    kind = "mae" if (tail_idx // len(feats)) % 2 else "rmse"
    sink = T.metric(T.project(Xv, [col]), y, kind=kind)
    return PipelineBatch([sink], [f"tail{tail_idx}"])


def _balanced_cohort_keys(n_cohorts: int, n_shards: int, vnodes: int = 64
                          ) -> list:
    """Affinity keys placing ``n_cohorts`` work groups evenly on an
    ``n_shards`` ring.  Placement is deterministic (blake2b ring), so the
    scaling measurement is not at the mercy of hash luck on 4 draws; with
    many real datasets the ring balances statistically, which is what this
    emulates."""
    from repro.service.fabric import ConsistentHashRing
    ring = ConsistentHashRing([f"shard-{i}" for i in range(n_shards)],
                              vnodes=vnodes)
    keys, used = [], set()
    i = 0
    while len(keys) < n_cohorts and i < 100_000:
        key = f"cohort-{i}"
        shard = ring.route(key)
        if shard not in used or len(used) == n_shards:
            if shard in used:           # ring full: start a second lap
                used.clear()
            used.add(shard)
            keys.append(key)
        i += 1
    return keys


def _run_fabric_mode(n_shards: int, n_agents: int, n_cohorts: int,
                     rounds: int, n_rows: int, jit_dir: str,
                     ring_shards_for_keys: int) -> dict:
    from repro.service import ServiceConfig
    from repro.service.fabric import ShardedStratum
    # per-shard cache sized to hold ~1.3 cohort working sets (one host's
    # RAM holds its own cohort with headroom): a shard serving its cohort
    # stays hot, one server serving every cohort LRU-thrashes — the
    # single-server ceiling the fabric removes.  A looser budget lets the
    # single server keep 2 cohorts resident and the measurement bimodal.
    # ~900 B/row ≈ one cohort's cached TableVectorizer intermediates
    # (measured at 30k rows; scales linearly with rows)
    per_cohort = int(n_rows * 900)
    mem_budget = 256 << 20
    cfg = ServiceConfig(
        memory_budget_bytes=mem_budget,
        cache_fraction=min(0.5, 1.3 * per_cohort / mem_budget),
        jit_cache_dir=jit_dir,
        coalesce_window_s=0.005,
        coalesce_max_jobs=2,
        max_jobs_per_tenant_per_round=1,
        # one executor per shard: per-shard resources are identical across
        # modes (the fabric's aggregate grows with shards, which is the
        # claim under test), and the single server's eviction pattern is
        # deterministic — with 2 executors, concurrently running
        # super-batches race each other's cache insertions and the
        # 1-shard number becomes a coin flip between thrash and reuse
        n_executors=1,
        # per-op dispatch: this experiment isolates INTERMEDIATE-cache
        # locality (shard-local working sets vs single-server LRU thrash).
        # Compiled segments make recompute ~4x cheaper, which shrinks the
        # thrash penalty and would entangle the two effects; the compiled
        # dispatch win is measured by its own section (--sections compiled)
        compiled_segments=False)
    keys = _balanced_cohort_keys(n_cohorts, ring_shards_for_keys)
    fab = ShardedStratum(n_shards=n_shards, config=cfg)
    sessions = [fab.session(f"agent-{i}") for i in range(n_agents)]
    scores = [[None] * rounds for _ in range(n_agents)]

    # open-loop: every agent's whole sweep is submitted up front, round by
    # round in agent order.  Adjacent submissions belong to *different*
    # cohorts (agent i → cohort i % n_cohorts), so a single shard sees a
    # strict cross-cohort interleave — the deterministic worst case for
    # its LRU cache — while each fabric shard's queue holds only its own
    # cohort's jobs.  (A closed loop measures the same effect but lets
    # same-cohort agents phase-lock into bursts, making the single-shard
    # number a coin flip.)
    t0 = time.perf_counter()
    futures = []
    for r in range(rounds):
        for i in range(n_agents):
            cohort = i % n_cohorts
            rank = i // n_cohorts           # position within the cohort
            tail = rank * rounds + r        # unique within the cohort
            futures.append((i, r, tail, sessions[i].submit(
                _cohort_job(cohort, n_rows, tail),
                affinity=keys[cohort])))
    for i, r, tail, fut in futures:
        res, _ = fut.result(timeout=600)
        scores[i][r] = float(np.asarray(res[f"tail{tail}"]))
    makespan = time.perf_counter() - t0
    g = fab.telemetry.global_snapshot()
    fab.stop()
    total_jobs = n_agents * rounds
    return {
        "shards": n_shards,
        "makespan_s": makespan,
        "throughput_jobs_per_s": total_jobs / makespan,
        "locality_hit_rate": g["signature_locality_hit_rate"],
        "super_batches": g["super_batches"],
        "envelopes_per_shard": {k: v["envelopes_routed"]
                                for k, v in g["per_shard"].items()},
        "scores": scores,
    }


def run_sharded(n_agents: int = 16, rounds: int = 3, n_rows: int = 30_000,
                n_cohorts: int = 4, shard_counts=(1, 4),
                warmup: bool = True) -> dict:
    """Aggregate throughput of the sharded fabric vs one service shard.

    ``n_agents`` agents in ``n_cohorts`` cohorts (one dataset each) submit
    open-loop multi-round sweeps.  Cohorts are pinned to ring
    positions via affinity keys, so with K shards each shard serves ~K-th
    of the cohorts and its intermediate cache stays hot; one shard serving
    every cohort thrashes its cache — the structural ceiling the ROADMAP's
    "shard the service across hosts" item targets.  Scores must be
    identical across shard counts (same deterministic pipelines)."""
    from repro.data.tabular import ensure_files
    for c in range(n_cohorts):
        ensure_files("uk_housing", n_rows, c)
    jit_dir = "/tmp/repro_jit_cache"
    max_shards = max(shard_counts)

    if warmup:   # compile each op shape once so no mode pays XLA compile
        s = Stratum(memory_budget_bytes=256 << 20, jit_cache_dir=jit_dir,
                    compiled_segments=False)   # match the modes' regime
        s.run_batch(_cohort_job(0, n_rows, 0))

    modes = {}
    for n_shards in shard_counts:
        modes[str(n_shards)] = _run_fabric_mode(
            n_shards, n_agents, n_cohorts, rounds, n_rows, jit_dir,
            ring_shards_for_keys=max_shards)

    lo = modes[str(min(shard_counts))]
    hi = modes[str(max(shard_counts))]
    scores_identical = all(
        abs(a - b) <= 1e-9 * max(abs(a), 1.0)
        for ra, rb in zip(lo["scores"], hi["scores"])
        for a, b in zip(ra, rb))
    out = {
        "agents": n_agents,
        "rounds": rounds,
        "rows": n_rows,
        "cohorts": n_cohorts,
        "modes": {k: {kk: vv for kk, vv in v.items() if kk != "scores"}
                  for k, v in modes.items()},
        "speedup": hi["throughput_jobs_per_s"] / lo["throughput_jobs_per_s"],
        "scores_identical": scores_identical,
    }
    return out


def sharded_rows(smoke: bool = False,
                 out: str = "BENCH_service.json") -> list:
    kw = dict(n_agents=16, rounds=3, n_rows=12_000) if smoke else {}
    r = run_sharded(**kw)
    key = "sharded_smoke" if smoke else "sharded"
    write_service_json({key: r}, out, merge=True)
    lo, hi = (r["modes"][str(k)] for k in (min(map(int, r["modes"])),
                                           max(map(int, r["modes"]))))
    return [
        (f"{key}_1shard_makespan", lo["makespan_s"] * 1e6,
         f"{lo['throughput_jobs_per_s']:.2f}_jobs_per_s"),
        (f"{key}_{hi['shards']}shard_makespan", hi["makespan_s"] * 1e6,
         f"{hi['throughput_jobs_per_s']:.2f}_jobs_per_s "
         f"(speedup={r['speedup']:.1f}x)"),
        (f"{key}_locality", hi["locality_hit_rate"] * 1e6, "hit_rate_x1e-6"),
        (f"{key}_scores_identical", float(r["scores_identical"]),
         "1=identical"),
    ]


# ---------------------------------------------------------------------------
# out-of-process fabric: CPU-bound cohort flood, 1 vs K worker processes
# ---------------------------------------------------------------------------

def _run_proc_mode(n_procs: int, n_agents: int, n_cohorts: int,
                   rounds: int, n_rows: int, jit_dir: str,
                   ring_shards_for_keys: int) -> dict:
    from repro.service import ServiceConfig
    from repro.service.fabric import ProcConfig, ProcStratumFabric
    cfg = ServiceConfig(
        memory_budget_bytes=256 << 20,
        jit_cache_dir=jit_dir,
        coalesce_window_s=0.005,
        coalesce_max_jobs=2,
        max_jobs_per_tenant_per_round=1,
        n_executors=1,
        compiled_segments=False)
    keys = _balanced_cohort_keys(n_cohorts, ring_shards_for_keys)
    fab = ProcStratumFabric(n_shards=n_procs, config=cfg,
                            proc=ProcConfig(heartbeat_s=0.25,
                                            heartbeat_timeout_s=10.0))
    try:
        sessions = [fab.session(f"agent-{i}") for i in range(n_agents)]
        scores = [[None] * rounds for _ in range(n_agents)]
        submitted = n_agents * rounds
        t0 = time.perf_counter()
        futures = []
        for r in range(rounds):
            for i in range(n_agents):
                cohort = i % n_cohorts
                tail = (i // n_cohorts) * rounds + r
                futures.append((i, r, tail, sessions[i].submit(
                    _cohort_job(cohort, n_rows, tail),
                    affinity=keys[cohort])))
        completed = 0
        for i, r, tail, fut in futures:
            res, _ = fut.result(timeout=600)
            scores[i][r] = float(np.asarray(res[f"tail{tail}"]))
            completed += 1
        makespan = time.perf_counter() - t0
        g = fab.telemetry.global_snapshot()
    finally:
        fab.stop()
    return {
        "procs": n_procs,
        "makespan_s": makespan,
        "throughput_jobs_per_s": submitted / makespan,
        "completed_frac": completed / submitted,
        "worker_spawns": g["proc"]["spawns"],
        "worker_failures": g["proc"]["worker_failures"],
        "scores": scores,
    }


def run_proc_fabric(n_agents: int = 8, rounds: int = 3, n_rows: int = 20_000,
                    n_cohorts: int = 2, proc_counts=(1, 2)) -> dict:
    """CPU-bound cohort flood through 1 vs K *worker processes*.

    Same open-loop workload as the sharded section, but each shard is a
    real OS process (``ProcStratumFabric``): the K-process mode escapes
    the GIL and, on a multi-core host, approaches Kx aggregate
    throughput.  ``n_cpus`` is recorded alongside the speedup because the
    headline number is honest only relative to the cores available —
    on a single-core runner the K-process mode measures pure fabric
    overhead (framing, supervision, heartbeats), not parallelism, so the
    regression gate rides on ``completed_frac`` (zero job loss), which
    holds on any machine."""
    from repro.data.tabular import ensure_files
    for c in range(n_cohorts):
        ensure_files("uk_housing", n_rows, c)
    jit_dir = "/tmp/repro_jit_cache"
    max_procs = max(proc_counts)

    modes = {}
    for n_procs in proc_counts:
        modes[str(n_procs)] = _run_proc_mode(
            n_procs, n_agents, n_cohorts, rounds, n_rows, jit_dir,
            ring_shards_for_keys=max_procs)

    lo = modes[str(min(proc_counts))]
    hi = modes[str(max(proc_counts))]
    scores_identical = all(
        abs(a - b) <= 1e-9 * max(abs(a), 1.0)
        for ra, rb in zip(lo["scores"], hi["scores"])
        for a, b in zip(ra, rb))
    return {
        "agents": n_agents,
        "rounds": rounds,
        "rows": n_rows,
        "cohorts": n_cohorts,
        "n_cpus": os.cpu_count(),
        "modes": {k: {kk: vv for kk, vv in v.items() if kk != "scores"}
                  for k, v in modes.items()},
        "speedup": hi["throughput_jobs_per_s"] / lo["throughput_jobs_per_s"],
        "completed_frac": min(lo["completed_frac"], hi["completed_frac"]),
        "scores_identical": scores_identical,
    }


def proc_fabric_rows(smoke: bool = False,
                     out: str = "BENCH_service.json") -> list:
    kw = (dict(n_agents=4, rounds=2, n_rows=3000)
          if smoke else {})
    r = run_proc_fabric(**kw)
    key = "fabric_proc_smoke" if smoke else "fabric_proc"
    write_service_json({key: r}, out, merge=True)
    lo, hi = (r["modes"][str(k)] for k in (min(map(int, r["modes"])),
                                           max(map(int, r["modes"]))))
    return [
        (f"{key}_1proc_makespan", lo["makespan_s"] * 1e6,
         f"{lo['throughput_jobs_per_s']:.2f}_jobs_per_s"),
        (f"{key}_{hi['procs']}proc_makespan", hi["makespan_s"] * 1e6,
         f"{hi['throughput_jobs_per_s']:.2f}_jobs_per_s "
         f"(speedup={r['speedup']:.2f}x on {r['n_cpus']} cpus)"),
        (f"{key}_completed", r["completed_frac"] * 1e6,
         "frac_x1e-6 (1e6=zero_loss)"),
        (f"{key}_scores_identical", float(r["scores_identical"]),
         "1=identical"),
    ]


# ---------------------------------------------------------------------------
# compiled plan-segment benchmark: repeated-structure workload, whole-segment
# jit + structural plan cache vs per-op dispatch
# ---------------------------------------------------------------------------

def _refinement_batch(round_i: int, n_variants: int, n_rows: int
                      ) -> PipelineBatch:
    """One round of AIDE-style refinements: ``n_variants`` pipelines with
    identical structure, differing only in tunable constants.  The clip
    quantile varies *early* in the DAG, so every downstream signature is
    fresh each round — the intermediate cache cannot short-circuit the
    work, and the measured gap is purely compiled-segment dispatch vs
    per-op dispatch over a warm structural plan cache."""
    from repro.data.tabular import feature_target_indices
    feats, tgt = feature_target_indices()
    cols = list(feats[:8])
    sinks, names = [], []
    x = T.read("uk_housing", n_rows, seed=0)
    y = T.project(x, [tgt])
    for j in range(n_variants):
        k = round_i * n_variants + j
        Xc = T.clip_outliers(T.project(x, cols), q=0.001 + 0.0004 * k)
        Xs = T.log1p(T.scale(T.impute(Xc)))
        w = T.ridge_fit(Xs, y, alpha=0.05 * (1 + k))
        sinks.append(T.metric(y, T.predict(w, Xs), kind="rmse"))
        names.append(f"r{round_i}v{j}")
    return PipelineBatch(sinks, names)


def _compiled_mode(compiled: bool, rounds: int, n_variants: int,
                   n_rows: int, jit_dir: str, **svc_kw) -> dict:
    svc = StratumService(memory_budget_bytes=2 << 30,
                         jit_cache_dir=jit_dir,
                         coalesce_window_s=0.0,
                         n_executors=1,
                         compiled_segments=compiled,
                         **svc_kw)
    try:
        ses = svc.session("agent")
        # two warmup rounds (indices past the measured range): the first
        # warms the per-op jit caches and the intermediate cache, the
        # second compiles the segment shape measured rounds actually see
        # (shared prefix ops become cache hits, changing the segment cut)
        for w in (rounds, rounds + 1):
            ses.submit(_refinement_batch(w, n_variants, n_rows)
                       ).result(timeout=600)
        scores = []
        round_times = []
        t0 = time.perf_counter()
        for r in range(rounds):
            r0 = time.perf_counter()
            res, _ = ses.submit(_refinement_batch(r, n_variants, n_rows)
                                ).result(timeout=600)
            round_times.append(time.perf_counter() - r0)
            scores.extend(float(np.asarray(res[f"r{r}v{j}"]))
                          for j in range(n_variants))
        makespan = time.perf_counter() - t0
        g = svc.telemetry.global_snapshot()
    finally:
        svc.stop()
    out = {
        "compiled_segments": compiled,
        "makespan_s": makespan,
        "round_median_s": float(np.median(round_times)),
        "pipelines_per_s": rounds * n_variants / makespan,
        "scores": scores,
    }
    if "plan_cache" in g:
        out["plan_cache"] = g["plan_cache"]
    return out


def run_compiled(rounds: int = 10, n_variants: int = 8,
                 n_rows: int = 4000) -> dict:
    """Compiled plan-segment backends vs per-op dispatch on the
    repeated-structure workload (structurally identical refinement rounds
    differing only in constants).  Scores must be identical — segmentation
    changes dispatch granularity, never semantics."""
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"
    per_op = _compiled_mode(False, rounds, n_variants, n_rows, jit_dir)
    comp = _compiled_mode(True, rounds, n_variants, n_rows, jit_dir)
    max_rel = max(abs(a - b) / max(abs(a), 1e-12)
                  for a, b in zip(comp["scores"], per_op["scores"]))
    out = {
        "rounds": rounds,
        "variants": n_variants,
        "rows": n_rows,
        "modes": {
            "per_op": {k: v for k, v in per_op.items() if k != "scores"},
            "compiled": {k: v for k, v in comp.items() if k != "scores"},
        },
        "speedup": per_op["makespan_s"] / comp["makespan_s"],
        # whole-segment XLA fusion may reassociate float32 reductions vs
        # the eager per-op order; 1e-6 relative is float32 parity, far
        # below any score-ranking significance
        "score_max_rel_diff": max_rel,
        "scores_identical": bool(max_rel <= 1e-6),
        "plan_cache_hit_rate":
            comp.get("plan_cache", {}).get("hit_rate", 0.0),
    }
    return out


def compiled_rows(smoke: bool = False,
                  out: str = "BENCH_service.json") -> list:
    kw = dict(rounds=5, n_variants=6, n_rows=2000) if smoke else {}
    r = run_compiled(**kw)
    key = "compiled_smoke" if smoke else "compiled"
    write_service_json({key: r}, out, merge=True)
    m = r["modes"]
    return [
        (f"{key}_per_op", m["per_op"]["makespan_s"] * 1e6,
         f"{m['per_op']['pipelines_per_s']:.1f}_pipelines_per_s"),
        (f"{key}_compiled", m["compiled"]["makespan_s"] * 1e6,
         f"{m['compiled']['pipelines_per_s']:.1f}_pipelines_per_s "
         f"(speedup={r['speedup']:.1f}x)"),
        (f"{key}_plan_cache_hit_rate", r["plan_cache_hit_rate"] * 1e6,
         "hit_rate_x1e-6"),
        (f"{key}_scores_identical", float(r["scores_identical"]),
         "1=identical"),
    ]


# ---------------------------------------------------------------------------
# batched variant solves: homogeneous variant fans traced ONCE and vmapped
# across the fan, vs the unrolled whole-segment jit and per-op dispatch
# ---------------------------------------------------------------------------

def _in_fresh_interpreter(fn_name: str, *args):
    """Run one module-level helper of this file in a FRESH python
    interpreter and return its JSON-decoded result.

    Cold-start numbers measured inside the long-lived multi-section
    bench process are fiction: earlier sections leave the persistent
    XLA cache initialized (``jax.config.update(...)`` cannot fully
    un-initialize it), XLA's in-process compilation machinery warm, and
    enough allocator/thread residue to swing first-touch latency
    severalfold between runs.  A fresh interpreter is what a cold agent
    service actually is, and makes the numbers reproducible regardless
    of which sections ran before."""
    import subprocess
    import sys
    here = os.path.dirname(os.path.abspath(__file__))
    paths = [here, os.path.abspath(os.path.join(here, "..", "src"))]
    code = (f"import sys\nsys.path[:0] = {paths!r}\n"
            "import json\n"
            "import e2e_agentic as m\n"
            f"r = getattr(m, {fn_name!r})(*{args!r})\n"
            "print('RESULT ' + json.dumps(r))\n")
    env = dict(os.environ)
    env.pop("JAX_COMPILATION_CACHE_DIR", None)  # cold means cold
    proc = subprocess.run([sys.executable, "-c", code], text=True,
                          capture_output=True, env=env, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"{fn_name}{args} subprocess failed:\n"
                           f"{proc.stderr[-2000:]}")
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"{fn_name}{args} subprocess printed no result")


def _cold_first_touch_s(batch_variants: bool, n_variants: int,
                        n_rows: int) -> float:
    """Wall time of the FIRST structurally-fresh round on a fresh session
    with blocking compiles and no persistent XLA cache: pure trace+jit
    cost of one refinement fan (plus one warm execution).  A tiny jit
    call first charges backend/LLVM bring-up to setup, not to the
    measured fan.  Meaningful only in a fresh interpreter — run it via
    ``_in_fresh_interpreter``."""
    import jax
    jax.block_until_ready(jax.jit(lambda v: v + 1.0)(
        np.zeros(8, np.float32)))
    st = Stratum(memory_budget_bytes=2 << 30, compiled_segments=True,
                 batch_variants=batch_variants)
    try:
        t0 = time.perf_counter()
        st.run_batch(_refinement_batch(97, n_variants, n_rows))
        return time.perf_counter() - t0
    finally:
        st.close()


def run_compiled_batched(rounds: int = 10, n_variants: int = 8,
                         n_rows: int = 4000) -> dict:
    """Batched variant solves on the repeated-structure workload: each
    AIDE-style refinement fan holds ``n_variants`` structurally identical
    pipelines, so with ``batch_variants=True`` the jax segment backend
    traces the fan ONCE and ``vmap``s it across the variants instead of
    unrolling ``n_variants`` copies into the traced body.  Warm
    throughput must at least match the unrolled compiled mode (one fused
    program either way — the work is compute-bound); the structural win
    is COLD COMPILE TIME, one traced body instead of ``n_variants``,
    measured as blocking first-touch wall time with the persistent XLA
    cache disabled.  Scores must match per-op dispatch to float32 parity
    (≤1e-6 relative): batching changes trace layout, never semantics.

    The gated ``speedup`` is the ratio of per-round MEDIANS, not total
    makespans: on a small shared CI box one OS-noise straggler round out
    of five can double a makespan, and a regression gate on that tail
    flakes; the median isolates the steady-state dispatch cost the
    section actually claims.  Makespans stay in the artifact."""
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"
    per_op = _compiled_mode(False, rounds, n_variants, n_rows, jit_dir)
    comp = _compiled_mode(True, rounds, n_variants, n_rows, jit_dir)
    bat = _compiled_mode(True, rounds, n_variants, n_rows, jit_dir,
                         batch_variants=True)
    max_rel = max(abs(a - b) / max(abs(a), 1e-12)
                  for a, b in zip(bat["scores"], per_op["scores"]))
    # cold-compile comparison: each layout in its own fresh interpreter,
    # so neither this process's warm XLA state nor the other layout's
    # compile can contaminate the first touch
    cold_unrolled = _in_fresh_interpreter(
        "_cold_first_touch_s", False, n_variants, n_rows)
    cold_batched = _in_fresh_interpreter(
        "_cold_first_touch_s", True, n_variants, n_rows)
    return {
        "rounds": rounds, "variants": n_variants, "rows": n_rows,
        "modes": {
            "per_op": {k: v for k, v in per_op.items() if k != "scores"},
            "compiled": {k: v for k, v in comp.items() if k != "scores"},
            "batched": {k: v for k, v in bat.items() if k != "scores"},
        },
        "speedup": per_op["round_median_s"] / bat["round_median_s"],
        "batched_over_compiled":
            comp["round_median_s"] / bat["round_median_s"],
        "cold_compile_unrolled_s": cold_unrolled,
        "cold_compile_batched_s": cold_batched,
        "cold_compile_speedup": cold_unrolled / cold_batched,
        "score_max_rel_diff": max_rel,
        "scores_identical": bool(max_rel <= 1e-6),
    }


def compiled_batched_rows(smoke: bool = False,
                          out: str = "BENCH_service.json") -> list:
    kw = dict(rounds=5, n_variants=6, n_rows=2000) if smoke else {}
    r = run_compiled_batched(**kw)
    key = "compiled_batched_smoke" if smoke else "compiled_batched"
    write_service_json({key: r}, out, merge=True)
    m = r["modes"]
    return [
        (f"{key}_batched", m["batched"]["makespan_s"] * 1e6,
         f"{m['batched']['pipelines_per_s']:.1f}_pipelines_per_s "
         f"(speedup={r['speedup']:.2f}x vs per_op, "
         f"{r['batched_over_compiled']:.2f}x vs unrolled)"),
        (f"{key}_cold_compile", r["cold_compile_batched_s"] * 1e6,
         f"vs_unrolled_{r['cold_compile_unrolled_s']:.2f}s "
         f"({r['cold_compile_speedup']:.1f}x_faster)"),
        (f"{key}_scores_identical", float(r["scores_identical"]),
         "1=identical"),
    ]


# ---------------------------------------------------------------------------
# async/speculative compilation: first-touch latency when pipeline structure
# keeps changing — blocking compiles vs background compiles + warm-up hints
# ---------------------------------------------------------------------------

def _cold_batch(struct_i: int, round_i: int, n_variants: int, n_rows: int
                ) -> PipelineBatch:
    """A refinement fan whose STRUCTURE changes with ``struct_i``: the
    post-scale ``log1p`` tower is ``struct_i + 1`` deep, so every new
    struct index is a fresh jax-segment structural signature (a plan
    cache miss), while ``round_i`` varies only tunable constants within
    it.  The clip quantile is GLOBALLY unique per (struct, round,
    variant): the shared prefix up to ``clip_outliers`` is structurally
    identical across the whole ladder, so a repeated quantile would make
    one variant's clip an intermediate-cache hit, silently changing that
    round's segment cut (and forcing a recompile) — a tiny offset keeps
    every signature fresh while leaving the quantile in range."""
    from repro.data.tabular import feature_target_indices
    feats, tgt = feature_target_indices()
    cols = list(feats[:8])
    sinks, names = [], []
    x = T.read("uk_housing", n_rows, seed=0)
    y = T.project(x, [tgt])
    for j in range(n_variants):
        k = (struct_i * 1000 + round_i) * n_variants + j
        Xc = T.clip_outliers(T.project(x, cols), q=0.001 + 1e-8 * k)
        Xs = T.scale(T.impute(Xc))
        for _ in range(struct_i + 1):
            Xs = T.log1p(Xs)
        w = T.ridge_fit(Xs, y, alpha=0.05 * (1 + (k % 997)))
        sinks.append(T.metric(y, T.predict(w, Xs), kind="rmse"))
        names.append(f"s{struct_i}r{round_i}v{j}")
    return PipelineBatch(sinks, names)


def _cold_mode(async_on: bool, n_structs: int, reps: int, n_variants: int,
               n_rows: int) -> dict:
    svc_kw = (dict(compile_async=True, speculative_depth=4)
              if async_on else {})
    svc = StratumService(memory_budget_bytes=2 << 30,
                         coalesce_window_s=0.0, n_executors=1,
                         compiled_segments=True, batch_variants=True,
                         **svc_kw)
    try:
        ses = svc.session("agent")
        # warm the per-op jits, the data files and (async mode) the
        # background worker on a throwaway structure the measured ladder
        # never revisits
        for w in (0, 1):
            ses.submit(_cold_batch(99, w, n_variants, n_rows)
                       ).result(timeout=600)
        cold, warm = [], []
        for s in range(n_structs):
            if async_on:
                # agent think time: speculatively warm the upcoming
                # structure (AIDE's speculate() hook sends the same hint
                # between rounds) and let the background compile land
                # before the next submit — none of this blocks the
                # measured path
                ses.precompile(_cold_batch(s, 998, n_variants, n_rows))
                svc.plan_cache.executor.drain(timeout=120.0)
            for rep in range(reps):
                t0 = time.perf_counter()
                ses.submit(_cold_batch(s, rep, n_variants, n_rows)
                           ).result(timeout=600)
                (cold if rep == 0 else warm).append(
                    time.perf_counter() - t0)
        g = svc.telemetry.global_snapshot()
    finally:
        svc.stop()
    pc = g.get("plan_cache") or {}
    return {
        "async": async_on,
        "cold_p50_s": float(np.median(cold)),
        "cold_p99_s": float(np.percentile(cold, 99)),
        "cold_max_s": max(cold),
        "warm_p50_s": float(np.median(warm)),
        "warm_p99_s": float(np.percentile(warm, 99)),
        "speculative_hits": pc.get("speculative_hits", 0),
        "async_compiles": pc.get("async_compiles", 0),
    }


def run_compiled_cold(n_structs: int = 4, reps: int = 6,
                      n_variants: int = 8, n_rows: int = 4000) -> dict:
    """First-touch latency when pipeline STRUCTURE keeps changing (an
    agent exploring new stages, not just retuning constants): a ladder of
    ``n_structs`` fresh structures, ``reps`` rounds each.  Blocking mode
    pays trace+jit inside the measured first round of every structure;
    ``compile_async=True`` plus a speculative warm-up hint during agent
    think time keeps the first touch on warm programs.  Each mode runs
    in its own fresh interpreter (no persistent XLA cache, no residue
    from other bench sections or from the other mode's compiles of the
    same structures) so every compile is real — see
    ``_in_fresh_interpreter``.

    Gating: ``speculative_hits`` (one per structure — deterministic:
    every measured first touch must land on a speculatively compiled
    program) and the median-based ``cold_p50_speedup``.  With only
    ``n_structs`` cold samples the p99 IS the max, and a single OS-noise
    outlier on a shared CI box would flake a tail gate; the p99s stay in
    the artifact as the headline datapoint, the medians carry the
    gate."""
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)
    blocking = _in_fresh_interpreter(
        "_cold_mode", False, n_structs, reps, n_variants, n_rows)
    async_m = _in_fresh_interpreter(
        "_cold_mode", True, n_structs, reps, n_variants, n_rows)
    # the conservative warm reference: slower of the two modes' warm p99
    warm_p99 = max(blocking["warm_p99_s"], async_m["warm_p99_s"])
    return {
        "structs": n_structs, "reps": reps,
        "variants": n_variants, "rows": n_rows,
        "modes": {"blocking": blocking, "async": async_m},
        "warm_p99_s": warm_p99,
        "cold_over_warm_blocking": blocking["cold_p99_s"] / warm_p99,
        "cold_over_warm_async": async_m["cold_p99_s"] / warm_p99,
        "cold_p99_speedup": blocking["cold_p99_s"] / async_m["cold_p99_s"],
        "cold_p50_speedup": blocking["cold_p50_s"] / async_m["cold_p50_s"],
        "speculative_hits": async_m["speculative_hits"],
    }


def compiled_cold_rows(smoke: bool = False,
                       out: str = "BENCH_service.json") -> list:
    kw = (dict(n_structs=3, reps=4, n_variants=6, n_rows=2000)
          if smoke else {})
    r = run_compiled_cold(**kw)
    key = "compiled_cold_smoke" if smoke else "compiled_cold"
    write_service_json({key: r}, out, merge=True)
    m = r["modes"]
    return [
        (f"{key}_blocking_p99", m["blocking"]["cold_p99_s"] * 1e6,
         f"{r['cold_over_warm_blocking']:.1f}x_warm"),
        (f"{key}_async_p99", m["async"]["cold_p99_s"] * 1e6,
         f"{r['cold_over_warm_async']:.1f}x_warm "
         f"(speedup={r['cold_p99_speedup']:.1f}x, "
         f"spec_hits={r['speculative_hits']})"),
        (f"{key}_warm_p99", r["warm_p99_s"] * 1e6, "s_x1e-6"),
    ]


# ---------------------------------------------------------------------------
# deadline-aware scheduling benchmark: SLO attainment under mixed load
# ---------------------------------------------------------------------------

def _deadline_mode(deadline_aware: bool, n_rows: int, n_cohorts: int,
                   n_bulk_agents: int, sweeps_per_agent: int,
                   probe_rows: int, deadline_s: float,
                   probe_interval_s: float, jit_dir: str) -> dict:
    """One mode of the deadline benchmark: bulk tenants flood the BATCH
    band with deadline-free cohort sweeps while one tenant submits
    sequential probes carrying ``deadline_s`` — the SAME band, so WFQ
    priorities cannot help and only deadline-awareness (EDF tie-break,
    tight-slack solo dispatch, shedding) separates the modes.

    Bulk jobs are ``_cohort_job``\\ s cycling across ``n_cohorts``
    datasets with the intermediate cache squeezed to (effectively)
    nothing: every job recomputes its TableVectorizer prefix, giving each
    bulk job a flat ~0.5s of real work.  (The sharded bench's ~1.3
    working-set squeeze is deliberately NOT used here: with two
    executors, cross-cohort eviction races make job cost — and therefore
    the mode's makespan — bimodal, which would drown the scheduling
    signal this benchmark isolates.)

    The flood is FIXED WORK (``sweeps_per_agent`` jobs each, closed-loop
    3 outstanding) and the prober is OPEN-LOOP (one probe every
    ``probe_interval_s`` until the flood drains): both modes execute the
    same bulk work under the same probe arrival process, so batch
    throughput = total work / makespan is directly comparable, and
    attainment differences come from scheduling alone."""
    mem_budget = 256 << 20
    svc = StratumService(memory_budget_bytes=mem_budget,
                         cache_fraction=1e-5,    # see docstring: flat cost
                         jit_cache_dir=jit_dir,
                         coalesce_window_s=0.02,
                         coalesce_max_jobs=2,
                         max_jobs_per_tenant_per_round=1,
                         n_executors=2,
                         aging_s=None,
                         deadline_aware=deadline_aware,
                         deadline_tight_slack_s=deadline_s)
    try:
        t_start = time.perf_counter()
        flood_done = threading.Event()
        n_flooders_done = [0]
        done_lock = threading.Lock()
        sweeps_done = [0] * n_bulk_agents
        flood_errors: list = []

        def flooder(a: int) -> None:
            try:
                ses = svc.session(f"bulk-{a}")
                from collections import deque
                inflight: "deque" = deque()
                for j in range(sweeps_per_agent):
                    cohort = (a + j) % n_cohorts
                    inflight.append(ses.submit(_cohort_job(
                        cohort, n_rows, a * 100_000 + j)))
                    while len(inflight) >= 3:
                        inflight.popleft().result(timeout=600)
                        sweeps_done[a] += 1
                while inflight:
                    inflight.popleft().result(timeout=600)
                    sweeps_done[a] += 1
            except Exception as e:      # noqa: BLE001
                flood_errors.append(e)
            finally:
                with done_lock:
                    n_flooders_done[0] += 1
                    if n_flooders_done[0] == n_bulk_agents:
                        flood_done.set()

        threads = [threading.Thread(target=flooder, args=(a,))
                   for a in range(n_bulk_agents)]
        for t in threads:
            t.start()
        time.sleep(1.0)            # let the flood reach the runtime
        ses = svc.session("deadline")
        probes: list = []          # (i, t_submit, future)
        done_t: dict = {}          # i -> completion instant (done callback)
        i = 0
        next_t = time.perf_counter()
        while not flood_done.is_set():
            now = time.perf_counter()
            if now >= next_t:
                fut = ses.submit(_probe_batch(i, probe_rows),
                                 deadline_s=deadline_s)
                idx = i
                fut.add_done_callback(
                    lambda f, idx=idx: done_t.setdefault(
                        idx, time.perf_counter()))
                probes.append((idx, now, fut))
                i += 1
                next_t += probe_interval_s
            time.sleep(0.01)
        for t in threads:
            t.join()
        makespan = time.perf_counter() - t_start   # bulk work is done
        lats, scores = [], []
        n_met = n_shed = 0
        for idx, t0, fut in probes:
            try:
                res, _ = fut.result(timeout=600)
                scores.append(float(np.asarray(res[f"probe{idx}"])))
                lat = done_t[idx] - t0
                if lat <= deadline_s:
                    n_met += 1
            except DeadlineExceeded:
                scores.append(None)     # shed = missed, no result at all
                lat = done_t.get(idx, time.perf_counter()) - t0
                n_shed += 1
            lats.append(lat)
        if flood_errors:
            raise flood_errors[0]
        g = svc.telemetry.global_snapshot()
    finally:
        svc.stop()
    return {
        "deadline_aware": deadline_aware,
        "probes_issued": len(lats),
        "attainment": (n_met / len(lats)) if lats else 0.0,
        "probes_met": n_met,
        "probes_shed": n_shed,
        "probe_p50_s": float(np.percentile(lats, 50)) if lats else 0.0,
        "probe_p99_s": float(np.percentile(lats, 99)) if lats else 0.0,
        "sweeps_completed": int(sum(sweeps_done)),
        "batch_makespan_s": makespan,
        "batch_throughput_jobs_per_s": float(sum(sweeps_done)) / makespan,
        "telemetry_deadline": g["deadline"],
        "scores": scores,
        "lats": lats,
    }


def run_deadline(n_rows: int = 30_000, n_cohorts: int = 6,
                 n_bulk_agents: int = 3, sweeps_per_agent: int = 30,
                 probe_rows: int = 2000, deadline_s: float = 0.6,
                 probe_interval_s: float = 1.0, reps: int = 2,
                 warmup: bool = True) -> dict:
    """Deadline-aware scheduling vs deadline-blind, same priority band.

    The claim under test (ROADMAP "deadline/SLO-based scheduling"): with
    EDF tie-breaks + tight-slack solo dispatch + shedding, p99 deadline
    attainment beats the blind scheduler while batch throughput stays
    within a few percent (deadline probes are a tiny fraction of the
    work either way)."""
    from repro.data.tabular import ensure_files
    for c in range(n_cohorts):
        ensure_files("uk_housing", n_rows, c)
    ensure_files("uk_housing", probe_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"

    if warmup:   # compile the jax kernels once so neither mode pays for it
        s = Stratum(memory_budget_bytes=4 << 30, jit_cache_dir=jit_dir)
        s.run_batch(_cohort_job(0, n_rows, 0))
        for i in range(4):                  # probes rotate column sets;
            s.run_batch(_probe_batch(i, probe_rows))   # compile each shape

    args = (n_rows, n_cohorts, n_bulk_agents, sweeps_per_agent, probe_rows,
            deadline_s, probe_interval_s, jit_dir)
    # interleave repetitions (blind, aware, blind, aware) and pool: one
    # fixed-work run is short enough that XLA/GC noise moves its makespan
    # by whole seconds, and the modes must not sit on opposite sides of a
    # machine-state drift
    blind_runs, aware_runs = [], []
    for _ in range(reps):
        blind_runs.append(_deadline_mode(False, *args))
        aware_runs.append(_deadline_mode(True, *args))

    def _pool(runs: list) -> dict:
        lats = [l for r in runs for l in r["lats"]]
        n = sum(r["probes_issued"] for r in runs)
        met = sum(r["probes_met"] for r in runs)
        out = {
            "deadline_aware": runs[0]["deadline_aware"],
            "reps": len(runs),
            "probes_issued": n,
            "attainment": met / n if n else 0.0,
            "probes_met": met,
            "probes_shed": sum(r["probes_shed"] for r in runs),
            "probe_p50_s": float(np.percentile(lats, 50)) if lats else 0.0,
            "probe_p99_s": float(np.percentile(lats, 99)) if lats else 0.0,
            "sweeps_completed": sum(r["sweeps_completed"] for r in runs),
            "batch_makespan_s": sum(r["batch_makespan_s"] for r in runs),
            "telemetry_deadline": runs[-1]["telemetry_deadline"],
        }
        out["batch_throughput_jobs_per_s"] = (
            out["sweeps_completed"] / out["batch_makespan_s"])
        return out

    aware, blind = _pool(aware_runs), _pool(blind_runs)
    # scores must agree wherever BOTH modes produced a result (aware mode
    # sheds expired probes instead of running them late); compare within
    # each repetition pair — probe index i is deterministic given i
    scored = [(a, b)
              for ra, rb in zip(aware_runs, blind_runs)
              for a, b in zip(ra["scores"], rb["scores"])
              if a is not None and b is not None]
    scores_identical = all(abs(a - b) <= 1e-9 * max(abs(a), 1.0)
                           for a, b in scored)
    blind_tp = blind["batch_throughput_jobs_per_s"]
    aware_tp = aware["batch_throughput_jobs_per_s"]
    return {
        "rows": n_rows,
        "cohorts": n_cohorts,
        "sweeps": n_bulk_agents * sweeps_per_agent * reps,
        "deadline_s": deadline_s,
        "aware": aware,
        "blind": blind,
        "attainment_aware": aware["attainment"],
        "attainment_blind": blind["attainment"],
        "p99_latency_improvement":
            blind["probe_p99_s"] / aware["probe_p99_s"],
        "batch_throughput_ratio": aware_tp / blind_tp if blind_tp else 0.0,
        "scores_identical": scores_identical,
    }


def deadline_rows(smoke: bool = False,
                  out: str = "BENCH_service.json") -> list:
    # smoke: lighter flood AND a looser SLO (2s) — CI runners are slower
    # and more contended than the machines the full datapoint is measured
    # on, and the gated metric is the aware-mode attainment rate
    kw = (dict(n_rows=6000, n_cohorts=4, n_bulk_agents=2,
               sweeps_per_agent=14, deadline_s=2.0, reps=1)
          if smoke else {})
    r = run_deadline(**kw)
    key = "deadline_smoke" if smoke else "deadline"
    write_service_json({key: r}, out, merge=True)
    return [
        (f"{key}_attainment_aware", r["attainment_aware"] * 1e6,
         f"blind={r['attainment_blind']:.2f} "
         f"(p99 {r['p99_latency_improvement']:.1f}x better)"),
        (f"{key}_probe_p99", r["aware"]["probe_p99_s"] * 1e6,
         f"blind={r['blind']['probe_p99_s'] * 1e6:.0f}us"),
        (f"{key}_batch_throughput_ratio",
         r["batch_throughput_ratio"] * 1e6, "aware/blind_x1e-6"),
        (f"{key}_scores_identical", float(r["scores_identical"]),
         "1=identical"),
    ]


# ---------------------------------------------------------------------------
# closed-loop control benchmark: adaptive admission vs static config
# ---------------------------------------------------------------------------

def _control_mode(controlled: bool, n_rows: int, n_cohorts: int,
                  n_bulk_agents: int, steady_sweeps: int, flood_sweeps: int,
                  probe_rows: int, deadline_s: float,
                  probe_interval_s: float, jit_dir: str) -> dict:
    """One mode of the control benchmark: a two-phase workload against a
    service whose admission gate is deliberately small
    (``max_queued_total=16``).

    Phase 1 (steady mix): each bulk tenant keeps 2 cohort sweeps
    outstanding — the queue never fills, everyone is admitted.  Phase 2
    (batch flood): each bulk tenant jumps to 12 outstanding and retries
    ``AdmissionError`` every 20ms, pinning the queue at its cap.  An
    open-loop prober submits one INTERACTIVE tight-deadline probe every
    ``probe_interval_s`` throughout; probes do NOT retry — a
    latency-bound agent that can't get in has already missed.

    Static config rejects flood-phase probes at the edge ("queue full"),
    so attainment collapses.  The controller's INTERACTIVE admission
    reserve (standing floor clamp) keeps probes admitted mid-flood, and
    its AIMD gate shrinks the bulk bands' queue depth — visible as
    ``retuned`` actuations.  Bulk work is FIXED (steady + flood sweeps
    per agent, closed-loop), so batch throughput stays comparable."""
    mem_budget = 256 << 20
    control = None
    if controlled:
        # p99 target well under the probe SLO: bulk queue wait at the full
        # 16-deep gate (~depth/drain) breaches it, so the AIMD gate
        # actually actuates during the flood (observable retunes)
        # floor at 14: the INTERACTIVE reserve (not the shrink) is what
        # keeps probes admitted, so the gate only needs to shave the
        # flood's queue wait — a deep floor keeps both executors fed and
        # the bulk throughput near static's
        # reserve 2: probes arrive one at a time (interval >> service
        # time), so a tiny reserve already guarantees admission while
        # carving the least bulk capacity out of the shared gate
        control = ControlPolicy(dispatch_p99_target_s=deadline_s / 4.0,
                                interactive_reserve=2,
                                min_queued_total=14,
                                tick_interval_s=0.25,
                                cooldown_s=1.0)
    # ~512KB intermediate cache: holds a repeat probe's working set
    # (2000-row read + 3 projected cols) but not a cohort prefix (~5MB),
    # so bulk-job cost stays flat while repeat probes are served from
    # cache in BOTH modes — the bench compares scheduling policy, not
    # who pays the probes' recompute
    svc = StratumService(memory_budget_bytes=mem_budget,
                         cache_fraction=2e-3,
                         jit_cache_dir=jit_dir,
                         # 5ms window: at 0.1s/job a long gather would
                         # idle an executor slot every time a solo probe
                         # or a thin band pops (the slot is held while
                         # the window waits)
                         coalesce_window_s=0.005,
                         coalesce_max_jobs=2,
                         max_jobs_per_tenant_per_round=1,
                         n_executors=2,
                         aging_s=None,
                         max_queued_total=16,
                         deadline_aware=True,
                         # solo dispatch only for genuinely endangered
                         # probes: at tight_slack == deadline every probe
                         # would dispatch solo from t0 and the drains
                         # would serialize the executors
                         deadline_tight_slack_s=deadline_s / 4.0,
                         control=control)
    try:
        t_start = time.perf_counter()
        flood_done = threading.Event()
        n_flooders_done = [0]
        done_lock = threading.Lock()
        sweeps_done = [0] * n_bulk_agents
        flood_errors: list = []

        def _submit_retry(ses, batch):
            # bulk clients are throughput-bound: back off and retry until
            # the edge admits them (same behaviour in both modes; the
            # backoff is short so a shrunken gate measures the gate, not
            # the client's poll interval)
            while True:
                try:
                    return ses.submit(batch)
                except AdmissionError:
                    time.sleep(0.005)

        def flooder(a: int) -> None:
            try:
                ses = svc.session(f"bulk-{a}")
                from collections import deque
                inflight: "deque" = deque()
                for j in range(steady_sweeps + flood_sweeps):
                    outstanding = 2 if j < steady_sweeps else 12
                    inflight.append(_submit_retry(ses, _cohort_job(
                        (a + j) % n_cohorts, n_rows, a * 100_000 + j)))
                    while len(inflight) >= outstanding:
                        inflight.popleft().result(timeout=600)
                        sweeps_done[a] += 1
                while inflight:
                    inflight.popleft().result(timeout=600)
                    sweeps_done[a] += 1
            except Exception as e:      # noqa: BLE001
                flood_errors.append(e)
            finally:
                with done_lock:
                    n_flooders_done[0] += 1
                    if n_flooders_done[0] == n_bulk_agents:
                        flood_done.set()

        threads = [threading.Thread(target=flooder, args=(a,))
                   for a in range(n_bulk_agents)]
        for t in threads:
            t.start()
        time.sleep(1.0)            # let the steady phase reach the runtime
        ses = svc.session("probe")
        probes: list = []          # (i, t_submit, future) — admitted only
        done_t: dict = {}
        n_rejected = 0
        i = 0
        next_t = time.perf_counter()
        while not flood_done.is_set():
            now = time.perf_counter()
            if now >= next_t:
                try:
                    # rotate over the 4 pre-warmed probe variants: an
                    # unbounded index would make every probe a fresh JIT
                    # compile (~0.3s), and the bench would measure
                    # compilation backpressure instead of scheduling
                    fut = ses.submit(_probe_batch(i % 4, probe_rows),
                                     priority=Priority.INTERACTIVE,
                                     deadline_s=deadline_s)
                except AdmissionError:
                    n_rejected += 1     # rejected at the edge = missed
                else:
                    idx = i
                    fut.add_done_callback(
                        lambda f, idx=idx: done_t.setdefault(
                            idx, time.perf_counter()))
                    probes.append((idx, now, fut))
                i += 1
                next_t += probe_interval_s
            time.sleep(0.01)
        for t in threads:
            t.join()
        makespan = time.perf_counter() - t_start
        lats = []
        scores: dict = {}          # probe index -> score (admitted+done)
        n_met = n_shed = 0
        for idx, t0, fut in probes:
            try:
                res, _ = fut.result(timeout=600)
                scores[idx] = float(np.asarray(res[f"probe{idx % 4}"]))
                lat = done_t[idx] - t0
                if lat <= deadline_s:
                    n_met += 1
                lats.append(lat)
            except DeadlineExceeded:
                n_shed += 1
        if flood_errors:
            raise flood_errors[0]
        g = svc.telemetry.global_snapshot()
    finally:
        svc.stop()
    issued = len(probes) + n_rejected
    ctl = g.get("control") or {}
    return {
        "controlled": controlled,
        "probes_issued": issued,
        "probes_admitted": len(probes),
        "probes_rejected": n_rejected,
        "probes_met": n_met,
        "probes_shed": n_shed,
        "attainment": (n_met / issued) if issued else 0.0,
        "probe_p50_s": float(np.percentile(lats, 50)) if lats else 0.0,
        "probe_p99_s": float(np.percentile(lats, 99)) if lats else 0.0,
        "sweeps_completed": int(sum(sweeps_done)),
        "batch_makespan_s": makespan,
        "batch_throughput_jobs_per_s": float(sum(sweeps_done)) / makespan,
        "retunes": ctl.get("retunes", 0),
        "control_snapshot": ctl or None,
        "scores": scores,
        "lats": lats,
    }


def run_control(n_rows: int = 12_000, n_cohorts: int = 4,
                n_bulk_agents: int = 3, steady_sweeps: int = 10,
                flood_sweeps: int = 60, probe_rows: int = 2000,
                deadline_s: float = 1.5, probe_interval_s: float = 0.4,
                reps: int = 2, warmup: bool = True) -> dict:
    """Closed-loop control vs static config on a two-phase workload.

    The claim under test (ROADMAP "closed-loop control from observed
    latency"): when a batch flood saturates a statically-sized admission
    gate, the feedback controller — INTERACTIVE admission reserve + AIMD
    gate + WFQ rebalancing, all driven by the windowed collector — keeps
    tight-deadline probe attainment high while static config collapses
    to edge rejections, at near-parity batch throughput."""
    from repro.data.tabular import ensure_files
    for c in range(n_cohorts):
        ensure_files("uk_housing", n_rows, c)
    ensure_files("uk_housing", probe_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"

    if warmup:
        # compile the jax kernels once so neither mode pays for it
        s = Stratum(memory_budget_bytes=4 << 30, jit_cache_dir=jit_dir)
        for c in range(n_cohorts):
            s.run_batch(_cohort_job(c, n_rows, c))
        for i in range(4):                  # probes rotate column sets
            s.run_batch(_probe_batch(i, probe_rows))
        # ... and warm the SERVICE path too: the first service instances
        # in a process run their jobs several times slower than steady
        # state (allocator/dispatch-cache warm-up), and the interleaved
        # rep order (static first) would book all of that cold cost to
        # the static mode, corrupting the throughput ratio
        warm = StratumService(memory_budget_bytes=256 << 20,
                              cache_fraction=1e-5, jit_cache_dir=jit_dir,
                              coalesce_window_s=0.02, coalesce_max_jobs=2,
                              max_jobs_per_tenant_per_round=1,
                              n_executors=2, aging_s=None)
        try:
            ses = warm.session("warm")
            futs = [ses.submit(_cohort_job(c % n_cohorts, n_rows,
                                           10_000 + c))
                    for c in range(6 * n_cohorts)]
            futs += [ses.submit(_probe_batch(i, probe_rows))
                     for i in range(4)]
            for f in futs:
                f.result(timeout=600)
        finally:
            warm.stop()

    args = (n_rows, n_cohorts, n_bulk_agents, steady_sweeps, flood_sweeps,
            probe_rows, deadline_s, probe_interval_s, jit_dir)
    # interleave repetitions and pool (same rationale as the deadline
    # bench: fixed-work makespans drift with machine state), ALTERNATING
    # which mode runs first in each pair: in-process drift biases the
    # second slot of a pair by several percent, and a fixed order books
    # all of it to one mode
    import gc
    static_runs, controlled_runs = [], []
    for rep in range(reps):
        order = (False, True) if rep % 2 == 0 else (True, False)
        for controlled in order:
            gc.collect()
            r = _control_mode(controlled, *args)
            (controlled_runs if controlled else static_runs).append(r)

    def _pool(runs: list) -> dict:
        lats = [l for r in runs for l in r["lats"]]
        issued = sum(r["probes_issued"] for r in runs)
        met = sum(r["probes_met"] for r in runs)
        out = {
            "controlled": runs[0]["controlled"],
            "reps": len(runs),
            "probes_issued": issued,
            "probes_admitted": sum(r["probes_admitted"] for r in runs),
            "probes_rejected": sum(r["probes_rejected"] for r in runs),
            "probes_met": met,
            "probes_shed": sum(r["probes_shed"] for r in runs),
            "attainment": met / issued if issued else 0.0,
            "probe_p50_s": float(np.percentile(lats, 50)) if lats else 0.0,
            "probe_p99_s": float(np.percentile(lats, 99)) if lats else 0.0,
            "sweeps_completed": sum(r["sweeps_completed"] for r in runs),
            "batch_makespan_s": sum(r["batch_makespan_s"] for r in runs),
            "retunes": sum(r["retunes"] for r in runs),
            "control_snapshot": runs[-1]["control_snapshot"],
        }
        out["batch_throughput_jobs_per_s"] = (
            out["sweeps_completed"] / out["batch_makespan_s"])
        return out

    controlled, static = _pool(controlled_runs), _pool(static_runs)
    # scores must agree wherever BOTH modes admitted and completed the
    # same probe index within a repetition pair (probe i is deterministic)
    scored = [(rc["scores"][i], rs["scores"][i])
              for rc, rs in zip(controlled_runs, static_runs)
              for i in set(rc["scores"]) & set(rs["scores"])]
    scores_identical = bool(scored) and all(
        abs(a - b) <= 1e-9 * max(abs(a), 1.0) for a, b in scored)
    static_tp = static["batch_throughput_jobs_per_s"]
    ctl_tp = controlled["batch_throughput_jobs_per_s"]
    return {
        "rows": n_rows,
        "cohorts": n_cohorts,
        "sweeps": n_bulk_agents * (steady_sweeps + flood_sweeps) * reps,
        "deadline_s": deadline_s,
        "controlled": controlled,
        "static": static,
        "attainment_controlled": controlled["attainment"],
        "attainment_static": static["attainment"],
        "retunes": controlled["retunes"],
        "batch_throughput_ratio": ctl_tp / static_tp if static_tp else 0.0,
        "scores_identical": scores_identical,
    }


def control_rows(smoke: bool = False,
                 out: str = "BENCH_service.json") -> list:
    # smoke: lighter flood and a looser SLO (2s), same shape — the gated
    # metric is the controlled-mode attainment under the flood phase.
    # 4 reps: fixed-work makespans drift with machine state, and the
    # alternating first-slot order needs an even count to balance
    kw = (dict(n_rows=6000, n_cohorts=4, n_bulk_agents=2,
               steady_sweeps=8, flood_sweeps=45, probe_rows=1000,
               deadline_s=2.0, probe_interval_s=0.6, reps=4)
          if smoke else {})
    r = run_control(**kw)
    key = "control_smoke" if smoke else "control"
    write_service_json({key: r}, out, merge=True)
    return [
        (f"{key}_attainment_controlled", r["attainment_controlled"] * 1e6,
         f"static={r['attainment_static']:.2f} "
         f"({r['retunes']} retunes)"),
        (f"{key}_attainment_static", r["attainment_static"] * 1e6,
         "static collapses under flood (lower=expected)"),
        (f"{key}_batch_throughput_ratio",
         r["batch_throughput_ratio"] * 1e6, "controlled/static_x1e-6"),
        (f"{key}_retunes", float(r["retunes"]), "actuations>0"),
        (f"{key}_scores_identical", float(r["scores_identical"]),
         "1=identical"),
    ]


# ---------------------------------------------------------------------------
# mixed-priority scheduling benchmark: interactive probes under batch load
# ---------------------------------------------------------------------------

def _probe_batch(i: int, n_rows: int = 4000) -> PipelineBatch:
    """A small, unique, latency-sensitive pipeline (agent blocked on it)."""
    cols = [3 + (i % 5), 8 + (i % 7), 13 + (i % 3)]
    x = T.read("uk_housing", n_rows, seed=0)
    xs = T.scale(T.impute(T.project(x, cols)))
    y = T.project(x, [0])
    sink = T.metric(T.project(xs, [0]), y, kind="mae" if i % 2 else "rmse")
    return PipelineBatch([sink], [f"probe{i}"])


def _sweep_batch(agent: int, j: int, n_rows: int, cv_k: int
                 ) -> PipelineBatch:
    """One bulk sweep job: half the paper's iteration-1 grid (one preproc
    strategy × 4 models), re-seeded per (agent, job) so model fits are
    unique work while reads and preprocessing stay shareable through the
    cache."""
    preproc = ("manual", "table_vectorizer")[j % 2]
    specs = [PipelineSpec(preproc=preproc, model=m, cv_k=cv_k,
                          n_rows=n_rows, seed=1000 * agent + j)
             for m in ("ridge", "elasticnet", "gbt_xgboost",
                       "gbt_lightgbm")]
    names = [f"a{agent}_j{j}_{k}" for k in range(len(specs))]
    return PipelineBatch([s.build() for s in specs], names)


def _mixed_priority_mode(priority_aware: bool, n_rows: int, cv_k: int,
                         n_batch_agents: int,
                         n_probes: int, probe_rows: int,
                         jit_dir: str) -> dict:
    # small super-batches (2 jobs) keep both executors continuously busy
    # with queued sweep work behind them — the contended regime the
    # scheduler exists for; aging is disabled so the measurement isolates
    # WFQ + preemption (the scavenger band still progresses via weight 1)
    svc = StratumService(memory_budget_bytes=4 << 30,
                         jit_cache_dir=jit_dir,
                         coalesce_window_s=0.02,
                         coalesce_max_jobs=2,
                         max_jobs_per_tenant_per_round=1,
                         n_executors=2,
                         priority_aware=priority_aware,
                         aging_s=None,
                         max_preemptions_per_job=32)
    try:
        t_start = time.perf_counter()
        # closed-loop flood: each bulk tenant keeps 2 sweeps outstanding
        # until the last probe is measured, so EVERY probe (in both modes)
        # is measured under sustained batch contention
        stop = threading.Event()
        sweeps_done = [0] * n_batch_agents
        flood_errors: list = []

        def flooder(a: int) -> None:
            try:
                ses = svc.session(f"bulk-{a}")
                from collections import deque
                inflight: "deque" = deque()
                j = 0
                while not stop.is_set():
                    inflight.append(
                        ses.submit(_sweep_batch(a, j, n_rows, cv_k),
                                   priority=Priority.SCAVENGER))
                    j += 1
                    while len(inflight) >= 2:
                        inflight.popleft().result(timeout=600)
                        sweeps_done[a] += 1
                while inflight:
                    inflight.popleft().result(timeout=600)
                    sweeps_done[a] += 1
            except Exception as e:      # noqa: BLE001
                flood_errors.append(e)

        threads = [threading.Thread(target=flooder, args=(a,))
                   for a in range(n_batch_agents)]
        for t in threads:
            t.start()
        time.sleep(1.0)            # let sweeps reach the runtime
        inter = svc.session("interactive")
        lats, scores = [], []
        for i in range(n_probes):
            t0 = time.perf_counter()
            res, _ = inter.submit(_probe_batch(i, probe_rows),
                                  priority=Priority.INTERACTIVE
                                  ).result(timeout=600)
            lats.append(time.perf_counter() - t0)
            scores.append(float(np.asarray(res[f"probe{i}"])))
            time.sleep(0.25)       # agent "thinks" between probes
        stop.set()
        for t in threads:
            t.join()
        makespan = time.perf_counter() - t_start
        if flood_errors:
            raise flood_errors[0]
        g = svc.telemetry.global_snapshot()
        snap = svc.telemetry.snapshot()
    finally:
        svc.stop()
    return {
        "interactive_p50_s": float(np.percentile(lats, 50)),
        "interactive_p99_s": float(np.percentile(lats, 99)),
        "interactive_mean_s": float(np.mean(lats)),
        "interactive_max_s": float(np.max(lats)),
        "sweeps_completed": int(sum(sweeps_done)),
        "batch_makespan_s": makespan,
        "batch_throughput_jobs_per_s":
            float(sum(sweeps_done)) / makespan,
        "preemptions": g["preemptions"],
        "interactive_queue_wait_s":
            snap["interactive"]["queue_wait_s"],
        "scores": scores,
    }


def run_mixed_priority(n_rows: int = 8000, cv_k: int = 2,
                       n_batch_agents: int = 2,
                       n_probes: int = 10, probe_rows: int = 4000,
                       warmup: bool = True) -> dict:
    """Priority-aware WFQ + preemption vs priority-blind round-robin."""
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)
    ensure_files("uk_housing", probe_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"

    if warmup:   # compile the jax kernels once so neither mode pays for it
        s = Stratum(memory_budget_bytes=4 << 30, jit_cache_dir=jit_dir)
        s.run_batch(_sweep_batch(0, 0, n_rows, cv_k))
        s.run_batch(_probe_batch(0, probe_rows))

    blind = _mixed_priority_mode(False, n_rows, cv_k, n_batch_agents,
                                 n_probes, probe_rows, jit_dir)
    aware = _mixed_priority_mode(True, n_rows, cv_k, n_batch_agents,
                                 n_probes, probe_rows, jit_dir)
    scores_identical = all(
        abs(a - b) <= 1e-9 * max(abs(a), 1.0)
        for a, b in zip(aware["scores"], blind["scores"]))
    return {
        "rows": n_rows,
        "probes": n_probes,
        "priority_aware": aware,
        "priority_blind": blind,
        "p50_improvement":
            blind["interactive_p50_s"] / aware["interactive_p50_s"],
        "p99_improvement":
            blind["interactive_p99_s"] / aware["interactive_p99_s"],
        "scores_identical": scores_identical,
    }


def mixed_priority_rows(**kw) -> list:
    r = run_mixed_priority(**kw)
    write_service_json({"mixed_priority": r}, merge=True)
    a, b = r["priority_aware"], r["priority_blind"]
    return [
        ("priority_interactive_p50", a["interactive_p50_s"] * 1e6,
         f"blind={b['interactive_p50_s'] * 1e6:.0f}us "
         f"({r['p50_improvement']:.1f}x)"),
        ("priority_interactive_p99", a["interactive_p99_s"] * 1e6,
         f"blind={b['interactive_p99_s'] * 1e6:.0f}us "
         f"({r['p99_improvement']:.1f}x)"),
        ("priority_preemptions", float(a["preemptions"]), "cooperative"),
        ("priority_scores_identical", float(r["scores_identical"]),
         "1=identical"),
    ]


def service_rows(n_agents: int = 4, n_rows: int = 20_000,
                 smoke: bool = False,
                 out: str = "BENCH_service.json") -> list:
    r = run_service(n_agents=n_agents, n_rows=n_rows)
    prefix = "service_smoke" if smoke else "service"
    if smoke:      # CI-sized datapoint, gated by check_regression.py
        write_service_json({"service_smoke": r}, out, merge=True)
    else:
        write_service_json(r, out, merge=True)
    return [
        (f"{prefix}_sequential", r["sequential_s"] * 1e6,
         f"{r['agents']}_isolated_sessions"),
        (f"{prefix}_concurrent", r["service_s"] * 1e6,
         f"speedup={r['speedup']:.1f}x"),
        (f"{prefix}_deduped_ops", float(r["ops_deduped_cross_agent"]),
         "cross_agent"),
        (f"{prefix}_cache_hits", float(r["shared_cache_hits"]),
         "shared_cache"),
        (f"{prefix}_score_agreement", r["score_rel_diff"] * 1e6,
         "rel_diff_x1e-6"),
    ]


# ---------------------------------------------------------------------------
# observability overhead: traced vs untraced throughput, same workload
# ---------------------------------------------------------------------------

def _traced_mode(traced: bool, rounds: int, n_variants: int, n_rows: int,
                 jit_dir: str, trace_dir=None) -> dict:
    """One mode of the observability benchmark: the compiled section's
    repeated-structure refinement workload, with per-job lifecycle
    tracing (and, when ``trace_dir`` is set, the flushed JSONL event
    log) either on or off.  Everything else is held identical."""
    svc = StratumService(memory_budget_bytes=2 << 30,
                         jit_cache_dir=jit_dir,
                         coalesce_window_s=0.0,
                         n_executors=1,
                         trace=traced,
                         trace_dir=trace_dir if traced else None)
    try:
        ses = svc.session("agent")
        for w in (rounds, rounds + 1):        # warmup (see _compiled_mode)
            ses.submit(_refinement_batch(w, n_variants, n_rows)
                       ).result(timeout=600)
        t0 = time.perf_counter()
        for r in range(rounds):
            _, rep = ses.submit(_refinement_batch(r, n_variants, n_rows)
                                ).result(timeout=600)
        makespan = time.perf_counter() - t0
        last_trace = rep.trace
    finally:
        svc.stop()
    return {
        "traced": traced,
        "makespan_s": makespan,
        "pipelines_per_s": rounds * n_variants / makespan,
        "last_trace_hops": len(last_trace),
    }


def run_observability(rounds: int = 8, n_variants: int = 6,
                      n_rows: int = 3000) -> dict:
    """Tracing overhead on the repeated-structure workload: full hop
    tracing + JSONL event log vs tracing off.  The gated metric is the
    throughput ratio ``traced_over_untraced`` — the committed baseline
    pins it at 1.0 (parity), so the CI gate enforces an absolute tracing
    overhead budget rather than drift against a noisy measurement."""
    import tempfile

    from repro.service.observability import replay

    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"
    untraced = _traced_mode(False, rounds, n_variants, n_rows, jit_dir)
    with tempfile.TemporaryDirectory() as td:
        traced = _traced_mode(True, rounds, n_variants, n_rows, jit_dir,
                              trace_dir=td)
        timelines = replay.reassemble(replay.load_events(td))
        jobs_traced = len(timelines)
        replayable = all(
            hops and hops[-1]["event"] == "completed"
            for hops in timelines.values())
    return {
        "rounds": rounds,
        "variants": n_variants,
        "rows": n_rows,
        "modes": {"untraced": untraced, "traced": traced},
        "traced_over_untraced": (traced["pipelines_per_s"]
                                 / untraced["pipelines_per_s"]),
        "overhead_frac": max(0.0, 1.0 - traced["pipelines_per_s"]
                             / untraced["pipelines_per_s"]),
        # the traced run really produced a replayable event log: every
        # measured+warmup job reassembled to a completed timeline
        "jobs_traced": jobs_traced,
        "replayable": bool(replayable and jobs_traced >= rounds),
        "trace_hops_per_job": traced["last_trace_hops"],
    }


def observability_rows(smoke: bool = False,
                       out: str = "BENCH_service.json") -> list:
    kw = dict(rounds=4, n_variants=5, n_rows=2000) if smoke else {}
    r = run_observability(**kw)
    key = "observability_smoke" if smoke else "observability"
    write_service_json({key: r}, out, merge=True)
    m = r["modes"]
    return [
        (f"{key}_untraced", m["untraced"]["makespan_s"] * 1e6,
         f"{m['untraced']['pipelines_per_s']:.1f}_pipelines_per_s"),
        (f"{key}_traced", m["traced"]["makespan_s"] * 1e6,
         f"{m['traced']['pipelines_per_s']:.1f}_pipelines_per_s "
         f"(ratio={r['traced_over_untraced']:.3f})"),
        (f"{key}_overhead_frac", r["overhead_frac"] * 1e6,
         "frac_x1e-6"),
        (f"{key}_replayable", float(r["replayable"]),
         f"{r['jobs_traced']}_jobs_traced"),
    ]


# ---------------------------------------------------------------------------
# pre-flight analysis benchmark: admission-time rejection vs execute-to-fail
# ---------------------------------------------------------------------------

def _invalid_batch(i: int, n_rows: int) -> PipelineBatch:
    """A statically-invalid pipeline: an op no backend implements.  With
    admission analysis OFF the job travels the whole queue before the
    executor's compile step rejects it; ON, ``submit`` itself raises."""
    from repro.core.dag import LazyOp, TRANSFORM
    t = T.read("uk_housing", n_rows, seed=0)
    bad = LazyOp(f"no_such_op_{i % 3}", TRANSFORM, inputs=(t,)).out()
    return PipelineBatch([bad], [f"bad_{i}"])


def _analysis_mode(admission: bool, rounds: int, n_variants: int,
                   n_rows: int, invalid_every: int, jit_dir: str) -> dict:
    """One mode of the analysis benchmark: the compiled section's
    repeated-structure refinement flood with a fixed fraction of
    statically-invalid submissions mixed in, admission analysis either
    on or off.  Measures valid-traffic throughput and the wall time from
    ``submit`` to the invalid jobs' verdicts."""
    from repro.core.analysis import AnalysisError
    svc = StratumService(memory_budget_bytes=2 << 30,
                         jit_cache_dir=jit_dir,
                         coalesce_window_s=0.0,
                         n_executors=1,
                         admission_analysis=admission)
    try:
        ses = svc.session("agent")
        # invalid traffic rides its own tenant: with analysis off the
        # coalescer would otherwise merge a bad job into a valid cohort
        # and fail the whole merged compile
        bad_ses = svc.session("adversary")
        for w in (rounds, rounds + 1):        # warmup (see _compiled_mode)
            ses.submit(_refinement_batch(w, n_variants, n_rows)
                       ).result(timeout=600)
        verdicts: list = []
        vlock = threading.Lock()
        valid_futures = []
        n_invalid = sync_rejects = 0
        t0 = time.perf_counter()
        for r in range(rounds):
            valid_futures.append(
                ses.submit(_refinement_batch(r, n_variants, n_rows)))
            if (r + 1) % invalid_every:
                continue
            n_invalid += 1
            tb = time.perf_counter()
            try:
                fut = bad_ses.submit(_invalid_batch(r, n_rows))
            except AnalysisError:             # rejected at submit
                sync_rejects += 1
                with vlock:
                    verdicts.append(time.perf_counter() - tb)
            else:                             # verdict rides the future

                def _stamp(_f, tb=tb):
                    with vlock:
                        verdicts.append(time.perf_counter() - tb)
                fut.add_done_callback(_stamp)
        for f in valid_futures:
            f.result(timeout=600)
        makespan = time.perf_counter() - t0
        deadline = time.perf_counter() + 120
        while time.perf_counter() < deadline:
            with vlock:
                if len(verdicts) >= n_invalid:
                    break
            time.sleep(0.01)
        snap = svc.telemetry.global_snapshot().get("analysis", {})
    finally:
        svc.stop()
    return {
        "admission": admission,
        "makespan_s": makespan,
        "pipelines_per_s": rounds * n_variants / makespan,
        "n_invalid": n_invalid,
        "rejected_at_submit": sync_rejects,
        "verdict_mean_s": (sum(verdicts) / len(verdicts)) if verdicts
        else float("inf"),
        "verdict_max_s": max(verdicts) if verdicts else float("inf"),
        "telemetry": snap,
    }


def run_analysis(rounds: int = 8, n_variants: int = 6, n_rows: int = 3000,
                 invalid_every: int = 2, repeats: int = 2) -> dict:
    """Pre-flight static analysis at admission (docs/ANALYSIS.md) on an
    agent flood with a fixed invalid fraction.  Two gated metrics:

    * ``reject_speedup`` — how much sooner an invalid submission gets its
      verdict when rejected at submit instead of failing at the executor
      behind the queue (must stay well above 1);
    * ``valid_work_frac`` — 1 minus the fraction of the analyzed mode's
      makespan spent inside the analyzer (from the telemetry ``analysis``
      block, so cached verdicts count at their true ~zero cost); the
      committed baseline pins it at 1.0, so the 0.05 gate tolerance IS
      the analyzer-overhead budget (≤5% of valid-traffic wall time).

    ``analyzed_over_plain`` (end-to-end throughput ratio, on vs off) is
    also recorded, informationally: the true analyzer overhead is well
    under the run-to-run makespan noise at smoke sizes, so the ratio
    hovers around 1.0 and is not a stable gate."""
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)
    jit_dir = "/tmp/repro_jit_cache"
    # alternate modes and keep each mode's best repeat: a single ~1s
    # makespan flakes on scheduler/compile noise, the min of two does not
    plain = analyzed = None
    for _ in range(repeats):
        p = _analysis_mode(False, rounds, n_variants, n_rows,
                           invalid_every, jit_dir)
        a = _analysis_mode(True, rounds, n_variants, n_rows,
                           invalid_every, jit_dir)
        if plain is None or p["makespan_s"] < plain["makespan_s"]:
            plain = p
        if analyzed is None or a["makespan_s"] < analyzed["makespan_s"]:
            analyzed = a
    analyzer_s = float(analyzed["telemetry"].get("time_s", 0.0))
    return {
        "rounds": rounds,
        "variants": n_variants,
        "rows": n_rows,
        "invalid_every": invalid_every,
        "modes": {"plain": plain, "analyzed": analyzed},
        "reject_speedup": (plain["verdict_mean_s"]
                           / max(analyzed["verdict_mean_s"], 1e-9)),
        "analyzed_over_plain": (analyzed["pipelines_per_s"]
                                / plain["pipelines_per_s"]),
        "valid_work_frac": max(
            0.0, 1.0 - analyzer_s / analyzed["makespan_s"]),
        # every invalid job was caught synchronously at submit, and every
        # valid job still completed (a false rejection would have raised)
        "all_rejected_at_submit": (analyzed["rejected_at_submit"]
                                   == analyzed["n_invalid"]),
        "analysis_telemetry": analyzed["telemetry"],
    }


def analysis_rows(smoke: bool = False,
                  out: str = "BENCH_service.json") -> list:
    kw = dict(rounds=6, n_variants=5, n_rows=2000, repeats=3) if smoke else {}
    r = run_analysis(**kw)
    key = "analysis_smoke" if smoke else "analysis"
    write_service_json({key: r}, out, merge=True)
    m = r["modes"]
    return [
        (f"{key}_verdict_plain", m["plain"]["verdict_mean_s"] * 1e6,
         f"{m['plain']['n_invalid']}_invalid_execute_to_fail"),
        (f"{key}_verdict_analyzed", m["analyzed"]["verdict_mean_s"] * 1e6,
         f"reject_speedup={r['reject_speedup']:.1f}x"),
        (f"{key}_throughput_ratio", r["analyzed_over_plain"] * 1e6,
         "analyzed_over_plain (informational)"),
        (f"{key}_valid_work_frac", r["valid_work_frac"] * 1e6,
         "1-analyzer_overhead (gate: >=0.95)"),
        (f"{key}_rejected_at_submit", float(r["all_rejected_at_submit"]),
         f"{m['analyzed']['rejected_at_submit']}_sync_rejects"),
    ]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    # None = "not passed": each mode picks its own default (service 4
    # agents / 20k rows, mixed-priority 8k rows, sharded 16 agents / 30k
    # rows — the parameters the committed BENCH_service.json entries and
    # the docs' numbers were measured at)
    ap.add_argument("--agents", type=int, default=None)
    ap.add_argument("--rows", type=int, default=None)
    ap.add_argument("--cv", type=int, default=3)
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--mixed-priority", action="store_true",
                    help="interactive latency under batch load: priority-"
                         "aware WFQ+preemption vs priority-blind")
    ap.add_argument("--deadline", action="store_true",
                    help="SLO attainment under mixed load: deadline-aware "
                         "EDF+shedding vs deadline-blind (same band)")
    ap.add_argument("--control", action="store_true",
                    help="closed-loop admission/WFQ control vs static "
                         "config on a two-phase flood workload")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="sharded-fabric scaling: compare 1 shard vs N "
                         "shards at --agents agents (default 16)")
    ap.add_argument("--rounds", type=int, default=3,
                    help="sweep rounds per agent, submitted open-loop "
                         "(--shards mode)")
    args = ap.parse_args()
    if args.shards:
        r = run_sharded(n_agents=args.agents or 16, rounds=args.rounds,
                        n_rows=args.rows or 30_000,
                        shard_counts=(1, args.shards))
        write_service_json({"sharded": r}, args.out, merge=True)
        for k in sorted(r["modes"], key=int):
            m = r["modes"][k]
            print(f"{k} shard(s): makespan {m['makespan_s']:.2f}s  "
                  f"{m['throughput_jobs_per_s']:.2f} jobs/s  "
                  f"locality={m['locality_hit_rate']:.2f}")
        print(f"aggregate throughput speedup: {r['speedup']:.1f}x  "
              f"scores identical: {r['scores_identical']}")
        print(f"wrote {args.out}")
        return
    if args.control:
        r = run_control(**(dict(n_rows=args.rows) if args.rows else {}))
        write_service_json({"control": r}, args.out, merge=True)
        c, s = r["controlled"], r["static"]
        print(f"attainment: controlled {r['attainment_controlled']:.2f} "
              f"vs static {r['attainment_static']:.2f} at deadline "
              f"{r['deadline_s'] * 1e3:.0f}ms "
              f"({r['retunes']} retunes, "
              f"{s['probes_rejected']} static edge rejections)")
        print(f"batch throughput ratio (controlled/static): "
              f"{r['batch_throughput_ratio']:.3f}")
        print(f"scores identical where both ran: {r['scores_identical']}")
        print(f"wrote {args.out}")
        return
    if args.deadline:
        r = run_deadline(n_rows=args.rows or 8000)
        write_service_json({"deadline": r}, args.out, merge=True)
        a, b = r["aware"], r["blind"]
        print(f"attainment: aware {r['attainment_aware']:.2f} vs blind "
              f"{r['attainment_blind']:.2f} at deadline "
              f"{r['deadline_s'] * 1e3:.0f}ms")
        print(f"probe p99: aware {a['probe_p99_s'] * 1e3:.0f}ms vs blind "
              f"{b['probe_p99_s'] * 1e3:.0f}ms "
              f"({r['p99_latency_improvement']:.1f}x)")
        print(f"batch throughput ratio (aware/blind): "
              f"{r['batch_throughput_ratio']:.3f}")
        print(f"scores identical where both ran: {r['scores_identical']}")
        print(f"wrote {args.out}")
        return
    if args.mixed_priority:
        r = run_mixed_priority(n_rows=args.rows or 8000, cv_k=args.cv)
        write_service_json({"mixed_priority": r}, args.out, merge=True)
        a, b = r["priority_aware"], r["priority_blind"]
        print(f"interactive p50: aware {a['interactive_p50_s'] * 1e3:.0f}ms"
              f" vs blind {b['interactive_p50_s'] * 1e3:.0f}ms"
              f"  ({r['p50_improvement']:.1f}x)")
        print(f"interactive p99: aware {a['interactive_p99_s'] * 1e3:.0f}ms"
              f" vs blind {b['interactive_p99_s'] * 1e3:.0f}ms"
              f"  ({r['p99_improvement']:.1f}x)")
        print(f"preemptions (aware): {a['preemptions']}  "
              f"batch makespan: aware {a['batch_makespan_s']:.1f}s "
              f"vs blind {b['batch_makespan_s']:.1f}s")
        print(f"probe scores identical across modes: "
              f"{r['scores_identical']}")
        print(f"wrote {args.out}")
        return
    n_agents = args.agents or 4
    r = run_service(n_agents=n_agents, n_rows=args.rows or 20_000,
                    cv_k=args.cv)
    write_service_json(r, args.out, merge=True)
    print(f"{n_agents} sequential sessions: {r['sequential_s']:.2f}s")
    print(f"{n_agents} agents via service:  {r['service_s']:.2f}s "
          f"({r['speedup']:.1f}x)")
    print(f"cross-agent ops deduped: {r['ops_deduped_cross_agent']}  "
          f"shared-cache hits: {r['shared_cache_hits']}")
    print(r["telemetry_report"])
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
