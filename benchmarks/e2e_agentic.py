"""End-to-end agentic pipeline search (paper Fig. 6a).

Workload (paper §6, verbatim structure): iteration 1 = 2 preprocessing
strategies × 4 models over UK-housing-like data; iteration 2 = grid search
on the winner.  Modes: Base (sequential AIDE), Base_par (naively parallel
AIDE), stratum (all optimizations).
"""

from __future__ import annotations

import time

import numpy as np

from repro.agents import paper_workload_batches
from repro.agents.aide import second_iteration_batch
from repro.core import Stratum

from .baselines import run_base, run_base_par


def _workload(n_rows: int, cv_k: int):
    name, batch, ctx = next(iter(paper_workload_batches(
        n_rows=n_rows, cv_k=cv_k)))
    return batch, ctx


def run(n_rows: int = 20_000, cv_k: int = 3, spill_dir: str | None = None,
        include_base_par: bool = True) -> dict:
    out = {}
    # materialize the data lake files once (setup, not measured)
    from repro.data.tabular import ensure_files
    ensure_files("uk_housing", n_rows, 0)

    # ---- Base ------------------------------------------------------------
    batch, ctx = _workload(n_rows, cv_k)
    res_base, t_base = run_base(batch.sinks)
    scores = {n: float(np.asarray(r)) for n, r in zip(batch.names, res_base)}
    best = min(scores, key=scores.get)
    b2, _ = second_iteration_batch(ctx["specs"][best])
    res2, t2 = run_base(b2.sinks)
    out["base_s"] = t_base + t2

    # ---- Base_par ----------------------------------------------------------
    if include_base_par:
        batch, ctx = _workload(n_rows, cv_k)
        _, tp1 = run_base_par(batch.sinks)
        _, tp2 = run_base_par(b2.sinks)
        out["base_par_s"] = tp1 + tp2

    # ---- stratum -----------------------------------------------------------
    batch, ctx = _workload(n_rows, cv_k)
    s = Stratum(memory_budget_bytes=4 << 30, spill_dir=spill_dir,
                jit_cache_dir="/tmp/repro_jit_cache")
    t0 = time.perf_counter()
    res1, rep1 = s.run_batch(batch)
    best = min(res1, key=lambda k: float(np.asarray(res1[k])))
    b2s, _ = second_iteration_batch(ctx["specs"][best])
    res2s, rep2 = s.run_batch(b2s)
    out["stratum_s"] = time.perf_counter() - t0
    out["stratum_cold"] = not getattr(run, "_warmed", False)
    run._warmed = True

    out["speedup_vs_base"] = out["base_s"] / out["stratum_s"]
    if include_base_par:
        out["speedup_vs_base_par"] = out["base_par_s"] / out["stratum_s"]
    out["stratum_cache_hits"] = rep2.run.ops_from_cache
    out["stratum_cse_merged"] = rep1.rewrites.cse_merged

    # scores must agree across modes (same seeds; dtype tolerance)
    s_base = float(np.asarray(scores[best]))
    s_strat = float(np.asarray(res1[best]))
    out["score_rel_diff"] = abs(s_base - s_strat) / abs(s_base)
    return out


def rows() -> list:
    r = run()
    out = [("e2e_base", r["base_s"] * 1e6, ""),
           ("e2e_stratum", r["stratum_s"] * 1e6,
            f"speedup={r['speedup_vs_base']:.1f}x"),
           ("e2e_score_agreement", r["score_rel_diff"] * 1e6,
            "rel_diff_x1e-6")]
    if "base_par_s" in r:
        out.insert(1, ("e2e_base_par", r["base_par_s"] * 1e6,
                       f"speedup={r.get('speedup_vs_base_par', 0):.1f}x"))
    return out
