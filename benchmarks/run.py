"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (mandated format).

Sections:
  * characterize — paper Fig. 2  (diff sizes, redundancy)
  * e2e          — paper Fig. 6a (Base / Base_par / stratum speedup)
  * ablation     — paper Fig. 6b (incremental optimizations)
  * micro        — paper §6 components (cache, selection tiers, kernels)
  * roofline     — §Roofline summary rows from the dry-run artifacts
  * service      — N concurrent agents through the multi-tenant execution
                   service vs N isolated sessions (writes BENCH_service.json)
  * priority     — interactive p50/p99 latency under batch load: priority-
                   aware WFQ + preemption vs priority-blind round-robin
                   (merged into BENCH_service.json)
  * sharded      — aggregate throughput of the consistent-hash sharded
                   fabric (1 shard vs 4) at 16 agents submitting
                   open-loop sweeps (merged into BENCH_service.json)
  * compiled     — repeated-structure workload: compiled plan-segment
                   backends (whole-segment jit + warm structural plan
                   cache) vs per-op dispatch (merged into
                   BENCH_service.json)
  * compiled_batched — batched variant solves: homogeneous refinement
                   fans traced once and vmapped across variants vs the
                   unrolled compiled mode and per-op dispatch; also
                   records blocking cold-compile first-touch time for
                   both trace layouts (merged into BENCH_service.json)
  * compiled_cold — first-touch latency on a changing-structure ladder:
                   blocking compiles vs compile_async + speculative
                   warm-up hints during agent think time (merged into
                   BENCH_service.json)
  * deadline     — SLO attainment under mixed load: deadline-aware
                   scheduling (EDF + tight-slack solo dispatch +
                   shedding) vs deadline-blind, same priority band
                   (merged into BENCH_service.json)
  * fabric_proc  — CPU-bound cohort flood through 1 vs K out-of-process
                   worker shards (ProcStratumFabric); records speedup,
                   n_cpus and zero-loss completed_frac (merged into
                   BENCH_service.json)
  * observability— tracing overhead: the repeated-structure workload with
                   per-job lifecycle traces + JSONL event log on vs off;
                   records the traced/untraced throughput ratio (merged
                   into BENCH_service.json, gated at ≤5% overhead)
  * control      — closed-loop admission/WFQ control from observed
                   windows vs static config on a two-phase flood
                   workload: probe attainment, batch-throughput parity
                   and retune count (merged into BENCH_service.json)
  * analysis     — pre-flight static analysis at admission: agent flood
                   with a fixed invalid fraction, admission analysis on
                   vs off; records reject-at-submit verdict speedup and
                   valid-traffic throughput ratio (merged into
                   BENCH_service.json, analyzer overhead gated ≤5%)

``--smoke`` runs CI-sized variants of the ``service``, ``sharded``,
``compiled``, ``compiled_batched``, ``compiled_cold``, ``deadline``,
``fabric_proc``, ``observability``, ``control`` and
``analysis`` sections (smaller rows / agents / rounds)
and records them under ``*_smoke`` keys, which
``benchmarks/check_regression.py`` gates against the committed baseline;
the other sections ignore the flag.

Exit code: nonzero iff any requested section failed.  Failures include a
section raising ``SystemExit`` mid-run (even ``SystemExit(0)`` — a section
must not be able to vouch for sections that never ran), so the CI bench
job can trust a zero exit.

``python -m benchmarks.run [--sections a,b,...] [--rows N] [--agents N]
                           [--smoke] [--out BENCH_service.json]``
"""

from __future__ import annotations

import argparse
import sys
import traceback


def _characterize(args):
    from . import characterize as mod
    return mod.rows()


def _micro(args):
    from . import micro as mod
    return mod.rows()


def _ablation(args):
    from .ablation import run as run_ablation
    return [(f"ablation_{label}", dt * 1e6, f"speedup={speedup:.2f}x")
            for label, dt, speedup, _ in run_ablation(n_rows=args.rows)]


def _e2e(args):
    from .e2e_agentic import run as run_e2e
    r = run_e2e(n_rows=args.rows)
    return [("e2e_base", r["base_s"] * 1e6, ""),
            ("e2e_base_par", r.get("base_par_s", 0) * 1e6,
             f"speedup={r.get('speedup_vs_base_par', 0):.1f}x"),
            ("e2e_stratum", r["stratum_s"] * 1e6,
             f"speedup={r['speedup_vs_base']:.1f}x (paper: 16.6x)"),
            ("e2e_score_agreement", r["score_rel_diff"] * 1e6,
             "rel_diff_x1e-6")]


def _roofline(args):
    from . import roofline as mod
    return mod.rows()


def _service(args):
    from .e2e_agentic import service_rows
    if args.smoke:
        return service_rows(n_agents=2, n_rows=3000, smoke=True,
                            out=args.out)
    return service_rows(n_agents=args.agents, n_rows=args.rows,
                        out=args.out)


def _priority(args):
    from .e2e_agentic import mixed_priority_rows
    return mixed_priority_rows()


def _sharded(args):
    from .e2e_agentic import sharded_rows
    return sharded_rows(smoke=args.smoke, out=args.out)


def _deadline(args):
    from .e2e_agentic import deadline_rows
    return deadline_rows(smoke=args.smoke, out=args.out)


def _compiled(args):
    from .e2e_agentic import compiled_rows
    return compiled_rows(smoke=args.smoke, out=args.out)


def _compiled_batched(args):
    from .e2e_agentic import compiled_batched_rows
    return compiled_batched_rows(smoke=args.smoke, out=args.out)


def _compiled_cold(args):
    from .e2e_agentic import compiled_cold_rows
    return compiled_cold_rows(smoke=args.smoke, out=args.out)


def _fabric_proc(args):
    from .e2e_agentic import proc_fabric_rows
    return proc_fabric_rows(smoke=args.smoke, out=args.out)


def _observability(args):
    from .e2e_agentic import observability_rows
    return observability_rows(smoke=args.smoke, out=args.out)


def _control(args):
    from .e2e_agentic import control_rows
    return control_rows(smoke=args.smoke, out=args.out)


def _analysis(args):
    from .e2e_agentic import analysis_rows
    return analysis_rows(smoke=args.smoke, out=args.out)


SECTIONS = {
    "characterize": _characterize,
    "micro": _micro,
    "ablation": _ablation,
    "e2e": _e2e,
    "roofline": _roofline,
    "service": _service,
    "priority": _priority,
    "sharded": _sharded,
    "compiled": _compiled,
    "compiled_batched": _compiled_batched,
    "compiled_cold": _compiled_cold,
    "deadline": _deadline,
    "fabric_proc": _fabric_proc,
    "observability": _observability,
    "control": _control,
    "analysis": _analysis,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sections",
                    default="characterize,micro,ablation,e2e,roofline")
    ap.add_argument("--rows", type=int, default=20_000,
                    help="dataset rows for the agentic workload")
    ap.add_argument("--agents", type=int, default=4,
                    help="concurrent agents for the service section")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized section variants, recorded under "
                         "*_smoke keys for the regression gate")
    ap.add_argument("--out", default="BENCH_service.json",
                    help="JSON artifact for service/sharded sections")
    args = ap.parse_args(argv)
    sections = args.sections.split(",")

    print("name,us_per_call,derived")
    failures = 0
    for section in sections:
        try:
            fn = SECTIONS[section]          # KeyError → unknown section
            for name, us, derived in fn(args):
                print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        except (Exception, SystemExit):
            # SystemExit included deliberately: a section calling
            # sys.exit(0) mid-run must register as a failure, not let the
            # harness report success for sections that never executed
            failures += 1
            print(f"{section},ERROR,{traceback.format_exc(limit=1)!r}")
            sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
