"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (mandated format).

Sections:
  * characterize — paper Fig. 2  (diff sizes, redundancy)
  * e2e          — paper Fig. 6a (Base / Base_par / stratum speedup)
  * ablation     — paper Fig. 6b (incremental optimizations)
  * micro        — paper §6 components (cache, selection tiers, kernels)
  * roofline     — §Roofline summary rows from the dry-run artifacts
  * service      — N concurrent agents through the multi-tenant execution
                   service vs N isolated sessions (writes BENCH_service.json)
  * priority     — interactive p50/p99 latency under batch load: priority-
                   aware WFQ + preemption vs priority-blind round-robin
                   (merged into BENCH_service.json)

``python -m benchmarks.run [--sections a,b,...] [--rows N] [--agents N]``
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sections",
                    default="characterize,micro,ablation,e2e,roofline")
    ap.add_argument("--rows", type=int, default=20_000,
                    help="dataset rows for the agentic workload")
    ap.add_argument("--agents", type=int, default=4,
                    help="concurrent agents for the service section")
    args = ap.parse_args()
    sections = args.sections.split(",")

    print("name,us_per_call,derived")
    failures = 0
    for section in sections:
        try:
            if section == "characterize":
                from . import characterize as mod
                rows = mod.rows()
            elif section == "micro":
                from . import micro as mod
                rows = mod.rows()
            elif section == "ablation":
                from .ablation import run as run_ablation
                rows = [(f"ablation_{label}", dt * 1e6,
                         f"speedup={speedup:.2f}x")
                        for label, dt, speedup, _ in run_ablation(
                            n_rows=args.rows)]
            elif section == "e2e":
                from .e2e_agentic import run as run_e2e
                r = run_e2e(n_rows=args.rows)
                rows = [("e2e_base", r["base_s"] * 1e6, ""),
                        ("e2e_base_par", r.get("base_par_s", 0) * 1e6,
                         f"speedup={r.get('speedup_vs_base_par', 0):.1f}x"),
                        ("e2e_stratum", r["stratum_s"] * 1e6,
                         f"speedup={r['speedup_vs_base']:.1f}x"
                         f" (paper: 16.6x)"),
                        ("e2e_score_agreement", r["score_rel_diff"] * 1e6,
                         "rel_diff_x1e-6")]
            elif section == "roofline":
                from . import roofline as mod
                rows = mod.rows()
            elif section == "service":
                from .e2e_agentic import service_rows
                rows = service_rows(n_agents=args.agents, n_rows=args.rows)
            elif section == "priority":
                from .e2e_agentic import mixed_priority_rows
                rows = mixed_priority_rows()
            else:
                raise KeyError(section)
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{section},ERROR,{traceback.format_exc(limit=1)!r}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
