"""Incremental optimization ablation (paper Fig. 6b).

Enable stratum's optimizations cumulatively on iteration 1 of the paper
workload:  none → +logical (CSE & rewrites) → +operator selection (native
backends) → +inter-op parallelism → +cache (iteration-2 path).
"""

from __future__ import annotations

import time

import numpy as np

from repro.agents import paper_workload_batches
from repro.agents.aide import second_iteration_batch
from repro.core import Stratum

LEVELS = [
    ("none", ("lowering",)),
    ("+logical", ("lowering", "logical")),
    ("+selection", ("lowering", "logical", "selection")),
    ("+parallel", ("lowering", "logical", "selection", "parallel")),
    ("+cache", ("lowering", "logical", "selection", "parallel", "cache")),
]


def _run_level(enable, n_rows, cv_k):
    name, batch, ctx = next(iter(paper_workload_batches(
        n_rows=n_rows, cv_k=cv_k)))
    s = Stratum(memory_budget_bytes=4 << 30, enable=enable)
    t0 = time.perf_counter()
    res, rep = s.run_batch(batch)
    best = min(res, key=lambda k: float(np.asarray(res[k])))
    b2, _ = second_iteration_batch(ctx["specs"][best])
    s.run_batch(b2)
    return time.perf_counter() - t0, rep


def run(n_rows: int = 20_000, cv_k: int = 3) -> list:
    """Full two-iteration workload per optimization level (paper Fig. 6b
    denominator: iteration 1 + grid-search iteration 2).

    Each level runs twice: an untimed warmup absorbs jit compilation (else
    the first jax-tier level is charged all compile cost and later levels
    ride its cache), then a FRESH session (cold result cache, warm jit
    cache) is timed — steady-state execution per level."""
    results = []
    base_time = None
    for label, enable in LEVELS:
        _run_level(enable, n_rows, cv_k)               # warmup, untimed
        dt, rep = _run_level(enable, n_rows, cv_k)     # timed
        if base_time is None:
            base_time = dt
        results.append((label, dt, base_time / dt, rep.run.per_backend))
    return results


def rows() -> list:
    out = []
    for label, dt, speedup, backends in run():
        out.append((f"ablation_{label}", dt * 1e6,
                    f"speedup={speedup:.2f}x backends={backends}"))
    return out
