"""Microbenchmarks (paper §6 components): cache, operator selection tiers,
kernel interpret-mode correctness cost, scheduler throughput."""

from __future__ import annotations

import time

import numpy as np

from repro.core import PipelineBatch, Stratum
from repro.core.cache import IntermediateCache
from repro.core.dag import LazyOp, TRANSFORM
from repro.core.selection import impls_for


def _time(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def cache_micro() -> list:
    c = IntermediateCache(budget_bytes=64 << 20)
    val = (np.zeros((1000, 64)),)
    t_put = _time(lambda: [c.put(f"k{i}", val) for i in range(100)]) / 100
    t_hit = _time(lambda: [c.get(f"k{i}") for i in range(100)]) / 100
    return [("micro_cache_put", t_put * 1e6, ""),
            ("micro_cache_hit", t_hit * 1e6,
             f"hit_rate={c.stats.hit_rate:.2f}")]


def selection_micro(n_rows: int = 40_000) -> list:
    """Per-op python vs jax tier times (what the cost model must order)."""
    from repro.data.tabular import generate_uk_housing
    X = np.asarray(generate_uk_housing(n_rows, seed=0))
    out = []
    cases = [
        ("onehot", {"cards": (5, 2, 3)}, [X[:, 2:5]], None),
        ("string_encode", {"dim": 16}, [X[:, 5:6]], 0),
        ("scaler_fit", {}, [np.nan_to_num(X[:, 10:14])], None),
        ("ridge_fit", {"alpha": 1.0},
         [np.nan_to_num(X[:, 1:]), np.log1p(X[:, 0])], 0),
    ]
    for name, spec, ins, seed in cases:
        op = LazyOp(name, TRANSFORM, spec=spec, seed=seed)
        impls = {i.backend: i for i in impls_for(name)
                 if i.fidelity == "exact"}
        times = {}
        for be, impl in impls.items():
            impl.fn(op, ins)  # warm (jit compile)
            times[be] = _time(lambda impl=impl: impl.fn(op, ins))
        ratio = times["python"] / times.get("jax", times["python"])
        out.append((f"micro_select_{name}", times["python"] * 1e6,
                    f"jax_speedup={ratio:.1f}x"))
    return out


def kernel_micro() -> list:
    """Reference-path kernel timings (CPU; TPU numbers come from §Roofline)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import flash_attention, rmsnorm, ssd_scan
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)), jnp.float32)
    fa = jax.jit(lambda q, k: flash_attention(q, k, k))
    fa(q, k).block_until_ready()
    t_fa = _time(lambda: fa(q, k).block_until_ready())

    x = jnp.asarray(rng.normal(size=(8, 1024, 512)), jnp.float32)
    w = jnp.ones((512,), jnp.float32)
    rn = jax.jit(lambda x, w: rmsnorm(x, w))
    rn(x, w).block_until_ready()
    t_rn = _time(lambda: rn(x, w).block_until_ready())

    c = jnp.asarray(rng.normal(size=(1, 4, 512, 16)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(1, 4, 512, 32)), jnp.float32)
    la = -jnp.abs(jnp.asarray(rng.normal(size=(1, 4, 512)), jnp.float32))
    sc = jax.jit(lambda c, xs, la: ssd_scan(c, c * 0.3, xs, la * 0.1,
                                            -la)[0])
    sc(c, xs, la).block_until_ready()
    t_sc = _time(lambda: sc(c, xs, la).block_until_ready())
    return [("micro_kernel_flash_ref", t_fa * 1e6, "S=1024 H=8 GQA4"),
            ("micro_kernel_rmsnorm_ref", t_rn * 1e6, "8x1024x512"),
            ("micro_kernel_ssd_ref", t_sc * 1e6, "S=512 H=4")]


def optimizer_overhead_micro() -> list:
    """Plan-time cost of the whole stratum compiler on the fused workload."""
    from repro.agents import paper_workload_batches
    _, batch, _ = next(iter(paper_workload_batches(n_rows=2000, cv_k=3)))
    s = Stratum(memory_budget_bytes=1 << 30)
    t = _time(lambda: s.compile_batch(
        PipelineBatch(list(batch.sinks), list(batch.names))))
    n = len(batch.sinks)
    return [("micro_compile_batch", t * 1e6, f"pipelines={n}")]


def rows() -> list:
    return (cache_micro() + selection_micro() + kernel_micro()
            + optimizer_overhead_micro())
