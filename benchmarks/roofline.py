"""Roofline table builder: reads dry-run artifacts (results/*.jsonl) and
emits the §Roofline rows — three terms, dominant bottleneck, MODEL_FLOPS
ratio, and a one-line improvement note per (arch × shape) cell."""

from __future__ import annotations

import json
import os

from repro.configs import get_config
from repro.models.config import SHAPES

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def model_flops(arch: str, shape_name: str) -> float:
    """Useful FLOPs: 6·N·D for training (N_active for MoE), 2·N·tokens for
    prefill, 2·N_active per decoded token (+ attention KV dot for decode)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = cfg.active_params_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens
    # decode: one token per sequence + attention over the cache
    flops = 2.0 * n_act * shape.global_batch
    if cfg.uses_attention:
        n_kv_layers = (cfg.n_layers if cfg.family in
                       ("dense", "moe", "vlm", "audio")
                       else cfg.n_layers // max(cfg.attn_every, 1))
        flops += (4.0 * shape.global_batch * n_kv_layers * cfg.n_heads
                  * cfg.d_head * shape.seq_len)
    return flops


def improvement_note(rec: dict) -> str:
    dom = rec["dominant"]
    pol = rec.get("policy", {})
    if dom == "collective":
        kinds = rec.get("collectives", {}).get("bytes", {})
        top = max(kinds, key=kinds.get) if kinds else "?"
        if top == "all-gather" and pol.get("fsdp"):
            return ("all-gather dominated: hoist FSDP param gathers out of "
                    "the microbatch scan (gather once/step)")
        if top == "all-reduce":
            return ("all-reduce dominated: reduce-scatter + bf16 collectives "
                    "/ overlap with compute")
        return f"{top} dominated: reschedule or shrink that collective"
    if dom == "compute":
        ratio = rec.get("model_flops_ratio", 1.0)
        if ratio < 0.3:
            return ("compute replicated across the model axis: fold `model` "
                    "into the batch axes for this (small) arch")
        return "near compute roofline: raise arithmetic intensity (fusion)"
    return "memory dominated: stream weights/cache better (layout, dtype)"


def load_cells(mesh: str = "16x16") -> list:
    path = os.path.join(
        RESULTS, "dryrun_single.jsonl" if mesh == "16x16"
        else "dryrun_multi.jsonl")
    rows = []
    for line in open(path):
        rec = json.loads(line)
        if rec.get("mesh") != mesh:
            continue
        if rec["status"] == "ok":
            mf = model_flops(rec["arch"], rec["shape"])
            rec["model_flops"] = mf
            rec["model_flops_ratio"] = mf / (rec["flops"] * rec_chips(rec))
            rec["note"] = improvement_note(rec)
        rows.append(rec)
    return rows


def rec_chips(rec: dict) -> int:
    return 512 if rec["mesh"] == "2x16x16" else 256


def table(mesh: str = "16x16") -> str:
    rows = load_cells(mesh)
    hdr = (f"| arch | shape | compute_s | memory_s | collective_s | "
           f"dominant | MODEL/HLO | note |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | {r['reason'][:60]} |")
        elif r["status"] == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
                f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
                f"{r['dominant']} | {r['model_flops_ratio']:.3f} | "
                f"{r['note'][:70]} |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | {r.get('error', '')[:60]} |")
    return "\n".join(lines)


def rows() -> list:
    out = []
    for rec in load_cells("16x16"):
        if rec["status"] != "ok":
            continue
        out.append((f"roofline_{rec['arch']}_{rec['shape']}",
                    rec["step_time_s"] * 1e6,
                    f"dom={rec['dominant']} "
                    f"ratio={rec.get('model_flops_ratio', 0):.3f}"))
    return out


if __name__ == "__main__":
    print(table("16x16"))
