#!/usr/bin/env python
"""Concurrency lint for the runtime itself (run by the CI ``lint`` job).

The pre-flight analyzer (``docs/ANALYSIS.md``) checks *pipelines* before
they run; this script points the same static-analysis discipline at the
runtime's own source.  Two rule families, both AST-based:

**blocking-under-lock** — a call that can block for unbounded time
(``time.sleep``, blocking socket ops: ``accept``/``connect``/``recv*``/
``sendall``/``makefile``, ``subprocess.run``/``check_output``) executed
while a lock is held.  A ``with`` context manager counts as a held lock
when its expression names a lock-ish attribute (``lock``, ``mutex``,
``cv``, ``cond`` — a ``threading.Condition`` holds its lock between
``wait`` calls).  ``Condition.wait``/``wait_for`` are *not* flagged:
they release the lock while blocked.

**unguarded-mutation** — mutation of an attribute annotated
``# guarded-by: <lock>`` outside a ``with self.<lock>:`` block.
Annotate at the attribute's initialisation site::

    self._pending = {}        # guarded-by: _lock

Flagged mutations: assignment, augmented assignment, subscript/attribute
stores and deletes, and calls of known mutating methods (``append``,
``pop``, ``update``, ...).  Reads are not flagged (many structures here
tolerate racy reads by design; write races are what corrupt them).

Waivers, for findings that are correct-by-construction:

* line waiver — trailing ``# lint: allow-blocking`` or
  ``# lint: allow-unguarded`` on the flagged line;
* function waiver — ``# guarded-by: caller`` trailing the ``def`` line
  treats every annotated lock as held for that function's whole body
  (the idiom for ``_foo_locked``-style helpers whose caller holds the
  lock).

Exit 0 when clean, 1 with one ``path:line: rule: message`` per finding.
"""

from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.abspath(__file__))
DEFAULT_PATHS = ("src",)

# context-manager expressions that hold a lock for the block's duration
_LOCKISH = re.compile(r"(lock|mutex|_cv\b|cond)", re.IGNORECASE)

# calls that can block for unbounded time
_BLOCKING_MODULE_CALLS = {
    ("time", "sleep"),
    ("subprocess", "run"),
    ("subprocess", "check_output"),
    ("subprocess", "check_call"),
    ("select", "select"),
}
_BLOCKING_SOCKET_METHODS = {
    "accept", "connect", "recv", "recv_into", "recvfrom", "recvmsg",
    "sendall", "makefile",
}

# method calls that mutate their receiver in place
_MUTATORS = {
    "append", "appendleft", "extend", "extendleft", "insert", "remove",
    "pop", "popleft", "popitem", "clear", "add", "discard", "update",
    "setdefault", "move_to_end", "sort", "reverse",
}

_GUARDED_RE = re.compile(
    r"self\.(\w+)\s*(?::[^=]+)?=.*#\s*guarded-by:\s*(\w+)")
_CALLER_HOLDS_RE = re.compile(r"#\s*guarded-by:\s*caller\b")
_WAIVER_RE = re.compile(r"#\s*lint:\s*allow-(blocking|unguarded)\b")


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted-name text of an expression (``self._lock``)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


class _FileLint(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.findings: list[str] = []
        # attr -> lock name, per enclosing class (built in a pre-pass)
        self.guarded: dict[str, str] = {}
        self._class_guarded: list[dict[str, str]] = []
        # stack of (lock_text, line) for lock-ish `with` blocks
        self._held: list[tuple[str, int]] = []
        # locks treated as held for the whole current function
        self._caller_holds: list[bool] = []

    # -- helpers -----------------------------------------------------------
    def _line(self, lineno: int) -> str:
        return self.lines[lineno - 1] if 0 < lineno <= len(self.lines) else ""

    def _waived(self, lineno: int, kind: str) -> bool:
        m = _WAIVER_RE.search(self._line(lineno))
        return bool(m and m.group(1) == kind)

    def _emit(self, lineno: int, rule: str, msg: str) -> None:
        self.findings.append(
            f"{os.path.relpath(self.path, ROOT)}:{lineno}: {rule}: {msg}")

    def _held_locks(self) -> list[str]:
        return [text for text, _ in self._held]

    def _lock_held(self, lock_attr: str) -> bool:
        if self._caller_holds and self._caller_holds[-1]:
            return True
        want = f"self.{lock_attr}"
        return any(text == want or text.endswith("." + lock_attr)
                   for text in self._held_locks())

    # -- pre-pass: collect guarded-by annotations --------------------------
    def collect_guards(self) -> None:
        for i, line in enumerate(self.lines, 1):
            m = _GUARDED_RE.search(line)
            if m:
                self.guarded[m.group(1)] = m.group(2)

    # -- with / function structure -----------------------------------------
    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            text = _dotted(item.context_expr)
            if not text and isinstance(item.context_expr, ast.Call):
                text = _dotted(item.context_expr.func)
            if text and _LOCKISH.search(text):
                self._held.append((text, node.lineno))
                pushed += 1
        for child in node.body:
            self.visit(child)
        for item in node.items:       # with-item expressions themselves
            self.visit(item.context_expr)
        for _ in range(pushed):
            self._held.pop()

    def _visit_function(self, node) -> None:
        caller_holds = bool(
            _CALLER_HOLDS_RE.search(self._line(node.lineno))
            or _CALLER_HOLDS_RE.search(self._line(node.body[0].lineno - 1)))
        self._caller_holds.append(caller_holds)
        held, self._held = self._held, []   # a def body runs later, lock-free
        self.generic_visit(node)
        self._held = held
        self._caller_holds.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    # -- rule: blocking call under a held lock -----------------------------
    def visit_Call(self, node: ast.Call) -> None:
        if self._held and not self._waived(node.lineno, "blocking"):
            func = node.func
            if isinstance(func, ast.Attribute):
                owner = _dotted(func.value)
                if (owner.split(".")[-1], func.attr) in _BLOCKING_MODULE_CALLS:
                    self._emit(node.lineno, "blocking-under-lock",
                               f"{owner}.{func.attr}() while holding "
                               f"{self._held_locks()[-1]}")
                elif (func.attr in _BLOCKING_SOCKET_METHODS
                      and re.search(r"(sock|conn)", owner, re.IGNORECASE)):
                    self._emit(node.lineno, "blocking-under-lock",
                               f"socket {owner}.{func.attr}() while holding "
                               f"{self._held_locks()[-1]}")
        self.generic_visit(node)
        self._check_mutator_call(node)

    # -- rule: guarded attribute mutated without its lock ------------------
    def _self_attr(self, node: ast.AST) -> str:
        """``self.<attr>`` -> attr; also unwraps one subscript level."""
        if isinstance(node, ast.Subscript):
            node = node.value
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node.attr
        return ""

    def _check_guard(self, node: ast.AST, lineno: int, what: str) -> None:
        attr = self._self_attr(node)
        lock = self.guarded.get(attr)
        if not lock or self._lock_held(lock):
            return
        if self._waived(lineno, "unguarded"):
            return
        if _GUARDED_RE.search(self._line(lineno)):
            return                     # the annotated initialisation itself
        self._emit(lineno, "unguarded-mutation",
                   f"{what} of self.{attr} (guarded-by: {lock}) outside "
                   f"`with self.{lock}:`")

    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            self._check_guard(target, node.lineno, "assignment")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_guard(node.target, node.lineno, "augmented assignment")
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._check_guard(node.target, node.lineno, "assignment")
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for target in node.targets:
            self._check_guard(target, node.lineno, "delete")
        self.generic_visit(node)

    def _check_mutator_call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr in _MUTATORS:
            self._check_guard(func.value, node.lineno,
                              f".{func.attr}() call")


def lint_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [f"{os.path.relpath(path, ROOT)}:{e.lineno}: "
                f"syntax-error: {e.msg}"]
    linter = _FileLint(path, source)
    linter.collect_guards()
    linter.visit(tree)
    return linter.findings


def main(argv: list[str]) -> int:
    paths = argv[1:] or [os.path.join(ROOT, p) for p in DEFAULT_PATHS]
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for dirpath, _dirnames, names in os.walk(p):
                if "__pycache__" in dirpath:
                    continue
                files += [os.path.join(dirpath, n)
                          for n in sorted(names) if n.endswith(".py")]
    findings: list[str] = []
    for path in sorted(files):
        findings += lint_file(path)
    for f in findings:
        print(f)
    print(f"checked {len(files)} file(s): "
          f"{len(findings)} finding(s)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
