#!/usr/bin/env python
"""Examples smoke runner (run by the CI ``examples`` job).

Runs every ``examples/*.py`` in a CI-sized smoke configuration and fails
(exit 1) when any exits nonzero — examples that only render in docs rot
silently.  An example without an entry in ``SMOKE_ARGS`` is a failure
too: adding an example means deciding how CI exercises it.

    PYTHONPATH=src python scripts_run_examples.py [--only quickstart.py]
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.abspath(__file__))

#: example file -> smoke-mode argv (small rows/steps so the whole matrix
#: stays a few minutes on a CI runner)
SMOKE_ARGS: dict = {
    "quickstart.py": ["--rows", "4000"],
    "agentic_search.py": ["--rows", "2000", "--cv", "2",
                          "--target", "service", "--agents", "2",
                          "--rounds", "2", "--deadline-ms", "30000"],
    "train_lm.py": ["--steps", "40", "--seq", "32", "--batch", "4",
                    "--ckpt-dir", "/tmp/repro_examples_smoke_ckpt"],
    "serve_lm.py": ["--requests", "4", "--lanes", "2"],
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single example (file name)")
    args = ap.parse_args(argv)

    ex_dir = os.path.join(ROOT, "examples")
    names = sorted(n for n in os.listdir(ex_dir)
                   if n.endswith(".py") and not n.startswith("_"))
    if args.only:
        names = [n for n in names if n == args.only]
        if not names:
            print(f"FAIL no example named {args.only!r}")
            return 1

    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    failures = 0
    for name in names:
        smoke = SMOKE_ARGS.get(name)
        if smoke is None:
            print(f"FAIL examples/{name}: no SMOKE_ARGS entry — decide "
                  f"how CI exercises it")
            failures += 1
            continue
        cmd = [sys.executable, os.path.join(ex_dir, name), *smoke]
        print(f"== examples/{name} {' '.join(smoke)}", flush=True)
        t0 = time.time()
        proc = subprocess.run(cmd, env=env, cwd=ROOT)
        status = "ok" if proc.returncode == 0 else "FAIL"
        print(f"== examples/{name}: {status} "
              f"({time.time() - t0:.1f}s, exit {proc.returncode})",
              flush=True)
        if proc.returncode != 0:
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
