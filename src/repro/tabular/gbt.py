"""Histogram gradient-boosted trees — the XGBoost/LightGBM stand-in.

Two implementations of the same algorithm (squared loss, level-wise growth on
quantile-binned features):

* :func:`fit_numpy` / :func:`predict_numpy` — naive per-node/per-feature
  Python loops over ``np.bincount`` histograms (the interpreted-library tier),
* :func:`fit_jax` / :func:`predict_jax` — one jitted program: ``lax.scan``
  over boosting rounds, level-wise split search fully vectorized over
  (nodes × features × bins) (the native-backend tier).

The model is a dense array pack so it can flow through the DAG/cache as a
plain tensor:  trees[t] = (feature[node], threshold_bin[node], leaf[node...]).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

N_BINS = 32  # fixed power-of-two bin count


# ---------------------------------------------------------------------------
# shared: quantile binning
# ---------------------------------------------------------------------------

def make_bins(X: np.ndarray, n_bins: int = N_BINS) -> np.ndarray:
    """(F, n_bins-1) ascending split thresholds per feature."""
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    return np.nanquantile(X, qs, axis=0).T.copy()  # (F, n_bins-1)


def bin_data(X: np.ndarray, bins: np.ndarray) -> np.ndarray:
    """Digitize each column; NaN → bin 0."""
    out = np.empty(X.shape, dtype=np.int32)
    for j in range(X.shape[1]):
        out[:, j] = np.searchsorted(bins[j], X[:, j], side="right")
    out[np.isnan(X)] = 0
    return np.clip(out, 0, bins.shape[1])


# ---------------------------------------------------------------------------
# numpy ("python"-tier) implementation
# ---------------------------------------------------------------------------

def fit_numpy(X: np.ndarray, y: np.ndarray, *, n_trees: int = 30,
              depth: int = 3, lr: float = 0.1, reg: float = 1.0,
              subsample: float = 1.0, seed: int = 0) -> np.ndarray:
    n, F = X.shape
    bins = make_bins(X)
    B = bin_data(X, bins)                      # (n, F) int32
    rng = np.random.default_rng(seed)
    n_nodes = 2 ** depth - 1                   # internal nodes
    n_leaves = 2 ** depth
    base = float(np.mean(y))
    pred = np.full(n, base)
    # model pack: per tree: feat(n_nodes), thr(n_nodes), leaf(n_leaves)
    feats = np.zeros((n_trees, n_nodes), dtype=np.int32)
    thrs = np.zeros((n_trees, n_nodes), dtype=np.int32)
    leaves = np.zeros((n_trees, n_leaves))

    for t in range(n_trees):
        g = pred - y                           # gradient of 0.5*(pred-y)^2
        if subsample < 1.0:
            use = rng.random(n) < subsample
        else:
            use = np.ones(n, dtype=bool)
        node = np.zeros(n, dtype=np.int32)     # node id per row, level order
        for d in range(depth):
            for k in range(2 ** d):
                nid = 2 ** d - 1 + k
                rows = use & (node == nid)
                if rows.sum() < 8:
                    feats[t, nid] = 0
                    thrs[t, nid] = N_BINS      # everything goes left
                    continue
                gb = g[rows]
                Bn = B[rows]
                best = (0.0, 0, N_BINS)
                g_tot = gb.sum()
                c_tot = gb.shape[0]
                for f in range(F):             # naive per-feature loop
                    hist_g = np.bincount(Bn[:, f], weights=gb,
                                         minlength=N_BINS)
                    hist_c = np.bincount(Bn[:, f], minlength=N_BINS)
                    cg = np.cumsum(hist_g)[:-1]
                    cc = np.cumsum(hist_c)[:-1]
                    gain = (cg ** 2 / (cc + reg)
                            + (g_tot - cg) ** 2 / (c_tot - cc + reg)
                            - g_tot ** 2 / (c_tot + reg))
                    bi = int(np.argmax(gain))
                    if gain[bi] > best[0]:
                        best = (float(gain[bi]), f, bi)
                _, bf, bb = best
                feats[t, nid] = bf
                thrs[t, nid] = bb
            # level-order: children of nid are 2*nid+1 (left), 2*nid+2 (right)
            go_right = B[np.arange(n), feats[t, node]] > thrs[t, node]
            node = node * 2 + 1 + go_right.astype(np.int32)
        # leaves
        leaf_id = node - (2 ** depth - 1)
        for k in range(n_leaves):
            rows = use & (leaf_id == k)
            gs = g[rows]
            leaves[t, k] = -lr * gs.sum() / (gs.shape[0] + reg)
        pred = pred + leaves[t, np.clip(leaf_id, 0, n_leaves - 1)]

    return pack(base, bins, feats, thrs, leaves, depth)


def predict_numpy(model: np.ndarray, X: np.ndarray) -> np.ndarray:
    base, bins, feats, thrs, leaves, depth = unpack(model, X.shape[1])
    B = bin_data(X, bins)
    n = X.shape[0]
    out = np.full(n, base)
    for t in range(feats.shape[0]):
        node = np.zeros(n, dtype=np.int32)
        for _ in range(depth):
            go_right = B[np.arange(n), feats[t, node]] > thrs[t, node]
            node = node * 2 + 1 + go_right.astype(np.int32)
        out += leaves[t, node - (2 ** depth - 1)]
    return out


# ---------------------------------------------------------------------------
# model packing (model = flat float64 array → flows through cache/DAG)
# ---------------------------------------------------------------------------

def pack(base, bins, feats, thrs, leaves, depth) -> np.ndarray:
    T, n_nodes = feats.shape
    F = bins.shape[0]
    header = np.array([base, T, n_nodes, leaves.shape[1], F, depth],
                      dtype=np.float64)
    return np.concatenate([header, bins.ravel(), feats.ravel().astype(np.float64),
                           thrs.ravel().astype(np.float64), leaves.ravel()])


def unpack(model: np.ndarray, F_expected: int):
    base = float(model[0])
    T, n_nodes, n_leaves, F, depth = (int(model[i]) for i in range(1, 6))
    off = 6
    bins = model[off:off + F * (N_BINS - 1)].reshape(F, N_BINS - 1)
    off += F * (N_BINS - 1)
    feats = model[off:off + T * n_nodes].reshape(T, n_nodes).astype(np.int32)
    off += T * n_nodes
    thrs = model[off:off + T * n_nodes].reshape(T, n_nodes).astype(np.int32)
    off += T * n_nodes
    leaves = model[off:off + T * n_leaves].reshape(T, n_leaves)
    return base, bins, feats, thrs, leaves, depth


# ---------------------------------------------------------------------------
# jax ("native"-tier) implementation — one compiled program per shape/config
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_trees", "depth", "n_bins"))
def _fit_jax_binned(B, g0_y, base, lr, reg, n_trees: int, depth: int,
                    n_bins: int):
    """B: (n,F) int32 binned features; returns (feats, thrs, leaves).

    Histograms via ONE flat segment_sum per level over (node, feature, bin)
    ids — O(n·F) adds, no (n, F, bins) one-hot materialization."""
    n, F = B.shape
    n_nodes = 2 ** depth - 1
    n_leaves = 2 ** depth
    feat_ids = jnp.arange(F, dtype=jnp.int32)[None, :]     # (1, F)

    def tree_round(pred, _):
        g = pred - g0_y                                   # (n,)
        node = jnp.zeros(n, dtype=jnp.int32)
        feats = jnp.zeros(n_nodes, dtype=jnp.int32)
        thrs = jnp.zeros(n_nodes, dtype=jnp.int32)

        def level(d, carry):
            node, feats, thrs = carry
            first = 2 ** d - 1
            width = 2 ** d
            level_node = jnp.clip(node - first, 0, width - 1)  # (n,)
            # flat segment id: ((node·F) + f)·bins + bin
            seg = ((level_node[:, None] * F + feat_ids) * n_bins
                   + B).reshape(-1)                            # (n·F,)
            n_segs = width * F * n_bins
            gf = jnp.broadcast_to(g.astype(jnp.float32)[:, None],
                                  (n, F)).reshape(-1)
            hist_g = jax.ops.segment_sum(
                gf, seg, num_segments=n_segs).reshape(width, F, n_bins)
            hist_c = jax.ops.segment_sum(
                jnp.ones_like(gf), seg,
                num_segments=n_segs).reshape(width, F, n_bins)
            cg = jnp.cumsum(hist_g, axis=-1)[..., :-1]
            cc = jnp.cumsum(hist_c, axis=-1)[..., :-1]
            g_tot = hist_g.sum(axis=-1, keepdims=True)
            c_tot = hist_c.sum(axis=-1, keepdims=True)
            gain = (cg ** 2 / (cc + reg)
                    + (g_tot - cg) ** 2 / (c_tot - cc + reg)
                    - g_tot ** 2 / (c_tot + reg))          # (width,F,bins-1)
            flat = gain.reshape(width, -1)
            bi = jnp.argmax(flat, axis=1)
            bf = (bi // (n_bins - 1)).astype(jnp.int32)
            bb = (bi % (n_bins - 1)).astype(jnp.int32)
            idx = first + jnp.arange(width)
            feats = feats.at[idx].set(bf)
            thrs = thrs.at[idx].set(bb)
            go_right = (B[jnp.arange(n), feats[node]] > thrs[node])
            node = node * 2 + 1 + go_right.astype(jnp.int32)
            return node, feats, thrs

        # static unroll over depth (bounded, ≤ 4)
        carry = (node, feats, thrs)
        for d in range(depth):
            carry = level(d, carry)
        node, feats, thrs = carry

        leaf_id = node - (2 ** depth - 1)
        Loh = jax.nn.one_hot(leaf_id, n_leaves, dtype=jnp.float32)
        gs = Loh.T @ g.astype(jnp.float32)                 # (leaves,)
        cs = Loh.sum(axis=0)
        leaf_vals = (-lr * gs / (cs + reg)).astype(pred.dtype)
        pred = pred + leaf_vals[leaf_id]
        return pred, (feats, thrs, leaf_vals)

    pred0 = jnp.full((n,), base, dtype=jnp.float64
                     if g0_y.dtype == jnp.float64 else jnp.float32)
    _, (feats, thrs, leaves) = jax.lax.scan(
        tree_round, pred0, None, length=n_trees)
    return feats, thrs, leaves


def fit_jax(X: np.ndarray, y: np.ndarray, *, n_trees: int = 30,
            depth: int = 3, lr: float = 0.1, reg: float = 1.0,
            subsample: float = 1.0, seed: int = 0) -> np.ndarray:
    # binning on host (cheap, one pass), training compiled
    bins = make_bins(X)
    B = bin_data(X, bins)
    base = float(np.mean(y))
    if subsample < 1.0:
        # deterministic row subsample per seed (applied once — cheaper than
        # per-round; documented deviation of the fast tier)
        rng = np.random.default_rng(seed)
        keep = rng.random(X.shape[0]) < subsample
        B_fit, y_fit = B[keep], y[keep]
    else:
        B_fit, y_fit = B, y
    feats, thrs, leaves = _fit_jax_binned(
        jnp.asarray(B_fit), jnp.asarray(y_fit, dtype=jnp.float32),
        base, lr, reg, n_trees, depth, N_BINS)
    return pack(base, bins, np.asarray(feats).reshape(n_trees, -1),
                np.asarray(thrs).reshape(n_trees, -1),
                np.asarray(leaves, dtype=np.float64).reshape(n_trees, -1),
                depth)


@partial(jax.jit, static_argnames=("depth",))
def _predict_jax(B, feats, thrs, leaves, base, depth: int):
    n = B.shape[0]

    def one_tree(carry, tree):
        f, th, lv = tree
        node = jnp.zeros(n, dtype=jnp.int32)
        for _ in range(depth):
            go_right = B[jnp.arange(n), f[node]] > th[node]
            node = node * 2 + 1 + go_right.astype(jnp.int32)
        return carry + lv[node - (2 ** depth - 1)], None

    out, _ = jax.lax.scan(one_tree, jnp.full((n,), base, dtype=leaves.dtype),
                          (feats, thrs, leaves))
    return out


def predict_jax(model: np.ndarray, X: np.ndarray) -> np.ndarray:
    base, bins, feats, thrs, leaves, depth = unpack(model, X.shape[1])
    B = bin_data(X, bins)
    out = _predict_jax(jnp.asarray(B), jnp.asarray(feats), jnp.asarray(thrs),
                       jnp.asarray(leaves), base, depth)
    return np.asarray(out)
