"""Lazy operator constructors — the skrub-DataOps-style surface that agents
target.  Each function returns a :class:`LazyRef`; nothing executes until a
:class:`Stratum` session runs the batch.
"""

from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import numpy as np

from ..core.dag import (COMPOSITE, CONST, ESTIMATOR, EVAL, LazyOp, LazyRef,
                        PROJECT, SOURCE, TRANSFORM)

# ---------------------------------------------------------------------------
# sources & structural ops
# ---------------------------------------------------------------------------


def read(dataset: str, n_rows: int, seed: int = 0) -> LazyRef:
    return LazyOp("read", SOURCE,
                  spec={"dataset": dataset, "n_rows": n_rows, "seed": seed}
                  ).out()


def const(value) -> LazyRef:
    return LazyOp("const", CONST, spec={"value": np.asarray(value)}).out()


def project(x: LazyRef, cols: Sequence[int]) -> LazyRef:
    return LazyOp("project", PROJECT,
                  spec={"cols": tuple(int(c) for c in cols)},
                  inputs=(x,)).out()


def concat(xs: Sequence[LazyRef]) -> LazyRef:
    return LazyOp("concat", TRANSFORM, inputs=tuple(xs)).out()


def join(left: LazyRef, right: LazyRef, left_key: int, right_key: int
         ) -> LazyRef:
    return LazyOp("join", TRANSFORM,
                  spec={"left_key": int(left_key), "right_key": int(right_key)},
                  inputs=(left, right)).out()


# ---------------------------------------------------------------------------
# preprocessing — fit/apply pairs (leak-free under unrolled CV)
# ---------------------------------------------------------------------------


def _fit_apply(fit_name: str, apply_name: str, fit_on: LazyRef,
               apply_to: LazyRef, spec: Mapping[str, Any],
               seed: Optional[int] = None,
               extra_fit_inputs: tuple = ()) -> LazyRef:
    state = LazyOp(fit_name, TRANSFORM, spec=dict(spec),
                   inputs=(fit_on,) + extra_fit_inputs, seed=seed).out()
    return LazyOp(apply_name, TRANSFORM, spec=dict(spec),
                  inputs=(state, apply_to)).out()


def impute(x: LazyRef, fit_on: Optional[LazyRef] = None,
           strategy: str = "mean") -> LazyRef:
    return _fit_apply("impute_fit", "impute_apply", fit_on or x, x,
                      {"strategy": strategy})


def scale(x: LazyRef, fit_on: Optional[LazyRef] = None) -> LazyRef:
    return _fit_apply("scaler_fit", "scaler_apply", fit_on or x, x, {})


def onehot(x: LazyRef, cardinalities: Sequence[int]) -> LazyRef:
    return LazyOp("onehot", TRANSFORM,
                  spec={"cards": tuple(int(c) for c in cardinalities)},
                  inputs=(x,)).out()


def string_encode(x: LazyRef, dim: int = 32, seed: int = 0) -> LazyRef:
    """Hashing-based high-cardinality encoder (skrub StringEncoder analogue)."""
    return LazyOp("string_encode", TRANSFORM,
                  spec={"dim": int(dim)}, inputs=(x,), seed=seed).out()


def target_encode(x: LazyRef, y: LazyRef, cardinality: int,
                  fit_on_x: Optional[LazyRef] = None,
                  fit_on_y: Optional[LazyRef] = None,
                  smoothing: float = 20.0, seed: int = 0) -> LazyRef:
    state = LazyOp("target_encode_fit", TRANSFORM,
                   spec={"card": int(cardinality), "smoothing": smoothing},
                   inputs=(fit_on_x or x, fit_on_y or y), seed=seed).out()
    return LazyOp("target_encode_apply", TRANSFORM,
                  spec={"card": int(cardinality)},
                  inputs=(state, x)).out()


def datetime_encode(x: LazyRef) -> LazyRef:
    return LazyOp("datetime_encode", TRANSFORM, inputs=(x,)).out()


def log1p(x: LazyRef) -> LazyRef:
    return LazyOp("log1p", TRANSFORM, inputs=(x,)).out()


def clip_outliers(x: LazyRef, q: float = 0.01) -> LazyRef:
    """Quantile clipping; ``q`` is a tunable constant (declared in
    impls.py), so refinements sweeping it share one compiled segment."""
    return LazyOp("clip_outliers", TRANSFORM, spec={"q": float(q)},
                  inputs=(x,)).out()


def svd_reduce(x: LazyRef, k: int = 16, seed: int = 0) -> LazyRef:
    """Dimensionality reduction; has an 'approx' Frequent-Directions-style
    physical impl selectable under stage=explore annotations (paper §4.2)."""
    return LazyOp("svd_reduce", TRANSFORM, spec={"k": int(k)},
                  inputs=(x,), seed=seed).out()


def table_vectorizer(x: LazyRef, schema: Mapping[str, Any],
                     feature_cols: Sequence[int],
                     fit_on: Optional[LazyRef] = None) -> LazyRef:
    """Composite (paper §4.2 lowering example): cleaner + per-group encoders."""
    spec = {"schema": {k: tuple(v) for k, v in schema.items()},
            "cols": tuple(int(c) for c in feature_cols)}
    inputs = (x,) if fit_on is None else (x, fit_on)
    return LazyOp("table_vectorizer", COMPOSITE, spec=spec,
                  inputs=inputs).out()


# ---------------------------------------------------------------------------
# splits
# ---------------------------------------------------------------------------


def train_test_split(x: LazyRef, y: LazyRef, test_frac: float = 0.2,
                     seed: int = 0) -> tuple:
    op = LazyOp("train_test_split", TRANSFORM,
                spec={"test_frac": float(test_frac)},
                inputs=(x, y), seed=seed, n_outputs=4)
    return op.out(0), op.out(1), op.out(2), op.out(3)  # Xtr, ytr, Xte, yte


def kfold_split(x: LazyRef, y: LazyRef, k: int, fold: int, seed: int = 0
                ) -> tuple:
    op = LazyOp("kfold_split", TRANSFORM,
                spec={"k": int(k), "fold": int(fold)},
                inputs=(x, y), seed=seed, n_outputs=4)
    return op.out(0), op.out(1), op.out(2), op.out(3)


# ---------------------------------------------------------------------------
# estimators & metrics
# ---------------------------------------------------------------------------


def ridge_fit(x: LazyRef, y: LazyRef, alpha: float = 1.0,
              seed: int = 0) -> LazyRef:
    return LazyOp("ridge_fit", ESTIMATOR, spec={"alpha": float(alpha)},
                  inputs=(x, y), seed=seed).out()


def elasticnet_fit(x: LazyRef, y: LazyRef, alpha: float = 1.0,
                   l1_ratio: float = 0.5, iters: int = 200,
                   seed: int = 0) -> LazyRef:
    return LazyOp("elasticnet_fit", ESTIMATOR,
                  spec={"alpha": float(alpha), "l1_ratio": float(l1_ratio),
                        "iters": int(iters)},
                  inputs=(x, y), seed=seed).out()


def gbt_fit(x: LazyRef, y: LazyRef, flavor: str = "lightgbm",
            n_trees: int = 30, depth: int = 3, learning_rate: float = 0.1,
            reg: float = 1.0, subsample: float = 1.0, seed: int = 0
            ) -> LazyRef:
    # flavor ∈ {xgboost, lightgbm}: same algorithm family, different default
    # subsampling — kept as distinct specs so agents can explore both.
    if flavor == "xgboost" and subsample == 1.0:
        subsample = 0.9
    return LazyOp("gbt_fit", ESTIMATOR,
                  spec={"flavor": flavor, "n_trees": int(n_trees),
                        "depth": int(depth),
                        "learning_rate": float(learning_rate),
                        "reg": float(reg), "subsample": float(subsample)},
                  inputs=(x, y), seed=seed).out()


_PREDICT_FOR = {"ridge_fit": "linear_predict",
                "elasticnet_fit": "linear_predict",
                "gbt_fit": "gbt_predict"}


def predict(model: LazyRef, x: LazyRef) -> LazyRef:
    pred_name = _PREDICT_FOR.get(model.op.op_name, "linear_predict")
    return LazyOp(pred_name, ESTIMATOR, inputs=(model, x)).out()


def metric(y: LazyRef, yhat: LazyRef, kind: str = "rmse") -> LazyRef:
    return LazyOp("metric", EVAL, spec={"kind": kind},
                  inputs=(y, yhat)).out()


def mean_of(scores: Sequence[LazyRef]) -> LazyRef:
    return LazyOp("mean_scalars", EVAL, inputs=tuple(scores)).out()


# ---------------------------------------------------------------------------
# composites lowered by lowerings.py
# ---------------------------------------------------------------------------


def cv_score(x: LazyRef, y: LazyRef, estimator: Mapping[str, Any],
             k: int = 5, seed: int = 0) -> LazyRef:
    """estimator: {"name": "ridge_fit", **hyperparams}"""
    return LazyOp("cv_score", COMPOSITE,
                  spec={"estimator": dict(estimator), "k": int(k)},
                  inputs=(x, y), seed=seed).out()


def grid_search(x: LazyRef, y: LazyRef, estimator_name: str,
                grid: Sequence[Mapping[str, Any]], k: int = 5,
                seed: int = 0) -> tuple:
    op = LazyOp("grid_search", COMPOSITE,
                spec={"estimator_name": estimator_name,
                      "grid": tuple({k2: v for k2, v in g.items()}
                                    for g in grid),
                      "k": int(k)},
                inputs=(x, y), seed=seed, n_outputs=2)
    return op.out(0), op.out(1)  # best_score, best_index
