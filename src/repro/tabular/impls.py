"""Physical implementations of the tabular operator vocabulary.

Two tiers per logical op (paper §4.2 "tiered operator hierarchy"):

* ``python`` — the Pandas/scikit-learn stand-in: eager NumPy in float64 with
  the overheads the paper attributes to these libraries (validation passes à
  la ``check_array``, defensive copies, temporaries, no fusion),
* ``jax``    — the native-backend analogue: float32 jitted jnp kernels with
  shape-specialized compile caching (XLA plays the role of the Rust/Rayon
  kernels on CPU and of the TPU backend at scale).

Also registered here: metadata (shape/flops) rules and columnwise structural
declarations used by projection pushdown.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dag import LazyOp, declare_tunable
from ..core.metadata import OpMetadata, TensorInfo, register_meta
from ..core.rewrites import declare_columnwise
from ..core.selection import register_impl
from ..data import tabular as datasets
from . import gbt

F64, F32 = "float64", "float32"


def _validate(X):
    """sklearn-style check_array pass: full scan + dtype copy."""
    X = np.asarray(X, dtype=np.float64)
    if X.ndim == 1:
        X = X.reshape(-1, 1)
    np.isinf(X).any()  # full pass, result intentionally unused (cost model)
    return X.copy()    # defensive copy, as sklearn with copy=True


def _rows(op, i=0):
    return op.inputs[i].op.meta.outputs[op.inputs[i].index].rows


# ===========================================================================
# sources & structural
# ===========================================================================

@register_impl("read", "python")
def read_py(op: LazyOp, ins):
    """Interpreted tier: CSV parse per execution — what agent scripts do
    (pd.read_csv); the paper: 'repeated data loading often dominates'."""
    X = datasets.load_csv(op.spec["dataset"], op.spec["n_rows"],
                          op.spec.get("seed", 0))
    return (X,)


@register_impl("read", "jax")
def read_native(op: LazyOp, ins):
    """Native tier: binary column store (the Polars/Arrow reader analogue)."""
    X = datasets.load_binary(op.spec["dataset"], op.spec["n_rows"],
                             op.spec.get("seed", 0))
    return (np.asarray(X),)


@register_meta("read")
def read_meta(op, ins):
    cols = len(datasets.UK_HOUSING_SCHEMA)
    info = TensorInfo((op.spec["n_rows"], cols), F64)
    return OpMetadata(outputs=[info], flops=5.0 * info.rows * info.cols,
                      peak_bytes=2 * info.nbytes, library="io")


@register_impl("project", "python")
def project_py(op, ins):
    X = _validate(ins[0])
    return (X[:, list(op.spec["cols"])].copy(),)


@register_impl("project", "jax", traceable=True)
def project_jax(op, ins):
    return (jnp.asarray(ins[0])[:, list(op.spec["cols"])],)


@register_meta("project")
def project_meta(op, ins):
    info = TensorInfo((ins[0].rows, len(op.spec["cols"])), ins[0].dtype)
    return OpMetadata(outputs=[info], flops=info.rows * info.cols,
                      peak_bytes=ins[0].nbytes + info.nbytes)


@register_impl("concat", "python")
def concat_py(op, ins):
    arrs = [_validate(x) for x in ins]
    return (np.hstack(arrs),)


@register_impl("concat", "jax", traceable=True)
def concat_jax(op, ins):
    arrs = [jnp.asarray(x) if jnp.ndim(x) == 2 else
            jnp.asarray(x).reshape(len(x), -1) for x in ins]
    return (jnp.concatenate(arrs, axis=1),)


@register_meta("concat")
def concat_meta(op, ins):
    cols = sum(t.cols for t in ins)
    info = TensorInfo((ins[0].rows, cols), ins[0].dtype)
    return OpMetadata(outputs=[info], flops=info.rows * cols,
                      peak_bytes=2 * info.nbytes)


@register_impl("join", "python")
def join_py(op, ins):
    L, R = _validate(ins[0]), _validate(ins[1])
    lk, rk = op.spec["left_key"], op.spec["right_key"]
    order = np.argsort(R[:, rk], kind="stable")
    Rs = R[order]
    idx = np.searchsorted(Rs[:, rk], L[:, lk])
    idx = np.clip(idx, 0, len(Rs) - 1)
    matched = Rs[idx]
    keep = [j for j in range(R.shape[1]) if j != rk]
    return (np.hstack([L, matched[:, keep]]),)


@register_meta("join")
def join_meta(op, ins):
    cols = ins[0].cols + ins[1].cols - 1
    info = TensorInfo((ins[0].rows, cols), F64)
    return OpMetadata(outputs=[info],
                      flops=float(ins[1].rows) * np.log2(max(ins[1].rows, 2))
                      + ins[0].rows,
                      peak_bytes=2 * (ins[0].nbytes + ins[1].nbytes))


# ===========================================================================
# elementwise / columnwise feature transforms (projection pushdown targets)
# ===========================================================================

@register_impl("log1p", "python")
def log1p_py(op, ins):
    X = _validate(ins[0])
    return (np.log1p(np.maximum(X, 0.0)),)


@register_impl("log1p", "jax", traceable=True)
def log1p_jax(op, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    return (jnp.log1p(jnp.maximum(X, 0.0)),)


@register_impl("clip_outliers", "python")
def clip_py(op, ins):
    X = _validate(ins[0])
    q = op.spec.get("q", 0.01)
    lo = np.nanquantile(X, q, axis=0)
    hi = np.nanquantile(X, 1 - q, axis=0)
    return (np.clip(X, lo, hi),)


@register_impl("clip_outliers", "jax", traceable=True)
def clip_jax(op, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    q = op.spec.get("q", 0.01)
    lo = jnp.nanquantile(X, q, axis=0)
    hi = jnp.nanquantile(X, 1 - q, axis=0)
    return (jnp.clip(X, lo, hi),)


declare_columnwise("log1p", "clip_outliers", "cleaner")

for _name in ("log1p", "clip_outliers"):
    @register_meta(_name)
    def _elem_meta(op, ins):
        info = TensorInfo(ins[0].shape, ins[0].dtype)
        return OpMetadata(outputs=[info], flops=4.0 * info.rows * info.cols,
                          peak_bytes=3 * info.nbytes)


# ===========================================================================
# fitted preprocessing (fit/apply pairs)
# ===========================================================================

@register_impl("impute_fit", "python")
def impute_fit_py(op, ins):
    X = _validate(ins[0])
    if op.spec.get("strategy", "mean") == "median":
        stats = np.nanmedian(X, axis=0)
    else:
        stats = np.nanmean(X, axis=0)
    return (np.nan_to_num(stats),)


@register_impl("impute_fit", "jax", traceable=True)
def impute_fit_jax(op, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    stats = jnp.nanmean(X, axis=0)
    return (jnp.nan_to_num(stats),)


@register_impl("impute_apply", "python")
def impute_apply_py(op, ins):
    stats, X = np.asarray(ins[0]), _validate(ins[1])
    mask = np.isnan(X)
    X[mask] = np.broadcast_to(stats, X.shape)[mask]
    return (X,)


@register_impl("impute_apply", "jax", traceable=True)
def impute_apply_jax(op, ins):
    stats = jnp.asarray(ins[0], dtype=jnp.float32)
    X = jnp.asarray(ins[1], dtype=jnp.float32)
    return (jnp.where(jnp.isnan(X), stats[None, :], X),)


@register_meta("impute_fit")
def impute_fit_meta(op, ins):
    info = TensorInfo((ins[0].cols,), ins[0].dtype)
    return OpMetadata(outputs=[info], flops=2.0 * ins[0].rows * ins[0].cols,
                      peak_bytes=2 * ins[0].nbytes)


@register_meta("impute_apply")
def impute_apply_meta(op, ins):
    info = TensorInfo(ins[1].shape, ins[1].dtype)
    return OpMetadata(outputs=[info], flops=2.0 * info.rows * info.cols,
                      peak_bytes=3 * info.nbytes)


@register_impl("scaler_fit", "python")
def scaler_fit_py(op, ins):
    X = _validate(ins[0])
    mu = np.nanmean(X, axis=0)
    sd = np.nanstd(X, axis=0)
    sd[sd == 0] = 1.0
    return (np.stack([mu, sd]),)


@register_impl("scaler_fit", "jax", traceable=True)
def scaler_fit_jax(op, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    mu = jnp.nanmean(X, axis=0)
    sd = jnp.nanstd(X, axis=0)
    sd = jnp.where(sd == 0, 1.0, sd)
    return (jnp.stack([mu, sd]),)


@register_impl("scaler_apply", "python")
def scaler_apply_py(op, ins):
    stats, X = np.asarray(ins[0]), _validate(ins[1])
    centered = X - stats[0]          # temporary
    return (centered / stats[1],)    # second temporary


@register_impl("scaler_apply", "jax", traceable=True)
def scaler_apply_jax(op, ins):
    stats = jnp.asarray(ins[0], dtype=jnp.float32)
    X = jnp.asarray(ins[1], dtype=jnp.float32)
    return ((X - stats[0]) / stats[1],)


@register_meta("scaler_fit")
def scaler_fit_meta(op, ins):
    info = TensorInfo((2, ins[0].cols), ins[0].dtype)
    return OpMetadata(outputs=[info], flops=4.0 * ins[0].rows * ins[0].cols,
                      peak_bytes=2 * ins[0].nbytes)


@register_meta("scaler_apply")
def scaler_apply_meta(op, ins):
    info = TensorInfo(ins[1].shape, ins[1].dtype)
    return OpMetadata(outputs=[info], flops=2.0 * info.rows * info.cols,
                      peak_bytes=3 * info.nbytes)


# ---------------------------------------------------------------------------
# encoders
# ---------------------------------------------------------------------------

@register_impl("onehot", "python")
def onehot_py(op, ins):
    X = _validate(ins[0])
    cards = op.spec["cards"]
    pieces = []
    for j, card in enumerate(cards):
        col = np.nan_to_num(X[:, j]).astype(np.int64)
        col = np.clip(col, 0, card - 1)
        out = np.zeros((len(col), card))
        for c in range(card):             # per-category loop (naive tier)
            out[:, c] = (col == c).astype(np.float64)
        pieces.append(out)
    return (np.hstack(pieces),)


@register_impl("onehot", "jax", traceable=True)
def onehot_jax(op, ins):
    X = jnp.nan_to_num(jnp.asarray(ins[0]))
    cards = op.spec["cards"]
    pieces = []
    for j, card in enumerate(cards):
        col = jnp.clip(X[:, j].astype(jnp.int32), 0, card - 1)
        pieces.append(jax.nn.one_hot(col, card, dtype=jnp.float32))
    return (jnp.concatenate(pieces, axis=1),)


@register_meta("onehot")
def onehot_meta(op, ins):
    cols = sum(op.spec["cards"])
    info = TensorInfo((ins[0].rows, cols), F32)
    return OpMetadata(outputs=[info], flops=float(info.rows) * cols,
                      peak_bytes=2 * info.nbytes)


def _hash_mix(ids: np.ndarray, dim: int, seed: int) -> np.ndarray:
    """SplitMix-style integer hash → (n, dim) pseudo-random features.
    uint64 wraparound is intended (modular arithmetic)."""
    with np.errstate(over="ignore"):
        z = (ids[:, None].astype(np.uint64)
             + np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
             + (np.arange(dim, dtype=np.uint64)[None, :] + np.uint64(1))
             * np.uint64(0xBF58476D1CE4E5B9))
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
    return (z.astype(np.float64) / 2.0 ** 64) * 2.0 - 1.0


@register_impl("string_encode", "python")
def string_encode_py(op, ins):
    X = _validate(ins[0])
    dim, seed = op.spec["dim"], op.seed or 0
    cols = []
    for j in range(X.shape[1]):
        ids = np.nan_to_num(X[:, j]).astype(np.int64)
        cols.append(_hash_mix(ids, dim, seed + j))
    return (np.hstack(cols),)


@register_impl("string_encode", "jax")
def string_encode_jax(op, ins):
    # hashing is integer-heavy; compute per unique id then gather (the fast
    # tier exploits low unique-count vs rows)
    X = np.asarray(ins[0])
    dim, seed = op.spec["dim"], op.seed or 0
    cols = []
    for j in range(X.shape[1]):
        ids = np.nan_to_num(X[:, j]).astype(np.int64)
        uniq, inv = np.unique(ids, return_inverse=True)
        table = _hash_mix(uniq, dim, seed + j).astype(np.float32)
        cols.append(jnp.asarray(table)[jnp.asarray(inv)])
    return (jnp.concatenate(cols, axis=1),)


@register_meta("string_encode")
def string_encode_meta(op, ins):
    info = TensorInfo((ins[0].rows, op.spec["dim"] * ins[0].cols), F64)
    return OpMetadata(outputs=[info],
                      flops=12.0 * info.rows * info.cols,
                      peak_bytes=2 * info.nbytes)


@register_impl("target_encode_fit", "python")
def te_fit_py(op, ins):
    x, y = _validate(ins[0]).ravel(), np.asarray(ins[1]).ravel()
    card, sm = op.spec["card"], op.spec.get("smoothing", 20.0)
    ids = np.clip(np.nan_to_num(x).astype(np.int64), 0, card - 1)
    sums = np.bincount(ids, weights=y, minlength=card)
    counts = np.bincount(ids, minlength=card)
    prior = y.mean()
    return ((sums + sm * prior) / (counts + sm),)


@register_impl("target_encode_fit", "jax", traceable=True)
def te_fit_jax(op, ins):
    x = jnp.nan_to_num(jnp.asarray(ins[0]).ravel())
    y = jnp.asarray(ins[1], dtype=jnp.float32).ravel()
    card, sm = op.spec["card"], op.spec.get("smoothing", 20.0)
    ids = jnp.clip(x.astype(jnp.int32), 0, card - 1)
    sums = jax.ops.segment_sum(y, ids, num_segments=card)
    counts = jax.ops.segment_sum(jnp.ones_like(y), ids, num_segments=card)
    prior = y.mean()
    return ((sums + sm * prior) / (counts + sm),)


@register_impl("target_encode_apply", "python")
def te_apply_py(op, ins):
    table, x = np.asarray(ins[0]), _validate(ins[1]).ravel()
    card = op.spec["card"]
    ids = np.clip(np.nan_to_num(x).astype(np.int64), 0, card - 1)
    return (table[ids].reshape(-1, 1),)


@register_impl("target_encode_apply", "jax", traceable=True)
def te_apply_jax(op, ins):
    table = jnp.asarray(ins[0], dtype=jnp.float32)
    x = jnp.nan_to_num(jnp.asarray(ins[1]).ravel())
    card = op.spec["card"]
    ids = jnp.clip(x.astype(jnp.int32), 0, card - 1)
    return (table[ids].reshape(-1, 1),)


@register_meta("target_encode_fit")
def te_fit_meta(op, ins):
    info = TensorInfo((op.spec["card"],), F64)
    return OpMetadata(outputs=[info], flops=6.0 * ins[0].rows,
                      peak_bytes=2 * ins[0].nbytes)


@register_meta("target_encode_apply")
def te_apply_meta(op, ins):
    info = TensorInfo((ins[1].rows, 1), F64)
    return OpMetadata(outputs=[info], flops=float(ins[1].rows),
                      peak_bytes=2 * info.nbytes + ins[1].nbytes)


@register_impl("datetime_encode", "python")
def dt_py(op, ins):
    days = _validate(ins[0]).ravel()
    year = days / 365.25
    month = (days % 365.25) / 30.44
    dow = days % 7
    return (np.stack([days, year, np.floor(month), dow], axis=1),)


@register_impl("datetime_encode", "jax", traceable=True)
def dt_jax(op, ins):
    days = jnp.asarray(ins[0], dtype=jnp.float32).ravel()
    year = days / 365.25
    month = (days % 365.25) / 30.44
    dow = days % 7
    return (jnp.stack([days, year, jnp.floor(month), dow], axis=1),)


@register_meta("datetime_encode")
def dt_meta(op, ins):
    info = TensorInfo((ins[0].rows, 4), ins[0].dtype)
    return OpMetadata(outputs=[info], flops=6.0 * ins[0].rows,
                      peak_bytes=2 * info.nbytes)


@register_impl("cleaner", "python")
def cleaner_py(op, ins):
    X = _validate(ins[0])
    X[~np.isfinite(X)] = np.nan
    return (X,)


@register_impl("cleaner", "jax", traceable=True)
def cleaner_jax(op, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    return (jnp.where(jnp.isfinite(X), X, jnp.nan),)


@register_meta("cleaner")
def cleaner_meta(op, ins):
    info = TensorInfo(ins[0].shape, ins[0].dtype)
    return OpMetadata(outputs=[info], flops=2.0 * info.rows * info.cols,
                      peak_bytes=2 * info.nbytes)


# ---------------------------------------------------------------------------
# SVD reduction (exact + Frequent-Directions approx for stage=explore)
# ---------------------------------------------------------------------------

@register_impl("svd_reduce", "python")
def svd_py(op, ins):
    X = _validate(ins[0])
    k = op.spec["k"]
    U, s, _ = np.linalg.svd(X, full_matrices=False)
    return (U[:, :k] * s[:k],)


@partial(jax.jit, static_argnames=("k",))
def _svd_jax(X, k: int):
    U, s, _ = jnp.linalg.svd(X, full_matrices=False)
    return U[:, :k] * s[:k]


@register_impl("svd_reduce", "jax", traceable=True)
def svd_jax(op, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    return (_svd_jax(X, op.spec["k"]),)


@register_impl("svd_reduce", "jax", fidelity="approx", traceable=True)
def svd_fd_jax(op, ins):
    """Frequent-Directions sketch (paper cites Huang'19) — approximate,
    selectable under stage=explore."""
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    k = op.spec["k"]
    ell = min(2 * k, X.shape[1])
    sketch = jnp.zeros((ell, X.shape[1]), dtype=jnp.float32)
    chunk = max(ell, 4096)
    for start in range(0, X.shape[0], chunk):
        blk = jnp.vstack([sketch, X[start:start + chunk]])
        _, s, Vt = jnp.linalg.svd(blk, full_matrices=False)
        s2 = jnp.maximum(s[:ell] ** 2 - s[ell - 1] ** 2, 0.0) ** 0.5
        sketch = s2[:, None] * Vt[:ell]
    # project X on sketch's top-k right singular vectors
    _, _, Vt = jnp.linalg.svd(sketch, full_matrices=False)
    return (X @ Vt[:k].T,)


@register_meta("svd_reduce")
def svd_meta(op, ins):
    info = TensorInfo((ins[0].rows, op.spec["k"]), F32)
    n, d = ins[0].rows, ins[0].cols
    return OpMetadata(outputs=[info], flops=2.0 * n * d * d,
                      peak_bytes=3 * ins[0].nbytes)


# ===========================================================================
# splits
# ===========================================================================

def _perm(n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).permutation(n)


@register_impl("train_test_split", "python")
def tts_py(op, ins):
    X, y = np.asarray(ins[0]), np.asarray(ins[1])
    n = X.shape[0]
    n_test = int(round(n * op.spec["test_frac"]))
    p = _perm(n, op.seed or 0)
    te, tr = p[:n_test], p[n_test:]
    return (X[tr].copy(), y[tr].copy(), X[te].copy(), y[te].copy())


@register_impl("kfold_split", "python")
def kfold_py(op, ins):
    X, y = np.asarray(ins[0]), np.asarray(ins[1])
    n = X.shape[0]
    k, fold = op.spec["k"], op.spec["fold"]
    fold_size = n // k                       # equal folds → static shapes
    p = _perm(n, op.seed or 0)
    te = p[fold * fold_size:(fold + 1) * fold_size]
    tr = np.concatenate([p[:fold * fold_size],
                         p[(fold + 1) * fold_size:]])
    return (X[tr].copy(), y[tr].copy(), X[te].copy(), y[te].copy())


@register_meta("train_test_split")
def tts_meta(op, ins):
    n = ins[0].rows
    n_test = int(round(n * op.spec["test_frac"]))
    n_train = n - n_test
    outs = [TensorInfo((n_train, ins[0].cols), ins[0].dtype),
            TensorInfo((n_train,), ins[1].dtype),
            TensorInfo((n_test, ins[0].cols), ins[0].dtype),
            TensorInfo((n_test,), ins[1].dtype)]
    return OpMetadata(outputs=outs, flops=float(n),
                      peak_bytes=2 * (ins[0].nbytes + ins[1].nbytes))


@register_meta("kfold_split")
def kfold_meta(op, ins):
    n = ins[0].rows
    fold_size = n // op.spec["k"]
    n_train = n - fold_size
    outs = [TensorInfo((n_train, ins[0].cols), ins[0].dtype),
            TensorInfo((n_train,), ins[1].dtype),
            TensorInfo((fold_size, ins[0].cols), ins[0].dtype),
            TensorInfo((fold_size,), ins[1].dtype)]
    return OpMetadata(outputs=outs, flops=float(n),
                      peak_bytes=2 * (ins[0].nbytes + ins[1].nbytes))


# ===========================================================================
# estimators
# ===========================================================================

@register_impl("ridge_fit", "python")
def ridge_py(op, ins):
    X, y = _validate(ins[0]), np.asarray(ins[1], dtype=np.float64).ravel()
    alpha = op.spec["alpha"]
    Xb = np.hstack([X, np.ones((X.shape[0], 1))])   # bias column copy
    XtX = Xb.T @ Xb                                  # temporary
    XtX += alpha * np.eye(Xb.shape[1])
    Xty = Xb.T @ y
    w = np.linalg.solve(XtX, Xty)
    return (w,)


@partial(jax.jit)
def _ridge_solve(X, y, alpha):
    Xb = jnp.concatenate([X, jnp.ones((X.shape[0], 1), X.dtype)], axis=1)
    XtX = Xb.T @ Xb + alpha * jnp.eye(Xb.shape[1], dtype=X.dtype)
    Xty = Xb.T @ y
    return jax.scipy.linalg.solve(XtX, Xty, assume_a="pos")


@register_impl("ridge_fit", "jax", vmappable=True, traceable=True)
def ridge_jax(op, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    y = jnp.asarray(ins[1], dtype=jnp.float32).ravel()
    return (_ridge_solve(X, y, op.spec["alpha"]),)


@register_meta("ridge_fit")
def ridge_meta(op, ins):
    n, d = ins[0].rows, ins[0].cols + 1
    info = TensorInfo((d,), F64)
    return OpMetadata(outputs=[info], flops=2.0 * n * d * d + d ** 3 / 3,
                      peak_bytes=2 * ins[0].nbytes + 8 * d * d)


@register_impl("elasticnet_fit", "python")
def enet_py(op, ins):
    """Cyclic coordinate descent, interpreted loop per coordinate."""
    X, y = _validate(ins[0]), np.asarray(ins[1], dtype=np.float64).ravel()
    alpha, l1r = op.spec["alpha"], op.spec["l1_ratio"]
    iters = op.spec.get("iters", 200)
    n, d = X.shape
    mu, sd = X.mean(0), X.std(0)
    sd[sd == 0] = 1
    Xs = (X - mu) / sd
    ym = y.mean()
    yc = y - ym
    w = np.zeros(d)
    r = yc.copy()
    l1 = alpha * l1r * n
    l2 = alpha * (1 - l1r) * n
    col_sq = (Xs ** 2).sum(0)
    for _ in range(iters):
        for j in range(d):                     # interpreted inner loop
            wj = w[j]
            rho = Xs[:, j] @ r + wj * col_sq[j]
            w[j] = np.sign(rho) * max(abs(rho) - l1, 0) / (col_sq[j] + l2)
            if w[j] != wj:
                r -= Xs[:, j] * (w[j] - wj)
    w_out = np.concatenate([w / sd, [ym - (mu / sd) @ w]])
    return (w_out,)


@partial(jax.jit, static_argnames=("iters",))
def _enet_fista(X, y, alpha, l1r, iters: int):
    n, d = X.shape
    mu, sd = X.mean(0), X.std(0)
    sd = jnp.where(sd == 0, 1, sd)
    Xs = (X - mu) / sd
    ym = y.mean()
    yc = y - ym
    l1 = alpha * l1r * n
    l2 = alpha * (1 - l1r) * n
    G = Xs.T @ Xs
    L = jnp.linalg.norm(G, ord=2) + l2 + 1e-6   # Lipschitz bound
    Xty = Xs.T @ yc

    def step(carry, _):
        w, z, t = carry
        grad = G @ z - Xty + l2 * z
        u = z - grad / L
        w_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - l1 / L, 0)
        t_new = (1 + jnp.sqrt(1 + 4 * t * t)) / 2
        z_new = w_new + ((t - 1) / t_new) * (w_new - w)
        return (w_new, z_new, t_new), None

    (w, _, _), _ = jax.lax.scan(step, (jnp.zeros(d, X.dtype),
                                       jnp.zeros(d, X.dtype),
                                       jnp.asarray(1.0, X.dtype)),
                                None, length=iters)
    bias = ym - (mu / sd) @ w
    return jnp.concatenate([w / sd, bias[None]])


@register_impl("elasticnet_fit", "jax", vmappable=True, traceable=True)
def enet_jax(op, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    y = jnp.asarray(ins[1], dtype=jnp.float32).ravel()
    return (_enet_fista(X, y, op.spec["alpha"], op.spec["l1_ratio"],
                        op.spec.get("iters", 200)),)


@register_meta("elasticnet_fit")
def enet_meta(op, ins):
    n, d = ins[0].rows, ins[0].cols
    iters = op.spec.get("iters", 200)
    info = TensorInfo((d + 1,), F64)
    return OpMetadata(outputs=[info], flops=2.0 * iters * n * d,
                      peak_bytes=3 * ins[0].nbytes)


@register_impl("gbt_fit", "python")
def gbt_py(op, ins):
    X, y = np.asarray(ins[0], dtype=np.float64), \
        np.asarray(ins[1], dtype=np.float64).ravel()
    s = op.spec
    return (gbt.fit_numpy(X, y, n_trees=s["n_trees"], depth=s["depth"],
                          lr=s["learning_rate"], reg=s["reg"],
                          subsample=s["subsample"], seed=op.seed or 0),)


@register_impl("gbt_fit", "jax")
def gbt_jx(op, ins):
    X, y = np.asarray(ins[0], dtype=np.float64), \
        np.asarray(ins[1], dtype=np.float64).ravel()
    s = op.spec
    return (gbt.fit_jax(X, y, n_trees=s["n_trees"], depth=s["depth"],
                        lr=s["learning_rate"], reg=s["reg"],
                        subsample=s["subsample"], seed=op.seed or 0),)


@register_meta("gbt_fit")
def gbt_meta(op, ins):
    n, d = ins[0].rows, ins[0].cols
    s = op.spec
    T, depth = s["n_trees"], s["depth"]
    n_nodes, n_leaves = 2 ** depth - 1, 2 ** depth
    size = 6 + d * (gbt.N_BINS - 1) + T * n_nodes * 2 + T * n_leaves
    info = TensorInfo((size,), F64)
    flops = float(T) * depth * n * (d * 2 + 8)
    return OpMetadata(outputs=[info], flops=flops,
                      peak_bytes=int(2.5 * ins[0].nbytes))


@register_impl("linear_predict", "python")
def linpred_py(op, ins):
    w, X = np.asarray(ins[0]), _validate(ins[1])
    return (X @ w[:-1] + w[-1],)


@register_impl("linear_predict", "jax", traceable=True)
def linpred_jax(op, ins):
    w = jnp.asarray(ins[0], dtype=jnp.float32)
    X = jnp.asarray(ins[1], dtype=jnp.float32)
    return (X @ w[:-1] + w[-1],)


@register_meta("linear_predict")
def linpred_meta(op, ins):
    info = TensorInfo((ins[1].rows,), F64)
    return OpMetadata(outputs=[info],
                      flops=2.0 * ins[1].rows * ins[1].cols,
                      peak_bytes=ins[1].nbytes)


@register_impl("gbt_predict", "python")
def gbtpred_py(op, ins):
    return (gbt.predict_numpy(np.asarray(ins[0]), np.asarray(ins[1],
                                                             dtype=np.float64)),)


@register_impl("gbt_predict", "jax")
def gbtpred_jax(op, ins):
    return (gbt.predict_jax(np.asarray(ins[0]),
                            np.asarray(ins[1], dtype=np.float64)),)


@register_meta("gbt_predict")
def gbtpred_meta(op, ins):
    info = TensorInfo((ins[1].rows,), F64)
    return OpMetadata(outputs=[info], flops=30.0 * ins[1].rows,
                      peak_bytes=2 * ins[1].nbytes)


# ===========================================================================
# metrics & reductions
# ===========================================================================

@register_impl("metric", "python")
def metric_py(op, ins):
    y, yhat = (np.asarray(v, dtype=np.float64).ravel() for v in ins)
    kind = op.spec.get("kind", "rmse")
    if kind == "rmse":
        return (np.sqrt(np.mean((y - yhat) ** 2)),)
    if kind == "mae":
        return (np.mean(np.abs(y - yhat)),)
    if kind == "r2":
        ss = np.sum((y - yhat) ** 2)
        st = np.sum((y - y.mean()) ** 2)
        return (1.0 - ss / st,)
    raise KeyError(kind)


@register_meta("metric")
def metric_meta(op, ins):
    return OpMetadata(outputs=[TensorInfo((), F64)],
                      flops=4.0 * ins[0].rows,
                      peak_bytes=2 * ins[0].nbytes)


@register_impl("mean_scalars", "python")
def mean_scalars_py(op, ins):
    return (float(np.mean([float(np.asarray(v)) for v in ins])),)


@register_meta("mean_scalars")
def mean_scalars_meta(op, ins):
    return OpMetadata(outputs=[TensorInfo((), F64)], flops=len(ins))


@register_impl("best_of", "python")
def best_of_py(op, ins):
    vals = np.array([float(np.asarray(v)) for v in ins])
    if op.spec.get("mode", "min") == "min":
        i = int(np.argmin(vals))
    else:
        i = int(np.argmax(vals))
    return (vals[i], i)


@register_meta("best_of")
def best_of_meta(op, ins):
    return OpMetadata(outputs=[TensorInfo((), F64), TensorInfo((), "int64")],
                      flops=len(ins))


@register_impl("gbt_prefix", "python")
def gbt_prefix_py(op, ins):
    """Extract the k-tree prefix model from a larger fitted GBT pack
    (boosting prefix property — see core.rewrites.gbt_prefix_sharing)."""
    model = np.asarray(ins[0])
    k = op.spec["n_trees"]
    base, bins, feats, thrs, leaves, depth = gbt.unpack(model, 0)
    return (gbt.pack(base, bins, feats[:k], thrs[:k], leaves[:k], depth),)


@register_meta("gbt_prefix")
def gbt_prefix_meta(op, ins):
    info = TensorInfo(ins[0].shape, ins[0].dtype)  # ≤ input size
    return OpMetadata(outputs=[info], flops=float(info.rows),
                      peak_bytes=2 * ins[0].nbytes)


# ===========================================================================
# variant batching registrations (§Perf H3.4): hyperparameter-grid fits that
# share (X, y) execute as one vmapped solve
# ===========================================================================

from ..core.selection import register_vmap_group  # noqa: E402

# tunable hyperparameters: scalar spec fields safe to trace as runtime
# arguments of a compiled segment (never shapes, static loop bounds or
# branch selectors) — excluded from structural signatures, so structurally
# identical hyperparameter variants share one compiled program
declare_tunable("ridge_fit", "alpha")
declare_tunable("elasticnet_fit", "alpha", "l1_ratio")
declare_tunable("clip_outliers", "q")
declare_tunable("target_encode_fit", "smoothing")


def _inputs_key(op):
    return tuple(r.signature for r in op.inputs)


def _ridge_batch(ops, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    y = jnp.asarray(ins[1], dtype=jnp.float32).ravel()
    alphas = jnp.asarray([op.spec["alpha"] for op in ops], jnp.float32)
    ws = jax.vmap(_ridge_solve, in_axes=(None, None, 0))(X, y, alphas)
    return [(ws[i],) for i in range(len(ops))]


register_vmap_group("ridge_fit", _inputs_key, _ridge_batch)


def _enet_key(op):
    return (_inputs_key(op), op.spec.get("iters", 200))


def _enet_batch(ops, ins):
    X = jnp.asarray(ins[0], dtype=jnp.float32)
    y = jnp.asarray(ins[1], dtype=jnp.float32).ravel()
    alphas = jnp.asarray([op.spec["alpha"] for op in ops], jnp.float32)
    l1rs = jnp.asarray([op.spec["l1_ratio"] for op in ops], jnp.float32)
    iters = ops[0].spec.get("iters", 200)
    ws = jax.vmap(_enet_fista, in_axes=(None, None, 0, 0, None))(
        X, y, alphas, l1rs, iters)
    return [(ws[i],) for i in range(len(ops))]


register_vmap_group("elasticnet_fit", _enet_key, _enet_batch)
