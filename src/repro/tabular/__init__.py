"""repro.tabular — the ML-pipeline operator library used by agentic search.

Pipeline stages as stratum logical operators, each with a "python" tier
(naive NumPy: the Pandas/scikit-learn stand-in, copies + per-op dispatch) and
a "jax" tier (jitted jnp: the paper's Rust-kernel analogue), plus metadata
rules and composite lowerings (cv_score, table_vectorizer, grid_search).

Importing this package registers all implementations with repro.core.
"""

from . import impls  # noqa: F401  (registration side effects)
from . import lowerings  # noqa: F401
from .ops import (clip_outliers, concat, cv_score, elasticnet_fit, gbt_fit,
                  grid_search, join, kfold_split, log1p, mean_of, metric,
                  onehot, predict, project, read, ridge_fit, scale,
                  string_encode, table_vectorizer, target_encode,
                  datetime_encode, impute, svd_reduce, train_test_split)

__all__ = [
    "read", "project", "concat", "join", "impute", "scale", "onehot",
    "string_encode", "target_encode", "datetime_encode", "table_vectorizer",
    "svd_reduce", "ridge_fit", "elasticnet_fit", "gbt_fit", "predict",
    "metric", "kfold_split", "train_test_split", "cv_score", "grid_search",
    "mean_of", "log1p", "clip_outliers",
]
