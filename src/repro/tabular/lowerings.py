"""Composite-operator lowerings (paper §4.2 "Operator Lowering").

* ``cv_score``         → per-fold split/fit/predict/metric subgraphs + mean.
  Cross-validation becomes an *explicit* DAG instead of k re-executions of an
  opaque subgraph; folds share the parent data node, so CSE and the cache see
  through them.
* ``grid_search``      → one cv_score subgraph per grid point + best_of.
  All grid points share fold splits (identical (X, y, k, seed)) — the CSE win
  the paper highlights.
* ``table_vectorizer`` → cleaner + per-column-group encoders + concat, the
  paper's running example (skrub TableVectorizer decomposition).
"""

from __future__ import annotations

from typing import Any, Mapping

from ..core.dag import EVAL, LazyOp, LazyRef, TRANSFORM
from ..core.lowering import register_lowering
from . import ops
from ..data.tabular import CATEGORICAL, DATETIME, NUMERIC

_FIT_BUILDERS = {
    "ridge_fit": lambda x, y, p, seed: ops.ridge_fit(
        x, y, alpha=p.get("alpha", 1.0), seed=seed),
    "elasticnet_fit": lambda x, y, p, seed: ops.elasticnet_fit(
        x, y, alpha=p.get("alpha", 1.0), l1_ratio=p.get("l1_ratio", 0.5),
        iters=p.get("iters", 200), seed=seed),
    "gbt_fit": lambda x, y, p, seed: ops.gbt_fit(
        x, y, flavor=p.get("flavor", "lightgbm"),
        n_trees=p.get("n_trees", 30), depth=p.get("depth", 3),
        learning_rate=p.get("learning_rate", 0.1), reg=p.get("reg", 1.0),
        subsample=p.get("subsample", 1.0), seed=seed),
}


def build_fit(name: str, x: LazyRef, y: LazyRef, params: Mapping[str, Any],
              seed: int) -> LazyRef:
    if name not in _FIT_BUILDERS:
        raise KeyError(f"unknown estimator {name!r}")
    return _FIT_BUILDERS[name](x, y, dict(params), seed)


@register_lowering("cv_score")
def lower_cv(op: LazyOp, inputs: tuple):
    x, y = inputs
    k = op.spec["k"]
    est = dict(op.spec["estimator"])
    name = est.pop("name")
    seed = op.seed or 0
    scores = []
    for fold in range(k):
        xtr, ytr, xte, yte = ops.kfold_split(x, y, k, fold, seed=seed)
        model = build_fit(name, xtr, ytr, est, seed)
        yhat = ops.predict(model, xte)
        scores.append(ops.metric(yte, yhat, kind="rmse"))
    return [ops.mean_of(scores)]


@register_lowering("grid_search")
def lower_grid(op: LazyOp, inputs: tuple):
    x, y = inputs
    k = op.spec["k"]
    name = op.spec["estimator_name"]
    seed = op.seed or 0
    scores = []
    for params in op.spec["grid"]:
        scores.append(ops.cv_score(x, y, {"name": name, **dict(params)},
                                   k=k, seed=seed))
    best = LazyOp("best_of", EVAL, spec={"mode": "min"},
                  inputs=tuple(scores), n_outputs=2)
    return [best.out(0), best.out(1)]


@register_lowering("table_vectorizer")
def lower_tv(op: LazyOp, inputs: tuple):
    x = inputs[0]
    fit_on = inputs[1] if len(inputs) > 1 else x
    schema = op.spec["schema"]
    cols = op.spec["cols"]
    kinds = schema["kinds"]
    cards = schema["cards"]

    clean = LazyOp("cleaner", TRANSFORM, inputs=(x,)).out()
    clean_fit = clean if fit_on is x else \
        LazyOp("cleaner", TRANSFORM, inputs=(fit_on,)).out()

    num_idx = [i for i, c in enumerate(cols) if kinds[c] == NUMERIC]
    low_card = [i for i, c in enumerate(cols)
                if kinds[c] == CATEGORICAL and cards[c] <= 16]
    high_card = [i for i, c in enumerate(cols)
                 if kinds[c] == CATEGORICAL and cards[c] > 16]
    dt_idx = [i for i, c in enumerate(cols) if kinds[c] == DATETIME]

    # NOTE: `cols` indexes the *original* table; the TV input is already the
    # projected feature block, so positions are relative to `cols`.
    parts = []
    if num_idx:
        xn = ops.project(clean, num_idx)
        fn = ops.project(clean_fit, num_idx)
        imputed = ops.impute(xn, fit_on=fn)
        imputed_fit = ops.impute(fn, fit_on=fn)
        parts.append(ops.scale(imputed, fit_on=imputed_fit))
    if low_card:
        xc = ops.project(clean, low_card)
        parts.append(ops.onehot(
            xc, [cards[cols[i]] for i in low_card]))
    if high_card:
        xh = ops.project(clean, high_card)
        parts.append(ops.string_encode(xh, dim=16, seed=op.seed or 0))
    if dt_idx:
        for i in dt_idx:
            parts.append(ops.datetime_encode(ops.project(clean, [i])))
    if not parts:
        return [clean]
    return [ops.concat(parts)]
