"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

38 mamba layers; ONE shared attention+MLP block (same weights) applied after
every 6th mamba layer (6 applications + 2 tail mamba layers)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    act="swiglu", rope_theta=10_000.0,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_kernel=4,
    attn_every=6,
)
