"""xlstm-1.3b [ssm] — alternating mLSTM/sLSTM blocks
[arXiv:2405.04517; unverified].

48 blocks in 6 segments of (7 mLSTM + 1 sLSTM); d_ff=0 per the assignment —
xLSTM blocks carry their own up/down projections, no standalone MLP."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_period=8,
)
