"""repro.configs — one module per assigned architecture + registry.

``get_config(name)`` returns the exact published configuration;
``reduced(cfg)`` shrinks it family-preservingly for CPU smoke tests
(the full configs are exercised only via the dry-run's ShapeDtypeStructs).
"""

from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig, SHAPES, ShapeConfig, shape_applicable

from .llama3_405b import CONFIG as llama3_405b
from .qwen2_7b import CONFIG as qwen2_7b
from .nemotron_4_340b import CONFIG as nemotron_4_340b
from .starcoder2_15b import CONFIG as starcoder2_15b
from .granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from .arctic_480b import CONFIG as arctic_480b
from .internvl2_76b import CONFIG as internvl2_76b
from .zamba2_1_2b import CONFIG as zamba2_1_2b
from .xlstm_1_3b import CONFIG as xlstm_1_3b
from .musicgen_medium import CONFIG as musicgen_medium

REGISTRY: dict[str, ModelConfig] = {
    c.name: c for c in [
        llama3_405b, qwen2_7b, nemotron_4_340b, starcoder2_15b,
        granite_moe_3b_a800m, arctic_480b, internvl2_76b, zamba2_1_2b,
        xlstm_1_3b, musicgen_medium,
    ]
}

ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests."""
    common = dict(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512, remat=False, dtype="float32",
    )
    if cfg.family == "moe":
        common.update(n_experts=8, top_k=2, d_ff_expert=64)
    if cfg.family == "hybrid":
        common.update(n_layers=5, attn_every=2, n_kv_heads=4,
                      ssm_state=16, ssm_head_dim=32)
    if cfg.family == "ssm":
        common.update(n_layers=4, slstm_period=2, n_kv_heads=4)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **common)


__all__ = ["REGISTRY", "ARCH_NAMES", "get_config", "reduced", "SHAPES",
           "ShapeConfig", "shape_applicable"]
