"""internvl2-76b [vlm] — InternViT + InternLM2 backbone
[arXiv:2404.16821; unverified].

Backbone only (assignment): the ViT frontend is a STUB; input_specs()
provides precomputed patch embeddings (B, S, d_model)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256,
    act="swiglu", rope_theta=1_000_000.0,
    frontend="patch_embed",
)
