"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

Backbone only (assignment): the EnCodec frontend is a STUB; input_specs()
provides precomputed frame embeddings.  RoPE replaces the reference's
sinusoidal embeddings (positional scheme deviation, DESIGN.md §8)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    act="gelu", rope_theta=10_000.0,
    frontend="audio_tokens",
)
