"""granite-moe-3b-a800m [moe] — 40 experts top-8, 512-wide expert FFNs
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

40 experts are padded to 48 so expert parallelism divides the 16-way model
axis (router never selects padding — see ModelConfig.n_experts_padded)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    act="swiglu", rope_theta=10_000.0,
    n_experts=40, top_k=8, d_ff_expert=512,
)
