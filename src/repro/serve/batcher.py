"""Continuous-batching request scheduler for serving.

Slot-based continuous batching (vLLM-style, TPU-static shapes): a fixed
number of batch lanes; finished sequences free their lane, waiting requests
are prefilled into free lanes while decode continues for the rest.  All
shapes are static (lane count, max_len) so one compiled decode step serves
the whole lifetime — the TPU-idiomatic version of dynamic batching.
"""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    generated: list = field(default_factory=list)
    done: bool = False


class Batcher:
    def __init__(self, n_lanes: int, max_len: int, eos_id: int = -1):
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: collections.deque = collections.deque()
        self.lanes: list[Optional[Request]] = [None] * n_lanes
        self.finished: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free lanes from the queue; returns (lane, request) pairs
        needing prefill."""
        new = []
        for i in range(self.n_lanes):
            if self.lanes[i] is None and self.queue:
                req = self.queue.popleft()
                self.lanes[i] = req
                new.append((i, req))
        return new

    def active_lanes(self) -> list[int]:
        return [i for i, r in enumerate(self.lanes) if r is not None]

    def record_tokens(self, tokens: np.ndarray) -> None:
        """tokens: (n_lanes,) next token per lane; retires finished lanes."""
        for i, r in enumerate(self.lanes):
            if r is None:
                continue
            t = int(tokens[i])
            r.generated.append(t)
            if (len(r.generated) >= r.max_new_tokens
                    or (self.eos_id >= 0 and t == self.eos_id)):
                r.done = True
                self.finished.append(r)
                self.lanes[i] = None

    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.lanes)
