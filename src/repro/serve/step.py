"""Serving steps: prefill (prompt → cache) and decode (one token/step)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import decode_step as _decode_step
from ..models.model import prefill as _prefill


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, inputs):
        return _prefill(params, inputs, cfg, max_len=max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig, sample: str = "greedy"):
    def decode(params, state, token_or_embed):
        logits, state = _decode_step(params, state, token_or_embed, cfg)
        # mask padded vocab columns before sampling
        if cfg.vocab_padded > cfg.vocab:
            mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
            logits = jnp.where(mask[None, :], -jnp.inf, logits)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token[:, None], logits, state
    return decode
