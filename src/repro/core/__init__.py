"""repro.core — stratum: execution infrastructure for agentic pipeline search.

The paper's contribution (§4), as a composable library:

* :mod:`repro.core.dag`         lazy operator DAG + content hashing
* :mod:`repro.core.fusion`      pipeline-batch fusion, variant grouping
* :mod:`repro.core.metadata`    metadata collection pass
* :mod:`repro.core.rewrites`    CSE / read sharing / pushdown / folding
* :mod:`repro.core.lowering`    composite-operator lowering (CV unrolling...)
* :mod:`repro.core.selection`   tiered physical operator selection
* :mod:`repro.core.scheduler`   memory-budgeted parallelization planning
* :mod:`repro.core.cache`       intermediate reuse (RAM + disk spill)
* :mod:`repro.core.plan_cache`  compiled-plan cache (structural signatures)
* :mod:`repro.core.runtime`     segment executor over pluggable backends
* :mod:`repro.core.backends`    ExecutionBackend seam (per-op / compiled)
* :mod:`repro.core.api`         the Stratum session
"""

from .api import ALL_FEATURES, Stratum, StratumReport
from .backends import (ExecutionBackend, JaxSegmentBackend,
                       PythonThreadBackend, make_backends, register_backend)
from .dag import (COMPOSITE, CONST, ESTIMATOR, EVAL, FILTER, GENERIC, LazyOp,
                  LazyRef, PROJECT, SOURCE, TRANSFORM, count_ops,
                  declare_tunable, structural_signature, toposort,
                  tunable_fields)
from .fusion import PipelineBatch, group_variants
from .plan_cache import PlanCache
from .annotations import annotate

__all__ = [
    "ALL_FEATURES", "Stratum", "StratumReport", "LazyOp", "LazyRef",
    "PipelineBatch", "group_variants", "annotate", "count_ops", "toposort",
    "declare_tunable", "tunable_fields", "structural_signature",
    "ExecutionBackend", "JaxSegmentBackend", "PythonThreadBackend",
    "make_backends", "register_backend", "PlanCache",
    "SOURCE", "TRANSFORM", "PROJECT", "FILTER", "ESTIMATOR", "EVAL",
    "COMPOSITE", "CONST", "GENERIC",
]
