"""Compiled-plan cache: structural signature → compiled segment program.

Agentic searches emit thousands of structurally identical DAGs (AIDE
refinements differ only in constants and hyperparameters).  The
:class:`~repro.core.backends.jax_segment.JaxSegmentBackend` traces a whole
backend-homogeneous segment into one jitted callable with tunable
constants hoisted to arguments; this module keeps those callables keyed by
the segment's *structural* signature (``dag.py``), so the second
structurally identical plan — from any tenant of the same service shard —
skips tracing and compilation entirely and pays one dispatch per segment.

One :class:`PlanCache` is shared per service shard (wired through
``service/server.py``); hit rates surface in per-shard service telemetry
and in the fabric's aggregated snapshot, where signature-locality routing
makes compiled-plan locality visible fabric-wide.

Entries are LRU-evicted by count, not bytes: a compiled segment's host
footprint is dominated by the XLA executable, which jax already dedups
through its own compilation cache — this layer only bounds the number of
live python callables.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Hashable, Optional


@dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    compiles: int = 0      # callables built and inserted
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class PlanCache:
    """Thread-safe LRU of compiled segment callables.

    Keys are hashable descriptors built by the segment backend — the
    segment's structural signature plus whatever runtime cut the backend
    folds in (e.g. which ops were served from the intermediate cache and
    therefore became segment inputs instead of traced ops)."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.stats = PlanCacheStats()

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return entry

    def put(self, key: Hashable, compiled: Any) -> None:
        with self._lock:
            if key not in self._entries:
                self.stats.compiles += 1
            self._entries[key] = compiled
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def snapshot(self) -> dict:
        """Telemetry view, copied under the lock."""
        with self._lock:
            s = self.stats
            return {
                "entries": len(self._entries),
                "capacity": self.capacity,
                "hits": s.hits,
                "misses": s.misses,
                "compiles": s.compiles,
                "evictions": s.evictions,
                "hit_rate": round(s.hit_rate, 6),
            }
