"""Compiled-plan cache: structural signature → compiled segment program.

Agentic searches emit thousands of structurally identical DAGs (AIDE
refinements differ only in constants and hyperparameters).  The
:class:`~repro.core.backends.jax_segment.JaxSegmentBackend` traces a whole
backend-homogeneous segment into one jitted callable with tunable
constants hoisted to arguments; this module keeps those callables keyed by
the segment's *structural* signature (``dag.py``), so the second
structurally identical plan — from any tenant of the same service shard —
skips tracing and compilation entirely and pays one dispatch per segment.

One :class:`PlanCache` is shared per service shard (wired through
``service/server.py``); hit rates surface in per-shard service telemetry
and in the fabric's aggregated snapshot, where signature-locality routing
makes compiled-plan locality visible fabric-wide.

Entries are LRU-evicted by count, not bytes: a compiled segment's host
footprint is dominated by the XLA executable, which jax already dedups
through its own compilation cache — this layer only bounds the number of
live python callables.

Async compilation (``compile_async=True``): the cache owns a
:class:`CompileExecutor` — one bounded daemon worker thread that runs
trace+jit jobs off the critical path.  A segment backend that misses the
cache enqueues the compile and dispatches the current round per-op; the
next structurally identical round finds the entry warm.  ``submit`` is
single-flight: a key that is already cached, already inflight, or already
queued is rejected, so N tenants racing on the same new signature trace it
once.  A second, lower-priority lane (``speculative=True``, bounded by
``speculative_depth``) carries predictor-driven warm-up jobs; the normal
lane always drains first and speculative entries dropped for lack of room
are counted, never blocked on.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Any, Callable, Hashable, Optional


@dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    compiles: int = 0            # callables built and inserted
    evictions: int = 0
    # async-compile lane (all zero when compile_async is off)
    async_compiles: int = 0      # background jobs that completed a build
    async_failures: int = 0      # background jobs that raised
    inflight: int = 0            # gauge: queued + running background jobs
    speculative_compiles: int = 0  # warm-up builds inserted ahead of demand
    speculative_hits: int = 0    # first demand-hit on a speculative entry
    speculative_dropped: int = 0  # warm-up jobs rejected (lane full)
    uncompilable: int = 0        # gauge: backend's bounded uncompilable set
    compile_time_s: float = 0.0  # cumulative seconds in background builds

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class CompileExecutor:
    """Bounded single-worker background compiler with single-flight dedup.

    A deliberate non-use of ``ThreadPoolExecutor``: its workers are
    non-daemon and joined at interpreter exit, which would let an inflight
    XLA compile hold a proc-fabric worker process open past SIGTERM.  Here
    the worker is one daemon thread, started lazily on first submit, and
    ``close()`` wakes it and joins with a timeout — a compile still running
    at that point finishes (or not) on a daemon thread that cannot block
    process exit.

    Two lanes: ``normal`` (demand misses, bounded by ``max_pending``) and
    ``speculative`` (predictor warm-ups, bounded by ``speculative_depth``,
    only drained when the normal lane is empty).  ``_inflight`` holds every
    queued-or-running key for single-flight dedup across both lanes.
    """

    def __init__(self, stats: PlanCacheStats, lock: threading.Lock,
                 contains: Callable[[Hashable], bool],
                 max_pending: int = 32, speculative_depth: int = 0):
        self._stats = stats
        self._stats_lock = lock
        self._contains = contains
        self.max_pending = max(1, int(max_pending))
        self.speculative_depth = max(0, int(speculative_depth))
        self._q: "deque[tuple[Hashable, Callable[[], Any]]]" = deque()
        self._spec_q: "deque[tuple[Hashable, Callable[[], Any]]]" = deque()
        self._inflight: set = set()
        self._mu = threading.Condition(threading.Lock())
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        self._idle = threading.Event()
        self._idle.set()

    # -- submission ----------------------------------------------------

    def submit(self, key: Hashable, job: Callable[[], Any],
               speculative: bool = False) -> bool:
        """Enqueue ``job`` (a zero-arg compile closure) under ``key``.

        Returns False without queuing when the key is already cached,
        already inflight, the lane is full, or the executor is closed.
        """
        with self._mu:
            if self._closed or key in self._inflight or self._contains(key):
                return False
            lane = self._spec_q if speculative else self._q
            limit = self.speculative_depth if speculative else self.max_pending
            if len(lane) >= limit:
                if speculative:
                    with self._stats_lock:
                        self._stats.speculative_dropped += 1
                return False
            self._inflight.add(key)
            lane.append((key, job))
            self._idle.clear()
            with self._stats_lock:
                self._stats.inflight = len(self._inflight)
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._run, name="stratum-compile", daemon=True)
                self._worker.start()
            self._mu.notify()
        return True

    def inflight(self, key: Hashable) -> bool:
        with self._mu:
            return key in self._inflight

    # -- worker --------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._mu:
                while not self._q and not self._spec_q and not self._closed:
                    self._idle.set()
                    self._mu.wait()
                if self._closed and not self._q and not self._spec_q:
                    self._idle.set()
                    return
                key, job = (self._q.popleft() if self._q
                            else self._spec_q.popleft())
            t0 = time.perf_counter()
            try:
                job()
                ok = True
            except Exception:
                ok = False
            dt = time.perf_counter() - t0
            with self._mu:
                self._inflight.discard(key)
                with self._stats_lock:
                    self._stats.inflight = len(self._inflight)
                    self._stats.compile_time_s += dt
                    if ok:
                        self._stats.async_compiles += 1
                    else:
                        self._stats.async_failures += 1
                if not self._q and not self._spec_q:
                    self._idle.set()

    # -- lifecycle -----------------------------------------------------

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until both lanes are empty and no job is running."""
        return self._idle.wait(timeout)

    def close(self, timeout: float = 5.0) -> None:
        """Stop accepting work, drop queued jobs, join the worker.

        Idempotent.  A job mid-compile when the timeout expires keeps
        running on the daemon thread but can no longer publish (the
        inflight set is cleared after it finishes regardless; ``submit``
        refuses everything once closed)."""
        with self._mu:
            if self._closed:
                worker = self._worker
            else:
                self._closed = True
                for key, _ in list(self._q) + list(self._spec_q):
                    self._inflight.discard(key)
                self._q.clear()
                self._spec_q.clear()
                with self._stats_lock:
                    self._stats.inflight = len(self._inflight)
                worker = self._worker
                self._mu.notify_all()
        if worker is not None:
            worker.join(timeout)


class PlanCache:
    """Thread-safe LRU of compiled segment callables.

    Keys are hashable descriptors built by the segment backend — the
    segment's structural signature plus whatever runtime cut the backend
    folds in (e.g. which ops were served from the intermediate cache and
    therefore became segment inputs instead of traced ops).

    With ``compile_async=True`` the cache also owns a
    :class:`CompileExecutor` (``self.executor``); the segment backend uses
    it to move trace+jit off the critical path and to accept speculative
    warm-up jobs (``speculative_depth`` > 0)."""

    def __init__(self, capacity: int = 256, compile_async: bool = False,
                 max_async_pending: int = 32, speculative_depth: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()  # guarded-by: _lock
        self._lock = threading.Lock()
        self.stats = PlanCacheStats()
        self._speculative: set = set()   # guarded-by: _lock
        self.executor: Optional[CompileExecutor] = None
        if compile_async:
            self.executor = CompileExecutor(
                self.stats, self._lock, self.__contains__,
                max_pending=max_async_pending,
                speculative_depth=speculative_depth)

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            if key in self._speculative:
                # first demand-hit on a warm-up entry: the prediction paid
                self._speculative.discard(key)
                self.stats.speculative_hits += 1
            return entry

    def put(self, key: Hashable, compiled: Any,
            speculative: bool = False) -> None:
        with self._lock:
            if key not in self._entries:
                self.stats.compiles += 1
                if speculative:
                    self._speculative.add(key)
                    self.stats.speculative_compiles += 1
            self._entries[key] = compiled
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                old, _ = self._entries.popitem(last=False)
                self._speculative.discard(old)
                self.stats.evictions += 1

    def note_uncompilable(self, n: int) -> None:
        """Backend gauge: current size of its bounded uncompilable set."""
        with self._lock:
            self.stats.uncompilable = n

    def close(self, timeout: float = 5.0) -> None:
        """Shut down the compile executor (no-op when async is off)."""
        if self.executor is not None:
            self.executor.close(timeout)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def snapshot(self) -> dict:
        """Telemetry view, copied under the lock."""
        with self._lock:
            s = self.stats
            return {
                "entries": len(self._entries),
                "capacity": self.capacity,
                "hits": s.hits,
                "misses": s.misses,
                "compiles": s.compiles,
                "evictions": s.evictions,
                "hit_rate": round(s.hit_rate, 6),
                "async": self.executor is not None,
                "async_compiles": s.async_compiles,
                "async_failures": s.async_failures,
                "inflight": s.inflight,
                "speculative_compiles": s.speculative_compiles,
                "speculative_hits": s.speculative_hits,
                "speculative_dropped": s.speculative_dropped,
                "uncompilable": s.uncompilable,
                "compile_time_s": round(s.compile_time_s, 6),
            }
