"""Metadata collection pass (paper §4.2).

Skrub treats operators as black boxes; stratum's first optimizer pass walks the
DAG and materializes per-operator metadata *inside the operator objects*:

* structural class (source / projection / estimator / ...) — already on the op,
* data characteristics: output shapes, dtypes, row/col counts,
* cost hints: estimated FLOPs, output bytes, and peak working-set bytes,
* backend availability (which physical implementations exist).

Shape/cost inference rules are registered per logical op name; GENERIC ops
without a rule get conservative estimates (propagate input sizes), which is
exactly the paper's "black-box UDF" caveat (§5 challenge 3).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from .dag import CONST, GENERIC, LazyOp, LazyRef, toposort


@dataclass
class TensorInfo:
    shape: tuple
    dtype: str

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * np.dtype(self.dtype).itemsize

    @property
    def rows(self) -> int:
        return int(self.shape[0]) if self.shape else 1

    @property
    def cols(self) -> int:
        return int(self.shape[1]) if len(self.shape) > 1 else 1


@dataclass
class OpMetadata:
    outputs: list            # list[TensorInfo], one per op output
    flops: float = 0.0       # estimated compute
    peak_bytes: int = 0      # working-set estimate (inputs + outputs + temps)
    backends: tuple = ()     # physical implementations available (selection.py)
    library: str = "repro"   # provenance hint ("pandas-like", "sklearn-like", ...)
    notes: dict = field(default_factory=dict)

    @property
    def out_bytes(self) -> int:
        return sum(t.nbytes for t in self.outputs)


# rule: (op, input TensorInfos) -> OpMetadata
_RULES: dict[str, Callable[[LazyOp, Sequence[TensorInfo]], OpMetadata]] = {}


def register_meta(op_name: str):
    def deco(fn):
        _RULES[op_name] = fn
        return fn
    return deco


def _fallback(op: LazyOp, ins: Sequence[TensorInfo]) -> OpMetadata:
    if op.op_class == CONST:
        value = op.spec.get("value")
        arr = np.asarray(value)
        info = TensorInfo(tuple(arr.shape), str(arr.dtype))
        return OpMetadata(outputs=[info], flops=0.0, peak_bytes=info.nbytes)
    if ins:
        # conservative: mirror the largest input per output
        biggest = max(ins, key=lambda t: t.nbytes)
        outs = [TensorInfo(biggest.shape, biggest.dtype)
                for _ in range(op.n_outputs)]
        flops = float(sum(np.prod(t.shape, dtype=np.int64) for t in ins))
        peak = sum(t.nbytes for t in ins) + sum(t.nbytes for t in outs)
        return OpMetadata(outputs=outs, flops=flops, peak_bytes=peak)
    outs = [TensorInfo((), "float64") for _ in range(op.n_outputs)]
    return OpMetadata(outputs=outs)


def collect_metadata(sinks: Sequence[LazyRef]) -> list[LazyOp]:
    """Run the metadata pass over the DAG; returns the topo order visited.

    Metadata is materialized on ``op.meta`` (paper: "materializes it within
    the operator objects").  Idempotent: ops with meta already set and
    unchanged inputs are skipped.
    """
    order = toposort(sinks)
    infos: dict[str, list[TensorInfo]] = {}
    for op in order:
        ins: list[TensorInfo] = []
        for ref in op.inputs:
            ins.append(infos[ref.op.signature][ref.index])
        rule = _RULES.get(op.op_name, _fallback)
        meta = rule(op, ins)
        if len(meta.outputs) != op.n_outputs:
            raise ValueError(
                f"meta rule for {op.op_name} returned {len(meta.outputs)} "
                f"outputs, op declares {op.n_outputs}")
        op.meta = meta
        infos[op.signature] = meta.outputs
    return order


def output_info(ref: LazyRef) -> TensorInfo:
    if ref.op.meta is None:
        raise RuntimeError("metadata pass has not run for this DAG")
    return ref.op.meta.outputs[ref.index]


def has_rule(op_name: str) -> bool:
    return op_name in _RULES
