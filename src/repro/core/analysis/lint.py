"""Pipeline lint — legal-but-suspicious patterns over the same IR.

Four rule families, each with op-level provenance:

* ``dead-output`` — an output of a multi-output op that no consumer reads
  and that is not a sink: the op still computes it, the value is discarded.
* ``dead-op`` — ops reachable from ``extra_roots`` (e.g. steps declared by
  an orchestrator) but from no sink: they never execute, which is usually
  a wiring mistake in the program that built the DAG.
* ``duplicate-subgraph`` — distinct op objects sharing a content signature;
  CSE will merge them, so this is free information about batch redundancy.
* ``undeclared-tunable`` — structurally identical ops whose specs differ
  only in scalar fields *not* declared tunable: each variant occupies its
  own plan-cache entry and compiles separately, defeating the
  structural-signature cache (``dag.declare_tunable`` is the fix).  Only
  raised for ops with a traceable jax impl — others never enter the plan
  cache.
"""

from __future__ import annotations

import hashlib
from typing import Sequence

from ..dag import LazyRef, tunable_fields, toposort
from ..selection import impls_for
from .report import Finding, SEV_INFO, SEV_WARNING

_SCALAR = (int, float, bool)


def _has_traceable_jax(op_name: str) -> bool:
    return any(i.backend == "jax" and i.traceable
               for i in impls_for(op_name))


def _blind_signature(op, memo: dict) -> str:
    """Content signature with ALL scalar spec values (and seeds) blanked —
    two ops share it iff declaring their differing scalars tunable would
    let them share one compiled plan."""
    cached = memo.get(op.uid)
    if cached is not None:
        return cached
    h = hashlib.blake2b(digest_size=16)
    h.update(op.op_name.encode())
    h.update(str(op.n_outputs).encode())
    for k in sorted(op.spec):
        v = op.spec[k]
        if isinstance(v, bool) or not isinstance(v, _SCALAR):
            # bools and non-scalars select code paths — keep their value
            h.update(f"{k}={v!r}".encode())
        else:
            h.update(f"<{k}>".encode())
    for ref in op.inputs:
        h.update(_blind_signature(ref.op, memo).encode())
        h.update(str(ref.index).encode())
    sig = h.hexdigest()
    memo[op.uid] = sig
    return sig


def lint_pipeline(sinks: Sequence[LazyRef],
                  extra_roots: Sequence[LazyRef] = ()) -> list:
    findings: list = []
    order = toposort(sinks)

    # ---- dead outputs -------------------------------------------------
    consumed: dict[int, set] = {}
    for op in order:
        for ref in op.inputs:
            consumed.setdefault(ref.op.uid, set()).add(ref.index)
    for ref in sinks:
        consumed.setdefault(ref.op.uid, set()).add(ref.index)
    for op in order:
        if op.n_outputs <= 1:
            continue
        unused = sorted(set(range(op.n_outputs))
                        - consumed.get(op.uid, set()))
        if unused:
            findings.append(Finding(
                "dead-output", SEV_INFO,
                f"outputs {unused} are computed but never consumed",
                op_name=op.op_name, op_uid=op.uid,
                detail=(("unused", tuple(unused)),)))

    # ---- dead ops (declared roots that reach no sink) -----------------
    if extra_roots:
        live = {op.uid for op in order}
        declared = toposort([r for r in extra_roots
                             if isinstance(r, LazyRef)])
        for op in declared:
            if op.uid not in live:
                findings.append(Finding(
                    "dead-op", SEV_WARNING,
                    "op is declared by the program but reaches no sink; "
                    "it will never execute",
                    op_name=op.op_name, op_uid=op.uid))

    # ---- duplicate subgraphs (CSE fodder) -----------------------------
    by_sig: dict[str, int] = {}
    for op in order:
        by_sig[op.signature] = by_sig.get(op.signature, 0) + 1
    dup_groups = sum(1 for n in by_sig.values() if n > 1)
    redundant = sum(n - 1 for n in by_sig.values() if n > 1)
    if dup_groups:
        findings.append(Finding(
            "duplicate-subgraph", SEV_INFO,
            f"{dup_groups} duplicated subgraph(s) ({redundant} redundant "
            "ops) — CSE will merge them",
            detail=(("groups", dup_groups), ("redundant_ops", redundant))))

    # ---- undeclared tunables ------------------------------------------
    memo: dict = {}
    groups: dict[str, list] = {}
    for op in order:
        if not op.spec or not _has_traceable_jax(op.op_name):
            continue
        if not any(isinstance(v, _SCALAR) and not isinstance(v, bool)
                   for v in op.spec.values()):
            continue
        groups.setdefault(_blind_signature(op, memo), []).append(op)
    for members in groups.values():
        if len(members) < 2:
            continue
        declared = tunable_fields(members[0].op_name)
        varying: set = set()
        for k in members[0].spec:
            v0 = members[0].spec[k]
            if not isinstance(v0, _SCALAR) or isinstance(v0, bool):
                continue
            if any(m.spec.get(k) != v0 for m in members[1:]):
                varying.add(k)
        undeclared = sorted(varying - set(declared))
        if undeclared:
            op = members[0]
            findings.append(Finding(
                "undeclared-tunable", SEV_WARNING,
                f"spec field(s) {undeclared} vary across {len(members)} "
                "structurally-identical ops but are not declared tunable; "
                "each variant compiles its own plan-cache entry "
                "(dag.declare_tunable to share one)",
                op_name=op.op_name, op_uid=op.uid,
                detail=(("fields", tuple(undeclared)),
                        ("variants", len(members)))))
    return findings


def segment_split_findings(segments, selection) -> list:
    """Non-traceable ops that split an otherwise-compilable run: python
    segments sandwiched between jax segments, attributed to the ops in
    them lacking a traceable jax-tier impl."""
    findings: list = []
    for i, seg in enumerate(segments):
        if seg.kind != "python" or not (0 < i < len(segments) - 1):
            continue
        if not (segments[i - 1].kind == "jax"
                and segments[i + 1].kind == "jax"):
            continue
        culprits: dict[str, int] = {}
        uid = -1
        name = ""
        for wave in seg.waves:
            for op in wave.ops:
                impl = selection.get(op.signature)
                traceable = (impl is not None and impl.backend == "jax"
                             and impl.traceable)
                if not traceable:
                    culprits[op.op_name] = culprits.get(op.op_name, 0) + 1
                    if uid < 0:
                        uid, name = op.uid, op.op_name
        if culprits:
            findings.append(Finding(
                "segment-split", SEV_INFO,
                f"non-traceable op(s) {sorted(culprits)} split two "
                "compilable segments; a traceable jax impl would fuse "
                "them into one jitted program",
                op_name=name, op_uid=uid,
                detail=tuple(sorted(culprits.items()))))
    return findings
