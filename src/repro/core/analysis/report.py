"""Typed findings, the analysis report, and the picklable rejection error.

Everything in this module is built from primitives (tuples, strings, ints)
so a report — or an :class:`AnalysisError` raised at admission — pickles
through the fabric envelope codec unchanged, exactly like ``AdmissionError``
and ``ExecutionError`` do.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

SEV_ERROR = "error"        # pipeline is statically invalid; execution WILL fail
SEV_WARNING = "warning"    # legal but suspicious (perf or cache pathology)
SEV_INFO = "info"          # observations (CSE opportunities, dead outputs)

SEVERITIES = (SEV_ERROR, SEV_WARNING, SEV_INFO)


@dataclass(frozen=True)
class Finding:
    """One analyzer observation, with op-level provenance.

    ``detail`` is a tuple of ``(key, value)`` pairs (primitives only) so the
    finding stays hashable and picklable.
    """
    rule: str                # e.g. "cycle", "unknown-op", "shape-mismatch"
    severity: str            # one of SEVERITIES
    message: str
    op_name: str = ""        # "" for DAG-level findings
    op_uid: int = -1         # uid of the offending op (-1 for DAG-level)
    detail: tuple = ()       # extra provenance: ((key, value), ...)

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def as_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "message": self.message, "op_name": self.op_name,
                "op_uid": self.op_uid, "detail": dict(self.detail)}

    def __str__(self) -> str:
        where = f" @{self.op_name}" if self.op_name else ""
        return f"[{self.severity}] {self.rule}{where}: {self.message}"


@dataclass
class AnalysisReport:
    """Result of statically analyzing a pipeline batch.

    ``op_shapes`` maps op signature -> tuple of ``(shape, dtype)`` pairs, one
    per output — the inferred abstract value of every op the shape pass
    reached.  ``segments`` is the compile-feasibility classification: one
    summary dict per predicted execution segment (kind, op count, and for
    jax segments the predicted plan-cache key digest).
    """
    findings: tuple = ()                 # tuple[Finding]
    op_shapes: dict = field(default_factory=dict)
    segments: tuple = ()                 # tuple[dict]
    n_ops: int = 0
    n_pipelines: int = 0
    analysis_time_s: float = 0.0
    preverified_segments: int = 0        # jax segments whose probe was
    #                                      statically discharged (see
    #                                      JaxSegmentBackend.mark_preverified)

    # -- views ----------------------------------------------------------
    @property
    def errors(self) -> tuple:
        return tuple(f for f in self.findings if f.severity == SEV_ERROR)

    @property
    def warnings(self) -> tuple:
        return tuple(f for f in self.findings if f.severity == SEV_WARNING)

    @property
    def infos(self) -> tuple:
        return tuple(f for f in self.findings if f.severity == SEV_INFO)

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_rule(self) -> dict[str, int]:
        tally: dict[str, int] = {}
        for f in self.findings:
            tally[f.rule] = tally.get(f.rule, 0) + 1
        return tally

    def raise_if_invalid(self) -> None:
        if not self.ok:
            raise AnalysisError(self.errors)

    # -- serialization --------------------------------------------------
    def as_dict(self) -> dict:
        return {
            "ok": self.ok,
            "findings": [f.as_dict() for f in self.findings],
            "op_shapes": {sig: [list(pair) for pair in outs]
                          for sig, outs in self.op_shapes.items()},
            "segments": [dict(s) for s in self.segments],
            "n_ops": self.n_ops,
            "n_pipelines": self.n_pipelines,
            "analysis_time_s": self.analysis_time_s,
            "preverified_segments": self.preverified_segments,
        }

    def summary(self) -> str:
        head = ("OK" if self.ok
                else f"REJECTED ({len(self.errors)} errors)")
        lines = [f"analysis: {head} — {self.n_ops} ops, "
                 f"{len(self.segments)} segments, "
                 f"{self.analysis_time_s * 1e3:.2f}ms"]
        lines.extend(str(f) for f in self.findings)
        return "\n".join(lines)


class AnalysisError(RuntimeError):
    """A pipeline was rejected by static analysis before execution.

    Carries the error findings with op-level provenance.  Picklable with
    plain pickle (findings are frozen primitive dataclasses), so it rides
    the fabric envelope codec across process boundaries intact — the same
    contract ``AdmissionError`` has at ``Session.submit``.
    """

    def __init__(self, findings: Sequence[Finding], message: str = ""):
        self.findings = tuple(findings)
        if not message:
            errs = [f for f in self.findings if f.severity == SEV_ERROR]
            shown = "; ".join(
                f"{f.rule}@{f.op_name or '<dag>'}: {f.message}"
                for f in errs[:3])
            more = f" (+{len(errs) - 3} more)" if len(errs) > 3 else ""
            message = f"pipeline rejected by static analysis: {shown}{more}"
        super().__init__(message)

    @property
    def rules(self) -> tuple:
        return tuple(f.rule for f in self.findings)

    def __reduce__(self):
        return (AnalysisError, (self.findings, self.args[0]))


def find(findings: Sequence[Finding], rule: str,
         severity: Optional[str] = None) -> list:
    """Filter helper used by tests and the AIDE repair loop."""
    return [f for f in findings
            if f.rule == rule and (severity is None
                                   or f.severity == severity)]
