"""Static pipeline analysis: pre-flight verification without execution.

See docs/ANALYSIS.md for the rule catalog, report format and admission
semantics.  Public surface:

* :func:`analyze` — full analysis (wiring, shape inference, lint,
  compile feasibility) returning an :class:`AnalysisReport`,
* :func:`validate_wiring` — the cheap always-on structural subset,
* :class:`AnalysisError` — the picklable rejection raised at submit,
* :func:`register_check` — extend the shape pass with per-op
  input-consistency rules.
"""

from .analyzer import analyze
from .infer import has_check, infer_shapes, register_check
from .lint import lint_pipeline
from .report import (AnalysisError, AnalysisReport, Finding, SEV_ERROR,
                     SEV_INFO, SEV_WARNING, find)
from .wiring import validate_wiring

__all__ = [
    "analyze", "AnalysisError", "AnalysisReport", "Finding",
    "SEV_ERROR", "SEV_INFO", "SEV_WARNING", "find", "has_check",
    "infer_shapes", "lint_pipeline", "register_check", "validate_wiring",
]
