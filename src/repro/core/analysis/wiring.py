"""Wiring/schema validation — the cheap, always-on layer of the analyzer.

Checks only structural facts that make execution *certain* to fail: cycles,
out-of-range output references, missing required inputs, CONST ops without a
payload, and op names with no registered implementation of any kind.  The
rules deliberately mirror :func:`repro.core.runtime.execute_reference`'s
fallback chain (registry impl → reference impl → ``spec["fn"]`` callable),
so anything flagged here is exactly what the runtime would later surface as
an op-dependent ``ExecutionError`` at dispatch time.

``validate_wiring`` runs on every submission (``Stratum.compile_batch``
calls it unconditionally) so malformed DAGs fail deterministically and
early even with admission analysis off — one structured error type,
independent of wave layout.
"""

from __future__ import annotations

from typing import Sequence

from ..dag import (CONST, ESTIMATOR, EVAL, FILTER, LazyRef, PROJECT,
                   TRANSFORM, toposort)
from ..lowering import is_lowerable
from ..selection import impls_for, reference_impl
from .report import Finding, SEV_ERROR

# op classes whose semantics require at least one input (a source/const/
# generic op may legitimately take none)
_NEEDS_INPUT = (TRANSFORM, PROJECT, FILTER, ESTIMATOR, EVAL)


def _has_implementation(op) -> bool:
    """Mirror of execute_reference's dispatch chain, without executing."""
    if op.op_class == CONST:
        return True
    if is_lowerable(op.op_name):       # composites dissolve before dispatch
        return True
    if impls_for(op.op_name):
        return True
    if reference_impl(op.op_name) is not None:
        return True
    return callable(op.spec.get("fn"))


def validate_wiring(sinks: Sequence[LazyRef]) -> list:
    """Return error findings for structurally-invalid wiring; [] if clean."""
    findings: list = []
    try:
        order = toposort(sinks)
    except ValueError as e:
        return [Finding("cycle", SEV_ERROR, str(e))]
    except RecursionError:
        return [Finding("cycle", SEV_ERROR,
                        "pipeline DAG too deep or cyclic")]

    for i, ref in enumerate(sinks):
        if not isinstance(ref, LazyRef):
            findings.append(Finding(
                "bad-sink", SEV_ERROR,
                f"sink {i} is {type(ref).__name__}, expected LazyRef"))
        elif not 0 <= ref.index < ref.op.n_outputs:
            findings.append(Finding(
                "bad-arity", SEV_ERROR,
                f"sink {i} references output {ref.index} of "
                f"{ref.op.op_name!r}, which has {ref.op.n_outputs}",
                op_name=ref.op.op_name, op_uid=ref.op.uid))

    for op in order:
        if op.n_outputs < 1:
            findings.append(Finding(
                "bad-arity", SEV_ERROR,
                f"op declares n_outputs={op.n_outputs}",
                op_name=op.op_name, op_uid=op.uid))
        for ref in op.inputs:
            if not 0 <= ref.index < ref.op.n_outputs:
                findings.append(Finding(
                    "bad-arity", SEV_ERROR,
                    f"input references output {ref.index} of "
                    f"{ref.op.op_name!r}, which has {ref.op.n_outputs}",
                    op_name=op.op_name, op_uid=op.uid,
                    detail=(("producer", ref.op.op_name),
                            ("index", ref.index))))
        if op.op_class == CONST and "value" not in op.spec:
            findings.append(Finding(
                "const-missing-value", SEV_ERROR,
                "CONST op has no 'value' in its spec",
                op_name=op.op_name, op_uid=op.uid))
        if op.op_class in _NEEDS_INPUT and not op.inputs:
            findings.append(Finding(
                "missing-input", SEV_ERROR,
                f"{op.op_class} op has no inputs",
                op_name=op.op_name, op_uid=op.uid))
        if not _has_implementation(op):
            findings.append(Finding(
                "unknown-op", SEV_ERROR,
                f"no implementation registered for {op.op_name!r} "
                "(no physical impl, no reference impl, no lowering, "
                "no spec['fn'] callable)",
                op_name=op.op_name, op_uid=op.uid))
    return findings
