"""The analyzer entry point: abstract-interpret a pipeline batch.

``analyze`` mirrors ``Stratum.compile_batch``'s stage order — lowering →
shape inference → (lint) → selection → planning → segment partitioning —
but every stage runs *guarded*: instead of raising mid-optimization the
way the execution path would, each failure becomes a :class:`Finding`
with op-level provenance, and downstream stages skip the poisoned
subgraph.  Nothing executes; the most expensive thing the analyzer does
is ``jax.eval_shape`` on single ops (and optionally on whole predicted
segments, to discharge the runtime's first-dispatch probe).
"""

from __future__ import annotations

import time
from typing import Optional, Sequence

from ..dag import LazyRef
from ..lowering import lower
from ..metadata import OpMetadata
from ..scheduler import SchedulerConfig, plan as make_plan
from ..selection import SelectionConfig, select
from .infer import infer_shapes
from .lint import lint_pipeline, segment_split_findings
from .report import AnalysisReport, Finding, SEV_ERROR, SEV_WARNING
from .wiring import validate_wiring


def _as_sinks(batch_or_sinks) -> tuple[list, int]:
    """Accept a PipelineBatch, a sequence of LazyRefs, or one LazyRef."""
    if hasattr(batch_or_sinks, "fused_sinks"):
        sinks = list(batch_or_sinks.fused_sinks())
        return sinks, len(sinks)
    if isinstance(batch_or_sinks, LazyRef):
        return [batch_or_sinks], 1
    sinks = list(batch_or_sinks)
    return sinks, len(sinks)


def _materialize_meta(order, infos) -> None:
    """Attach inferred avals as op.meta so the planner's memory model and
    impl cost hints see the same shapes the metadata pass would produce."""
    for op in order:
        if op.meta is not None:
            continue
        outs = infos.get(op.signature)
        if outs is not None and len(outs) == op.n_outputs:
            op.meta = OpMetadata(outputs=list(outs))


def _feasibility(sinks, infos, *, platform: str,
                 memory_budget_bytes: int, allowed_backends,
                 segment_time_budget_s, jax_backend):
    """Predict per-segment backend + plan-cache key without executing.

    Reuses the real ``select`` + ``scheduler.plan`` (and therefore
    ``partition_segments``) so the prediction is the partition the runtime
    will actually dispatch.  For jax segments with a live backend, also
    builds the segment program and ``eval_shape``-probes it on the inferred
    avals — on success the runtime's execute-time probe is discharged
    (``JaxSegmentBackend.mark_preverified``)."""
    findings: list = []
    summaries: list = []
    preverified = 0
    sel = select(sinks, SelectionConfig(
        platform=platform, memory_budget_bytes=memory_budget_bytes,
        allowed_backends=allowed_backends))
    p = make_plan(sinks, sel, SchedulerConfig(
        memory_budget_bytes=memory_budget_bytes,
        segment_time_budget_s=segment_time_budget_s))
    findings.extend(segment_split_findings(p.segments, sel))
    for seg in p.segments:
        ops = [op for w in seg.waves for op in w.ops]
        names: dict[str, int] = {}
        for op in ops:
            names[op.op_name] = names.get(op.op_name, 0) + 1
        summary = {"kind": seg.kind, "n_ops": len(ops),
                   "n_waves": len(seg.waves),
                   "ops": dict(sorted(names.items()))}
        if seg.kind == "jax":
            import hashlib
            h = hashlib.blake2b(digest_size=8)
            for op in ops:
                h.update(op.structural_signature.encode())
            summary["plan_key"] = h.hexdigest()
            if jax_backend is not None and hasattr(
                    jax_backend, "preverify_segment"):
                key = jax_backend.preverify_segment(seg, sel, infos)
                summary["preverified"] = key is not None
                if key is not None:
                    preverified += 1
        summaries.append(summary)
    return findings, summaries, preverified, p


def analyze(batch_or_sinks, *,
            platform: str = "",
            memory_budget_bytes: int = 8 << 30,
            lowering: bool = True,
            use_eval_shape: bool = True,
            lint: bool = True,
            feasibility: bool = True,
            allowed_backends: Sequence[str] = ("python", "jax", "pallas"),
            segment_time_budget_s: Optional[float] = None,
            extra_roots: Sequence[LazyRef] = (),
            jax_backend=None) -> AnalysisReport:
    """Statically analyze a pipeline batch; never executes data ops.

    Stages (each optional past the first):

    1. wiring/schema validation — cycles, arity, missing inputs, unknown
       impls (always on; the same rules ``compile_batch`` enforces),
    2. abstract shape/dtype inference over the lowered DAG,
    3. pipeline lint (dead outputs/ops, CSE duplicates, undeclared
       tunables),
    4. compile-feasibility classification via the real scheduler
       partitioning, predicting per-segment backend + plan-cache key, and
       — given a live ``jax_backend`` — statically discharging the
       runtime's first-dispatch ``eval_shape`` probe.
    """
    t0 = time.perf_counter()
    sinks, n_pipelines = _as_sinks(batch_or_sinks)
    findings: list = list(validate_wiring(sinks))
    cyclic = any(f.rule in ("cycle", "bad-sink") for f in findings)

    report = AnalysisReport(n_pipelines=n_pipelines)
    if cyclic:
        report.findings = tuple(findings)
        report.analysis_time_s = time.perf_counter() - t0
        return report

    error_uids = frozenset(f.op_uid for f in findings
                           if f.severity == SEV_ERROR and f.op_uid >= 0)

    lowered = sinks
    if lowering:
        try:
            lowered = lower(sinks)
        except Exception as e:
            findings.append(Finding(
                "lowering-error", SEV_ERROR,
                f"lowering raised {type(e).__name__}: {e}"))
            lowered = sinks

    from ..dag import toposort
    order = toposort(lowered)
    infos, infer_findings = infer_shapes(
        order, skip_uids=error_uids, use_eval_shape=use_eval_shape)
    findings.extend(infer_findings)

    if lint:
        try:
            findings.extend(lint_pipeline(lowered, extra_roots=extra_roots))
        except Exception as e:       # lint must never block a verdict
            findings.append(Finding(
                "lint-error", SEV_WARNING,
                f"lint pass raised {type(e).__name__}: {e}"))

    has_errors = any(f.severity == SEV_ERROR for f in findings)
    segments: list = []
    preverified = 0
    if feasibility and not has_errors:
        try:
            _materialize_meta(order, infos)
            seg_findings, segments, preverified, _p = _feasibility(
                lowered, infos, platform=platform,
                memory_budget_bytes=memory_budget_bytes,
                allowed_backends=tuple(allowed_backends),
                segment_time_budget_s=segment_time_budget_s,
                jax_backend=jax_backend)
            findings.extend(seg_findings)
        except Exception as e:       # feasibility is advisory, not a gate
            findings.append(Finding(
                "feasibility-error", SEV_WARNING,
                f"feasibility pass raised {type(e).__name__}: {e}"))

    report.findings = tuple(findings)
    report.op_shapes = {sig: tuple((tuple(t.shape), t.dtype) for t in outs)
                        for sig, outs in infos.items()}
    report.segments = tuple(segments)
    report.n_ops = len(order)
    report.preverified_segments = preverified
    report.analysis_time_s = time.perf_counter() - t0
    return report
