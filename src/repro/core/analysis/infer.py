"""Abstract shape/dtype inference — execute the DAG on avals, not arrays.

Reuses the optimizer's per-op metadata rules (``core.metadata._RULES``) as
abstract transfer functions, layered with *consistency checks* registered
per op name that flag input combinations guaranteed to fail at runtime
(out-of-range projections, row-count mismatches feeding a solver, ...).
Ops with no metadata rule but a traceable jax implementation fall back to
``jax.eval_shape`` over the impl itself; anything still unknown mirrors the
conservative ``metadata._fallback`` so inference always terminates.

Severity contract: a failed *check* or a raising *rule* is an ``error``
(execution would raise); a failed ``eval_shape`` on a traceable impl is a
``warning`` only — the runtime's probed fallback keeps mis-declared impls
correct by re-routing them to the python path, so they are slow, not wrong.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from ..dag import LazyOp
from ..metadata import _RULES, _fallback, TensorInfo
from ..selection import impls_for
from .report import Finding, SEV_ERROR, SEV_WARNING

# consistency check: (op, input TensorInfos) -> list[str] problem messages
_CHECKS: dict[str, Callable[[LazyOp, Sequence[TensorInfo]], list]] = {}


def register_check(op_name: str):
    """Register a static input-consistency check for a logical op."""
    def deco(fn):
        _CHECKS[op_name] = fn
        return fn
    return deco


def has_check(op_name: str) -> bool:
    return op_name in _CHECKS


def _numel(t: TensorInfo) -> int:
    return int(np.prod(t.shape, dtype=np.int64)) if t.shape else 1


# ---------------------------------------------------------------------------
# checks for the tabular op vocabulary (each mirrors its impl's hard
# requirements — anything flagged here raises when the impl runs)
# ---------------------------------------------------------------------------

@register_check("project")
def _check_project(op, ins):
    cols = ins[0].cols
    bad = [c for c in op.spec.get("cols", ()) if not 0 <= int(c) < cols]
    if bad:
        return [f"column indices {bad} out of range for input with "
                f"{cols} columns"]
    return []


@register_check("concat")
def _check_concat(op, ins):
    rows = {t.rows for t in ins}
    if len(rows) > 1:
        return [f"inputs disagree on row count: {sorted(rows)}"]
    return []


@register_check("join")
def _check_join(op, ins):
    problems = []
    lk = int(op.spec.get("left_key", 0))
    rk = int(op.spec.get("right_key", 0))
    if not 0 <= lk < ins[0].cols:
        problems.append(f"left_key {lk} out of range for {ins[0].cols} "
                        "left columns")
    if not 0 <= rk < ins[1].cols:
        problems.append(f"right_key {rk} out of range for {ins[1].cols} "
                        "right columns")
    return problems


@register_check("onehot")
def _check_onehot(op, ins):
    cards = op.spec.get("cards", ())
    if len(cards) > ins[0].cols:
        return [f"{len(cards)} cardinalities for an input with only "
                f"{ins[0].cols} columns"]
    return []


def _rows_agree(op, ins):
    """X/y pairs: every impl ravels y and pairs it 1:1 with X's rows."""
    if len(ins) < 2:
        return []
    n, y = ins[0].rows, _numel(ins[1])
    if y != n:
        return [f"X has {n} rows but y has {y} elements"]
    return []


for _name in ("ridge_fit", "elasticnet_fit", "gbt_fit", "train_test_split",
              "kfold_split", "target_encode_fit"):
    _CHECKS[_name] = _rows_agree


@register_check("linear_predict")
def _check_linear_predict(op, ins):
    # coef layout: (d weights, 1 intercept) against X of d columns
    coef, d = _numel(ins[0]), ins[1].cols
    if coef != d + 1:
        return [f"coefficient vector has {coef} entries but X has {d} "
                f"columns (expected {d + 1})"]
    return []


@register_check("metric")
def _check_metric(op, ins):
    a, b = _numel(ins[0]), _numel(ins[1])
    if a != b and 1 not in (a, b):
        return [f"y has {a} elements but yhat has {b}"]
    return []


@register_check("scaler_apply")
def _check_scaler_apply(op, ins):
    state_cols, x_cols = ins[0].cols, ins[1].cols
    if len(ins[0].shape) == 2 and state_cols != x_cols and 1 not in (
            state_cols, x_cols):
        return [f"scaler state fitted on {state_cols} columns applied to "
                f"{x_cols}"]
    return []


@register_check("impute_apply")
def _check_impute_apply(op, ins):
    stats, x_cols = _numel(ins[0]), ins[1].cols
    if stats != x_cols and 1 not in (stats, x_cols):
        return [f"impute state fitted on {stats} columns applied to "
                f"{x_cols}"]
    return []


# ---------------------------------------------------------------------------
# inference driver
# ---------------------------------------------------------------------------

def _traceable_impl(op_name: str):
    for impl in impls_for(op_name):
        if impl.backend == "jax" and impl.traceable:
            return impl
    return None


def _eval_shape_outputs(op, ins) -> Optional[list]:
    """Abstractly evaluate a traceable impl on ShapeDtypeStructs."""
    impl = _traceable_impl(op.op_name)
    if impl is None:
        return None
    import jax
    structs = tuple(jax.ShapeDtypeStruct(t.shape, np.dtype(t.dtype))
                    for t in ins)
    outs = jax.eval_shape(lambda *xs: impl.fn(op, xs), *structs)
    return [TensorInfo(tuple(o.shape), str(np.dtype(o.dtype)))
            for o in outs]


def infer_shapes(order: Sequence[LazyOp], *, skip_uids: frozenset =
                 frozenset(), use_eval_shape: bool = True):
    """Walk ``order`` inferring per-op output avals.

    Returns ``(infos, findings)`` where ``infos`` maps op signature ->
    list[TensorInfo].  Ops in ``skip_uids`` (already flagged by wiring
    validation) and their downstream dependents are skipped silently —
    one root cause, one finding.
    """
    findings: list = []
    infos: dict[str, list] = {}
    poisoned: set = set(skip_uids)

    for op in order:
        ins: list = []
        dead = op.uid in poisoned
        for ref in op.inputs:
            if ref.op.uid in poisoned:
                dead = True
                break
            outs = infos.get(ref.op.signature)
            if outs is None or ref.index >= len(outs):
                dead = True
                break
            ins.append(outs[ref.index])
        if dead:
            poisoned.add(op.uid)
            continue

        check = _CHECKS.get(op.op_name)
        if check is not None:
            try:
                problems = check(op, ins)
            except Exception:       # a confused check must never reject
                problems = []
            if problems:
                for msg in problems:
                    findings.append(Finding(
                        "shape-mismatch", SEV_ERROR, msg,
                        op_name=op.op_name, op_uid=op.uid))
                poisoned.add(op.uid)
                continue

        rule = _RULES.get(op.op_name)
        if rule is not None:
            try:
                meta = rule(op, ins)
                if len(meta.outputs) != op.n_outputs:
                    raise ValueError(
                        f"rule produced {len(meta.outputs)} outputs, op "
                        f"declares {op.n_outputs}")
                infos[op.signature] = meta.outputs
            except Exception as e:
                findings.append(Finding(
                    "infer-error", SEV_ERROR,
                    f"shape rule raised {type(e).__name__}: {e}",
                    op_name=op.op_name, op_uid=op.uid))
                poisoned.add(op.uid)
            continue

        if use_eval_shape:
            try:
                outs = _eval_shape_outputs(op, ins)
            except Exception as e:
                # probed fallback keeps mis-declared impls correct at
                # runtime; statically this is a perf smell, not an error
                findings.append(Finding(
                    "untraceable-impl", SEV_WARNING,
                    f"impl declared traceable but eval_shape failed "
                    f"({type(e).__name__}: {e}); runtime will demote it "
                    "to the python path",
                    op_name=op.op_name, op_uid=op.uid))
                outs = None
            if outs is not None:
                if len(outs) == op.n_outputs:
                    infos[op.signature] = outs
                    continue
                findings.append(Finding(
                    "infer-error", SEV_ERROR,
                    f"traceable impl produced {len(outs)} outputs, op "
                    f"declares {op.n_outputs}",
                    op_name=op.op_name, op_uid=op.uid))
                poisoned.add(op.uid)
                continue

        infos[op.signature] = _fallback(op, ins).outputs

    return infos, findings
