"""The stratum session — the user/agent-facing entry point.

Ties the whole §4 pipeline together::

    batch → lowering → metadata → logical rewrites → metadata →
    cache-candidate marking → operator selection → parallel plan → execute

Every stage can be toggled via ``enable`` for the paper's ablation study
(Fig. 6b): ``logical`` (CSE & friends), ``lowering``, ``selection`` (native
backends), ``parallel`` (inter-op), ``cache`` (intermediate reuse).
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Any, Optional, Sequence

from .analysis import AnalysisError, AnalysisReport, analyze, validate_wiring
from .backends import make_backends
from .cache import CacheStats, IntermediateCache, mark_cache_candidates
from .dag import LazyRef, count_ops
from .fusion import PipelineBatch
from .lowering import lower
from .metadata import collect_metadata
from .plan_cache import PlanCache
from .rewrites import RewriteStats, optimize_logical
from .runtime import RunReport, Runtime, execute_reference
from .scheduler import Plan, SchedulerConfig, plan as make_plan
from .selection import SelectionConfig, select

ALL_FEATURES = ("logical", "lowering", "selection", "parallel", "cache")


@dataclass
class StratumReport:
    rewrites: RewriteStats
    plan: Plan
    run: RunReport
    cache: Optional[CacheStats]
    ops_submitted: int
    ops_planned: int
    optimize_time_s: float
    plan_cache: Optional[dict] = None   # PlanCache.snapshot() at run end

    def summary(self) -> str:
        lines = [
            f"ops: {self.ops_submitted} submitted -> {self.ops_planned} planned",
            f"rewrites: cse={self.rewrites.cse_merged} "
            f"reads_shared={self.rewrites.reads_shared} "
            f"folded={self.rewrites.constants_folded} "
            f"pushed={self.rewrites.projections_pushed}",
            f"waves: {self.run.waves} inter_op={self.plan.inter_op_parallelism}",
            f"executed: {self.run.ops_executed} "
            f"cached: {self.run.ops_from_cache} "
            f"backends: {self.run.per_backend}",
            f"wall: {self.run.wall_time_s:.4f}s "
            f"(optimize {self.optimize_time_s:.4f}s)",
        ]
        if self.plan_cache is not None:
            lines.append(
                f"plan cache: {self.plan_cache['entries']} entries "
                f"hit_rate={self.plan_cache['hit_rate']:.2f} "
                f"(compiles {self.plan_cache['compiles']})")
        return "\n".join(lines)


_DEFAULT_CACHE_FRACTION = 0.10      # paper default
_DEFAULT_PLAN_CACHE_ENTRIES = 256
_warned_once: set = set()


def _warn_once(message: str) -> None:
    """Emit each distinct config warning once per process — a service
    constructing thousands of sessions must not spam the log."""
    if message in _warned_once:
        return
    _warned_once.add(message)
    warnings.warn(message, UserWarning, stacklevel=3)


class Stratum:
    """A stratum execution session (one per agent / tenant).

    Prefer constructing through :class:`repro.client.StratumConfig` and a
    :class:`repro.client.StratumClient` target — this constructor's flat
    keyword surface is retained as a stable shim for existing callers.
    """

    def __init__(self,
                 memory_budget_bytes: int = 8 << 30,
                 cache_fraction: Optional[float] = None,
                 spill_dir: Optional[str] = None,
                 platform: str = "",
                 enable: Sequence[str] = ALL_FEATURES,
                 hardware_threads: int = 0,
                 jit_cache_dir: Optional[str] = None,
                 cache: Optional[IntermediateCache] = None,
                 compiled_segments: bool = True,
                 plan_cache: Optional[PlanCache] = None,
                 plan_cache_entries: Optional[int] = None,
                 segment_time_budget_s: Optional[float] = None,
                 compile_async: bool = False,
                 batch_variants: bool = False,
                 speculative_depth: int = 0):
        unknown = set(enable) - set(ALL_FEATURES)
        if unknown:
            raise ValueError(f"unknown features {unknown}")
        # validate cross-feature kwargs instead of silently accepting them:
        # a tuned cache_fraction with "cache" disabled (or a plan-cache
        # size with compiled segments off) is a config bug, not a no-op
        if "cache" not in enable:
            if cache_fraction is not None:
                _warn_once("Stratum(cache_fraction=...) has no effect: the "
                           "'cache' feature is disabled in enable=")
            if spill_dir is not None:
                _warn_once("Stratum(spill_dir=...) has no effect: the "
                           "'cache' feature is disabled in enable=")
        if not compiled_segments:
            if plan_cache_entries is not None:
                _warn_once("Stratum(plan_cache_entries=...) has no effect "
                           "with compiled_segments=False")
            if plan_cache is not None:
                _warn_once("Stratum(plan_cache=...) has no effect with "
                           "compiled_segments=False")
            if compile_async:
                _warn_once("Stratum(compile_async=True) has no effect "
                           "with compiled_segments=False")
            if batch_variants:
                _warn_once("Stratum(batch_variants=True) has no effect "
                           "with compiled_segments=False")
        if speculative_depth and not compile_async:
            _warn_once("Stratum(speculative_depth=...) has no effect "
                       "without compile_async=True")
        if cache_fraction is None:
            cache_fraction = _DEFAULT_CACHE_FRACTION
        if plan_cache_entries is None:
            plan_cache_entries = _DEFAULT_PLAN_CACHE_ENTRIES
        if jit_cache_dir:
            # persistent XLA compilation cache: a long-lived stratum service
            # compiles each (op, shape) once across sessions/processes —
            # the analogue of the paper's precompiled Rust kernels
            import jax
            jax.config.update("jax_compilation_cache_dir", jit_cache_dir)
            jax.config.update("jax_persistent_cache_min_compile_time_secs",
                              0.1)
        self.enable = tuple(enable)
        self.memory_budget_bytes = memory_budget_bytes
        self.platform = platform
        self.hardware_threads = hardware_threads
        self.segment_time_budget_s = segment_time_budget_s
        # an injected cache is shared infrastructure (the multi-tenant
        # service hands every session the same thread-safe instance)
        self.cache: Optional[IntermediateCache] = None
        if cache is not None and "cache" in enable:
            self.cache = cache
        elif "cache" in enable:
            self.cache = IntermediateCache(
                budget_bytes=int(memory_budget_bytes * cache_fraction),
                spill_dir=spill_dir)
        # compiled-plan cache + pluggable backends: an injected plan cache
        # is shared infrastructure (a service shard hands every run the
        # same instance so structurally identical plans compile once)
        self.compiled_segments = compiled_segments
        self.plan_cache: Optional[PlanCache] = None
        if compiled_segments:
            self.plan_cache = (plan_cache if plan_cache is not None
                               else PlanCache(
                                   capacity=plan_cache_entries,
                                   compile_async=compile_async,
                                   speculative_depth=speculative_depth))
        self._backends = make_backends(self.plan_cache,
                                       compiled=compiled_segments,
                                       batch_variants=batch_variants)

    # ------------------------------------------------------------------
    def compile_batch(self, batch: PipelineBatch):
        """Optimization-only path (for tests and plan inspection)."""
        t0 = time.perf_counter()
        sinks = batch.fused_sinks()
        # always-on structural validation: malformed wiring fails HERE,
        # deterministically, with one structured error type — never as an
        # op-dependent ExecutionError whose message varies with wave layout
        wiring_errors = [f for f in validate_wiring(sinks)
                         if f.severity == "error"]
        if wiring_errors:
            raise AnalysisError(wiring_errors)
        ops_submitted = count_ops(sinks)

        if "lowering" in self.enable:
            sinks = lower(sinks)
        collect_metadata(sinks)

        if "logical" in self.enable:
            sinks, rw = optimize_logical(sinks, execute_reference)
        else:
            rw = RewriteStats(ops_before=ops_submitted,
                              ops_after=count_ops(sinks))
        collect_metadata(sinks)

        candidates: set = set()
        if self.cache is not None:
            candidates = mark_cache_candidates(sinks)

        allowed = (("python", "jax", "pallas") if "selection" in self.enable
                   else ("python",))
        sel = select(sinks, SelectionConfig(
            platform=self.platform,
            memory_budget_bytes=self.memory_budget_bytes,
            allowed_backends=allowed))

        p = make_plan(sinks, sel, SchedulerConfig(
            memory_budget_bytes=self.memory_budget_bytes,
            hardware_threads=self.hardware_threads,
            enable_inter_op="parallel" in self.enable,
            compiled_segments=self.compiled_segments,
            segment_time_budget_s=self.segment_time_budget_s))

        opt_time = time.perf_counter() - t0
        return sinks, sel, p, candidates, rw, ops_submitted, opt_time

    def run_batch(self, batch: PipelineBatch
                  ) -> tuple[dict[str, Any], StratumReport]:
        (sinks, sel, p, candidates, rw, ops_submitted,
         opt_time) = self.compile_batch(batch)
        rt = Runtime(cache=self.cache, cache_candidates=candidates,
                     parallel="parallel" in self.enable,
                     backends=self._backends)
        results, run = rt.execute(sinks, p, sel)
        report = StratumReport(
            rewrites=rw, plan=p, run=run,
            cache=self.cache.stats if self.cache else None,
            ops_submitted=ops_submitted, ops_planned=p.n_ops,
            optimize_time_s=opt_time,
            plan_cache=(self.plan_cache.snapshot()
                        if self.plan_cache else None))
        # remap results onto the (possibly rewritten) sink order
        named = dict(zip(batch.names, results))
        return named, report

    # convenience: single pipeline
    def run(self, sink: LazyRef, name: str = "pipeline_0"):
        results, report = self.run_batch(PipelineBatch([sink], [name]))
        return results[name], report

    # ------------------------------------------------------------------
    def analyze_batch(self, batch: PipelineBatch, *,
                      feasibility: bool = True,
                      verify_segments: bool = True,
                      extra_roots: Sequence[LazyRef] = ()
                      ) -> AnalysisReport:
        """Statically analyze ``batch`` without executing it.

        With ``verify_segments`` (and compiled segments on), predicted jax
        segments are built and ``eval_shape``-probed against the inferred
        avals; successful probes are marked pre-verified on the backend so
        the first real dispatch skips its execute-time probe."""
        jax_be = (self._backends.get("jax")
                  if verify_segments and self.compiled_segments else None)
        allowed = (("python", "jax", "pallas") if "selection" in self.enable
                   else ("python",))
        return analyze(
            batch, platform=self.platform,
            memory_budget_bytes=self.memory_budget_bytes,
            lowering="lowering" in self.enable,
            feasibility=feasibility, allowed_backends=allowed,
            segment_time_budget_s=self.segment_time_budget_s,
            extra_roots=extra_roots, jax_backend=jax_be)

    # ------------------------------------------------------------------
    def precompile_batch(self, batch: PipelineBatch) -> dict:
        """Speculative warm-up: plan ``batch`` WITHOUT executing it and
        enqueue its jax segments on the background compile executor at low
        priority, so a likely-next submission finds its programs warm.
        No-op ({} of zero counts) unless ``compile_async=True``.  Returns
        a status-count dict (``{"enqueued": n, "cached": m, ...}``)."""
        counts: dict = {}
        jax_be = self._backends.get("jax")
        if jax_be is None or self.plan_cache is None \
                or self.plan_cache.executor is None:
            return counts
        _sinks, sel, p, _cand, _rw, _n, _t = self.compile_batch(batch)
        for seg in p.segments:
            if seg.kind != "jax":
                continue
            status = jax_be.precompile_segment(seg, sel, cache=self.cache)
            counts[status] = counts.get(status, 0) + 1
        return counts

    def close(self, timeout: float = 5.0) -> None:
        """Release background resources (the async compile executor).
        Safe to call on any session, including ones sharing an injected
        plan cache — the shutdown is idempotent."""
        if self.plan_cache is not None:
            self.plan_cache.close(timeout)
