"""Parallelization planning (paper §4.3).

The planner traverses the DAG and groups ready operators into *waves*:
sets of mutually independent ops that execute concurrently.  A wave is
admitted greedily under a worst-case memory budget (sum of each op's
backend-inflated working set + live intermediates), which is the paper's
"evaluates plans under worst-case memory budgets, selects a plan that
minimizes execution time subject to memory constraints".

Degree-of-parallelism planning (paper: avoid oversubscription from nested
parallelism): each op's *intra*-op parallelism is its backend's internal
parallelism (XLA/Rayon analogue), so the planner caps the number of
concurrently executing ops such that
``inter_op_parallelism × intra_op_threads ≤ hardware_threads`` — on the TPU
path inter-op parallelism instead maps to fusing a wave into one XLA program
and letting the XLA scheduler overlap it.

Liveness-based freeing: the planner emits, per wave, the set of intermediate
signatures whose last consumer has now run, so the runtime can drop them
(memory management, paper §3).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

from .dag import LazyOp, LazyRef, consumers, toposort
from .selection import PhysicalImpl


@dataclass
class Wave:
    ops: list            # list[LazyOp], mutually independent
    est_mem: int = 0
    est_time: float = 0.0
    free_after: list = field(default_factory=list)  # signatures now dead


@dataclass
class Plan:
    waves: list          # list[Wave]
    order: list          # full topo order (for sequential modes)
    inter_op_parallelism: int = 1
    intra_op_threads: int = 1
    est_peak_mem: int = 0

    @property
    def n_ops(self) -> int:
        return sum(len(w.ops) for w in self.waves)


@dataclass
class SchedulerConfig:
    memory_budget_bytes: int = 8 << 30
    hardware_threads: int = 0           # 0 → os.cpu_count()
    max_wave_ops: int = 64
    enable_inter_op: bool = True


def plan(sinks: Sequence[LazyRef],
         selection: dict[str, PhysicalImpl],
         config: SchedulerConfig) -> Plan:
    order = toposort(sinks)
    fanout = consumers(order)
    sink_sigs = {r.signature for r in sinks}

    threads = config.hardware_threads or (os.cpu_count() or 1)

    # remaining-consumer counts for liveness — aggregated per SIGNATURE:
    # without CSE the same signature may appear as several distinct ops
    # (the runtime stores values by signature), so a value is dead only
    # when *every* op sharing the signature has been fully consumed
    remaining: dict[str, int] = {}
    for op in order:
        remaining[op.signature] = (remaining.get(op.signature, 0)
                                   + len(fanout.get(op.uid, ())))

    indeg: dict[int, int] = {}
    dependents: dict[int, list[LazyOp]] = {}
    for op in order:
        uniq_parents = {r.op.uid for r in op.inputs}
        indeg[op.uid] = len(uniq_parents)
        for pu in uniq_parents:
            dependents.setdefault(pu, []).append(op)

    by_sig = {op.signature: op for op in order}
    ready = [op for op in order if indeg[op.uid] == 0]

    def op_mem(op: LazyOp) -> int:
        impl = selection.get(op.signature)
        if impl is not None:
            return impl.est_mem(op)
        return op.meta.peak_bytes if op.meta else 0

    def op_time(op: LazyOp) -> float:
        impl = selection.get(op.signature)
        if impl is not None:
            return impl.est_time(op)
        return 1e-6

    waves: list[Wave] = []
    live_bytes = 0
    peak = 0
    scheduled: set[int] = set()

    while ready:
        # longest-estimated-time first within a wave → better packing
        ready.sort(key=op_time, reverse=True)
        wave_ops: list[LazyOp] = []
        wave_mem = 0
        deferred: list[LazyOp] = []
        limit = config.max_wave_ops if config.enable_inter_op else 1
        for op in ready:
            m = op_mem(op)
            if wave_ops and (len(wave_ops) >= limit
                             or live_bytes + wave_mem + m
                             > config.memory_budget_bytes):
                deferred.append(op)
                continue
            wave_ops.append(op)
            wave_mem += m
        peak = max(peak, live_bytes + wave_mem)

        wave = Wave(ops=wave_ops, est_mem=wave_mem,
                    est_time=max((op_time(o) for o in wave_ops), default=0.0))

        # retire consumed intermediates
        freed: list[str] = []
        for op in wave_ops:
            scheduled.add(op.uid)
            for ref in op.inputs:
                sig = ref.op.signature
                remaining[sig] -= 1
                if remaining[sig] == 0 and not any(
                        s.startswith(sig) for s in sink_sigs):
                    freed.append(sig)
        wave.free_after = freed

        live_bytes += sum(op.meta.out_bytes if op.meta else 0
                          for op in wave_ops)
        for sig in freed:
            freed_op = by_sig[sig]
            live_bytes -= freed_op.meta.out_bytes if freed_op.meta else 0
        live_bytes = max(live_bytes, 0)

        waves.append(wave)

        next_ready = list(deferred)
        for op in wave_ops:
            for dep in dependents.get(op.uid, ()):
                indeg[dep.uid] -= 1
                if indeg[dep.uid] == 0:
                    next_ready.append(dep)
        ready = next_ready

    if len(scheduled) != len(order):
        raise RuntimeError("scheduler failed to plan all ops (cycle?)")

    # degree-of-parallelism: keep inter × intra ≤ hardware threads
    widest = max((len(w.ops) for w in waves), default=1)
    inter = min(widest, threads) if config.enable_inter_op else 1
    intra = max(1, threads // max(inter, 1))

    return Plan(waves=waves, order=order, inter_op_parallelism=inter,
                intra_op_threads=intra, est_peak_mem=peak)
