"""Parallelization planning (paper §4.3).

The planner traverses the DAG and groups ready operators into *waves*:
sets of mutually independent ops that execute concurrently.  A wave is
admitted greedily under a worst-case memory budget (sum of each op's
backend-inflated working set + live intermediates), which is the paper's
"evaluates plans under worst-case memory budgets, selects a plan that
minimizes execution time subject to memory constraints".

Degree-of-parallelism planning (paper: avoid oversubscription from nested
parallelism): each op's *intra*-op parallelism is its backend's internal
parallelism (XLA/Rayon analogue), so the planner caps the number of
concurrently executing ops such that
``inter_op_parallelism × intra_op_threads ≤ hardware_threads`` — on the TPU
path inter-op parallelism instead maps to fusing a wave into one XLA program
and letting the XLA scheduler overlap it.

Liveness-based freeing: the planner emits, per wave, the set of intermediate
signatures whose last consumer has now run, so the runtime can drop them
(memory management, paper §3).

Segment partitioning: after waves are laid out, contiguous runs of waves
whose every op selected a *traceable* jax-tier implementation are grouped
into maximal backend-homogeneous :class:`Segment`\\ s.  A ``"jax"`` segment
is executed by the JaxSegmentBackend as ONE jitted program (per-op python
dispatch disappears inside it); everything else stays a ``"python"``
segment executed by the per-op threaded backend.  Cache probes, liveness
freeing and preemption yields happen at segment boundaries, so segmenting
changes dispatch granularity, never semantics.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

from .dag import LazyOp, LazyRef, consumers, toposort
from .selection import PhysicalImpl


@dataclass
class Wave:
    ops: list            # list[LazyOp], mutually independent
    est_mem: int = 0
    est_time: float = 0.0
    free_after: list = field(default_factory=list)  # signatures now dead


@dataclass
class Segment:
    """A contiguous run of waves homogeneous in execution backend."""
    kind: str            # "jax" (whole-segment jit) | "python" (per-op)
    waves: list          # contiguous slice of Plan.waves
    start: int = 0       # index of the first wave within the plan

    @property
    def n_ops(self) -> int:
        return sum(len(w.ops) for w in self.waves)


@dataclass
class Plan:
    waves: list          # list[Wave]
    order: list          # full topo order (for sequential modes)
    inter_op_parallelism: int = 1
    intra_op_threads: int = 1
    est_peak_mem: int = 0
    segments: list = field(default_factory=list)   # list[Segment]

    @property
    def n_ops(self) -> int:
        return sum(len(w.ops) for w in self.waves)


@dataclass
class SchedulerConfig:
    memory_budget_bytes: int = 8 << 30
    hardware_threads: int = 0           # 0 → os.cpu_count()
    max_wave_ops: int = 64
    enable_inter_op: bool = True
    # whether jax segments will execute as ONE jitted program (the caller's
    # runtime setting): affects only the est_peak_mem the memory gate
    # reserves — a compiled segment defers per-wave freeing to its boundary
    compiled_segments: bool = True
    # cap on a compiled segment's summed est_time: a jitted program has no
    # internal yield points, so an unbounded super-batch segment delays an
    # interactive/deadline preempt by its whole wall time.  Splitting past
    # the budget bounds that latency to one slice (preemption polls run at
    # segment boundaries).  None = maximal segments (no cap)
    segment_time_budget_s: Optional[float] = None


def plan(sinks: Sequence[LazyRef],
         selection: dict[str, PhysicalImpl],
         config: SchedulerConfig) -> Plan:
    order = toposort(sinks)
    fanout = consumers(order)
    sink_sigs = {r.signature for r in sinks}

    threads = config.hardware_threads or (os.cpu_count() or 1)

    # remaining-consumer counts for liveness — aggregated per SIGNATURE:
    # without CSE the same signature may appear as several distinct ops
    # (the runtime stores values by signature), so a value is dead only
    # when *every* op sharing the signature has been fully consumed
    remaining: dict[str, int] = {}
    for op in order:
        remaining[op.signature] = (remaining.get(op.signature, 0)
                                   + len(fanout.get(op.uid, ())))

    indeg: dict[int, int] = {}
    dependents: dict[int, list[LazyOp]] = {}
    for op in order:
        uniq_parents = {r.op.uid for r in op.inputs}
        indeg[op.uid] = len(uniq_parents)
        for pu in uniq_parents:
            dependents.setdefault(pu, []).append(op)

    by_sig = {op.signature: op for op in order}
    ready = [op for op in order if indeg[op.uid] == 0]

    def op_mem(op: LazyOp) -> int:
        impl = selection.get(op.signature)
        if impl is not None:
            return impl.est_mem(op)
        return op.meta.peak_bytes if op.meta else 0

    def op_time(op: LazyOp) -> float:
        impl = selection.get(op.signature)
        if impl is not None:
            return impl.est_time(op)
        return 1e-6

    waves: list[Wave] = []
    live_bytes = 0
    peak = 0
    scheduled: set[int] = set()

    while ready:
        # longest-estimated-time first within a wave → better packing.
        # Equal-cost ops tie-break on structural signature so AIDE-style
        # variant fans (same structure, tunables differing) land adjacent:
        # the jax-segment variant batcher executes a group at its LAST
        # member's position, so clustering members minimizes the deferral
        # distance — and the chance a group is dropped for starving an
        # intermediate consumer.  Also makes wave layout deterministic.
        ready.sort(key=lambda o: (-op_time(o), o.structural_signature))
        wave_ops: list[LazyOp] = []
        wave_mem = 0
        deferred: list[LazyOp] = []
        limit = config.max_wave_ops if config.enable_inter_op else 1
        for op in ready:
            m = op_mem(op)
            if wave_ops and (len(wave_ops) >= limit
                             or live_bytes + wave_mem + m
                             > config.memory_budget_bytes):
                deferred.append(op)
                continue
            wave_ops.append(op)
            wave_mem += m
        peak = max(peak, live_bytes + wave_mem)

        wave = Wave(ops=wave_ops, est_mem=wave_mem,
                    est_time=max((op_time(o) for o in wave_ops), default=0.0))

        # retire consumed intermediates
        freed: list[str] = []
        for op in wave_ops:
            scheduled.add(op.uid)
            for ref in op.inputs:
                sig = ref.op.signature
                remaining[sig] -= 1
                if remaining[sig] == 0 and not any(
                        s.startswith(sig) for s in sink_sigs):
                    freed.append(sig)
        wave.free_after = freed

        live_bytes += sum(op.meta.out_bytes if op.meta else 0
                          for op in wave_ops)
        for sig in freed:
            freed_op = by_sig[sig]
            live_bytes -= freed_op.meta.out_bytes if freed_op.meta else 0
        live_bytes = max(live_bytes, 0)

        waves.append(wave)

        next_ready = list(deferred)
        for op in wave_ops:
            for dep in dependents.get(op.uid, ()):
                indeg[dep.uid] -= 1
                if indeg[dep.uid] == 0:
                    next_ready.append(dep)
        ready = next_ready

    if len(scheduled) != len(order):
        raise RuntimeError("scheduler failed to plan all ops (cycle?)")

    # degree-of-parallelism: keep inter × intra ≤ hardware threads
    widest = max((len(w.ops) for w in waves), default=1)
    inter = min(widest, threads) if config.enable_inter_op else 1
    intra = max(1, threads // max(inter, 1))

    segments = partition_segments(waves, selection,
                                  time_budget_s=config.segment_time_budget_s)
    # a compiled jax segment returns every op's outputs at once and only
    # applies per-wave liveness freeing at the segment boundary, so its
    # true peak is the sum of ALL its output bytes — raise the estimate
    # the service memory gate reserves accordingly.  Per-op runtimes
    # (compiled_segments=False) keep per-wave freeing, where the bump
    # would over-reserve and needlessly serialize concurrent super-batches
    if config.compiled_segments:
        for seg in segments:
            if seg.kind != "jax":
                continue
            seg_bytes = sum(op.meta.out_bytes if op.meta else 0
                            for w in seg.waves for op in w.ops)
            peak = max(peak, seg_bytes)

    return Plan(waves=waves, order=order, inter_op_parallelism=inter,
                intra_op_threads=intra, est_peak_mem=peak,
                segments=segments)


def partition_segments(waves: Sequence[Wave],
                       selection: dict[str, PhysicalImpl],
                       time_budget_s: Optional[float] = None
                       ) -> list[Segment]:
    """Group contiguous waves into maximal backend-homogeneous segments.

    A wave is jit-compilable iff every op in it selected a traceable
    jax-tier implementation; contiguous compilable waves merge into one
    ``"jax"`` segment.  One-op jax runs are demoted to ``"python"`` —
    a single op gains nothing from whole-segment tracing (its impl is
    typically already jitted) but would still occupy a plan-cache entry.

    Waves whose every op selected one *custom-registered* backend kind
    (``repro.core.backends.register_backend``) form segments of that kind
    the same way, so an out-of-process/Rust backend receives whole
    segments instead of being flattened onto the python path.

    ``time_budget_s`` caps a non-python segment's summed wave ``est_time``:
    compiled programs have no internal yield points, so the cap bounds how
    long a running segment can delay a cooperative preempt (the runtime
    polls at segment boundaries).  Splits happen at wave boundaries, so
    segmentation still never changes semantics."""
    # custom backend kinds are registered at runtime; resolve lazily to
    # keep core.scheduler importable before core.backends finishes loading
    from .backends.base import available_backends
    custom_kinds = set(available_backends()) - {"python", "jax"}

    def wave_kind(wave: Wave) -> str:
        kinds: set[str] = set()
        for op in wave.ops:
            impl = selection.get(op.signature)
            if impl is None:
                return "python"
            if impl.backend == "jax" and impl.traceable:
                kinds.add("jax")
            elif impl.backend in custom_kinds:
                kinds.add(impl.backend)
            else:
                return "python"
        if len(kinds) == 1:
            return kinds.pop()
        return "python"

    segments: list[Segment] = []
    for i, wave in enumerate(waves):
        kind = wave_kind(wave)
        if segments and segments[-1].kind == kind:
            segments[-1].waves.append(wave)
        else:
            segments.append(Segment(kind=kind, waves=[wave], start=i))
    # demote trivial jax segments, then re-merge adjacent same-kind runs
    merged: list[Segment] = []
    for seg in segments:
        if seg.kind == "jax" and seg.n_ops < 2:
            seg.kind = "python"
        if merged and merged[-1].kind == seg.kind:
            merged[-1].waves.extend(seg.waves)
        else:
            merged.append(seg)
    if time_budget_s is None:
        return merged
    # bound compiled-segment preempt latency: split past the est_time
    # budget (AFTER merging — adjacent same-kind segments would otherwise
    # re-coalesce and undo the cap)
    capped: list[Segment] = []
    for seg in merged:
        if seg.kind == "python":
            capped.append(seg)      # per-op path polls inside the segment
            continue
        cur: list[Wave] = []
        cur_t = 0.0
        start = seg.start
        for w in seg.waves:
            if cur and cur_t + w.est_time > time_budget_s:
                capped.append(Segment(kind=seg.kind, waves=cur,
                                      start=start))
                start += len(cur)
                cur, cur_t = [], 0.0
            cur.append(w)
            cur_t += w.est_time
        capped.append(Segment(kind=seg.kind, waves=cur, start=start))
    return capped
