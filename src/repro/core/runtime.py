"""Execution runtime (paper §4.2–4.3).

Executes a scheduler :class:`Plan` segment by segment through pluggable
:class:`~repro.core.backends.ExecutionBackend`\\ s: the runtime owns the
value store, cache handles, salvage state and preemption hooks, and each
backend-homogeneous :class:`~repro.core.scheduler.Segment` is handed to
the backend registered for its kind —

* ``"python"`` (:class:`~repro.core.backends.PythonThreadBackend`): per-op
  dispatch with cache probe before execution / insert-after for marked
  candidates (§4.3), late-bound physical impls (§4.2), inter-operator
  parallelism via a bounded thread pool, vmap variant batching, and
  intra-wave preemption polls;
* ``"jax"`` (:class:`~repro.core.backends.JaxSegmentBackend`): the whole
  segment traced into ONE jitted program (tunable constants hoisted to
  arguments), reused across structurally identical plans through the
  shared :class:`~repro.core.plan_cache.PlanCache`.

Invariants preserved across backends: liveness-driven freeing of
intermediates no later than segment boundaries, and cooperative
preemption — when the caller installs a ``preempt_check``, the runtime
polls it at every segment/wave boundary (and between op completions
inside wide python waves), and, if it fires, abandons the run with
:class:`ExecutionPreempted` carrying every already-completed intermediate
(the *salvage*); a re-run passes that salvage back as ``preloaded`` so no
finished work executes twice, and a liveness rule (yield only after ≥1
newly-executed op) guarantees progress under repeated preemption.  This
is how the multi-tenant service yields a low-priority super-batch to
freshly queued higher-priority work without losing progress.

``Base`` / ``Base_par`` executors for the paper's baselines live in
benchmarks (they bypass the optimizer entirely).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import (FIRST_COMPLETED, ThreadPoolExecutor,
                                wait as _fwait)
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from .cache import IntermediateCache
from .dag import CONST, LazyOp, LazyRef
from .plan_cache import PlanCache
from .scheduler import Plan, Segment
from .selection import PhysicalImpl, reference_impl, vmap_group_for


@dataclass
class RunReport:
    wall_time_s: float = 0.0
    ops_executed: int = 0
    ops_from_cache: int = 0
    ops_salvaged: int = 0   # restored from a preempted run's salvage
    waves: int = 0
    per_backend: dict = field(default_factory=dict)
    # op signature -> "cache" | "salvage" | backend name; lets multi-tenant
    # callers (service telemetry) attribute work per pipeline after merges
    sig_source: dict = field(default_factory=dict)
    # compiled plan-segment cache outcomes for THIS run (incremented by the
    # jax-seg backend): trace/jit skipped vs paid — surfaced on lifecycle
    # trace hops so a per-job record shows whether it hit warm plans
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    # rounds dispatched per-op while an async compile ran off the critical
    # path (compile_async): cold-start cost shifted, not paid
    plan_cache_fallback_rounds: int = 0


class ExecutionError(RuntimeError):
    def __init__(self, op: LazyOp, cause: Exception):
        super().__init__(f"executing {op.op_name}#{op.uid}: {cause!r}")
        self.op = op
        self.cause = cause

    def __reduce__(self):
        # default exception pickling replays __init__ with ``args`` (the
        # formatted message), which doesn't match this signature; the
        # fabric's result codec needs the (op, cause) form to survive the
        # wire so tenants still see .op/.cause across the shard boundary
        return (ExecutionError, (self.op, self.cause))


class ExecutionPreempted(Exception):
    """A cooperative yield, not a failure: the run stopped at a wave
    boundary because higher-priority work arrived.  ``salvage`` maps each
    completed op signature to its outputs tuple; feeding it back to a new
    :class:`Runtime` via ``preloaded`` resumes without recomputation."""

    def __init__(self, salvage: dict, waves_done: int):
        super().__init__(f"preempted after {waves_done} wave(s); "
                         f"{len(salvage)} intermediates salvaged")
        self.salvage = salvage
        self.waves_done = waves_done

    def __reduce__(self):
        # default exception pickling replays __init__ with ``args`` (the
        # formatted message) — a TypeError at *unpickle* time on the far
        # side of a process boundary.  Keep the (salvage, waves_done) form
        # so a preemption yield crossing the proc-fabric wire (worker →
        # supervisor diagnostics) survives with its payload intact.
        return (ExecutionPreempted, (self.salvage, self.waves_done))


def execute_reference(op: LazyOp, inputs: Sequence[Any]) -> tuple:
    """Reference evaluator (used by constant folding and as fallback)."""
    if op.op_class == CONST:
        return (op.spec["value"],)
    impl = reference_impl(op.op_name)
    if impl is None:
        fn = op.spec.get("fn")
        if callable(fn):
            out = fn(*inputs, **dict(op.spec.get("kwargs", {})))
            return out if isinstance(out, tuple) else (out,)
        raise KeyError(f"no implementation registered for {op.op_name!r}")
    return impl.fn(op, inputs)


class Runtime:
    def __init__(self,
                 cache: Optional[IntermediateCache] = None,
                 cache_candidates: Optional[set] = None,
                 parallel: bool = True,
                 preloaded: Optional[dict] = None,
                 preempt_check: Optional[Callable[[], bool]] = None,
                 sig_tenant: Optional[dict] = None,
                 plan_cache: Optional[PlanCache] = None,
                 backends: Optional[dict] = None,
                 compiled_segments: bool = True):
        self.cache = cache
        self.cache_candidates = cache_candidates or set()
        self.parallel = parallel
        # sig → outputs tuple salvaged from a preempted run of this DAG
        self.preloaded = preloaded or {}
        # polled at segment/wave boundaries; True → raise ExecutionPreempted
        self.preempt_check = preempt_check
        # sig → tenant owning the op (multi-tenant cache charge accounting)
        self.sig_tenant = sig_tenant or {}
        # segment kind → ExecutionBackend; long-lived callers (the service)
        # inject a shared set so the plan cache spans tenants and runs
        if backends is None:
            from .backends import make_backends   # lazy: avoids a cycle
            backends = make_backends(plan_cache,
                                     compiled=compiled_segments)
        self.backends = backends
        self._values: dict[str, Any] = {}      # "sig:index" -> value
        self._keys_by_sig: dict[str, list[str]] = {}   # sig -> stored keys
        self._skips: set = set()               # resume-skippable ops
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _resolve_impl(self, op: LazyOp,
                      selection: dict[str, PhysicalImpl]
                      ) -> Callable[[LazyOp, Sequence[Any]], tuple]:
        impl = selection.get(op.signature)
        if impl is not None:
            return impl.fn
        return lambda o, ins: execute_reference(o, ins)

    def _gather_inputs(self, op: LazyOp) -> list:
        with self._lock:
            return [self._values[r.signature] for r in op.inputs]

    def _store(self, op: LazyOp, outputs: tuple) -> None:
        with self._lock:
            keys = self._keys_by_sig.setdefault(op.signature, [])
            for i, v in enumerate(outputs):
                key = f"{op.signature}:{i}"
                self._values[key] = v
                if key not in keys:
                    keys.append(key)

    # -- shared backend helpers (both backends mutate runtime state
    # through these, so the semantics live in exactly one place) --------
    def _mark_salvaged(self, op: LazyOp, report: RunReport) -> None:
        """Record an op restored from (or skipped thanks to) preemption
        salvage — completed work is never redone on a resume."""
        with self._lock:
            report.ops_salvaged += 1
            report.sig_source[op.signature] = "salvage"

    def _free_wave(self, wave) -> None:
        """Liveness freeing: drop dead intermediates by their exact
        per-signature key lists (prefix/equality scans can collide and
        never matched the "sig" form, which is never stored)."""
        with self._lock:
            for sig in wave.free_after:
                for key in self._keys_by_sig.pop(sig, ()):
                    self._values.pop(key, None)

    def _try_cache_hit(self, op: LazyOp, report: RunReport
                       ) -> Optional[tuple]:
        """ONE tenant-aware intermediate-cache probe; on a hit the value
        is stored and attributed (hit count, sig_source, cross-tenant
        accounting inside the cache) in a single place — every backend's
        probe goes through here so the attribution can never drift."""
        if self.cache is None or not op.cacheable:
            return None
        sig = op.signature
        hit = self.cache.get(sig, tenant=self.sig_tenant.get(sig))
        if hit is None:
            return None
        self._store(op, hit)
        with self._lock:
            report.ops_from_cache += 1
            report.sig_source[sig] = "cache"
        return hit

    def _run_ops_parallel(self, todo: list, selection: dict,
                          report: RunReport) -> None:
        """Execute mutually independent ops — on the bounded pool when the
        plan allows, with cooperative-preemption polls between op
        completions (wide waves can run for many seconds); queued ops are
        cancelled on a yield, in-flight ones drained, and everything
        finished goes into the salvage."""
        pool = self._pool
        if pool is not None and len(todo) > 1:
            pending = {pool.submit(self._run_op, op, selection, report)
                       for op in todo}
            while pending:
                done, pending = _fwait(pending,
                                       return_when=FIRST_COMPLETED)
                for f in done:
                    f.result()
                if pending and self._should_yield(report):
                    running = [f for f in pending if not f.cancel()]
                    for f in running:
                        f.result()
                    raise self._preempted(report)
        else:
            for i, op in enumerate(todo):
                if i and self._should_yield(report):
                    raise self._preempted(report)
                self._run_op(op, selection, report)

    def _run_op(self, op: LazyOp, selection: dict, report: RunReport) -> None:
        sig = op.signature
        if sig in self.preloaded:
            # salvaged from a preempted run — completed work is never redone
            self._store(op, self.preloaded[sig])
            self._mark_salvaged(op, report)
            return
        if self._try_cache_hit(op, report) is not None:
            return
        inputs = self._gather_inputs(op)
        fn = self._resolve_impl(op, selection)
        try:
            outputs = fn(op, inputs)
        except Exception as e:  # noqa: BLE001 — surfaced with op context
            raise ExecutionError(op, e) from e
        if not isinstance(outputs, tuple):
            outputs = (outputs,)
        if len(outputs) != op.n_outputs:
            raise ExecutionError(
                op, ValueError(f"impl returned {len(outputs)} outputs, "
                               f"declared {op.n_outputs}"))
        self._store(op, outputs)
        impl = selection.get(sig)
        backend = impl.backend if impl else "ref"
        with self._lock:
            report.ops_executed += 1
            report.per_backend[backend] = report.per_backend.get(backend, 0) + 1
            report.sig_source[sig] = backend
        if (self.cache is not None and op.cacheable
                and sig in self.cache_candidates):
            self.cache.put(sig, outputs, tenant=self.sig_tenant.get(sig))

    # -- variant batching (§Perf H3.4) ---------------------------------
    def _batch_variants(self, wave_ops: list, selection: dict,
                        report: RunReport) -> list:
        """Execute homogeneous hyperparameter-variant groups as one vmapped
        call; returns the ops still needing individual execution."""
        groups: dict[tuple, list] = {}
        rest = []
        for op in wave_ops:
            reg = vmap_group_for(op.op_name)
            impl = selection.get(op.signature)
            if reg is None or impl is None or impl.backend != "jax" \
                    or not impl.vmappable \
                    or op.signature in self.preloaded:
                rest.append(op)
                continue
            key_fn, _ = reg
            groups.setdefault((op.op_name, key_fn(op)), []).append(op)
        for (op_name, _), ops_ in groups.items():
            if len(ops_) < 2:
                rest.extend(ops_)
                continue
            todo = []
            for op in ops_:
                # ONE tenant-aware get, result used directly: a raw
                # membership probe would skip cross-tenant hit attribution
                # for vmap-grouped ops and could race an eviction between
                # the probe and the use
                if self._try_cache_hit(op, report) is not None:
                    continue
                todo.append(op)
            if len(todo) < 2:
                rest.extend(todo)   # no group left worth one vmapped call
                continue
            _, batch_fn = vmap_group_for(op_name)
            inputs = self._gather_inputs(todo[0])
            outs = batch_fn(todo, inputs)
            for op, out in zip(todo, outs):
                self._store(op, out)
                if (self.cache is not None and op.cacheable
                        and op.signature in self.cache_candidates):
                    self.cache.put(op.signature, out,
                                   tenant=self.sig_tenant.get(op.signature))
            with self._lock:
                report.ops_executed += len(todo)
                report.per_backend["jax-vmap"] = \
                    report.per_backend.get("jax-vmap", 0) + len(todo)
                for op in todo:
                    report.sig_source[op.signature] = "jax-vmap"
        return rest

    # ------------------------------------------------------------------
    def _resume_skips(self, plan: Plan, sinks: Sequence[LazyRef]) -> set:
        """Ops a post-preemption resume can skip entirely.

        The preempted run freed intermediates liveness-driven, so the
        salvage only holds values that were still live at the yield point.
        An op absent from the salvage whose every consumer IS salvaged (or
        transitively skippable) completed before the yield and its output
        is dead — re-executing it would redo finished work.  Computed by a
        reverse-topological sweep: an op must run iff it is an un-salvaged
        sink or feeds an op that runs."""
        sink_ops = {r.op.signature for r in sinks}
        needed: set = set()     # input sigs of ops that will execute
        skips: set = set()
        for wave in reversed(plan.waves):
            for op in wave.ops:
                sig = op.signature
                used = sig in sink_ops or sig in needed
                if sig in self.preloaded:
                    if not used:   # salvaged but dead: don't even store it
                        skips.add(sig)
                    continue
                if used:
                    for r in op.inputs:
                        needed.add(r.op.signature)
                else:
                    skips.add(sig)
        return skips

    def _should_yield(self, report: RunReport) -> bool:
        """Yield only after real progress (≥1 newly-executed op this
        dispatch) so repeated preemption can never livelock a job."""
        return (self.preempt_check is not None and report.ops_executed > 0
                and self.preempt_check())

    def _preempted(self, report: RunReport) -> ExecutionPreempted:
        with self._lock:
            salvage = {sig: tuple(self._values[k] for k in keys)
                       for sig, keys in self._keys_by_sig.items()}
        # carry forward salvage not yet replayed (second yield of a resume)
        salvage.update(self.preloaded)
        return ExecutionPreempted(salvage, waves_done=report.waves)

    def execute(self, sinks: Sequence[LazyRef], plan: Plan,
                selection: dict[str, PhysicalImpl]) -> tuple[list, RunReport]:
        report = RunReport()
        self._skips = (self._resume_skips(plan, sinks)
                       if self.preloaded else set())
        t0 = time.perf_counter()
        self._pool = None
        if self.parallel and plan.inter_op_parallelism > 1:
            self._pool = ThreadPoolExecutor(
                max_workers=plan.inter_op_parallelism)
        # plans from older callers (or hand-built tests) may predate
        # segmentation — treat the whole wave list as one per-op segment
        segments = plan.segments or [Segment(kind="python",
                                             waves=list(plan.waves))]
        python_backend = self.backends["python"]
        try:
            for seg in segments:
                # cooperative yield point at the segment boundary — the
                # salvage carries every completed intermediate to the
                # requeued re-run (python segments add wave/op-level polls)
                if self._should_yield(report):
                    raise self._preempted(report)
                backend = self.backends.get(seg.kind, python_backend)
                backend.execute_segment(self, seg, selection, report)
        finally:
            if self._pool is not None:
                # cancel queued work and wait for in-flight ops so an error
                # mid-wave can't leak threads still mutating self._values
                self._pool.shutdown(wait=True, cancel_futures=True)
                self._pool = None
        with self._lock:
            results = [self._values[r.signature] for r in sinks]
        report.wall_time_s = time.perf_counter() - t0
        return results, report
