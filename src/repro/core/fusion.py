"""Pipeline-batch fusion (paper §4.2: "Agents emit pipeline variants in
overlapping batches.  Stratum fuses each batch into a unified DAG").

Fusion itself is trivial in a hash-consed world — the unified DAG is just the
union of the pipelines' sinks; CSE then merges every structurally identical
subgraph across pipelines (shared reads, shared preprocessing prefixes).
What this module adds on top:

* :class:`PipelineBatch` bookkeeping (which sink belongs to which pipeline,
  agent annotations, per-pipeline results de-multiplexing),
* *variant batching*: detection of homogeneous sink groups — identical DAG
  shape differing only in a scalar hyperparameter spec — which the runtime
  can execute as one vmapped program (TPU analogue of the paper's
  inter-operator parallelism; see DESIGN.md §2).
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Sequence

import hashlib

from .dag import LazyRef, toposort


@dataclass
class PipelineBatch:
    """A batch of agent-emitted pipelines; each pipeline is one sink ref."""
    sinks: list                      # list[LazyRef]
    names: list = field(default_factory=list)
    annotations: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.names:
            self.names = [f"pipeline_{i}" for i in range(len(self.sinks))]
        if len(self.names) != len(self.sinks):
            raise ValueError("names/sinks length mismatch")

    def fused_sinks(self) -> list:
        return list(self.sinks)

    def demux(self, results: Sequence[Any]) -> dict[str, Any]:
        return dict(zip(self.names, results))


# ---------------------------------------------------------------------------
# variant batching: group sinks whose DAGs are isomorphic up to scalar specs
# ---------------------------------------------------------------------------

def _shape_signature(ref: LazyRef, ignore_keys: frozenset) -> str:
    """Signature of the DAG *shape*: op names, wiring and non-ignored spec
    entries — but not the ignored hyperparameter values."""
    h = hashlib.blake2b(digest_size=16)
    order = toposort([ref])
    index = {op.uid: i for i, op in enumerate(order)}
    for op in order:
        h.update(op.op_name.encode())
        for k in sorted(op.spec):
            if k in ignore_keys:
                h.update(f"<{k}>".encode())
            else:
                h.update(f"{k}={op.spec[k]!r}".encode())
        for r in op.inputs:
            h.update(f"{index[r.op.uid]}:{r.index}".encode())
    h.update(f"@{index[ref.op.uid]}:{ref.index}".encode())
    return h.hexdigest()


def group_variants(sinks: Sequence[LazyRef],
                   hyperparam_keys: Sequence[str] = ("alpha", "l1_ratio",
                                                     "learning_rate", "reg"),
                   ) -> list[list[int]]:
    """Return groups of sink indices that are hyperparameter-only variants of
    one another.  Groups of size ≥ 2 are vmap candidates."""
    ignore = frozenset(hyperparam_keys)
    buckets: dict[str, list[int]] = defaultdict(list)
    for i, ref in enumerate(sinks):
        buckets[_shape_signature(ref, ignore)].append(i)
    return [idxs for idxs in buckets.values()]
