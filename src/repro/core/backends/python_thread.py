"""Per-op threaded execution — the interpreted backend.

This is the runtime's original execution path, moved behind the
:class:`~.base.ExecutionBackend` seam: each wave's ops run individually
(vmap variant groups batched first), in parallel on the runtime's bounded
thread pool when the plan allows, with cooperative-preemption polls at
every wave boundary *and* between op completions inside wide waves, and
liveness-driven freeing after each wave.
"""

from __future__ import annotations

from .base import ExecutionBackend


class PythonThreadBackend(ExecutionBackend):
    name = "python"

    def execute_segment(self, rt, segment, selection, report) -> None:
        for wave in segment.waves:
            # cooperative yield point at the wave boundary — the salvage
            # carries every completed intermediate to the requeued re-run
            if rt._should_yield(report):
                raise rt._preempted(report)
            report.waves += 1
            wave_ops = []
            for op in wave.ops:
                if op.signature in rt._skips:
                    # completed before the preempting yield; its output
                    # is dead on this resume — never re-executed
                    rt._mark_salvaged(op, report)
                    continue
                wave_ops.append(op)
            todo = rt._batch_variants(wave_ops, selection, report)
            rt._run_ops_parallel(todo, selection, report)
            rt._free_wave(wave)
