"""Pluggable execution backends for the stratum runtime.

See :mod:`.base` for the seam, :mod:`.python_thread` for the per-op
interpreted path and :mod:`.jax_segment` for whole-segment jit
compilation with the structural plan cache.
"""

from .base import (ExecutionBackend, available_backends, make_backends,
                   register_backend)
from .jax_segment import JaxSegmentBackend
from .python_thread import PythonThreadBackend

__all__ = [
    "ExecutionBackend",
    "JaxSegmentBackend",
    "PythonThreadBackend",
    "available_backends",
    "make_backends",
    "register_backend",
]
