"""The pluggable execution-backend seam.

The :class:`~repro.core.runtime.Runtime` no longer executes ops itself: it
walks the plan's backend-homogeneous :class:`~repro.core.scheduler.Segment`
list and hands each segment to the :class:`ExecutionBackend` registered
for its kind.  The runtime instance *is* the execution context — it owns
the value store, the intermediate cache handle, the salvage/preload state
and the preemption hooks — and backends drive it through its helper
surface (``_gather_inputs`` / ``_store`` / ``_run_op`` / ``_should_yield``
/ ``_preempted``).

Backends shipped here:

* ``"python"`` — :class:`~.python_thread.PythonThreadBackend`: the per-op
  interpreted path (bounded thread pool, vmap variant batching, intra-wave
  preemption polls);
* ``"jax"``    — :class:`~.jax_segment.JaxSegmentBackend`: traces a whole
  segment of traceable jax-tier ops into ONE jitted program with tunable
  constants hoisted to arguments, cached by structural signature in a
  shared :class:`~repro.core.plan_cache.PlanCache`.

A future out-of-process backend (the paper's Rust-runtime analogue) plugs
in by registering a new kind here and teaching the scheduler's
``partition_segments`` to emit segments of that kind; nothing in the
runtime loop changes.
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from typing import Callable


class ExecutionBackend(ABC):
    """Executes one backend-homogeneous plan segment.

    Contract (what the runtime loop relies on):

    * every op of the segment ends in exactly one of four states, recorded
      in the run report's ``sig_source``: salvaged (preload/skip), cache
      hit, executed, or deduplicated onto an identical-signature peer;
    * outputs of every non-skipped op are in the runtime's value store
      when ``execute_segment`` returns (downstream segments read them);
    * intermediate-cache probes are tenant-aware ``get``\\ s and marked
      candidates are ``put`` back — both through the runtime's handles;
    * liveness freeing (``wave.free_after``) is applied no later than the
      segment boundary;
    * cooperative preemption may only be raised via the runtime's
      ``_preempted`` helper so salvage stays exact.
    """

    name: str = "abstract"

    @abstractmethod
    def execute_segment(self, rt, segment, selection, report) -> None:
        """Execute ``segment`` against runtime context ``rt``.

        ``selection`` maps op signature → chosen PhysicalImpl; ``report``
        is the run's mutable :class:`~repro.core.runtime.RunReport`.  May
        raise :class:`~repro.core.runtime.ExecutionError` (op failure) or
        :class:`~repro.core.runtime.ExecutionPreempted` (cooperative
        yield)."""


# ---------------------------------------------------------------------------
# backend registry: segment kind -> factory
# ---------------------------------------------------------------------------

_FACTORIES: dict[str, Callable[..., ExecutionBackend]] = {}


def register_backend(kind: str, factory: Callable[..., ExecutionBackend]
                     ) -> None:
    """Register a backend factory for a segment kind (the seam a future
    out-of-process / Rust backend bolts onto)."""
    _FACTORIES[kind] = factory


def available_backends() -> tuple:
    return tuple(sorted(_FACTORIES))


def make_backends(plan_cache=None, compiled: bool = True,
                  batch_variants: bool = False
                  ) -> dict[str, ExecutionBackend]:
    """Default backend set for a runtime: the per-op python path, plus the
    compiled jax segment path when ``compiled`` (sharing ``plan_cache``
    when given; ``batch_variants`` turns on vmap-batched variant groups
    inside compiled segments).  ``compiled=False`` reproduces the
    pre-segment per-op runtime exactly — jax segments fall back to the
    python backend."""
    from .jax_segment import JaxSegmentBackend
    from .python_thread import PythonThreadBackend
    backends: dict[str, ExecutionBackend] = {"python": PythonThreadBackend()}
    if compiled:
        backends["jax"] = JaxSegmentBackend(plan_cache=plan_cache,
                                            batch_variants=batch_variants)
    for kind, factory in _FACTORIES.items():
        if kind not in backends:
            backends[kind] = factory(plan_cache=plan_cache)
    return backends
