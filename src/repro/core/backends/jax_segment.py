"""Whole-segment jit compilation — the compiled backend.

A ``"jax"`` segment contains only ops whose selected implementation is a
*traceable* jax-tier function (``PhysicalImpl.traceable``).  Instead of
dispatching them one by one through python, this backend traces the whole
segment into ONE jitted program:

* **inputs** — values produced outside the compute set (earlier segments,
  intermediate-cache hits, preemption salvage) enter as runtime arguments;
* **tunable constants** — spec fields declared via
  :func:`repro.core.dag.declare_tunable` (``alpha``, ``l1_ratio``, ...)
  are hoisted to traced scalar arguments, so hyperparameter variants of
  the same structure reuse one compiled program with zero retraces;
* **outputs** — every computed op's outputs are returned and stored back
  into the runtime's value store, so cache inserts, liveness freeing and
  preemption salvage behave exactly as on the per-op path.

Compiled programs live in a :class:`~repro.core.plan_cache.PlanCache`
keyed by the segment's structural signature plus the runtime *cut* (which
ops were served from cache/salvage and therefore became inputs).  The
cache is shared per service shard, so a thousand structurally identical
agent plans compile once and then pay one dispatch per segment.

Semantics at the boundary: the intermediate cache is probed (one
tenant-aware ``get`` per op) *before* tracing — hits become inputs, not
traced ops — and marked candidates are inserted after execution;
cooperative preemption yields between segments.  Failure handling keeps
the "degrades performance, never correctness" contract: a segment shape
that fails a trace-only ``jax.eval_shape`` probe (mis-declared traceable
impl) is remembered as uncompilable — kept out of the plan cache so hit
rates stay honest — and runs per-op forever after; a *runtime* failure of a
compiled program (possibly transient, e.g. resource exhaustion) falls
back per-op for that round only, reproducing any precise per-op error
exactly as the uncompiled path would.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Sequence

import jax

from ..dag import LazyOp, tunable_fields
from ..plan_cache import PlanCache
from .base import ExecutionBackend

_EXT, _INT = 0, 1


class _TracedOp:
    """Stand-in for a LazyOp during tracing: exposes exactly the surface
    impl functions read (``op_name``/``op_class``/``spec``/``n_outputs``)
    without pinning the source plan's DAG — no ``inputs``, no ``meta``, so
    a cached compiled segment never keeps a whole submitted plan alive.

    Reading ``seed`` raises: seed *values* are excluded from structural
    signatures, so a traceable impl consuming one would bake this plan's
    seed into a program reused by seed-variants of the same structure.
    The trap turns that contract violation into a trace-time error — the
    backend falls back to per-op execution, degrading performance, never
    correctness."""

    __slots__ = ("op_name", "op_class", "spec", "n_outputs")

    def __init__(self, op_name: str, op_class: str, spec: dict,
                 n_outputs: int):
        self.op_name = op_name
        self.op_class = op_class
        self.spec = spec
        self.n_outputs = n_outputs

    @classmethod
    def of(cls, op: LazyOp) -> "_TracedOp":
        return cls(op.op_name, op.op_class, dict(op.spec), op.n_outputs)

    def with_spec(self, spec: dict) -> "_TracedOp":
        return _TracedOp(self.op_name, self.op_class, spec, self.n_outputs)

    @property
    def seed(self):
        raise TypeError(
            "op.seed is unavailable inside a compiled segment: seed values "
            "are not part of the structural signature, so a traceable impl "
            "must not read them (mark the impl traceable=False)")


class JaxSegmentBackend(ExecutionBackend):
    name = "jax"

    def __init__(self, plan_cache: Optional[PlanCache] = None):
        # a private cache when none is injected: a bare Runtime still
        # benefits within its own lifetime; services inject the shared
        # per-shard cache so all tenants reuse each other's compiles
        self.plan_cache = plan_cache if plan_cache is not None \
            else PlanCache()
        # segment shapes whose tracing failed (mis-declared traceable
        # impl): go straight to per-op, never re-trace.  Kept OUT of the
        # plan cache so its hit rate reflects compiled reuse only, and
        # bounded so one bad impl on an open-ended stream of distinct
        # structures cannot grow a shard's memory without limit
        self._uncompilable: "OrderedDict" = OrderedDict()
        self._uncompilable_max = 1024

    # ------------------------------------------------------------------
    def execute_segment(self, rt, segment, selection, report) -> None:
        report.waves += len(segment.waves)
        compute: list[LazyOp] = []
        produced: set[str] = set()
        for wave in segment.waves:
            for op in wave.ops:
                sig = op.signature
                if sig in rt._skips:
                    rt._mark_salvaged(op, report)
                    continue
                if sig in produced:
                    continue      # identical-signature peer: one compute
                if sig in rt.preloaded:
                    rt._store(op, rt.preloaded[sig])
                    rt._mark_salvaged(op, report)
                    continue
                # one tenant-aware probe; the hit becomes a segment
                # input instead of a traced op
                if rt._try_cache_hit(op, report) is not None:
                    continue
                compute.append(op)
                produced.add(sig)
        if compute:
            self._run_compiled(rt, segment, compute, selection, report)
        # liveness freeing at the segment boundary (the planner's
        # est_peak_mem accounts for the deferral — see scheduler.plan)
        for wave in segment.waves:
            rt._free_wave(wave)

    # ------------------------------------------------------------------
    def _wiring(self, compute: Sequence[LazyOp]):
        """Input wiring for the compute set: per op, each input is either
        (_INT, producer_position, out_index) — produced inside the segment
        — or (_EXT, arg_position, 0) — fetched from the value store."""
        pos_by_sig: dict[str, int] = {}
        for i, op in enumerate(compute):
            pos_by_sig.setdefault(op.signature, i)
        ext_keys: list[str] = []
        ext_index: dict[str, int] = {}
        in_specs = []
        for op in compute:
            specs = []
            for r in op.inputs:
                p = pos_by_sig.get(r.op.signature)
                if p is not None:
                    specs.append((_INT, p, r.index))
                else:
                    key = r.signature
                    j = ext_index.get(key)
                    if j is None:
                        j = ext_index[key] = len(ext_keys)
                        ext_keys.append(key)
                    specs.append((_EXT, j, 0))
            in_specs.append(tuple(specs))
        return tuple(in_specs), ext_keys

    def _fallback(self, rt, segment, compute, selection, report) -> None:
        """Per-op execution of the segment's compute set, wave-aligned so
        it keeps the python path's pool parallelism and intra-wave
        preemption polls — the fallback must never be worse than running
        with compiled segments disabled."""
        pending = {id(op) for op in compute}
        for wave in segment.waves:
            todo = [op for op in wave.ops if id(op) in pending]
            if todo:
                rt._run_ops_parallel(todo, selection, report)

    def _run_compiled(self, rt, segment, compute, selection,
                      report) -> None:
        in_specs, ext_keys = self._wiring(compute)
        hoists = tuple(tuple(sorted(tunable_fields(op.op_name)
                                    & set(op.spec))) for op in compute)
        # key: structure of every traced op + the cut (which inputs are
        # external) + the exact impl chosen (fidelity annotations can
        # swap impls between structurally identical plans)
        key = ("jax-seg",
               tuple(op.structural_signature for op in compute),
               in_specs,
               tuple(id(selection[op.signature]) for op in compute))
        if key in self._uncompilable:
            self._fallback(rt, segment, compute, selection, report)
            return
        with rt._lock:
            ext_vals = tuple(rt._values[k] for k in ext_keys)
        hoist_vals = tuple(op.spec[f]
                           for op, fs in zip(compute, hoists)
                           for f in fs)
        compiled = self.plan_cache.get(key)
        with rt._lock:
            if compiled is None:
                report.plan_cache_misses += 1
            else:
                report.plan_cache_hits += 1
        if compiled is None:
            seg_fn, compiled = self._build(compute, in_specs, hoists,
                                           selection)
            try:
                # abstract trace probe: a segment shape that cannot trace
                # (mis-declared traceable impl, seed read, host numpy) is
                # a deterministic property — remember it and never retry.
                # eval_shape never lowers/compiles, so the probe costs a
                # fraction of the real compile it precedes
                jax.eval_shape(seg_fn, ext_vals, hoist_vals)
            except Exception:  # noqa: BLE001 — tracing failure
                self._uncompilable[key] = True
                while len(self._uncompilable) > self._uncompilable_max:
                    self._uncompilable.popitem(last=False)
                # per-op reproduces any precise error
                self._fallback(rt, segment, compute, selection, report)
                return
            self.plan_cache.put(key, compiled)
        try:
            outs = compiled(ext_vals, hoist_vals)
        except Exception:  # noqa: BLE001 — XLA runtime failure
            # possibly transient (e.g. resource exhaustion): run per-op
            # this round WITHOUT forgetting the compiled program — tracing
            # failures were already excluded by the eval_shape probe, so the
            # next structurally identical plan tries compiled again
            self._fallback(rt, segment, compute, selection, report)
            return
        self._commit(rt, compute, outs, selection, report)

    def _build(self, compute, in_specs, hoists, selection):
        """Returns ``(seg_fn, jitted)`` — the raw traceable function (for
        the abstract-trace probe) and its jit wrapper (what the plan
        cache stores)."""
        impl_fns = [selection[op.signature].fn for op in compute]
        # proxies, not the LazyOps: a cached program must not pin the
        # submitting plan's DAG (inputs/meta/const payloads) in memory
        protos = [_TracedOp.of(op) for op in compute]

        def seg_fn(ext_vals, hoist_vals):
            outs: list[tuple] = []
            h = 0
            for i, fn in enumerate(impl_fns):
                ins = [ext_vals[j] if tag == _EXT else outs[j][oi]
                       for tag, j, oi in in_specs[i]]
                op = protos[i]
                if hoists[i]:
                    # fresh spec per trace: tracers must not leak into the
                    # shared proto (concurrent retraces would race on it)
                    spec = dict(op.spec)
                    for f in hoists[i]:
                        spec[f] = hoist_vals[h]
                        h += 1
                    op = op.with_spec(spec)
                o = fn(op, ins)
                if not isinstance(o, tuple):
                    o = (o,)
                outs.append(o)
            return tuple(outs)

        return seg_fn, jax.jit(seg_fn)

    def _commit(self, rt, compute, outs, selection, report) -> None:
        from ..runtime import ExecutionError
        for op, out in zip(compute, outs):
            if len(out) != op.n_outputs:
                raise ExecutionError(
                    op, ValueError(f"impl returned {len(out)} outputs, "
                                   f"declared {op.n_outputs}"))
            rt._store(op, out)
            sig = op.signature
            with rt._lock:
                report.ops_executed += 1
                report.per_backend["jax-seg"] = \
                    report.per_backend.get("jax-seg", 0) + 1
                report.sig_source[sig] = "jax-seg"
            if (rt.cache is not None and op.cacheable
                    and sig in rt.cache_candidates):
                rt.cache.put(sig, out, tenant=rt.sig_tenant.get(sig))
