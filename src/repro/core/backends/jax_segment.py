"""Whole-segment jit compilation — the compiled backend.

A ``"jax"`` segment contains only ops whose selected implementation is a
*traceable* jax-tier function (``PhysicalImpl.traceable``).  Instead of
dispatching them one by one through python, this backend traces the whole
segment into ONE jitted program:

* **inputs** — values produced outside the compute set (earlier segments,
  intermediate-cache hits, preemption salvage) enter as runtime arguments;
* **tunable constants** — spec fields declared via
  :func:`repro.core.dag.declare_tunable` (``alpha``, ``l1_ratio``, ...)
  are hoisted to traced scalar arguments, so hyperparameter variants of
  the same structure reuse one compiled program with zero retraces;
* **outputs** — every computed op's outputs are returned and stored back
  into the runtime's value store, so cache inserts, liveness freeing and
  preemption salvage behave exactly as on the per-op path.

Compiled programs live in a :class:`~repro.core.plan_cache.PlanCache`
keyed by the segment's structural signature plus the runtime *cut* (which
ops were served from cache/salvage and therefore became inputs).  The
cache is shared per service shard, so a thousand structurally identical
agent plans compile once and then pay one dispatch per segment.

**Batched variant solves** (``batch_variants=True``): ops inside one
segment that share a structural signature and implementation but differ in
hoisted tunable values (an agent's hyperparameter sweep, coalesced into
one plan) are grouped and traced as ONE ``jax.vmap`` call over stacked
tunable columns — a single batched solve feeding the MXU instead of N
sequential solves unrolled in the program.  Inputs shared across members
(the common design matrix) pass through unbatched (``in_axes=None``);
inputs that differ are stacked.  Outputs are unstacked per member before
commit, so salvage, cache inserts and telemetry are byte-identical to the
unbatched path.  Grouping is a pure function of the plan-cache key, and
batched keys carry a distinct tag, so programs built with and without the
knob never mix.

**Async compilation**: when the plan cache owns a
:class:`~repro.core.plan_cache.CompileExecutor` (``compile_async=True``),
a cache miss no longer blocks the round on trace+jit.  The backend snaps
the segment's shape (proxy ops, wiring, input avals) into a closure,
enqueues it on the executor — single-flight, so concurrent tenants racing
on the same new signature compile once — and dispatches the current round
per-op through the fallback path (variant groups still vmap-batched
there).  The background job probes, builds, warm-calls on zero-filled
inputs and publishes to the cache; the next structurally identical round
runs compiled.  ``precompile_segment`` feeds the same machinery
speculatively: a predictor (e.g. the AIDE driver's next-refinement guess)
can enqueue likely-next shapes at low priority before any tenant submits
them, using observed input avals (falling back to inferred metadata) to
warm the exact program.

Semantics at the boundary: the intermediate cache is probed (one
tenant-aware ``get`` per op) *before* tracing — hits become inputs, not
traced ops — and marked candidates are inserted after execution;
cooperative preemption yields between segments.  Failure handling keeps
the "degrades performance, never correctness" contract: a segment shape
that fails a trace-only ``jax.eval_shape`` probe (mis-declared traceable
impl) is remembered as uncompilable — kept out of the plan cache so hit
rates stay honest, in an LRU bounded by ``uncompilable_max`` so an
adversarial stream of distinct bad shapes cannot grow a shard's memory —
and runs per-op forever after (a batched build that fails its probe first
retries unbatched before giving up); a *runtime* failure of a compiled
program (possibly transient, e.g. resource exhaustion) falls back per-op
for that round only, reproducing any precise per-op error exactly as the
uncompiled path would.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..dag import LazyOp, tunable_fields
from ..plan_cache import PlanCache
from .base import ExecutionBackend

_EXT, _INT = 0, 1


class _TracedOp:
    """Stand-in for a LazyOp during tracing: exposes exactly the surface
    impl functions read (``op_name``/``op_class``/``spec``/``n_outputs``)
    without pinning the source plan's DAG — no ``inputs``, no ``meta``, so
    a cached compiled segment never keeps a whole submitted plan alive.

    Reading ``seed`` raises: seed *values* are excluded from structural
    signatures, so a traceable impl consuming one would bake this plan's
    seed into a program reused by seed-variants of the same structure.
    The trap turns that contract violation into a trace-time error — the
    backend falls back to per-op execution, degrading performance, never
    correctness."""

    __slots__ = ("op_name", "op_class", "spec", "n_outputs")

    def __init__(self, op_name: str, op_class: str, spec: dict,
                 n_outputs: int):
        self.op_name = op_name
        self.op_class = op_class
        self.spec = spec
        self.n_outputs = n_outputs

    @classmethod
    def of(cls, op: LazyOp) -> "_TracedOp":
        return cls(op.op_name, op.op_class, dict(op.spec), op.n_outputs)

    def with_spec(self, spec: dict) -> "_TracedOp":
        return _TracedOp(self.op_name, self.op_class, spec, self.n_outputs)

    @property
    def seed(self):
        raise TypeError(
            "op.seed is unavailable inside a compiled segment: seed values "
            "are not part of the structural signature, so a traceable impl "
            "must not read them (mark the impl traceable=False)")


class JaxSegmentBackend(ExecutionBackend):
    name = "jax"

    def __init__(self, plan_cache: Optional[PlanCache] = None,
                 batch_variants: bool = False,
                 uncompilable_max: int = 1024):
        # a private cache when none is injected: a bare Runtime still
        # benefits within its own lifetime; services inject the shared
        # per-shard cache so all tenants reuse each other's compiles
        self.plan_cache = plan_cache if plan_cache is not None \
            else PlanCache()
        self.batch_variants = bool(batch_variants)
        # programs built with variant batching are traced differently, so
        # they key under a distinct tag — the off path stays byte-identical
        self._key_tag = "jax-seg-vb" if self.batch_variants else "jax-seg"
        # segment shapes whose tracing failed (mis-declared traceable
        # impl): go straight to per-op, never re-trace.  Kept OUT of the
        # plan cache so its hit rate reflects compiled reuse only, and
        # bounded so one bad impl on an open-ended stream of distinct
        # structures cannot grow a shard's memory without limit.  Guarded
        # by its own lock: background compile jobs mark entries too.
        self._uncompilable: "OrderedDict" = OrderedDict()
        self._uncompilable_max = max(1, int(uncompilable_max))
        self._unc_lock = threading.Lock()
        # keys whose eval_shape probe the static analyzer already
        # discharged (analysis.preverify_segment): first dispatch builds
        # and jits without re-probing.  Advisory only — a key absent here
        # just probes as before.  Shares _unc_lock with _uncompilable.
        self._preverified: "OrderedDict" = OrderedDict()
        self._preverified_max = max(1, int(uncompilable_max))
        # observed avals of segment-external inputs, keyed by the input
        # ref's full signature: speculative precompiles warm with the
        # exact runtime (shape, dtype) instead of trusting inferred
        # metadata, so the warmed program matches the real dispatch
        self._ext_avals: "OrderedDict[str, tuple]" = OrderedDict()
        self._ext_avals_max = 4096
        self._aval_lock = threading.Lock()

    # ------------------------------------------------------------------
    def execute_segment(self, rt, segment, selection, report) -> None:
        report.waves += len(segment.waves)
        compute: list[LazyOp] = []
        produced: set[str] = set()
        for wave in segment.waves:
            for op in wave.ops:
                sig = op.signature
                if sig in rt._skips:
                    rt._mark_salvaged(op, report)
                    continue
                if sig in produced:
                    continue      # identical-signature peer: one compute
                if sig in rt.preloaded:
                    rt._store(op, rt.preloaded[sig])
                    rt._mark_salvaged(op, report)
                    continue
                # one tenant-aware probe; the hit becomes a segment
                # input instead of a traced op
                if rt._try_cache_hit(op, report) is not None:
                    continue
                compute.append(op)
                produced.add(sig)
        if compute:
            self._run_compiled(rt, segment, compute, selection, report)
        # liveness freeing at the segment boundary (the planner's
        # est_peak_mem accounts for the deferral — see scheduler.plan)
        for wave in segment.waves:
            rt._free_wave(wave)

    # ------------------------------------------------------------------
    def _wiring(self, compute: Sequence[LazyOp]):
        """Input wiring for the compute set: per op, each input is either
        (_INT, producer_position, out_index) — produced inside the segment
        — or (_EXT, arg_position, 0) — fetched from the value store."""
        pos_by_sig: dict[str, int] = {}
        for i, op in enumerate(compute):
            pos_by_sig.setdefault(op.signature, i)
        ext_keys: list[str] = []
        ext_index: dict[str, int] = {}
        in_specs = []
        for op in compute:
            specs = []
            for r in op.inputs:
                p = pos_by_sig.get(r.op.signature)
                if p is not None:
                    specs.append((_INT, p, r.index))
                else:
                    key = r.signature
                    j = ext_index.get(key)
                    if j is None:
                        j = ext_index[key] = len(ext_keys)
                        ext_keys.append(key)
                    specs.append((_EXT, j, 0))
            in_specs.append(tuple(specs))
        return tuple(in_specs), ext_keys

    def _fallback(self, rt, segment, compute, selection, report) -> None:
        """Per-op execution of the segment's compute set, wave-aligned so
        it keeps the python path's pool parallelism, vmap variant
        batching and intra-wave preemption polls — the fallback must
        never be worse than running with compiled segments disabled."""
        pending = {id(op) for op in compute}
        for wave in segment.waves:
            wave_ops = [op for op in wave.ops if id(op) in pending]
            if wave_ops:
                todo = rt._batch_variants(wave_ops, selection, report)
                rt._run_ops_parallel(todo, selection, report)

    # -- uncompilable bookkeeping --------------------------------------

    def _is_uncompilable(self, key) -> bool:
        with self._unc_lock:
            return key in self._uncompilable

    def _mark_uncompilable(self, key) -> None:
        with self._unc_lock:
            self._uncompilable[key] = True
            self._uncompilable.move_to_end(key)
            while len(self._uncompilable) > self._uncompilable_max:
                self._uncompilable.popitem(last=False)
            n = len(self._uncompilable)
        self.plan_cache.note_uncompilable(n)

    # -- statically pre-verified segments (analysis feasibility pass) ---

    def mark_preverified(self, key) -> None:
        with self._unc_lock:
            self._preverified[key] = True
            self._preverified.move_to_end(key)
            while len(self._preverified) > self._preverified_max:
                self._preverified.popitem(last=False)

    def _is_preverified(self, key) -> bool:
        with self._unc_lock:
            return key in self._preverified

    def preverify_segment(self, segment, selection, infos):
        """Statically discharge a segment's first-dispatch probe.

        Builds the segment program exactly as ``_run_compiled`` would and
        ``eval_shape``-probes it on the analyzer's inferred input avals
        (``infos``: op signature -> list[TensorInfo]).  On success the
        plan-cache key is marked pre-verified and returned; on failure
        returns None and changes nothing — inferred avals may be less
        precise than runtime values, so a static miss must never poison
        the runtime's own probe.  Never executes or compiles."""
        compute: list = []
        produced: set = set()
        for wave in segment.waves:
            for op in wave.ops:
                if op.signature in produced:
                    continue
                compute.append(op)
                produced.add(op.signature)
        if not compute or any(op.signature not in selection
                              for op in compute):
            return None
        in_specs, ext_keys = self._wiring(compute)
        hoists = tuple(tuple(sorted(tunable_fields(op.op_name)
                                    & set(op.spec))) for op in compute)
        ssigs = tuple(op.structural_signature for op in compute)
        impl_ids = tuple(id(selection[op.signature]) for op in compute)
        key = (self._key_tag, ssigs, in_specs, impl_ids)
        ext_info: dict = {}
        for op in compute:
            for r in op.inputs:
                if r.op.signature in produced:
                    continue
                outs = infos.get(r.op.signature)
                if outs is None or r.index >= len(outs):
                    return None
                ext_info[r.signature] = outs[r.index]
        try:
            ext_example = tuple(
                jax.ShapeDtypeStruct(tuple(ext_info[k].shape),
                                     np.dtype(ext_info[k].dtype))
                for k in ext_keys)
            hoist_example = tuple(op.spec[f]
                                  for op, fs in zip(compute, hoists)
                                  for f in fs)
            protos = [_TracedOp.of(op) for op in compute]
            impl_fns = [selection[op.signature].fn for op in compute]
            seg_fn, _jitted = self._build(protos, impl_fns, in_specs,
                                          hoists, ())
            jax.eval_shape(seg_fn, ext_example, hoist_example)
        except Exception:  # noqa: BLE001 — advisory probe, stay silent
            return None
        self.mark_preverified(key)
        return key

    # -- observed input avals (speculative warm-up fidelity) -----------

    @staticmethod
    def _aval_of(v):
        if hasattr(v, "shape") and hasattr(v, "dtype"):
            return ("arr", tuple(v.shape), str(v.dtype))
        return ("raw", v)

    def _note_ext(self, ext_keys, ext_vals) -> None:
        with self._aval_lock:
            for k, v in zip(ext_keys, ext_vals):
                a = self._aval_of(v)
                if a[0] == "raw" and not isinstance(
                        v, (int, float, bool, str, bytes, type(None))):
                    continue   # don't pin arbitrary host objects
                self._ext_avals[k] = a
                self._ext_avals.move_to_end(k)
            while len(self._ext_avals) > self._ext_avals_max:
                self._ext_avals.popitem(last=False)

    @staticmethod
    def _zeros(ext_specs):
        """Zero-filled stand-ins matching recorded avals — numpy zeros
        share the jit aval of the runtime jax arrays (shape, dtype,
        weak_type=False), so warming on them compiles the exact program
        the real dispatch will look up."""
        out = []
        for spec in ext_specs:
            if spec[0] == "arr":
                _, shape, dtype = spec
                out.append(np.zeros(shape, dtype))
            else:
                out.append(spec[1])
        return tuple(out)

    # ------------------------------------------------------------------
    def _run_compiled(self, rt, segment, compute, selection,
                      report) -> None:
        in_specs, ext_keys = self._wiring(compute)
        hoists = tuple(tuple(sorted(tunable_fields(op.op_name)
                                    & set(op.spec))) for op in compute)
        ssigs = tuple(op.structural_signature for op in compute)
        impl_ids = tuple(id(selection[op.signature]) for op in compute)
        # key: structure of every traced op + the cut (which inputs are
        # external) + the exact impl chosen (fidelity annotations can
        # swap impls between structurally identical plans)
        key = (self._key_tag, ssigs, in_specs, impl_ids)
        if self._is_uncompilable(key):
            self._fallback(rt, segment, compute, selection, report)
            return
        with rt._lock:
            ext_vals = tuple(rt._values[k] for k in ext_keys)
        if self.plan_cache.executor is not None:
            self._note_ext(ext_keys, ext_vals)
        hoist_vals = tuple(op.spec[f]
                           for op, fs in zip(compute, hoists)
                           for f in fs)
        compiled = self.plan_cache.get(key)
        with rt._lock:
            if compiled is None:
                report.plan_cache_misses += 1
            else:
                report.plan_cache_hits += 1
        if compiled is None:
            groups = self._plan_groups(ssigs, impl_ids, in_specs, hoists) \
                if self.batch_variants else ()
            protos = [_TracedOp.of(op) for op in compute]
            impl_fns = [selection[op.signature].fn for op in compute]
            ex = self.plan_cache.executor
            if ex is not None:
                # async: build off the critical path, run this round
                # per-op.  The job closes over proxies and avals only —
                # never the submitted DAG.
                specs = tuple(self._aval_of(v) for v in ext_vals)
                ex.submit(key, self._make_job(
                    key, protos, impl_fns, in_specs, hoists, groups,
                    specs, hoist_vals, speculative=False))
                with rt._lock:
                    report.plan_cache_fallback_rounds += 1
                self._fallback(rt, segment, compute, selection, report)
                return
            compiled = self._build_probed(
                key, protos, impl_fns, in_specs, hoists, groups,
                ext_vals, hoist_vals)
            if compiled is None:
                # per-op reproduces any precise error
                self._fallback(rt, segment, compute, selection, report)
                return
            self.plan_cache.put(key, compiled)
        try:
            outs = compiled(ext_vals, hoist_vals)
        except Exception:  # noqa: BLE001 — XLA runtime failure
            # possibly transient (e.g. resource exhaustion): run per-op
            # this round WITHOUT forgetting the compiled program — tracing
            # failures were already excluded by the eval_shape probe, so the
            # next structurally identical plan tries compiled again
            self._fallback(rt, segment, compute, selection, report)
            return
        self._commit(rt, compute, outs, selection, report)

    def _make_job(self, key, protos, impl_fns, in_specs, hoists, groups,
                  ext_specs, hoist_vals, speculative: bool):
        """Background compile closure: probe → build → warm-call on
        zero-filled inputs → publish.  A runtime failure of the warm call
        on zeros (value-dependent, e.g. a singular solve) does not block
        publication — the probe already passed, matching the sync path's
        contract where such programs fall back per-op one round at a
        time."""
        def job():
            zeros = self._zeros(ext_specs)
            jitted = self._build_probed(
                key, protos, impl_fns, in_specs, hoists, groups,
                zeros, hoist_vals)
            if jitted is None:
                return           # marked uncompilable; demand runs per-op
            try:
                jax.block_until_ready(jitted(zeros, hoist_vals))
            except Exception:  # noqa: BLE001 — value-dependent on zeros
                pass
            self.plan_cache.put(key, jitted, speculative=speculative)
        return job

    def _build_probed(self, key, protos, impl_fns, in_specs, hoists,
                      groups, ext_example, hoist_example):
        """Build + abstract-trace probe, batched first.  A batched build
        whose probe fails (non-uniform member shapes, an impl vmap can't
        lift) silently retries unbatched; only when the plain build also
        fails to trace is the shape marked uncompilable.  eval_shape never
        lowers/compiles, so each probe costs a fraction of the real
        compile it precedes."""
        for gs in ((groups, ()) if groups else ((),)):
            seg_fn, jitted = self._build(protos, impl_fns, in_specs,
                                         hoists, gs)
            if not gs and self._is_preverified(key):
                # the static analyzer already eval_shape-probed this exact
                # build (analysis feasibility pass) — skip the re-probe.
                # Batched (gs) builds still probe: vmap-liftability is a
                # separate question the analyzer does not answer.
                return jitted
            try:
                jax.eval_shape(seg_fn, ext_example, hoist_example)
                return jitted
            except Exception:  # noqa: BLE001 — tracing failure
                continue
        self._mark_uncompilable(key)
        return None

    # -- variant-group planning ----------------------------------------

    @staticmethod
    def _plan_groups(ssigs, impl_ids, in_specs, hoists):
        """Homogeneous variant groups, as a pure function of the plan-cache
        key components (so every plan that maps to the key gets the same
        grouping).  Members share a structural signature and impl — same
        non-tunable spec, same wiring shape.  What varies per member is
        the batched axis: hoisted tunable values, differing inputs, or
        both — so a whole refinement chain (clip → impute → scale → fit →
        predict → metric) collapses stage by stage into batched calls,
        not just the tunable-carrying ops.  (Members with nothing varying
        cannot exist past CSE; a degenerate group fails the vmap probe
        and retries unbatched.)  A group executes at its LAST member's
        position; any group whose deferral would starve an earlier
        consumer (an internal edge whose producer moves past its reader)
        is dropped, checked to fixpoint since dropping one group shifts
        execution positions."""
        classes: dict = {}
        for i, (s, m) in enumerate(zip(ssigs, impl_ids)):
            classes.setdefault((s, m), []).append(i)
        groups = [tuple(g) for g in classes.values() if len(g) >= 2]
        while groups:
            group_of = {}
            last = {}
            for gi, g in enumerate(groups):
                for i in g:
                    group_of[i] = gi
                last[gi] = max(g)

            def exec_pos(i):
                return last[group_of[i]] if i in group_of else i

            bad = set()
            for i, specs in enumerate(in_specs):
                for tag, p, _oi in specs:
                    if tag == _INT and exec_pos(p) >= exec_pos(i):
                        bad.add(group_of[p] if p in group_of
                                else group_of[i])
            if not bad:
                break
            groups = [g for gi, g in enumerate(groups) if gi not in bad]
        return tuple(groups)

    # ------------------------------------------------------------------
    def _build(self, protos, impl_fns, in_specs, hoists, groups=()):
        """Returns ``(seg_fn, jitted)`` — the raw traceable function (for
        the abstract-trace probe) and its jit wrapper (what the plan
        cache stores).  Takes proxies + impl functions, never LazyOps:
        background compile jobs must not pin submitted DAGs.

        With ``groups``, each variant group becomes ONE ``jax.vmap`` call:
        per-member hoisted tunables stack into (k,) columns (``in_axes=0``
        each); per-member inputs that are the same traced value pass
        through shared (``in_axes=None``), differing ones stack on a new
        leading axis.  Outputs unstack per member, so everything
        downstream — later traced ops, commit, salvage — is oblivious."""
        n = len(protos)
        h_idx, h = [], 0
        for fs in hoists:
            h_idx.append(tuple(range(h, h + len(fs))))
            h += len(fs)
        group_of, last = {}, {}
        for gi, g in enumerate(groups):
            for i in g:
                group_of[i] = gi
            last[gi] = max(g)

        def gather(i, ext_vals, outs):
            return [ext_vals[j] if tag == _EXT else outs[j][oi]
                    for tag, j, oi in in_specs[i]]

        def run_one(i, ext_vals, hoist_vals, outs):
            op = protos[i]
            if hoists[i]:
                # fresh spec per trace: tracers must not leak into the
                # shared proto (concurrent retraces would race on it)
                spec = dict(op.spec)
                for f, hx in zip(hoists[i], h_idx[i]):
                    spec[f] = hoist_vals[hx]
                op = op.with_spec(spec)
            o = impl_fns[i](op, gather(i, ext_vals, outs))
            return o if isinstance(o, tuple) else (o,)

        def run_group(gi, ext_vals, hoist_vals, outs):
            members = groups[gi]
            proto, fn = protos[members[0]], impl_fns[members[0]]
            fields = hoists[members[0]]
            per_in = [gather(m, ext_vals, outs) for m in members]
            axes, bins = [], []
            for t in range(len(per_in[0])):
                vals = [row[t] for row in per_in]
                if all(v is vals[0] for v in vals[1:]):
                    axes.append(None)       # shared (the design matrix)
                    bins.append(vals[0])
                else:
                    axes.append(0)          # member-varying: stack
                    bins.append(jnp.stack(vals))
            h_cols = tuple(
                jnp.stack([jnp.asarray(hoist_vals[h_idx[m][t]])
                           for m in members])
                for t in range(len(fields)))

            def member_fn(hv, ins):
                spec = dict(proto.spec)
                for f, v in zip(fields, hv):
                    spec[f] = v
                o = fn(proto.with_spec(spec), list(ins))
                return o if isinstance(o, tuple) else (o,)

            stacked = jax.vmap(
                member_fn,
                in_axes=((0,) * len(fields), tuple(axes)))(
                h_cols, tuple(bins))
            for q, m in enumerate(members):
                outs[m] = tuple(o[q] for o in stacked)

        def seg_fn(ext_vals, hoist_vals):
            outs: list = [None] * n
            for i in range(n):
                gi = group_of.get(i)
                if gi is None:
                    outs[i] = run_one(i, ext_vals, hoist_vals, outs)
                elif i == last[gi]:
                    run_group(gi, ext_vals, hoist_vals, outs)
            return tuple(outs)

        return seg_fn, jax.jit(seg_fn)

    # -- speculative warm-up -------------------------------------------

    def precompile_segment(self, segment, selection, cache=None) -> str:
        """Enqueue a low-priority background compile for a segment of a
        plan that has NOT been submitted — the speculative warm-up hook.
        Simulates the runtime cut against the intermediate cache
        side-effect-free (``in`` probes only: no hit counting, no LRU
        touch, no tenant attribution — the plan is hypothetical), derives
        the same plan-cache key the real dispatch would, and submits on
        the speculative lane.  Input avals come from observations of the
        same input signatures on real runs, falling back to inferred op
        metadata.  Returns a status string (for telemetry/tests):
        ``enqueued`` | ``cached`` | ``inflight`` | ``uncompilable`` |
        ``rejected`` (lane full / closed) | ``no-executor`` | ``empty`` |
        ``no-spec`` (an input's aval is unknown)."""
        ex = self.plan_cache.executor
        if ex is None:
            return "no-executor"
        compute: list[LazyOp] = []
        produced: set[str] = set()
        for wave in segment.waves:
            for op in wave.ops:
                sig = op.signature
                if sig in produced:
                    continue
                if cache is not None and sig in cache:
                    continue      # would be served as a segment input
                compute.append(op)
                produced.add(sig)
        if not compute:
            return "empty"
        in_specs, ext_keys = self._wiring(compute)
        hoists = tuple(tuple(sorted(tunable_fields(op.op_name)
                                    & set(op.spec))) for op in compute)
        ssigs = tuple(op.structural_signature for op in compute)
        impl_ids = tuple(id(selection[op.signature]) for op in compute)
        key = (self._key_tag, ssigs, in_specs, impl_ids)
        if self._is_uncompilable(key):
            return "uncompilable"
        if key in self.plan_cache:
            return "cached"
        if ex.inflight(key):
            return "inflight"
        ref_by_sig: dict = {}
        for op in compute:
            for r in op.inputs:
                ref_by_sig.setdefault(r.signature, r)
        specs = []
        with self._aval_lock:
            observed = {k: self._ext_avals.get(k) for k in ext_keys}
        for k in ext_keys:
            a = observed.get(k)
            if a is None:
                r = ref_by_sig[k]
                try:
                    ti = r.op.meta.outputs[r.index]
                    a = ("arr", tuple(ti.shape), ti.dtype)
                except Exception:  # noqa: BLE001 — no inferred metadata
                    return "no-spec"
            specs.append(a)
        hoist_vals = tuple(op.spec[f]
                           for op, fs in zip(compute, hoists)
                           for f in fs)
        groups = self._plan_groups(ssigs, impl_ids, in_specs, hoists) \
            if self.batch_variants else ()
        protos = [_TracedOp.of(op) for op in compute]
        impl_fns = [selection[op.signature].fn for op in compute]
        ok = ex.submit(key, self._make_job(
            key, protos, impl_fns, in_specs, hoists, groups,
            tuple(specs), hoist_vals, speculative=True), speculative=True)
        return "enqueued" if ok else "rejected"

    # ------------------------------------------------------------------
    def _commit(self, rt, compute, outs, selection, report) -> None:
        from ..runtime import ExecutionError
        for op, out in zip(compute, outs):
            if len(out) != op.n_outputs:
                raise ExecutionError(
                    op, ValueError(f"impl returned {len(out)} outputs, "
                                   f"declared {op.n_outputs}"))
            rt._store(op, out)
            sig = op.signature
            with rt._lock:
                report.ops_executed += 1
                report.per_backend["jax-seg"] = \
                    report.per_backend.get("jax-seg", 0) + 1
                report.sig_source[sig] = "jax-seg"
            if (rt.cache is not None and op.cacheable
                    and sig in rt.cache_candidates):
                rt.cache.put(sig, out, tenant=rt.sig_tenant.get(sig))
