"""Agent–system co-design hooks (paper §3).

Agents may annotate pipelines with lightweight metadata which stratum uses to
adjust execution:

* ``stage``: "explore" | "exploit" — explore permits lower-fidelity operator
  selection (approximate SVD, subsampled fits) and tighter iteration caps;
* ``budget_s``: soft per-pipeline time budget (runtime may early-stop
  iterative estimators);
* ``diff_of``: name of the parent pipeline when the agent emits incremental
  specifications (pipeline diffs) — fusion uses it for bookkeeping only,
  since hash-consing already recovers sharing structurally.
"""

from __future__ import annotations

from typing import Any

from .dag import LazyRef

KNOWN_KEYS = ("stage", "budget_s", "diff_of", "fidelity")


def annotate(sink: LazyRef, **notes: Any) -> LazyRef:
    """Attach annotations to every op reachable from ``sink``.

    Annotations do not affect operator signatures (they are hints, not
    semantics) — mutating in place is deliberate: cache keys must not change.
    """
    for key in notes:
        if key not in KNOWN_KEYS:
            raise KeyError(f"unknown annotation {key!r}; known: {KNOWN_KEYS}")
    from .dag import toposort
    for op in toposort([sink]):
        merged = dict(op.annotations)
        merged.update(notes)
        op.annotations = merged
    return sink
