"""Reuse of intermediates (paper §4.3).

A hash map from operator signatures (content hash of input hashes + op spec +
seed) to materialized outputs, with

* a fixed memory fraction for in-RAM entries (paper default: 10%),
* LRU eviction to an on-disk spill directory (paper uses Parquet; we use
  ``.npz`` since outputs are arrays/array-trees),
* lazy reload on hit across agent iterations (paper: "the hash map is
  reloaded and intermediates are fetched lazily"),
* speculative cache-candidate marking by the optimizer (expensive
  preprocessing ops), so cheap ops don't pollute the budget.

Non-deterministic, unseeded ops are excluded (``LazyOp.cacheable``).
"""

from __future__ import annotations

import os
import pickle
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import numpy as np

from .dag import LazyOp, LazyRef, toposort


def _nbytes(value: Any) -> int:
    if isinstance(value, (tuple, list)):
        return sum(_nbytes(v) for v in value)
    if isinstance(value, dict):
        return sum(_nbytes(v) for v in value.values())
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    return 64


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    disk_hits: int = 0
    evictions: int = 0
    inserted: int = 0
    bytes_in_ram: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class IntermediateCache:
    """Thread-safe signature→outputs cache with RAM budget + disk spill."""

    def __init__(self, budget_bytes: int, spill_dir: Optional[str] = None):
        self.budget_bytes = int(budget_bytes)
        self.spill_dir = spill_dir
        self._ram: OrderedDict[str, tuple] = OrderedDict()
        self._sizes: dict[str, int] = {}
        self._on_disk: set[str] = set()
        self._lock = threading.Lock()
        self.stats = CacheStats()
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)
            self._load_disk_index()

    # -- index persistence across agent iterations / process restarts -------
    def _disk_path(self, sig: str) -> str:
        assert self.spill_dir is not None
        return os.path.join(self.spill_dir, f"{sig}.pkl")

    def _load_disk_index(self) -> None:
        for name in os.listdir(self.spill_dir):
            if name.endswith(".pkl"):
                self._on_disk.add(name[:-4])

    # -- core protocol -------------------------------------------------------
    def get(self, sig: str) -> Optional[tuple]:
        with self._lock:
            if sig in self._ram:
                self._ram.move_to_end(sig)
                self.stats.hits += 1
                return self._ram[sig]
        if self.spill_dir and sig in self._on_disk:
            try:
                with open(self._disk_path(sig), "rb") as f:
                    value = pickle.load(f)
            except Exception:
                with self._lock:
                    self._on_disk.discard(sig)
                    self.stats.misses += 1
                return None
            with self._lock:
                self.stats.hits += 1
                self.stats.disk_hits += 1
            self._insert_ram(sig, value)
            return value
        with self._lock:
            self.stats.misses += 1
        return None

    def put(self, sig: str, outputs: tuple, spill: bool = True) -> None:
        self._insert_ram(sig, outputs)
        with self._lock:
            self.stats.inserted += 1
        if spill and self.spill_dir:
            self._spill(sig, outputs)

    def _insert_ram(self, sig: str, outputs: tuple) -> None:
        size = _nbytes(outputs)
        if size > self.budget_bytes:
            return  # larger than the whole budget: disk-only
        with self._lock:
            self._ram[sig] = outputs
            self._ram.move_to_end(sig)
            self._sizes[sig] = size
            self.stats.bytes_in_ram = sum(self._sizes[s] for s in self._ram)
            while self.stats.bytes_in_ram > self.budget_bytes and len(self._ram) > 1:
                old_sig, old_val = self._ram.popitem(last=False)
                self.stats.bytes_in_ram -= self._sizes.pop(old_sig)
                self.stats.evictions += 1
                if self.spill_dir and old_sig not in self._on_disk:
                    self._spill(old_sig, old_val)

    def _spill(self, sig: str, outputs: tuple) -> None:
        tmp = self._disk_path(sig) + f".tmp{os.getpid()}"
        try:
            host = tuple(np.asarray(o) if hasattr(o, "shape") else o
                         for o in outputs)
            with open(tmp, "wb") as f:
                pickle.dump(host, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, self._disk_path(sig))  # atomic
            with self._lock:
                self._on_disk.add(sig)
        except Exception:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def clear_ram(self) -> None:
        """Simulate an agent-iteration boundary / process restart."""
        with self._lock:
            self._ram.clear()
            self._sizes.clear()
            self.stats.bytes_in_ram = 0

    def __contains__(self, sig: str) -> bool:
        with self._lock:
            if sig in self._ram:
                return True
        return bool(self.spill_dir) and sig in self._on_disk


# ---------------------------------------------------------------------------
# speculative cache-candidate marking (paper: "the optimizer speculatively
# marks selected operators (e.g. expensive preprocessing) as cache candidates")
# ---------------------------------------------------------------------------

def mark_cache_candidates(sinks: Sequence[LazyRef],
                          min_cost_s: float = 1e-4,
                          min_consumers: int = 1) -> set[str]:
    """Signatures worth materializing: deterministic-or-seeded ops whose
    estimated recompute cost exceeds ``min_cost_s`` (based on collected
    metadata), preferring ops with fanout (shared across pipelines)."""
    from .dag import consumers as _consumers
    order = toposort(sinks)
    fanout = _consumers(order)
    marked: set[str] = set()
    for op in order:
        if not op.cacheable or op.meta is None:
            continue
        est = op.meta.flops / 2e9 + op.meta.out_bytes / 2e9
        if est >= min_cost_s and len(fanout.get(op.uid, ())) >= min_consumers:
            marked.add(op.signature)
    return marked
