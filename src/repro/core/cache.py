"""Reuse of intermediates (paper §4.3), shared across tenants.

A hash map from operator signatures (content hash of input hashes + op spec +
seed) to materialized outputs, with

* a fixed memory fraction for in-RAM entries (paper default: 10%),
* LRU eviction to an on-disk spill directory (paper uses Parquet; we use
  pickled host arrays since outputs are arrays/array-trees),
* lazy reload on hit across agent iterations (paper: "the hash map is
  reloaded and intermediates are fetched lazily"),
* speculative cache-candidate marking by the optimizer (expensive
  preprocessing ops), so cheap ops don't pollute the budget,
* **cross-tenant arbitration** — when the cache is shared by a multi-tenant
  service, each entry is *charged* to the tenant whose job materialized it.
  With ``arbitration="quota"`` every tenant gets a soft quota
  (``tenant_quota_fraction × budget``); under RAM pressure the victim is the
  least-recently-used entry of an *over-quota* tenant, and an under-quota
  tenant's entries are evicted only when no over-quota victim exists.  Hits
  on an entry charged to a different tenant are counted as
  ``cross_tenant_hits`` (the work-sharing win the service exists for).

Non-deterministic, unseeded ops are excluded (``LazyOp.cacheable``).
"""

from __future__ import annotations

import os
import pickle
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import numpy as np

from .dag import LazyOp, LazyRef, toposort


def _nbytes(value: Any) -> int:
    if isinstance(value, (tuple, list)):
        return sum(_nbytes(v) for v in value)
    if isinstance(value, dict):
        return sum(_nbytes(v) for v in value.values())
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    return 64


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    disk_hits: int = 0
    evictions: int = 0
    inserted: int = 0
    bytes_in_ram: int = 0
    # cross-tenant attribution (only populated when callers pass tenant=)
    cross_tenant_hits: int = 0
    hits_by_tenant: dict = field(default_factory=dict)
    evictions_by_tenant: dict = field(default_factory=dict)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class IntermediateCache:
    """Thread-safe signature→outputs cache with RAM budget + disk spill.

    ``arbitration`` selects the RAM-pressure victim policy:

    * ``"lru"`` — global least-recently-used (single-tenant behaviour);
    * ``"quota"`` — per-tenant soft quotas: evict the LRU entry of a tenant
      charged more than ``tenant_quota_fraction × budget_bytes`` first, and
      fall back to global LRU only when nobody is over quota.  Entries with
      no tenant (``tenant=None``) are treated as a tenant of their own.
    """

    def __init__(self, budget_bytes: int, spill_dir: Optional[str] = None,
                 arbitration: str = "lru",
                 tenant_quota_fraction: float = 0.5):
        if arbitration not in ("lru", "quota"):
            raise ValueError(f"unknown arbitration policy {arbitration!r}")
        self.budget_bytes = int(budget_bytes)
        self.spill_dir = spill_dir
        self.arbitration = arbitration
        self.tenant_quota_fraction = float(tenant_quota_fraction)
        self._ram: OrderedDict[str, tuple] = OrderedDict()
        self._sizes: dict[str, int] = {}
        self._owner: dict[str, Optional[str]] = {}   # sig -> charged tenant
        # sig -> first materializer; survives eviction so a disk-hit reload
        # keeps both the quota charge and the cross-tenant hit attribution
        # with the tenant whose job originally produced the value
        self._origin: dict[str, Optional[str]] = {}
        self._tenant_bytes: dict[Optional[str], int] = {}
        self._on_disk: set[str] = set()
        self._lock = threading.Lock()
        self.stats = CacheStats()
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)
            self._load_disk_index()

    # -- index persistence across agent iterations / process restarts -------
    def _disk_path(self, sig: str) -> str:
        assert self.spill_dir is not None
        return os.path.join(self.spill_dir, f"{sig}.pkl")

    def _load_disk_index(self) -> None:
        for name in os.listdir(self.spill_dir):
            if name.endswith(".pkl"):
                self._on_disk.add(name[:-4])

    # -- core protocol -------------------------------------------------------
    def _record_hit_locked(self, sig: str, tenant: Optional[str]) -> None:
        self.stats.hits += 1
        if tenant is not None:
            self.stats.hits_by_tenant[tenant] = \
                self.stats.hits_by_tenant.get(tenant, 0) + 1
            origin = self._origin.get(sig)
            if origin is not None and origin != tenant:
                self.stats.cross_tenant_hits += 1

    def get(self, sig: str, tenant: Optional[str] = None) -> Optional[tuple]:
        with self._lock:
            if sig in self._ram:
                self._ram.move_to_end(sig)
                self._record_hit_locked(sig, tenant)
                return self._ram[sig]
        if self.spill_dir and sig in self._on_disk:
            try:
                with open(self._disk_path(sig), "rb") as f:
                    value = pickle.load(f)
            except Exception:
                with self._lock:
                    self._on_disk.discard(sig)
                    self.stats.misses += 1
                return None
            with self._lock:
                self._record_hit_locked(sig, tenant)
                self.stats.disk_hits += 1
            self._insert_ram(sig, value, tenant)
            return value
        with self._lock:
            self.stats.misses += 1
        return None

    def put(self, sig: str, outputs: tuple, spill: bool = True,
            tenant: Optional[str] = None) -> None:
        self._insert_ram(sig, outputs, tenant)
        with self._lock:
            self.stats.inserted += 1
        if spill and self.spill_dir:
            self._spill(sig, outputs)

    # -- charge accounting + victim selection --------------------------------
    def _charge_locked(self, sig: str, tenant: Optional[str],
                       size: int) -> None:
        if sig not in self._origin and tenant is not None:
            self._origin[sig] = tenant     # first materializer, forever
        if sig in self._sizes:
            # entry already in RAM: drop the previous byte charge first
            owner = self._owner.get(sig)
            self._tenant_bytes[owner] = \
                self._tenant_bytes.get(owner, 0) - self._sizes[sig]
            if self._tenant_bytes[owner] <= 0:
                del self._tenant_bytes[owner]
        # the charge stays with the first materializer even when another
        # tenant re-inserts (repeat put) or reloads it from disk — their
        # access was a hit, not a burden
        owner = self._origin.get(sig, tenant)
        self._owner[sig] = owner
        self._tenant_bytes[owner] = self._tenant_bytes.get(owner, 0) + size

    def _uncharge_locked(self, sig: str, size: int) -> Optional[str]:
        owner = self._owner.pop(sig, None)
        self._tenant_bytes[owner] = self._tenant_bytes.get(owner, 0) - size
        if self._tenant_bytes[owner] <= 0:
            del self._tenant_bytes[owner]
        return owner

    def _pick_victim_locked(self) -> str:
        """The signature to evict next under RAM pressure."""
        if self.arbitration == "quota":
            quota = self.tenant_quota_fraction * self.budget_bytes
            over = {t for t, b in self._tenant_bytes.items() if b > quota}
            if over:
                for sig in self._ram:          # LRU → MRU order
                    if self._owner.get(sig) in over:
                        return sig
        return next(iter(self._ram))           # global LRU

    def _insert_ram(self, sig: str, outputs: tuple,
                    tenant: Optional[str] = None) -> None:
        size = _nbytes(outputs)
        if size > self.budget_bytes:
            return  # larger than the whole budget: disk-only
        with self._lock:
            self._ram[sig] = outputs
            self._ram.move_to_end(sig)
            self._charge_locked(sig, tenant, size)
            self._sizes[sig] = size
            self.stats.bytes_in_ram = sum(self._sizes[s] for s in self._ram)
            while self.stats.bytes_in_ram > self.budget_bytes \
                    and len(self._ram) > 1:
                victim = self._pick_victim_locked()
                if victim == sig and len(self._ram) > 1:
                    # never evict the entry being inserted while an
                    # alternative exists (it would thrash immediately)
                    it = iter(self._ram)
                    victim = next(it)
                    if victim == sig:
                        victim = next(it)
                old_val = self._ram.pop(victim)
                vsize = self._sizes.pop(victim)
                self.stats.bytes_in_ram -= vsize
                self.stats.evictions += 1
                owner = self._uncharge_locked(victim, vsize)
                if owner is not None:
                    self.stats.evictions_by_tenant[owner] = \
                        self.stats.evictions_by_tenant.get(owner, 0) + 1
                if self.spill_dir and victim not in self._on_disk:
                    self._spill(victim, old_val)

    def _spill(self, sig: str, outputs: tuple) -> None:
        tmp = self._disk_path(sig) + f".tmp{os.getpid()}"
        try:
            host = tuple(np.asarray(o) if hasattr(o, "shape") else o
                         for o in outputs)
            with open(tmp, "wb") as f:
                pickle.dump(host, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, self._disk_path(sig))  # atomic
            with self._lock:
                self._on_disk.add(sig)
        except Exception:
            if os.path.exists(tmp):
                os.unlink(tmp)

    # -- warm hand-off (elastic fabric: draining shard → ring successor) -----
    def export_hot_entries(self, max_entries: int = 64
                           ) -> list[tuple[str, bytes]]:
        """The hottest RAM entries as ``(sig, spill_bytes)`` pairs, most
        recently used first.  ``spill_bytes`` is exactly what ``_spill``
        writes to disk (a pickled host-array tuple), so the receiving side
        ingests them with the same code path that reloads a spill file —
        this is the wire form of a draining shard's warm cache hand-off."""
        with self._lock:
            sigs = list(self._ram)[-max_entries:][::-1]   # MRU first
            values = [self._ram[s] for s in sigs]
        out: list[tuple[str, bytes]] = []
        for sig, outputs in zip(sigs, values):
            host = tuple(np.asarray(o) if hasattr(o, "shape") else o
                         for o in outputs)
            try:
                out.append((sig, pickle.dumps(
                    host, protocol=pickle.HIGHEST_PROTOCOL)))
            except Exception:  # noqa: BLE001 — skip unpicklable payloads
                continue
        return out

    def import_spilled(self, entries) -> int:
        """Ingest ``(sig, spill_bytes)`` pairs produced by
        :meth:`export_hot_entries` (or read from spill files).  Corrupt
        entries are skipped; returns how many were inserted."""
        n = 0
        for sig, blob in entries:
            try:
                outputs = pickle.loads(blob)
            except Exception:  # noqa: BLE001 — corrupt hand-off entry
                continue
            self.put(sig, outputs, spill=False)
            n += 1
        return n

    # -- introspection -------------------------------------------------------
    def tenant_bytes(self) -> dict:
        """Bytes currently charged per tenant (RAM entries only)."""
        with self._lock:
            return dict(self._tenant_bytes)

    def owners(self) -> dict:
        with self._lock:
            return dict(self._owner)

    def arbitration_snapshot(self) -> dict:
        """Cross-tenant arbitration state, copied under the lock (the live
        stats dicts mutate concurrently with evictions — iterating them
        unlocked can raise mid-iteration)."""
        with self._lock:
            return {
                "cross_tenant_hits": self.stats.cross_tenant_hits,
                "bytes_by_tenant": dict(self._tenant_bytes),
                "evictions_by_tenant": dict(self.stats.evictions_by_tenant),
            }

    def clear_ram(self) -> None:
        """Simulate an agent-iteration boundary / process restart."""
        with self._lock:
            self._ram.clear()
            self._sizes.clear()
            self._owner.clear()
            self._origin.clear()   # not persisted: a restart loses it too
            self._tenant_bytes.clear()
            self.stats.bytes_in_ram = 0

    def __contains__(self, sig: str) -> bool:
        with self._lock:
            if sig in self._ram:
                return True
        return bool(self.spill_dir) and sig in self._on_disk


# ---------------------------------------------------------------------------
# speculative cache-candidate marking (paper: "the optimizer speculatively
# marks selected operators (e.g. expensive preprocessing) as cache candidates")
# ---------------------------------------------------------------------------

def mark_cache_candidates(sinks: Sequence[LazyRef],
                          min_cost_s: float = 1e-4,
                          min_consumers: int = 1) -> set[str]:
    """Signatures worth materializing: deterministic-or-seeded ops whose
    estimated recompute cost exceeds ``min_cost_s`` (based on collected
    metadata), preferring ops with fanout (shared across pipelines)."""
    from .dag import consumers as _consumers
    order = toposort(sinks)
    fanout = _consumers(order)
    marked: set[str] = set()
    for op in order:
        if not op.cacheable or op.meta is None:
            continue
        est = op.meta.flops / 2e9 + op.meta.out_bytes / 2e9
        if est >= min_cost_s and len(fanout.get(op.uid, ())) >= min_consumers:
            marked.add(op.signature)
    return marked
