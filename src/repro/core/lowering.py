"""Operator lowering (paper §4.2): decompose COMPOSITE operators into
fine-grained operator subgraphs to expand the optimization space.

Examples from the paper that are implemented here via registered rules
(the rules themselves live next to the operator definitions in
``repro.tabular``):

* ``cv_score``          → unrolled per-fold split/fit/predict/metric DAG
                          (instead of re-executing one subgraph k times),
* ``table_vectorizer``  → cleaner + per-column-group encoders + concat,
* ``grid_search``       → one fit/score branch per grid point + argmax.

Lowering runs to a fixpoint (lowered subgraphs may contain composites) and is
followed by a CSE pass — unrolling is what *creates* most sharing (folds share
preprocessing; grid points share everything but the hyperparameter).

Multi-output composites lower through a transient ``tuple`` passthrough op
which is eliminated in the same pass (refs are rewired to the tuple's inputs),
so the final DAG never contains passthrough nodes.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

from .dag import COMPOSITE, GENERIC, LazyOp, LazyRef, rebuild

# rule: (op, new_inputs) -> list[LazyRef] replacement outputs (len n_outputs)
_LOWERINGS: dict[str, Callable[[LazyOp, tuple], Sequence[LazyRef]]] = {}

_TUPLE = "__tuple__"


def register_lowering(op_name: str):
    def deco(fn):
        _LOWERINGS[op_name] = fn
        return fn
    return deco


def _untuple(ref: LazyRef) -> LazyRef:
    while ref.op.op_name == _TUPLE:
        ref = ref.op.inputs[ref.index]
    return ref


def lower(sinks: Sequence[LazyRef], max_rounds: int = 8) -> list[LazyRef]:
    out = list(sinks)
    for _ in range(max_rounds):
        changed = False

        def replace(op: LazyOp, new_inputs: tuple) -> Optional[LazyOp]:
            nonlocal changed
            wired = tuple(_untuple(r) for r in new_inputs)
            if op.op_class == COMPOSITE and op.op_name in _LOWERINGS:
                outs = [
                    _untuple(r) for r in _LOWERINGS[op.op_name](op, wired)
                ]
                if len(outs) != op.n_outputs:
                    raise ValueError(
                        f"lowering for {op.op_name} produced {len(outs)} "
                        f"outputs, expected {op.n_outputs}")
                changed = True
                if op.n_outputs == 1 and outs[0].index == 0:
                    return outs[0].op
                return LazyOp(_TUPLE, GENERIC, inputs=tuple(outs),
                              n_outputs=len(outs))
            if (wired != new_inputs
                    or len(wired) != len(op.inputs)
                    or any(a.op is not b.op or a.index != b.index
                           for a, b in zip(wired, op.inputs))):
                return op.with_inputs(wired)
            return None

        out = [_untuple(r) for r in rebuild(out, replace)]
        if not changed:
            break
    return out


def is_lowerable(op_name: str) -> bool:
    return op_name in _LOWERINGS
