"""Lazy operator DAG — stratum's declarative abstraction (paper §4.1).

Every computation in a pipeline is a :class:`LazyOp` node; edges are data
dependencies.  The DAG is control-flow free and lazily evaluated, mirroring
skrub's DataOps.  Nodes carry

* ``op_name``    — logical operator identity ("read", "standard_scaler", ...)
* ``op_class``   — broad category used by the optimizer (SOURCE/TRANSFORM/...)
* ``spec``       — hashable operator specification (hyperparameters)
* ``inputs``     — upstream :class:`LazyRef` handles
* ``seed``       — explicit randomness; ops without a seed that declare
                   themselves non-deterministic are excluded from caching
* ``signature``  — content hash H(input signatures, op_name, spec, seed),
                   cached on the node for O(1) equality (paper §4.3 Reuse).

The signature doubles as the cache key and the CSE equivalence class.
"""

from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Optional, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# operator categories (paper §4.2 "operator type" metadata)
# ---------------------------------------------------------------------------

SOURCE = "source"          # data ingestion (read sharing applies)
TRANSFORM = "transform"    # stateless or fitted row/col transforms
PROJECT = "project"        # column selection (pushdown applies)
FILTER = "filter"          # row predicate (pushdown applies)
ESTIMATOR = "estimator"    # fit/predict model ops
EVAL = "eval"              # metrics / scoring
COMPOSITE = "composite"    # lowered by lowering.py (cv, table_vectorizer, ...)
CONST = "const"            # literal payloads (constant folding applies)
GENERIC = "generic"        # black-box UDF — optimizer must preserve as-is

OP_CLASSES = (SOURCE, TRANSFORM, PROJECT, FILTER, ESTIMATOR, EVAL, COMPOSITE,
              CONST, GENERIC)

_uid = itertools.count()


def _hash_payload(value: Any) -> str:
    """Stable content hash for spec payloads and constant data."""
    h = hashlib.blake2b(digest_size=16)

    def feed(v: Any) -> None:
        if isinstance(v, np.ndarray):
            h.update(b"nd")
            h.update(str(v.dtype).encode())
            h.update(str(v.shape).encode())
            h.update(np.ascontiguousarray(v).tobytes())
        elif isinstance(v, (list, tuple)):
            h.update(b"seq")
            for item in v:
                feed(item)
        elif isinstance(v, Mapping):
            h.update(b"map")
            for k in sorted(v):
                h.update(str(k).encode())
                feed(v[k])
        elif isinstance(v, (str, bytes)):
            h.update(b"s")
            h.update(v.encode() if isinstance(v, str) else v)
        elif isinstance(v, (int, float, bool, complex)) or v is None:
            h.update(repr(v).encode())
        elif hasattr(v, "tobytes"):  # jax arrays and friends
            h.update(b"arr")
            h.update(np.asarray(v).tobytes())
        else:
            # Fall back to repr; GENERIC ops should pass identifying specs.
            h.update(repr(v).encode())

    feed(value)
    return h.hexdigest()


@dataclass(frozen=True)
class LazyRef:
    """A handle to output ``index`` of ``op`` — the DAG's edge type."""

    op: "LazyOp"
    index: int = 0

    @property
    def signature(self) -> str:
        return f"{self.op.signature}:{self.index}"


@dataclass(eq=False)
class LazyOp:
    op_name: str
    op_class: str
    spec: Mapping[str, Any] = field(default_factory=dict)
    inputs: tuple = ()  # tuple[LazyRef, ...]
    seed: Optional[int] = None
    n_outputs: int = 1
    deterministic: bool = True
    annotations: Mapping[str, Any] = field(default_factory=dict)  # §3 co-design
    uid: int = field(default_factory=lambda: next(_uid))
    # filled by the metadata pass (metadata.py)
    meta: Optional[Any] = None
    _signature: Optional[str] = field(default=None, repr=False)

    def __post_init__(self) -> None:
        if self.op_class not in OP_CLASSES:
            raise ValueError(f"unknown op_class {self.op_class!r}")
        for ref in self.inputs:
            if not isinstance(ref, LazyRef):
                raise TypeError(f"inputs must be LazyRef, got {type(ref)!r}")

    # -- content hashing (paper §4.3: hash from input hashes + spec + seed) --
    @property
    def signature(self) -> str:
        if self._signature is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(self.op_name.encode())
            h.update(self.op_class.encode())
            h.update(_hash_payload(self.spec).encode())
            h.update(repr(self.seed).encode())
            if not self.deterministic and self.seed is None:
                # unseeded non-determinism: unique signature → never CSE'd/cached
                h.update(str(self.uid).encode())
            for ref in self.inputs:
                h.update(ref.signature.encode())
            object.__setattr__(self, "_signature", h.hexdigest())
        return self._signature

    @property
    def cacheable(self) -> bool:
        return self.deterministic or self.seed is not None

    def out(self, index: int = 0) -> LazyRef:
        if not (0 <= index < self.n_outputs):
            raise IndexError(f"{self.op_name} has {self.n_outputs} outputs")
        return LazyRef(self, index)

    def with_inputs(self, inputs: Sequence[LazyRef]) -> "LazyOp":
        """Copy this op with new inputs (used by rewrites)."""
        return LazyOp(
            op_name=self.op_name, op_class=self.op_class, spec=dict(self.spec),
            inputs=tuple(inputs), seed=self.seed, n_outputs=self.n_outputs,
            deterministic=self.deterministic, annotations=dict(self.annotations),
        )

    def __repr__(self) -> str:  # compact for DAG dumps
        ins = ",".join(str(r.op.uid) for r in self.inputs)
        return f"<{self.op_name}#{self.uid}({ins})>"


# ---------------------------------------------------------------------------
# graph utilities
# ---------------------------------------------------------------------------

def toposort(sinks: Iterable[LazyRef]) -> list[LazyOp]:
    """Deterministic topological order of all ops reachable from ``sinks``."""
    order: list[LazyOp] = []
    state: dict[int, int] = {}  # uid -> 0 visiting / 1 done
    stack: list[tuple[LazyOp, bool]] = [(r.op, False) for r in sinks]
    while stack:
        op, processed = stack.pop()
        if processed:
            state[op.uid] = 1
            order.append(op)
            continue
        if op.uid in state:
            if state[op.uid] == 0:
                raise ValueError("cycle detected in pipeline DAG")
            continue
        state[op.uid] = 0
        stack.append((op, True))
        for ref in reversed(op.inputs):
            if ref.op.uid not in state:
                stack.append((ref.op, False))
            elif state[ref.op.uid] == 0:
                raise ValueError("cycle detected in pipeline DAG")
    return order


def consumers(ops: Sequence[LazyOp]) -> dict[int, list[LazyOp]]:
    out: dict[int, list[LazyOp]] = {op.uid: [] for op in ops}
    for op in ops:
        for ref in op.inputs:
            out.setdefault(ref.op.uid, []).append(op)
    return out


def rebuild(sinks: Sequence[LazyRef],
            replace: Callable[[LazyOp, tuple], Optional[LazyOp]]) -> list[LazyRef]:
    """Bottom-up DAG reconstruction.

    ``replace(op, new_inputs)`` returns a replacement op (or None to keep a
    copy with ``new_inputs``).  Node identity is memoized per uid so shared
    subgraphs stay shared.  Returns sinks pointing into the new DAG.
    """
    memo: dict[int, LazyOp] = {}

    for op in toposort(sinks):
        new_inputs = tuple(LazyRef(memo[r.op.uid], r.index) for r in op.inputs)
        new_op = replace(op, new_inputs)
        if new_op is None:
            if (all(a.op is b.op and a.index == b.index
                    for a, b in zip(new_inputs, op.inputs))
                    and len(new_inputs) == len(op.inputs)):
                new_op = op  # untouched — keep identity (and signature cache)
            else:
                new_op = op.with_inputs(new_inputs)
        memo[op.uid] = new_op
    return [LazyRef(memo[r.op.uid], r.index) for r in sinks]


def count_ops(sinks: Sequence[LazyRef]) -> int:
    return len(toposort(sinks))


def graphviz(sinks: Sequence[LazyRef]) -> str:
    """Debug dump (dot format)."""
    lines = ["digraph stratum {"]
    for op in toposort(sinks):
        label = f"{op.op_name}\\n{op.op_class}"
        lines.append(f'  n{op.uid} [label="{label}"];')
        for ref in op.inputs:
            lines.append(f"  n{ref.op.uid} -> n{op.uid};")
    lines.append("}")
    return "\n".join(lines)
