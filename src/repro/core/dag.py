"""Lazy operator DAG — stratum's declarative abstraction (paper §4.1).

Every computation in a pipeline is a :class:`LazyOp` node; edges are data
dependencies.  The DAG is control-flow free and lazily evaluated, mirroring
skrub's DataOps.  Nodes carry

* ``op_name``    — logical operator identity ("read", "standard_scaler", ...)
* ``op_class``   — broad category used by the optimizer (SOURCE/TRANSFORM/...)
* ``spec``       — hashable operator specification (hyperparameters)
* ``inputs``     — upstream :class:`LazyRef` handles
* ``seed``       — explicit randomness; ops without a seed that declare
                   themselves non-deterministic are excluded from caching
* ``signature``  — content hash H(input signatures, op_name, spec, seed),
                   cached on the node for O(1) equality (paper §4.3 Reuse).

The signature doubles as the cache key and the CSE equivalence class.

A second, coarser identity — the **structural signature** — hashes the DAG
*shape* modulo payload constants: op names, wiring, output arity and the
non-tunable parts of each spec, but not tunable hyperparameter values,
seeds, or constant payloads (only their shape/dtype).  Two AIDE refinements
that differ only in ``alpha`` share one structural signature, which is the
key the compiled-plan cache (``core/plan_cache.py``) uses to reuse a
whole-segment jitted program across thousands of near-identical agent
plans.  Which spec fields count as *tunable* is declared per op name via
:func:`declare_tunable` (impl modules register theirs next to the physical
implementations); a tunable field's value is hoisted to a runtime argument
of the compiled segment, so excluding it from the hash is sound.
"""

from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Optional, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# operator categories (paper §4.2 "operator type" metadata)
# ---------------------------------------------------------------------------

SOURCE = "source"          # data ingestion (read sharing applies)
TRANSFORM = "transform"    # stateless or fitted row/col transforms
PROJECT = "project"        # column selection (pushdown applies)
FILTER = "filter"          # row predicate (pushdown applies)
ESTIMATOR = "estimator"    # fit/predict model ops
EVAL = "eval"              # metrics / scoring
COMPOSITE = "composite"    # lowered by lowering.py (cv, table_vectorizer, ...)
CONST = "const"            # literal payloads (constant folding applies)
GENERIC = "generic"        # black-box UDF — optimizer must preserve as-is

OP_CLASSES = (SOURCE, TRANSFORM, PROJECT, FILTER, ESTIMATOR, EVAL, COMPOSITE,
              CONST, GENERIC)

_uid = itertools.count()

# ---------------------------------------------------------------------------
# tunable spec fields: hyperparameters excluded from the structural signature
# because the compiled-segment backend hoists them to runtime arguments
# ---------------------------------------------------------------------------

_TUNABLE_FIELDS: dict[str, frozenset] = {}


def declare_tunable(op_name: str, *fields: str) -> None:
    """Declare spec ``fields`` of ``op_name`` as tunable scalars: traced as
    arguments by compiled segments and ignored by structural signatures.
    Only declare fields whose value never changes trace *structure* (no
    shapes, no static loop bounds, no branch selectors)."""
    _TUNABLE_FIELDS[op_name] = (_TUNABLE_FIELDS.get(op_name, frozenset())
                                | frozenset(fields))


def tunable_fields(op_name: str) -> frozenset:
    return _TUNABLE_FIELDS.get(op_name, frozenset())


def _hash_payload(value: Any) -> str:
    """Stable content hash for spec payloads and constant data."""
    h = hashlib.blake2b(digest_size=16)

    def feed(v: Any) -> None:
        if isinstance(v, np.ndarray):
            h.update(b"nd")
            h.update(str(v.dtype).encode())
            h.update(str(v.shape).encode())
            h.update(np.ascontiguousarray(v).tobytes())
        elif isinstance(v, (list, tuple)):
            h.update(b"seq")
            for item in v:
                feed(item)
        elif isinstance(v, Mapping):
            h.update(b"map")
            for k in sorted(v):
                h.update(str(k).encode())
                feed(v[k])
        elif isinstance(v, (str, bytes)):
            h.update(b"s")
            h.update(v.encode() if isinstance(v, str) else v)
        elif isinstance(v, (int, float, bool, complex)) or v is None:
            h.update(repr(v).encode())
        elif hasattr(v, "tobytes"):  # jax arrays and friends
            h.update(b"arr")
            h.update(np.asarray(v).tobytes())
        else:
            # Fall back to repr; GENERIC ops should pass identifying specs.
            h.update(repr(v).encode())

    feed(value)
    return h.hexdigest()


def _hash_structural_payload(value: Any) -> str:
    """Like :func:`_hash_payload` but constants collapse to their *type
    skeleton*: arrays hash dtype+shape only, scalars hash their type — the
    payload bits that decide what a compiled program looks like, not what
    it computes on."""
    h = hashlib.blake2b(digest_size=16)

    def feed(v: Any) -> None:
        if isinstance(v, np.ndarray):
            h.update(b"nd")
            h.update(str(v.dtype).encode())
            h.update(str(v.shape).encode())
        elif isinstance(v, (list, tuple)):
            h.update(b"seq")
            for item in v:
                feed(item)
        elif isinstance(v, Mapping):
            h.update(b"map")
            for k in sorted(v):
                h.update(str(k).encode())
                feed(v[k])
        elif isinstance(v, (int, float, bool, complex)) or v is None:
            h.update(type(v).__name__.encode())
        elif hasattr(v, "shape") and hasattr(v, "dtype"):
            h.update(b"arr")
            h.update(str(v.dtype).encode())
            h.update(str(v.shape).encode())
        else:
            h.update(repr(v).encode())

    feed(value)
    return h.hexdigest()


@dataclass(frozen=True)
class LazyRef:
    """A handle to output ``index`` of ``op`` — the DAG's edge type."""

    op: "LazyOp"
    index: int = 0

    @property
    def signature(self) -> str:
        return f"{self.op.signature}:{self.index}"


@dataclass(eq=False)
class LazyOp:
    op_name: str
    op_class: str
    spec: Mapping[str, Any] = field(default_factory=dict)
    inputs: tuple = ()  # tuple[LazyRef, ...]
    seed: Optional[int] = None
    n_outputs: int = 1
    deterministic: bool = True
    annotations: Mapping[str, Any] = field(default_factory=dict)  # §3 co-design
    uid: int = field(default_factory=lambda: next(_uid))
    # filled by the metadata pass (metadata.py)
    meta: Optional[Any] = None
    _signature: Optional[str] = field(default=None, repr=False)
    _structural_signature: Optional[str] = field(default=None, repr=False)

    def __post_init__(self) -> None:
        if self.op_class not in OP_CLASSES:
            raise ValueError(f"unknown op_class {self.op_class!r}")
        for ref in self.inputs:
            if not isinstance(ref, LazyRef):
                raise TypeError(f"inputs must be LazyRef, got {type(ref)!r}")

    # -- content hashing (paper §4.3: hash from input hashes + spec + seed) --
    @property
    def signature(self) -> str:
        if self._signature is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(self.op_name.encode())
            h.update(self.op_class.encode())
            h.update(_hash_payload(self.spec).encode())
            h.update(repr(self.seed).encode())
            if not self.deterministic and self.seed is None:
                # unseeded non-determinism: unique signature → never CSE'd/cached
                h.update(str(self.uid).encode())
            for ref in self.inputs:
                h.update(ref.signature.encode())
            object.__setattr__(self, "_signature", h.hexdigest())
        return self._signature

    @property
    def structural_signature(self) -> str:
        """Hash of the op's *shape*: name, class, arity, wiring and the
        non-tunable spec entries — but not tunable hyperparameter values,
        the seed value, or constant payloads (shape/dtype only).  Two ops
        share a structural signature iff a compiled program traced for one
        (with tunables hoisted to arguments and constants fed as inputs)
        is reusable verbatim for the other."""
        if self._structural_signature is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(self.op_name.encode())
            h.update(self.op_class.encode())
            h.update(str(self.n_outputs).encode())
            tun = tunable_fields(self.op_name)
            if self.op_class == CONST:
                # const payloads reach compiled segments as runtime inputs,
                # never baked constants — only their type skeleton matters
                h.update(_hash_structural_payload(self.spec).encode())
            else:
                pruned = {k: v for k, v in self.spec.items() if k not in tun}
                h.update(_hash_payload(pruned).encode())
                # which tunables are present still shapes the hoisted
                # argument list, so their *names* (not values) are hashed
                h.update(",".join(sorted(tun & set(self.spec))).encode())
            h.update(b"s1" if self.seed is not None else b"s0")
            h.update(b"d1" if self.deterministic else b"d0")
            for ref in self.inputs:
                h.update(ref.op.structural_signature.encode())
                h.update(str(ref.index).encode())
            object.__setattr__(self, "_structural_signature", h.hexdigest())
        return self._structural_signature

    @property
    def cacheable(self) -> bool:
        return self.deterministic or self.seed is not None

    def out(self, index: int = 0) -> LazyRef:
        if not (0 <= index < self.n_outputs):
            raise IndexError(f"{self.op_name} has {self.n_outputs} outputs")
        return LazyRef(self, index)

    def with_inputs(self, inputs: Sequence[LazyRef]) -> "LazyOp":
        """Copy this op with new inputs (used by rewrites)."""
        return LazyOp(
            op_name=self.op_name, op_class=self.op_class, spec=dict(self.spec),
            inputs=tuple(inputs), seed=self.seed, n_outputs=self.n_outputs,
            deterministic=self.deterministic, annotations=dict(self.annotations),
        )

    def __repr__(self) -> str:  # compact for DAG dumps
        ins = ",".join(str(r.op.uid) for r in self.inputs)
        return f"<{self.op_name}#{self.uid}({ins})>"


# ---------------------------------------------------------------------------
# graph utilities
# ---------------------------------------------------------------------------

def toposort(sinks: Iterable[LazyRef]) -> list[LazyOp]:
    """Deterministic topological order of all ops reachable from ``sinks``."""
    order: list[LazyOp] = []
    state: dict[int, int] = {}  # uid -> 0 visiting / 1 done
    stack: list[tuple[LazyOp, bool]] = [(r.op, False) for r in sinks]
    while stack:
        op, processed = stack.pop()
        if processed:
            state[op.uid] = 1
            order.append(op)
            continue
        if op.uid in state:
            if state[op.uid] == 0:
                raise ValueError("cycle detected in pipeline DAG")
            continue
        state[op.uid] = 0
        stack.append((op, True))
        for ref in reversed(op.inputs):
            if ref.op.uid not in state:
                stack.append((ref.op, False))
            elif state[ref.op.uid] == 0:
                raise ValueError("cycle detected in pipeline DAG")
    return order


def consumers(ops: Sequence[LazyOp]) -> dict[int, list[LazyOp]]:
    out: dict[int, list[LazyOp]] = {op.uid: [] for op in ops}
    for op in ops:
        for ref in op.inputs:
            out.setdefault(ref.op.uid, []).append(op)
    return out


def rebuild(sinks: Sequence[LazyRef],
            replace: Callable[[LazyOp, tuple], Optional[LazyOp]]) -> list[LazyRef]:
    """Bottom-up DAG reconstruction.

    ``replace(op, new_inputs)`` returns a replacement op (or None to keep a
    copy with ``new_inputs``).  Node identity is memoized per uid so shared
    subgraphs stay shared.  Returns sinks pointing into the new DAG.
    """
    memo: dict[int, LazyOp] = {}

    for op in toposort(sinks):
        new_inputs = tuple(LazyRef(memo[r.op.uid], r.index) for r in op.inputs)
        new_op = replace(op, new_inputs)
        if new_op is None:
            if (all(a.op is b.op and a.index == b.index
                    for a, b in zip(new_inputs, op.inputs))
                    and len(new_inputs) == len(op.inputs)):
                new_op = op  # untouched — keep identity (and signature cache)
            else:
                new_op = op.with_inputs(new_inputs)
        memo[op.uid] = new_op
    return [LazyRef(memo[r.op.uid], r.index) for r in sinks]


def count_ops(sinks: Sequence[LazyRef]) -> int:
    return len(toposort(sinks))


def structural_signature(sinks: Sequence[LazyRef]) -> str:
    """Structural signature of a whole plan: per-sink structural signatures
    in sink order (each already encodes its subgraph recursively).  Plans
    differing only in payload constants / tunable hyperparameters collide;
    plans differing in topology, op vocabulary or output wiring do not."""
    h = hashlib.blake2b(digest_size=16)
    for ref in sinks:
        h.update(ref.op.structural_signature.encode())
        h.update(str(ref.index).encode())
    return h.hexdigest()


def graphviz(sinks: Sequence[LazyRef]) -> str:
    """Debug dump (dot format)."""
    lines = ["digraph stratum {"]
    for op in toposort(sinks):
        label = f"{op.op_name}\\n{op.op_class}"
        lines.append(f'  n{op.uid} [label="{label}"];')
        for ref in op.inputs:
            lines.append(f"  n{ref.op.uid} -> n{op.uid};")
    lines.append("}")
    return "\n".join(lines)
