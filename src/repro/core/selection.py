"""Operator selection (paper §4.2): tiered logical→physical hierarchy with
cost-based late binding.

The paper's hierarchy is ``abstract class → logical op → physical leaf``
(e.g. ReadOp → ReadPolars/ReadPandas).  Here each logical op name maps to a
set of :class:`PhysicalImpl` entries, one per backend tier:

* ``python``  — naive interpreted implementation (the Pandas/scikit-learn
                analogue: eager NumPy with the usual temporaries and copies),
* ``jax``     — jnp implementation, fused into whole-wave ``jit`` programs by
                the runtime (the "native / Rust kernel" analogue on TPU),
* ``pallas``  — hand-tiled Pallas TPU kernel for hot-spot ops
                (flash-attention, rmsnorm, ...; selected on TPU targets).

Selection minimizes estimated execution time subject to a per-device memory
budget, using metadata collected by metadata.py (paper: "minimize execution
time under memory constraints").  Fidelity annotations (paper §3 co-design)
can force cheaper approximate implementations during early exploration —
e.g. ``svd`` → ``svd_sketch`` (Frequent-Directions-style) when the pipeline
is annotated ``stage=explore``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax

from .dag import LazyOp, LazyRef, toposort

# ---------------------------------------------------------------------------
# backend profiles: effective rates used by the cost model.  Rates are
# relative (calibrated by benchmarks/micro_selection.py); absolute accuracy is
# not required — only the *ordering* of candidate implementations matters.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BackendProfile:
    name: str
    flops_per_s: float
    bytes_per_s: float
    dispatch_overhead_s: float  # per-op fixed cost (interpreter / launch)
    mem_multiplier: float       # working-set inflation vs metadata estimate


BACKENDS: dict[str, BackendProfile] = {
    # interpreted tier: per-op dispatch dominates small ops; temporaries
    # inflate memory (Pandas-style copies).
    "python": BackendProfile("python", 2e9, 2e9, 50e-6, 3.0),
    # XLA-compiled tier: fused, no per-op dispatch once inside a jit wave.
    "jax": BackendProfile("jax", 50e9, 10e9, 1e-6, 1.5),
    # Pallas tier: only hot-spot ops register implementations here.
    "pallas": BackendProfile("pallas", 197e12, 819e9, 2e-6, 1.1),
}


@dataclass
class PhysicalImpl:
    op_name: str
    backend: str
    fn: Callable[[LazyOp, Sequence[Any]], tuple]
    # override cost terms; default derives from op.meta
    flops_fn: Optional[Callable[[LazyOp], float]] = None
    bytes_fn: Optional[Callable[[LazyOp], float]] = None
    fidelity: str = "exact"      # "exact" | "approx"
    platforms: tuple = ("cpu", "tpu", "gpu")
    vmappable: bool = False      # homogeneous variants can batch via vmap
    # pure jnp function of (traced inputs, spec): safe to trace into a
    # whole-segment jit program by the JaxSegmentBackend.  False for impls
    # doing IO, host-side numpy, or data-dependent control flow.
    traceable: bool = False

    def est_time(self, op: LazyOp) -> float:
        prof = BACKENDS[self.backend]
        flops = self.flops_fn(op) if self.flops_fn else (
            op.meta.flops if op.meta else 0.0)
        nbytes = self.bytes_fn(op) if self.bytes_fn else (
            float(op.meta.peak_bytes) if op.meta else 0.0)
        return (flops / prof.flops_per_s + nbytes / prof.bytes_per_s
                + prof.dispatch_overhead_s)

    def est_mem(self, op: LazyOp) -> int:
        prof = BACKENDS[self.backend]
        base = op.meta.peak_bytes if op.meta else 0
        return int(base * prof.mem_multiplier)


_REGISTRY: dict[str, list[PhysicalImpl]] = {}


def register_impl(op_name: str, backend: str, *, flops_fn=None, bytes_fn=None,
                  fidelity: str = "exact", platforms=("cpu", "tpu", "gpu"),
                  vmappable: bool = False, traceable: bool = False):
    def deco(fn):
        _REGISTRY.setdefault(op_name, []).append(PhysicalImpl(
            op_name=op_name, backend=backend, fn=fn, flops_fn=flops_fn,
            bytes_fn=bytes_fn, fidelity=fidelity, platforms=platforms,
            vmappable=vmappable, traceable=traceable))
        return fn
    return deco


def impls_for(op_name: str) -> list[PhysicalImpl]:
    return _REGISTRY.get(op_name, [])


# ---------------------------------------------------------------------------
# variant batching (beyond-paper, §Perf H3.4): ops in one wave that differ
# only in scalar hyperparameters execute as ONE vmapped program — the MXU/
# SIMD analogue of the paper's inter-operator parallelism for HPO grids.
# ---------------------------------------------------------------------------

_VMAP_GROUPS: dict[str, tuple] = {}   # op_name -> (key_fn, batch_fn)


def register_vmap_group(op_name: str, key_fn, batch_fn) -> None:
    """key_fn(op) -> hashable group key (must include input signatures);
    batch_fn(ops, inputs) -> list of per-op output tuples."""
    _VMAP_GROUPS[op_name] = (key_fn, batch_fn)


def vmap_group_for(op_name: str):
    return _VMAP_GROUPS.get(op_name)


def reference_impl(op_name: str) -> Optional[PhysicalImpl]:
    """The exact 'python'-tier impl — used by Base mode and constant folding."""
    for impl in _REGISTRY.get(op_name, []):
        if impl.backend == "python" and impl.fidelity == "exact":
            return impl
    for impl in _REGISTRY.get(op_name, []):
        if impl.fidelity == "exact":
            return impl
    return None


# ---------------------------------------------------------------------------
# selection pass
# ---------------------------------------------------------------------------


@dataclass
class SelectionConfig:
    platform: str = ""                 # default: jax.default_backend()
    memory_budget_bytes: int = 8 << 30
    allowed_backends: tuple = ("python", "jax", "pallas")
    honor_fidelity_annotations: bool = True

    def resolved_platform(self) -> str:
        return self.platform or jax.default_backend()


def select(sinks: Sequence[LazyRef], config: SelectionConfig
           ) -> dict[str, PhysicalImpl]:
    """Pick one PhysicalImpl per op signature.  Late binding: the decision is
    stored in a side table (signature → impl), not burned into the DAG, so
    re-planning under different budgets/platforms needs no graph rebuild."""
    platform = config.resolved_platform()
    chosen: dict[str, PhysicalImpl] = {}
    for op in toposort(sinks):
        cands = [i for i in _REGISTRY.get(op.op_name, [])
                 if i.backend in config.allowed_backends
                 and platform in i.platforms]
        if not cands:
            continue  # runtime falls back to the op's own callable / error
        want_approx = (config.honor_fidelity_annotations
                       and op.annotations.get("stage") == "explore")
        if not want_approx:
            exact = [i for i in cands if i.fidelity == "exact"]
            cands = exact or cands
        fitting = [i for i in cands
                   if i.est_mem(op) <= config.memory_budget_bytes]
        pool = fitting or cands  # nothing fits: still pick cheapest-mem
        if not fitting:
            pool = sorted(cands, key=lambda i: i.est_mem(op))[:1]
        # under stage=explore, break est-time ties toward approx impls
        best = min(pool, key=lambda i: (i.est_time(op),
                                        0 if (want_approx
                                              and i.fidelity == "approx")
                                        else 1))
        chosen[op.signature] = best
    return chosen
