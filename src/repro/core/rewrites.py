"""Logical rewrites (paper §4.2): CSE / read sharing, projection pushdown,
constant folding, DCE — applied after metadata collection, preserving semantic
equivalence.

Rewrite ordering is workload-dependent (paper: "delaying projection pushdown
for higher CSE opportunities"); the default pipeline is therefore
``cse → constant_fold → cse → project_pushdown → cse`` — CSE first maximizes
sharing across fused pipelines *before* pushdown specializes subgraphs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .dag import (CONST,
                  GENERIC,
                  LazyOp,
                  LazyRef,
                  PROJECT,
                  SOURCE,
                  TRANSFORM,
                  count_ops,
                  rebuild,
                  toposort)

# ---------------------------------------------------------------------------
# structural properties: which transforms commute with column projection
# (paper: "structural properties (e.g. selection and projection)")
# ---------------------------------------------------------------------------

_COLUMNWISE: set[str] = set()     # op(x)[:, cols] == op(x[:, cols])
_ROW_PRESERVING: set[str] = set() # output rows == input rows (filter pushdown)


def declare_columnwise(*op_names: str) -> None:
    _COLUMNWISE.update(op_names)


def declare_row_preserving(*op_names: str) -> None:
    _ROW_PRESERVING.update(op_names)


@dataclass
class RewriteStats:
    cse_merged: int = 0
    reads_shared: int = 0
    constants_folded: int = 0
    projections_pushed: int = 0
    ops_before: int = 0
    ops_after: int = 0

    def merge(self, other: "RewriteStats") -> None:
        self.cse_merged += other.cse_merged
        self.reads_shared += other.reads_shared
        self.constants_folded += other.constants_folded
        self.projections_pushed += other.projections_pushed


# ---------------------------------------------------------------------------
# CSE + read sharing: hash-consing on the content signature
# ---------------------------------------------------------------------------

def cse(sinks: Sequence[LazyRef], stats: Optional[RewriteStats] = None
        ) -> list[LazyRef]:
    """Merge ops with equal signatures.  Unseeded non-deterministic ops have
    unique signatures by construction (dag.py), so they are never merged —
    the paper's correctness condition for reuse."""
    canonical: dict[str, LazyOp] = {}

    def replace(op: LazyOp, new_inputs: tuple) -> Optional[LazyOp]:
        cand = op if all(a.op is b.op for a, b in zip(new_inputs, op.inputs)) \
            else op.with_inputs(new_inputs)
        sig = cand.signature
        if sig in canonical:
            if stats is not None:
                if op.op_class == SOURCE:
                    stats.reads_shared += 1
                else:
                    stats.cse_merged += 1
            return canonical[sig]
        canonical[sig] = cand
        return cand

    return rebuild(sinks, replace)


# ---------------------------------------------------------------------------
# constant folding: evaluate deterministic ops over CONST inputs at plan time
# ---------------------------------------------------------------------------

_MAX_FOLD_BYTES = 1 << 20  # never fold anything producing > 1 MiB


def constant_fold(sinks: Sequence[LazyRef], execute_ref,
                  stats: Optional[RewriteStats] = None) -> list[LazyRef]:
    """``execute_ref(op, input_values) -> tuple(outputs)`` is the reference
    backend evaluator (injected to avoid a core→runtime import cycle)."""

    def replace(op: LazyOp, new_inputs: tuple) -> Optional[LazyOp]:
        if (op.op_class in (SOURCE, GENERIC) or not op.deterministic
                or op.op_class == CONST or not new_inputs):
            return None
        if not all(r.op.op_class == CONST for r in new_inputs):
            return None
        if op.meta is not None and op.meta.out_bytes > _MAX_FOLD_BYTES:
            return None
        values = [np.asarray(r.op.spec["value"]) for r in new_inputs]
        try:
            outs = execute_ref(op, values)
        except Exception:
            return None  # not foldable — leave for runtime
        if stats is not None:
            stats.constants_folded += 1
        if op.n_outputs == 1:
            return LazyOp("const", CONST, spec={"value": np.asarray(outs[0])})
        # multi-output folding not supported; keep op
        return None

    return rebuild(sinks, replace)


# ---------------------------------------------------------------------------
# projection pushdown: project(columnwise_op(x)) -> columnwise_op(project(x))
# ---------------------------------------------------------------------------

def project_pushdown(sinks: Sequence[LazyRef],
                     stats: Optional[RewriteStats] = None) -> list[LazyRef]:

    def replace(op: LazyOp, new_inputs: tuple) -> Optional[LazyOp]:
        if op.op_class != PROJECT or len(new_inputs) != 1:
            return None
        child = new_inputs[0].op
        movable = (child.op_class == TRANSFORM
                   and child.op_name in _COLUMNWISE
                   and child.n_outputs == 1
                   and len(child.inputs) == 1)
        if not movable:
            return None
        # project(T(x)) == T(project(x)) for columnwise T
        pushed = op.with_inputs(child.inputs)
        new_child = child.with_inputs((pushed.out(0),))
        if stats is not None:
            stats.projections_pushed += 1
        return new_child

    # iterate to fixpoint (a projection can sink through a chain)
    prev = -1
    cur = count_ops(sinks)
    out = list(sinks)
    while cur != prev:
        out = rebuild(out, replace)
        prev, cur = cur, count_ops(out)
    return out


# ---------------------------------------------------------------------------
# API-aware rewrite: boosting prefix sharing (beyond-paper; the paper's
# "API-aware rewrites" category §4.2).  A k-tree GBT is a strict prefix of
# the K>k-tree GBT with otherwise identical spec/inputs/seed — so a grid
# over n_trees needs ONE fit of max(n_trees); smaller models are extracted
# with a cheap `gbt_prefix` op.
# ---------------------------------------------------------------------------

def gbt_prefix_sharing(sinks: Sequence[LazyRef],
                       stats: Optional[RewriteStats] = None
                       ) -> list[LazyRef]:
    from .dag import toposort as _topo

    groups: dict[tuple, list[LazyOp]] = {}
    for op in _topo(sinks):
        if op.op_name != "gbt_fit":
            continue
        key_spec = tuple(sorted((k, v) for k, v in op.spec.items()
                                if k != "n_trees"))
        key = (key_spec, op.seed,
               tuple(r.signature for r in op.inputs))
        groups.setdefault(key, []).append(op)

    replacements: dict[int, LazyOp] = {}
    for ops_ in groups.values():
        if len(ops_) < 2:
            continue
        biggest = max(ops_, key=lambda o: o.spec["n_trees"])
        for op in ops_:
            if op is biggest:
                continue
            replacements[op.uid] = op  # marker; rebuilt below
        for op in ops_:
            if op is not biggest and stats is not None:
                stats.cse_merged += 1

    if not replacements:
        return list(sinks)

    by_key: dict[int, LazyOp] = {}
    for ops_ in groups.values():
        biggest = max(ops_, key=lambda o: o.spec["n_trees"])
        for op in ops_:
            if op is not biggest:
                by_key[op.uid] = biggest

    def replace(op: LazyOp, new_inputs: tuple) -> Optional[LazyOp]:
        big = by_key.get(op.uid)
        if big is None:
            return None
        # rebuild the big fit over the (possibly rewritten) inputs
        big_new = big.with_inputs(new_inputs)
        return LazyOp("gbt_prefix", TRANSFORM,
                      spec={"n_trees": op.spec["n_trees"]},
                      inputs=(big_new.out(0),))

    return rebuild(sinks, replace)


# ---------------------------------------------------------------------------
# the default rewrite pipeline
# ---------------------------------------------------------------------------

def optimize_logical(sinks: Sequence[LazyRef], execute_ref=None,
                     enable: Sequence[str] = ("cse", "fold", "pushdown",
                                              "gbt_prefix"),
                     ) -> tuple[list[LazyRef], RewriteStats]:
    stats = RewriteStats(ops_before=count_ops(sinks))
    out = list(sinks)
    if "cse" in enable:
        out = cse(out, stats)
    if "fold" in enable and execute_ref is not None:
        out = constant_fold(out, execute_ref, stats)
        out = cse(out, stats)
    if "pushdown" in enable:
        out = project_pushdown(out, stats)
        out = cse(out, stats)
    if "gbt_prefix" in enable:
        out = gbt_prefix_sharing(out, stats)
        out = cse(out, stats)
    stats.ops_after = count_ops(out)
    return out, stats
