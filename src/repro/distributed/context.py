"""Activation-sharding context.

Model code is written once, sharding-agnostic; layers annotate activations
with *logical* names (``"act_btd"``, ``"kv_cache"``, ...).  When a
:class:`ShardingContext` is active (set by the launcher / dry-run), the
annotation becomes ``jax.lax.with_sharding_constraint`` with the policy's
PartitionSpec; with no context it is a no-op (CPU tests).

This is the standard logical-axis-rules pattern (MaxText/T5X) reduced to
its essentials.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@dataclass
class ShardingContext:
    mesh: Mesh
    rules: dict                    # logical name -> PartitionSpec
    ep_axis: Optional[str] = None  # expert-parallel mesh axis (MoE shard_map)
    sp_axis: Optional[str] = None  # sequence-parallel axis (decode KV shards)
    dp_axes: tuple = ()            # batch axes (MoE local-dispatch shard_map
                                   # when EP is off — see models/moe.py)

    def spec(self, name: str) -> Optional[P]:
        return self.rules.get(name)


def set_context(ctx: Optional[ShardingContext]) -> None:
    _state.ctx = ctx


def current_context() -> Optional[ShardingContext]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_context(ctx: ShardingContext):
    prev = current_context()
    set_context(ctx)
    try:
        yield ctx
    finally:
        set_context(prev)


def shard(x, name: str):
    """Annotate activation ``x`` with the logical sharding ``name``."""
    ctx = current_context()
    if ctx is None:
        return x
    spec = ctx.spec(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))
