"""Distributed flash-decode: single-token attention over a sequence-sharded
KV cache without gathering it (§Perf H4).

Baseline GSPMD behaviour for decode with the cache sharded (batch→data,
seq→model): the attention einsum forces an all-gather of the WHOLE cache
shard per layer — 16.9 GB/step/device of the 22 GB decode collective total
for llama3-405b/32k/128 (measured from the partitioned HLO).

Instead, each model-rank computes *partial* attention over its local
S/tp cache slice and the ranks merge O(B·H·dh)-sized statistics:

    m_i, l_i, o_i   = local max / sumexp / unnormalized context
    M               = pmax_i m_i
    w_i             = exp(m_i − M)
    out             = Σ_i o_i·w_i  /  Σ_i l_i·w_i          (psum, exact)

— the same log-sum-exp merge the Pallas decode kernel emits (`return_lse`),
lifted to the mesh.  The new token's K/V are also written inside the same
manual region (only the owning shard writes), which removes the
"involuntary full rematerialization" resharding XLA warned about.

Merge traffic: two psums + one pmax of (B, H, dh)-sized tensors per layer
(~1 MB) versus the 134 MB/layer cache gather — ≈100× less.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import shard_map

from .context import current_context


def seq_sharded_decode(q, k_cache, v_cache, cache_len, new_k, new_v,
                       scale: float):
    """q: (B, Hq, dh) — post-rope query for the new token.
    k_cache/v_cache: (B, S, Hkv, dh), seq-sharded over `model`.
    cache_len: (B,) int32 — per-lane current lengths.
    new_k/new_v: (B, Hkv, dh) — the new token's K/V to insert at cache_len.
    Returns (out (B, Hq, dh), k_cache, v_cache)."""
    ctx = current_context()
    mesh = ctx.mesh
    tp = mesh.shape["model"]
    B, S, Hkv, dh = k_cache.shape
    Hq = q.shape[1]
    assert S % tp == 0
    s_loc = S // tp
    group = Hq // Hkv

    def local(qf, kc, vc, lens, nk, nv):
        idx = jax.lax.axis_index("model")
        lo = idx * s_loc
        lane = jnp.arange(B)
        # -- insert the new token on the owning shard only ----------------
        pos_local = lens - lo                       # (B,)
        owns = (pos_local >= 0) & (pos_local < s_loc)
        wpos = jnp.clip(pos_local, 0, s_loc - 1)
        kc = jnp.where(
            owns[:, None, None, None],
            kc.at[lane, wpos].set(nk.astype(kc.dtype), mode="drop"), kc)
        vc = jnp.where(
            owns[:, None, None, None],
            vc.at[lane, wpos].set(nv.astype(vc.dtype), mode="drop"), vc)

        # -- partial attention over the local slice -----------------------
        kq = jnp.repeat(kc, group, axis=2)          # (B, s_loc, Hq, dh)
        vq = jnp.repeat(vc, group, axis=2)
        s = jnp.einsum("bhd,bshd->bhs", qf.astype(jnp.float32),
                       kq.astype(jnp.float32)) * scale
        valid = (lo + jnp.arange(s_loc))[None, None, :] \
            < (lens + 1)[:, None, None]
        s = jnp.where(valid, s, -1e30)
        m = s.max(axis=-1)                          # (B, Hq)
        p = jnp.exp(s - m[..., None])
        p = jnp.where(valid, p, 0.0)
        l = p.sum(axis=-1)
        o = jnp.einsum("bhs,bshd->bhd", p, vq.astype(jnp.float32))

        # -- LSE merge across shards --------------------------------------
        M = jax.lax.pmax(m, "model")
        w = jnp.exp(m - M)
        l_tot = jax.lax.psum(l * w, "model")
        o_tot = jax.lax.psum(o * w[..., None], "model")
        out = o_tot / jnp.maximum(l_tot, 1e-30)[..., None]
        return out, kc, vc

    # f32 at the boundary for replicated operands (XLA-CPU bf16 promotion
    # abort — see distributed/vocab_ce.py); the cache stays in its dtype
    # (sharded operands don't hit the replication all-reduce path).
    out, kc, vc = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, "model"), P(None, "model"), P(), P(), P()),
        out_specs=(P(), P(None, "model"), P(None, "model")),
        axis_names={"model"}, check_vma=False,
    )(q.astype(jnp.float32), k_cache, v_cache,
      cache_len.astype(jnp.int32), new_k.astype(jnp.float32),
      new_v.astype(jnp.float32))
    return out.astype(q.dtype), kc, vc
