"""repro.distributed — mesh policy, sharding rules, collective helpers."""

from .context import ShardingContext, current_context, set_context, shard

__all__ = ["ShardingContext", "current_context", "set_context", "shard"]
