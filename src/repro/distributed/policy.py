"""Sharding policy: maps (ModelConfig, ShapeConfig, Mesh) → parameter
PartitionSpecs, activation rules, and runtime knobs (DESIGN.md §5).

Decisions (all derived, all overridable for §Perf experiments):

* TP: matmul dims sharded over ``model`` when d_model ≥ TP_MIN_DMODEL
  (small models replicate weights — TP latency isn't worth it at 1–3B);
* FSDP: parameters *additionally* sharded over ``data`` when the model
  exceeds FSDP_MIN_PARAMS (param+optimizer state must fit 16 GB/chip);
* EP: MoE experts always sharded over ``model`` (the MoE layer's shard_map
  requires it);
* SP: the residual stream's sequence dim sharded over ``model`` for large
  models in training (bounds the per-layer remat checkpoints — a 126-layer
  16384-wide model saves 16.9 GB/chip of layer inputs without SP);
* KV cache: batch over ``data``, sequence over ``model`` (flash-decode
  sharding — a 405B/32k/128-batch cache is 2.2 TB);
* microbatching: gradient accumulation count chosen so one microbatch's
  activations fit alongside params+optimizer.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig
from .context import ShardingContext

TP_MIN_DMODEL = 3584
FSDP_MIN_PARAMS = 10e9
SP_MIN_DMODEL = 6144


@dataclass
class Policy:
    mesh: Mesh
    dp_axes: tuple                 # batch axes, e.g. ("data",) or ("pod","data")
    tp: bool
    fsdp: bool
    sp: bool
    ep_axis: Optional[str]
    microbatches: int
    rules: dict = field(default_factory=dict)
    # dp spec for THIS shape's batch dim (None when batch < dp, e.g. B=1)
    batch_dp: object = None

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def context(self) -> ShardingContext:
        return ShardingContext(mesh=self.mesh, rules=self.rules,
                               ep_axis=self.ep_axis, dp_axes=self.dp_axes)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def make_policy(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                *, tp: Optional[bool] = None, fsdp: Optional[bool] = None,
                sp: Optional[bool] = None,
                microbatches: Optional[int] = None,
                dp_over_model: bool = False) -> Policy:
    n_params = cfg.params_count()
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if dp_over_model:
        # small-model remesh (§Perf H2): the model axis contributes nothing
        # to a ≤few-B-param model except replicated compute — fold it into
        # the batch axes (pure DP over all chips, ZeRO over all chips)
        dp_axes = dp_axes + ("model",)
        tp = False
        sp = False if sp is None else sp
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    tp = tp if tp is not None else cfg.d_model >= TP_MIN_DMODEL
    fsdp = fsdp if fsdp is not None else n_params >= FSDP_MIN_PARAMS
    # SP always on in training: per-layer remat checkpoints of the residual
    # stream are the dominant live buffer; sharding S over `model` cuts them
    # 16× (found via buffer-assignment analysis, see EXPERIMENTS.md §Dry-run)
    sp = sp if sp is not None else shape.kind == "train"
    ep_axis = ("model" if cfg.family == "moe" and not dp_over_model
               else None)

    if microbatches is None:
        if shape.kind == "train":
            # bound live activations: ≤ 1 sequence/shard/microbatch for
            # ≥30B models, ≤ 2 below
            per_shard = max(1, shape.global_batch // dp_size)
            microbatches = per_shard if n_params >= 30e9 else \
                max(1, per_shard // 2)
        else:
            microbatches = 1

    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    batch_dp = dp
    if shape.kind == "decode" and shape.global_batch < dp_size:
        batch_dp = None              # long_500k batch=1: nothing to shard

    model_if_tp = "model" if tp else None
    seq_model = "model" if sp else None
    # prefill emits per-layer K/V destined for a seq-sharded cache; keep the
    # collected tensors seq-sharded from the start (DESIGN.md §5)
    kv_seq = "model" if shape.kind in ("prefill", "decode") else None

    rules = {
        # residual stream (B, S, D)
        "act_btd": P(batch_dp, seq_model, None),
        # q / kv projections (B, S, H, dh)
        "act_bshd": P(batch_dp, None, model_if_tp, None),
        "act_bskd": P(batch_dp, kv_seq, None, None),
        "act_bshd_flat": P(batch_dp, None, model_if_tp),
        # mlp hidden (B, S, F)
        "act_btf": P(batch_dp, None, model_if_tp),
        # mamba inner stream (B, S, d_inner)
        "act_btd_inner": P(batch_dp, None, model_if_tp),
        # decode KV cache (B, S, Hkv, dh): batch over data, seq over model
        "kv_cache": P(batch_dp, "model", None, None),
        "kv_cache_stacked": P(None, batch_dp, "model", None, None),
    }

    return Policy(mesh=mesh, dp_axes=dp_axes, tp=tp, fsdp=fsdp, sp=sp,
                  ep_axis=ep_axis, microbatches=microbatches, rules=rules,
                  batch_dp=batch_dp)


# ---------------------------------------------------------------------------
# parameter PartitionSpecs by tree path
# ---------------------------------------------------------------------------

def _param_spec(path: str, leaf, pol: Policy, cfg: ModelConfig) -> P:
    """path: '/'-joined dict keys, e.g. 'layers/attn/wq'."""
    ndim = len(leaf.shape)
    lead = ndim - 2                 # stacked layer/group dims
    if pol.fsdp:
        # ZeRO/FSDP shard axis: "data", or all dp axes when the model axis
        # was folded into the batch (dp_over_model)
        fsdp = (pol.dp if "model" in pol.dp_axes else pol.dp_axes[-1])
    else:
        fsdp = None
    tp = "model" if pol.tp else None
    name = path.split("/")[-1]

    def spec(*dims):
        return P(*([None] * lead + list(dims)))

    # vocab-parallel embedding/head, unless `model` is already a dp axis
    vocab_tp = None if (isinstance(fsdp, tuple) and "model" in fsdp) \
        else "model"
    if name in ("w",) or "norm" in path:                 # norm scales
        return P(*([None] * ndim))
    if name == "tok":
        return P(vocab_tp, fsdp)
    if name == "lm_head":
        return P(fsdp, vocab_tp)
    if name == "router":
        return spec(None, None)
    if ("/moe/" in path or path.startswith("moe/")) and "dense" not in path:
        # expert-stacked weights (arctic's dense residual branch falls
        # through to the plain-MLP rules below); EP axis only when expert
        # parallelism is active (dp_over_model disables it)
        ep = pol.ep_axis
        if name in ("w_gate", "w_up"):                   # (E, D, Fe)
            return P(*([None] * (ndim - 3) + [ep, None, fsdp]))
        if name == "w_down":                             # (E, Fe, D)
            return P(*([None] * (ndim - 3) + [ep, fsdp, None]))
    if name in ("wq", "wk", "wv"):                       # (D, H·dh)
        return spec(fsdp, tp)
    if name == "wo":                                     # (H·dh, D)
        return spec(tp, fsdp)
    if name in ("bq", "bk", "bv"):
        return P(*([None] * (ndim - 1) + [tp]))
    if name in ("w_gate", "w_up"):                       # mlp (D, F)
        return spec(fsdp, tp)
    if name == "w_down":                                 # (F, D)
        return spec(tp, fsdp)
    # mamba / xlstm projections
    if name == "w_in":                                   # (D, 2di+2N+H)
        return spec(fsdp, tp)
    if name == "w_out":                                  # (di, D) / (D, D)
        return spec(tp, fsdp)
    if name in ("w_q", "w_k", "w_v"):                    # (di, di)
        return spec(fsdp, tp)
    if name == "w_x":                                    # (D, 4D)
        return spec(fsdp, tp)
    if name == "w_gates":
        return spec(None, None)
    if name == "r":                                      # (H, dh, 4dh)
        return P(*([None] * ndim))
    if name == "w_conv":                                 # (k, di)
        return P(*([None] * (ndim - 1) + [tp]))
    # small vectors (dt_bias, a_log, d_skip, b, b_gates, ...)
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(params_tree, pol: Policy, cfg: ModelConfig):
    """PartitionSpec tree matching ``params_tree`` (arrays or structs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(_path_str(path), leaf, pol, cfg),
        params_tree)


def param_shardings(params_tree, pol: Policy, cfg: ModelConfig):
    return jax.tree.map(pol.named, param_pspecs(params_tree, pol, cfg))


# ---------------------------------------------------------------------------
# input / decode-state specs
# ---------------------------------------------------------------------------

def input_pspecs(input_tree, pol: Policy, kind: str):
    """Batch dims over DP axes.  train leaves: (M, mb, S[, D]);
    prefill: (B, S[, D]); decode token: (B, 1[, D])."""
    bdp = pol.dp if kind == "train" else pol.batch_dp
    if kind == "train":
        rule = lambda leaf: P(*([None, bdp] + [None] * (len(leaf.shape) - 2)))
    else:
        rule = lambda leaf: P(*([bdp] + [None] * (len(leaf.shape) - 1)))
    return jax.tree.map(rule, input_tree)


def decode_state_pspecs(state_tree, pol: Policy, batch: int):
    """Decode-state sharding: KV caches (n, B, S, Hkv, dh) batch→data,
    seq→model (flash-decode); recurrent states batch→data; scalars repl."""
    dp_size = 1
    for a in pol.dp_axes:
        dp_size *= pol.mesh.shape[a]
    bdp = pol.dp if batch >= dp_size else None

    def rule(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        if "/kv/" in ps or ps.startswith("kv/"):
            return P(None, bdp, "model", None, None)
        if ps == "len":
            return P(bdp)
        if ps.startswith("conv") or ps.startswith("ssd"):
            return P(*([None, bdp] + [None] * (nd - 2)))
        if ps.startswith("mlstm"):
            return P(*([None, None, bdp] + [None] * (nd - 3)))
        if ps.startswith("slstm"):
            return P(*([None, bdp] + [None] * (nd - 2)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, state_tree)


def tree_shardings(pspec_tree, pol: Policy):
    return jax.tree.map(pol.named, pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))
