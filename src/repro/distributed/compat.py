"""jax version-compat shims for ``shard_map`` and mesh construction.

The codebase targets the modern jax API (``jax.shard_map`` with
``check_vma=``/``axis_names=``, ``jax.sharding.AxisType``), but the
supported floor is jax 0.4.37, where

* ``shard_map`` lives in ``jax.experimental.shard_map`` with ``check_rep=``
  instead of ``check_vma=`` and ``auto=`` (the complement of
  ``axis_names``) instead of ``axis_names=``;
* ``jax.sharding.AxisType`` does not exist and ``jax.make_mesh`` takes no
  ``axis_types=`` keyword (every axis is implicitly Auto, which is exactly
  what the modern call sites request).

Call sites use :func:`shard_map` / :func:`make_mesh` from this module and
get whichever spelling the installed jax understands.  See the
"jax version gap" item in ROADMAP.md.
"""

from __future__ import annotations

from typing import Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[set] = None, check_vma: bool = True):
    """``jax.shard_map`` on modern jax; the experimental fallback on 0.4.x.

    ``axis_names`` names the *manual* axes (modern semantics); on old jax it
    is translated to ``auto=`` (the mesh axes left automatic).  ``check_vma``
    maps onto the old ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, **kwargs)
    # Old jax: partial-manual regions (auto=) miscompile jax.lax.axis_index
    # ("PartitionId instruction is not supported for SPMD partitioning"),
    # so run fully manual over every mesh axis instead.  That is equivalent
    # for our call sites: bodies only issue collectives over the axes they
    # name, so the extra axes just see the body replicated — which is what
    # the P()/unmentioned-axis in_specs already say.
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma))


def make_mesh(shape, axes):
    """``jax.make_mesh`` with all-Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    return jax.make_mesh(shape, axes)
