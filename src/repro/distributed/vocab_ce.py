"""Vocab-parallel cross-entropy (Megatron-style) via shard_map.

With the unembedding sharded over ``model``, each rank computes logits for
its V/tp vocab slice and exchanges only per-token scalars (max, sumexp,
label-logit) — three psums of O(T) instead of gathering O(T·V) logits.

Used by the distributed train step when a policy with TP is active; on a
single device (tests) it degenerates to the fused kernel path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed import current_context
from .compat import shard_map


def _local_ce_stats(x, w_local, labels, v_lo, v_hi, n_valid):
    """Per-shard stats over the local vocab slice [v_lo, v_hi)."""
    logits = x.astype(jnp.float32) @ w_local.astype(jnp.float32)  # (T, Vl)
    cols = v_lo + jnp.arange(w_local.shape[1])[None, :]
    logits = jnp.where(cols < n_valid, logits, -jnp.inf)
    m = logits.max(axis=1)
    sumexp = jnp.exp(logits - m[:, None]).sum(axis=1)
    hit = cols == labels[:, None]
    ll = jnp.where(hit, logits, -jnp.inf).max(axis=1)
    return m, sumexp, ll


def vocab_parallel_ce(x, w, labels, valid, n_valid: int, axis: str = "model"):
    """x: (T, D) (replicated over `axis`); w: (D, V) sharded over `axis`
    on V; labels/valid: (T,).  Returns mean NLL over valid tokens."""
    ctx = current_context()
    if ctx is None or axis not in ctx.mesh.shape:
        from ..kernels import fused_cross_entropy
        return fused_cross_entropy(x, w, labels, valid=valid,
                                   n_valid=n_valid)

    tp = ctx.mesh.shape[axis]
    v_shard = w.shape[1] // tp
    # f32 at the shard_map boundary: XLA-CPU's AllReducePromotion pass
    # aborts on the bf16 cotangent all-reduce this would otherwise produce
    # (the math below is f32 regardless)
    x = x.astype(jnp.float32)

    def local(xl, wl, lab, val):
        idx = jax.lax.axis_index(axis)
        v_lo = idx * v_shard
        m, sumexp, ll = _local_ce_stats(xl, wl, lab, v_lo,
                                        v_lo + v_shard, n_valid)
        # stabilizer only — lse is analytically invariant to it (pmax has no
        # differentiation rule, so stop the gradient at its input)
        m_glob = jax.lax.pmax(jax.lax.stop_gradient(m), axis)
        sumexp_glob = jax.lax.psum(sumexp * jnp.exp(m - m_glob), axis)
        # exactly one shard holds the label column (finite ll) → psum is
        # both exact and cleanly differentiable
        ll_glob = jax.lax.psum(jnp.where(jnp.isfinite(ll), ll, 0.0), axis)
        lse = m_glob + jnp.log(jnp.maximum(sumexp_glob, 1e-30))
        nll = lse - ll_glob
        vf = val.astype(jnp.float32)
        return (nll * vf).sum() / jnp.maximum(vf.sum(), 1.0)

    smapped = shard_map(
        local, mesh=ctx.mesh,
        in_specs=(P(), P(None, axis), P(), P()),
        out_specs=P(), axis_names={axis}, check_vma=False,
    )
    # jit the region: eager shard_map with partial-manual axes mis-infers
    # out_specs from committed input shardings (tests call this eagerly;
    # the train step always runs it under jit anyway)
    return jax.jit(smapped)(x, w, labels, valid)
