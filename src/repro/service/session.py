"""Agent-facing handles: :class:`Session` and :class:`PipelineFuture`.

The paper's decoupling claim (§3): the agent keeps *planning* (drafting the
next AIDE tree node) while *execution* proceeds inside the service.  A
``Session`` is a lightweight per-tenant handle onto a shared
:class:`~repro.service.server.StratumService`; ``submit`` is non-blocking
and returns a :class:`PipelineFuture` that resolves to the same
``(results, report)`` shape ``Stratum.run_batch`` produces, so a synchronous
agent can be ported by replacing ``run_batch(b)`` with
``submit(b).result()``.

``submit`` also takes a :class:`~repro.service.priority.Priority`: a
latency-sensitive probe the agent is blocked on goes in as ``INTERACTIVE``,
bulk sweeps as ``BATCH`` (default) or ``SCAVENGER`` — see
``docs/SCHEDULING.md`` for the scheduling semantics.

A ``Session`` is backend-agnostic: the same handle fronts a standalone
:class:`~repro.service.server.StratumService` or a sharded
:class:`~repro.service.fabric.StratumFabric` — anything exposing
``submit(tenant, batch, priority=..., affinity=...) -> PipelineFuture``
and a ``telemetry`` object with ``snapshot()``.  Against the fabric every
submission crosses the serializable envelope boundary; ``affinity`` (an
opaque string) pins related submissions to one shard by overriding the
content-derived routing key — e.g. one agent's whole search sticking to
the shard that holds its cached intermediates.
"""

from __future__ import annotations

import threading
from concurrent.futures import CancelledError
from typing import Any, Callable, Optional

from ..core.fusion import PipelineBatch
from .priority import Priority

_PENDING = "pending"
_RUNNING = "running"
_DONE = "done"
_CANCELLED = "cancelled"


class PipelineFuture:
    """Result handle for one submitted :class:`PipelineBatch`."""

    def __init__(self, job_id: int, tenant: str,
                 priority: Priority = Priority.BATCH):
        self.job_id = job_id
        self.tenant = tenant
        self.priority = priority
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._state = _PENDING
        self._results: Optional[dict[str, Any]] = None
        self._report: Any = None
        self._error: Optional[BaseException] = None
        self._callbacks: list[Callable[["PipelineFuture"], None]] = []
        self._cancel_hook: Optional[Callable[[int], bool]] = None

    # -- service side ------------------------------------------------------
    def _mark_running(self) -> bool:
        """Claim the job for execution.  True for pending jobs and for jobs
        already running (the failure-isolation retry re-executes innocent
        bystanders of a poisoned super-batch); False once cancelled/done."""
        with self._lock:
            if self._state == _PENDING:
                self._state = _RUNNING
                return True
            return self._state == _RUNNING

    def _set_result(self, results: dict[str, Any], report: Any) -> None:
        with self._lock:
            if self._state == _CANCELLED:
                return
            self._results, self._report = results, report
            self._state = _DONE
        self._finish()

    def _set_exception(self, exc: BaseException) -> None:
        with self._lock:
            if self._state == _CANCELLED:
                return
            self._error = exc
            self._state = _DONE
        self._finish()

    def _set_cancelled(self) -> None:
        with self._lock:
            if self._state == _DONE:
                return
            self._state = _CANCELLED
        self._finish()

    def _finish(self) -> None:
        self._event.set()
        callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            try:
                cb(self)
            except Exception:
                pass

    # -- agent side --------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        with self._lock:
            return self._state == _CANCELLED

    def cancel(self) -> bool:
        """Cancel iff the job is still queued (never pre-empts running work).

        Returns True when the job was removed from the queue."""
        hook = self._cancel_hook
        if hook is None:
            return False
        return hook(self.job_id)

    def result(self, timeout: Optional[float] = None
               ) -> tuple[dict[str, Any], Any]:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"job {self.job_id} (tenant {self.tenant!r}) not done "
                f"after {timeout}s")
        with self._lock:
            if self._state == _CANCELLED:
                raise CancelledError(f"job {self.job_id} was cancelled")
            if self._error is not None:
                raise self._error
            return self._results, self._report

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise TimeoutError(f"job {self.job_id} not done after {timeout}s")
        with self._lock:
            if self._state == _CANCELLED:
                raise CancelledError(f"job {self.job_id} was cancelled")
            return self._error

    def add_done_callback(self, fn: Callable[["PipelineFuture"], None]
                          ) -> None:
        run_now = False
        with self._lock:
            if self._event.is_set():
                run_now = True
            else:
                self._callbacks.append(fn)
        if run_now:
            fn(self)


class Session:
    """One tenant's handle onto an execution backend — a standalone
    :class:`StratumService` or a sharded fabric (see module docstring)."""

    def __init__(self, service, tenant: str):
        self._service = service
        self.tenant = tenant
        self._closed = False

    # -- non-blocking path (the point of the subsystem) --------------------
    def submit(self, batch: PipelineBatch,
               priority: Priority = Priority.BATCH,
               affinity: Optional[str] = None,
               deadline_s: Optional[float] = None,
               tags=(),
               options=None,
               verify: Optional[bool] = None) -> PipelineFuture:
        """Enqueue ``batch``; returns immediately.

        Prefer passing one :class:`repro.client.SubmitOptions` as
        ``options`` — it carries priority, deadline, affinity and tags in
        one frozen object and is the surface every
        :class:`~repro.client.StratumClient` target shares; when given it
        takes precedence over the individual keyword shims.

        ``affinity`` pins the job to the shard owning that key on a sharded
        backend (ignored by a standalone service); ``deadline_s`` is an SLO
        relative to now — a deadline-aware backend schedules EDF within the
        priority band and sheds expired work, failing the future with
        :class:`~repro.service.queue.DeadlineExceeded`.  Raises
        :class:`~repro.service.queue.AdmissionError` when admission control
        rejects the job (queue depth / tenant quota).

        ``verify`` overrides :attr:`ServiceConfig.admission_analysis` for
        this one submit: ``True`` forces pre-flight static analysis (raises
        :class:`~repro.core.analysis.AnalysisError` on a statically-invalid
        pipeline), ``False`` skips it, ``None`` defers to the service
        default."""
        if self._closed:
            raise RuntimeError(f"session {self.tenant!r} is closed")
        tenant = self.tenant
        if options is not None:
            priority = options.priority
            affinity = options.affinity
            deadline_s = options.deadline_s
            tags = options.tags
            # SubmitOptions.tenant is documented as an override — honor it
            # (quotas/telemetry attribute to the tenant that asked)
            if options.tenant is not None:
                tenant = options.tenant
            if getattr(options, "verify", None) is not None:
                verify = options.verify
        kwargs: dict = {"priority": priority, "affinity": affinity}
        # only pass the newer options to backends that predate them, so a
        # Session still fronts any object with the original submit shape
        if deadline_s is not None:
            kwargs["deadline_s"] = deadline_s
        if tags:
            kwargs["tags"] = tuple(tags)
        if verify is not None:
            kwargs["verify"] = verify
        return self._service.submit(tenant, batch, **kwargs)

    # -- drop-in synchronous compatibility with Stratum.run_batch ----------
    def run_batch(self, batch: PipelineBatch,
                  timeout: Optional[float] = None,
                  priority: Priority = Priority.BATCH,
                  affinity: Optional[str] = None,
                  deadline_s: Optional[float] = None,
                  tags=(),
                  options=None):
        return self.submit(batch, priority=priority, affinity=affinity,
                           deadline_s=deadline_s, tags=tags,
                           options=options).result(timeout)

    def precompile(self, batch: PipelineBatch) -> dict:
        """Speculative warm-up hint: plan ``batch`` without executing it
        and enqueue its compiled segments on the service's low-priority
        background compile lane (``compile_async`` +
        ``speculative_depth``).  Returns a status-count dict; ``{}`` when
        the backend cannot honor hints — guessing is never an error."""
        if self._closed:
            raise RuntimeError(f"session {self.tenant!r} is closed")
        precompile = getattr(self._service, "precompile", None)
        if precompile is None:
            return {}
        return precompile(self.tenant, batch)

    def analyze(self, batch: PipelineBatch, *, feasibility: bool = True):
        """Run the pre-flight static analyzer on ``batch`` without
        submitting it; returns an
        :class:`~repro.core.analysis.AnalysisReport`.  Raises
        ``NotImplementedError`` when the backend has no analyzer (older
        fabric shards)."""
        if self._closed:
            raise RuntimeError(f"session {self.tenant!r} is closed")
        analyze = getattr(self._service, "analyze", None)
        if analyze is None:
            raise NotImplementedError(
                f"backend {type(self._service).__name__} has no analyzer")
        return analyze(batch, feasibility=feasibility)

    @property
    def telemetry(self) -> dict:
        return self._service.telemetry.snapshot().get(self.tenant, {})

    def close(self) -> None:
        self._closed = True

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
