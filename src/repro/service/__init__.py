"""repro.service — persistent, multi-tenant stratum execution service.

Decouples agent planning from pipeline execution (paper §3): agents submit
:class:`~repro.core.fusion.PipelineBatch`es through non-blocking
:class:`Session` handles; the service coalesces concurrent submissions from
different agents into super-batches, dedups shared work via cross-agent CSE
and a shared intermediate cache with per-tenant quota arbitration,
schedules priority bands by weighted fair queuing (with starvation aging
and cooperative preemption of running low-priority work) under a global
memory budget, and resolves :class:`PipelineFuture`s with per-tenant
telemetry.  See ``docs/ARCHITECTURE.md`` and ``docs/SCHEDULING.md``.

    with StratumService(memory_budget_bytes=4 << 30) as svc:
        s1, s2 = svc.session("agent-1"), svc.session("agent-2")
        f1 = s1.submit(batch_a)          # non-blocking: keep planning
        f2 = s2.submit(batch_b)          # coalesced with batch_a
        results, report = f1.result()
        print(svc.telemetry.report())
"""

from .coalesce import SuperBatch, coalesce, cross_agent_dedup
from .control import (ControlPolicy, ServiceController,
                      merge_control_snapshots)
from .observability import (JobTrace, ThroughputCollector, TraceSink,
                            merge_window_snapshots)
from .priority import DEFAULT_WEIGHTS, Priority
from .queue import AdmissionError, DeadlineExceeded, FairQueue, Job
from .server import JobReport, ServiceConfig, StratumService
from .session import PipelineFuture, Session
from .telemetry import ServiceTelemetry, TenantStats, merge_tenant_snapshots
from .fabric import ShardedStratum, StratumFabric

__all__ = [
    "AdmissionError", "ControlPolicy", "DEFAULT_WEIGHTS",
    "DeadlineExceeded", "FairQueue", "Job", "JobReport", "JobTrace",
    "PipelineFuture", "Priority", "ServiceConfig", "ServiceController",
    "ServiceTelemetry", "Session", "ShardedStratum", "StratumFabric",
    "StratumService", "SuperBatch", "TenantStats", "ThroughputCollector",
    "TraceSink", "coalesce", "cross_agent_dedup",
    "merge_control_snapshots", "merge_tenant_snapshots",
    "merge_window_snapshots",
]
