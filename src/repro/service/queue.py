"""Admission-controlled, priority-stratified, tenant-fair job queue.

Four properties the service needs that a plain FIFO lacks:

* **admission control** — ``push`` rejects (raises :class:`AdmissionError`)
  once global or per-tenant queue depth limits are hit, so a runaway agent
  sheds load at the edge instead of OOMing the service;
* **priority stratification** — jobs land in one of three bands
  (:class:`~repro.service.priority.Priority`); ``pop_round`` picks the band
  to serve by weighted fair queuing (credit accrual proportional to
  configurable weights), so latency-sensitive INTERACTIVE probes do not sit
  behind another agent's bulk sweep, while BATCH/SCAVENGER retain a
  configurable fraction of throughput.  Each round serves exactly one band,
  keeping coalesced super-batches priority-homogeneous (a prerequisite for
  coherent preemption decisions);
* **fairness within a band** — jobs live in per-tenant FIFOs and a round
  drains them round-robin with a per-tenant cap, so a tenant flooding the
  queue cannot starve another tenant of the same priority;
* **deadline awareness** — a job may carry ``deadline_s`` (an SLO relative
  to submission).  Within the band WFQ selected, tenants holding
  deadline-carrying work are served earliest-deadline-first (EDF) ahead of
  deadline-free tenants, which keep their round-robin order — priorities
  decide *which band* runs, deadlines only break ties *inside* it.  A job
  whose deadline has already passed while queued is **shed** at the next
  scheduling round: it is removed, its future fails with
  :class:`DeadlineExceeded`, and the optional ``on_shed`` hook fires (the
  service records attainment telemetry there) — late work stops consuming
  the capacity that could still save an attainable deadline.  A job whose
  remaining slack is below the caller's ``tight_slack_s`` is popped
  *alone*, so the coalescer cannot weld it into a large super-batch whose
  execution time it would inherit.  ``deadline_aware=False`` records
  deadlines but schedules blind (the benchmark baseline).

Starvation-proofing: a queued job is *aged* — promoted one band for every
``aging_s`` seconds it has waited — so even a SCAVENGER job under sustained
INTERACTIVE load (or with a weight-0 band) eventually reaches the top band
and is served by ordinary round-robin there.

``requeue`` re-admits cooperatively preempted jobs at the *front* of their
tenant FIFO, bypassing admission limits (they were already admitted once).
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from ..core.fusion import PipelineBatch
from .observability import ADMITTED, QUEUED, REQUEUED
from .priority import DEFAULT_WEIGHTS, Priority
from .session import PipelineFuture


class AdmissionError(RuntimeError):
    """Job rejected at submission time (queue depth / tenant quota)."""


class DeadlineExceeded(RuntimeError):
    """The job's ``deadline_s`` passed before a result could be produced.

    Raised out of ``PipelineFuture.result()`` when a deadline-aware queue
    sheds the expired job (service/fabric targets) or when a local run
    finishes past the deadline.  Picklable with a plain message so it
    crosses the fabric's wire codec like any other error."""


@dataclass
class Job:
    id: int
    tenant: str
    batch: PipelineBatch
    future: PipelineFuture
    priority: Priority = Priority.BATCH
    submit_t: float = field(default_factory=time.perf_counter)
    # deadline SLO: relative seconds at submit; deadline_t is the absolute
    # perf_counter instant (derived once, so waiting never moves the goal)
    deadline_s: Optional[float] = None
    deadline_t: Optional[float] = None
    tags: tuple = ()
    # set at first dispatch; a failure-isolation retry must not re-measure
    # (the second measurement would include the failed run's execution time)
    dispatch_wait_s: Optional[float] = None
    # current effective band (≤ priority once aging promotes the job)
    band: int = -1
    # cooperative-preemption state: times this job's super-batch yielded,
    # and intermediates completed before the yield (sig → outputs tuple) so
    # the re-run loses no finished work
    preemptions: int = 0
    salvage: dict = field(default_factory=dict)
    # live JobTrace when lifecycle tracing is on (observability/), else None
    trace: object = None

    def __post_init__(self) -> None:
        if self.band < 0:
            self.band = int(self.priority)
        if self.deadline_t is None and self.deadline_s is not None:
            self.deadline_t = self.submit_t + self.deadline_s

    def slack(self, now: float) -> float:
        """Seconds until the deadline (+inf for deadline-free jobs)."""
        if self.deadline_t is None:
            return float("inf")
        return self.deadline_t - now

    def trace_slack(self) -> Optional[float]:
        """Slack for a trace hop stamp: None for deadline-free jobs."""
        if self.deadline_t is None:
            return None
        return self.deadline_t - time.perf_counter()


class FairQueue:
    """Priority-stratified weighted-fair queue with per-tenant round-robin.

    ``priority_aware=False`` collapses every job into the BATCH band,
    reproducing the original priority-blind round-robin scheduler (used as
    the baseline in ``benchmarks/e2e_agentic.py --mixed-priority``).
    """

    def __init__(self,
                 max_queued_total: int = 1024,
                 max_queued_per_tenant: int = 256,
                 weights: Optional[dict] = None,
                 aging_s: Optional[float] = 5.0,
                 priority_aware: bool = True,
                 deadline_aware: bool = True):
        self.max_queued_total = max_queued_total
        self.max_queued_per_tenant = max_queued_per_tenant
        # closed-loop control knobs (control/): per-band admission caps
        # ({} = uncapped) and an INTERACTIVE reserve — pushes into the
        # INTERACTIVE band below the reserve depth bypass the total gate
        # (tenant quota still applies), so a flood holding the queue at
        # its limit can never starve admission of latency probes
        self.band_limits: dict[int, int] = {}
        self.reserve_interactive = 0
        self.weights = {Priority(k): int(v)
                        for k, v in (weights or DEFAULT_WEIGHTS).items()}
        self.aging_s = aging_s
        self.priority_aware = priority_aware
        self.deadline_aware = deadline_aware
        # telemetry hook, called (outside the lock) per shed job AFTER its
        # future already failed with DeadlineExceeded
        self.on_shed: Optional[Callable[[Job], None]] = None
        # band → (tenant → FIFO); OrderedDict gives intra-band round-robin
        self._bands: dict[int, "OrderedDict[str, deque[Job]]"] = {
            int(p): OrderedDict() for p in Priority}
        self._credits: dict[int, float] = {int(p): 0.0 for p in Priority}   # guarded-by: _lock
        self._tenant_total: dict[str, int] = {}        # guarded-by: _lock
        self._total = 0                            # guarded-by: _lock
        # deadline-carrying jobs currently queued: the shed scan and the
        # EDF ordering are O(queued) per round, so with zero deadline jobs
        # (the common case) both must cost nothing
        self._deadline_total = 0                   # guarded-by: _lock
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._closed = False

    # ------------------------------------------------------------------
    def _band_depth_locked(self, band: int) -> int:
        return sum(len(q) for q in self._bands[band].values())

    def push(self, job: Job) -> None:
        with self._lock:
            if self._closed:
                raise AdmissionError("service is shutting down")
            if not self.priority_aware:
                job.band = int(Priority.BATCH)
            reserved = (job.band == int(Priority.INTERACTIVE)
                        and self.reserve_interactive > 0
                        and self._band_depth_locked(job.band)
                        < self.reserve_interactive)
            if not reserved:
                if self._total >= self.max_queued_total:
                    raise AdmissionError(
                        f"queue full ({self._total}/"
                        f"{self.max_queued_total})")
                limit = self.band_limits.get(job.band)
                if (limit is not None
                        and self._band_depth_locked(job.band) >= limit):
                    raise AdmissionError(
                        f"band {job.band} gated at {limit} queued jobs "
                        f"(admission controller)")
            n_tenant = self._tenant_total.get(job.tenant, 0)
            if n_tenant >= self.max_queued_per_tenant:
                raise AdmissionError(
                    f"tenant {job.tenant!r} over quota "
                    f"({n_tenant}/{self.max_queued_per_tenant})")
            band = self._bands[job.band]
            band.setdefault(job.tenant, deque()).append(job)
            self._tenant_total[job.tenant] = n_tenant + 1
            self._total += 1
            if job.deadline_t is not None:
                self._deadline_total += 1
            if job.trace is not None:
                # stamped under the lock so QUEUED always precedes the
                # dispatcher's DISPATCHED in the hop log
                job.trace.stamp(ADMITTED, slack=job.trace_slack())
                job.trace.stamp(QUEUED, slack=job.trace_slack(),
                                depth=self._total, band=job.band)
            self._not_empty.notify()

    def requeue(self, jobs: Sequence[Job]) -> None:
        """Re-admit preempted jobs at the front of their tenant FIFO.

        Bypasses depth limits — the jobs were admitted once already and
        rejecting them now would lose accepted work.  After the queue is
        closed the caller must fail the jobs instead."""
        with self._lock:
            if self._closed:
                raise AdmissionError("service is shutting down")
            for job in reversed(list(jobs)):
                if not self.priority_aware:
                    job.band = int(Priority.BATCH)
                band = self._bands[job.band]
                band.setdefault(job.tenant, deque()).appendleft(job)
                band.move_to_end(job.tenant, last=False)
                self._tenant_total[job.tenant] = \
                    self._tenant_total.get(job.tenant, 0) + 1
                self._total += 1
                if job.deadline_t is not None:
                    self._deadline_total += 1
                if job.trace is not None:
                    job.trace.stamp(REQUEUED, slack=job.trace_slack(),
                                    preemptions=job.preemptions)
            self._not_empty.notify_all()

    # -- closed-loop actuation surface (control/ServiceController) -----
    def set_limits(self, max_queued_total: Optional[int] = None,
                   band_limits: Optional[dict] = None,
                   reserve_interactive: Optional[int] = None) -> None:
        """Retune admission knobs atomically (None = leave unchanged).

        Shrinking a limit below the current depth only gates NEW pushes;
        already-admitted jobs stay queued and drain normally."""
        with self._lock:
            if max_queued_total is not None:
                self.max_queued_total = max(1, int(max_queued_total))
            if band_limits is not None:
                self.band_limits = {int(k): max(1, int(v))
                                    for k, v in band_limits.items()}
            if reserve_interactive is not None:
                self.reserve_interactive = max(0, int(reserve_interactive))

    def set_weights(self, weights: dict) -> None:
        """Replace the WFQ band weights (Priority → weight, floats ok)."""
        with self._lock:
            self.weights = {Priority(k): float(v)
                            for k, v in weights.items()}

    # ------------------------------------------------------------------
    def _age_locked(self, now: float) -> None:
        """Promote jobs one band per ``aging_s`` seconds waited."""
        if not self.aging_s or not self.priority_aware:
            return
        for b in (int(Priority.SCAVENGER), int(Priority.BATCH)):
            tenants = self._bands[b]
            for tenant in list(tenants):
                q = tenants[tenant]
                keep: deque = deque()
                for job in q:
                    target = max(0, int(job.priority)
                                 - int((now - job.submit_t) / self.aging_s))
                    if target < b:
                        job.band = b - 1   # one band per aging step
                        dst = self._bands[b - 1]
                        dst.setdefault(job.tenant, deque()).append(job)
                    else:
                        keep.append(job)
                if keep:
                    tenants[tenant] = keep
                else:
                    del tenants[tenant]

    def _select_band_locked(self) -> Optional[int]:  # guarded-by: caller
        """Weighted-fair band choice (surplus round-robin over credits)."""
        nonempty = [b for b in sorted(self._bands) if self._bands[b]]
        if not nonempty:
            return None
        if not self.priority_aware:
            return nonempty[0]
        weighted = [b for b in nonempty if self.weights.get(Priority(b), 0) > 0]
        candidates = weighted or nonempty
        if len(candidates) == 1:
            return candidates[0]
        for b in candidates:
            self._credits[b] += self.weights.get(Priority(b), 0)
        chosen = max(candidates, key=lambda b: (self._credits[b], -b))
        self._credits[chosen] -= sum(self.weights.get(Priority(b), 0)
                                     for b in candidates)
        return chosen

    def _shed_expired_locked(self, now: float) -> list[Job]:  # guarded-by: caller
        """Remove every queued job whose deadline already passed.

        Returns the shed jobs; the caller fails their futures OUTSIDE the
        lock (future callbacks may re-enter the queue)."""
        if not self.deadline_aware or not self._deadline_total:
            return []
        shed: list[Job] = []
        for tenants in self._bands.values():
            for tenant in list(tenants):
                q = tenants[tenant]
                keep: deque = deque()
                expired: list[Job] = []
                for job in q:
                    if job.deadline_t is not None and job.deadline_t <= now:
                        expired.append(job)
                    else:
                        keep.append(job)
                if not expired:
                    continue
                shed.extend(expired)
                self._total -= len(expired)
                self._deadline_total -= len(expired)
                self._tenant_total[tenant] -= len(expired)
                if not self._tenant_total[tenant]:
                    del self._tenant_total[tenant]
                if keep:
                    tenants[tenant] = keep
                else:
                    del tenants[tenant]
        return shed

    def _resolve_shed(self, shed: Sequence[Job]) -> None:
        for job in shed:
            job.future._set_exception(DeadlineExceeded(
                f"job {job.id} (tenant {job.tenant!r}) shed: deadline of "
                f"{job.deadline_s}s expired while queued"))
            if self.on_shed is not None:
                try:
                    self.on_shed(job)
                except Exception:   # noqa: BLE001 — telemetry must not kill
                    pass            # the dispatcher

    def _take_locked(self, tenants, tenant: str, q: deque, n: int,
                     now: float,
                     exclude_tight_s: Optional[float] = None) -> list[Job]:  # guarded-by: caller
        """Remove up to ``n`` jobs from one tenant FIFO — earliest-deadline
        first when any queued job carries one, plain FIFO otherwise.  With
        ``exclude_tight_s`` set (a coalescing-window extension), jobs whose
        slack is at or below it are left queued: a tight-deadline job must
        dispatch alone, never inside a growing merge."""
        edf = self.deadline_aware and self._deadline_total > 0
        idxs = range(len(q))
        if exclude_tight_s is not None and edf:
            idxs = [i for i in idxs if q[i].slack(now) > exclude_tight_s]
        if edf and any(j.deadline_t is not None for j in q):
            picked = sorted(idxs, key=lambda i: (q[i].slack(now), i))[:n]
        else:
            picked = list(idxs)[:n]
        out = [q[i] for i in picked]    # EDF order, not FIFO position
        for job in out:
            q.remove(job)
        if out:
            self._total -= len(out)
            self._deadline_total -= sum(1 for j in out
                                        if j.deadline_t is not None)
            self._tenant_total[tenant] -= len(out)
            if not self._tenant_total[tenant]:
                del self._tenant_total[tenant]
        if not q:
            del tenants[tenant]
        return out

    def pop_round(self, max_jobs: int, max_per_tenant: int = 1,
                  timeout: Optional[float] = None,
                  band: Optional[int] = None,
                  tight_slack_s: Optional[float] = None) -> list[Job]:
        """One fair scheduling round, confined to a single priority band.

        Blocks up to ``timeout`` for work, sheds deadline-expired jobs,
        ages waiting jobs, selects a band by weighted fair queuing (or uses
        ``band`` when the caller is extending an in-progress coalescing
        window — super-batches must stay priority-homogeneous), then takes
        ≤ ``max_per_tenant`` jobs from each of the band's tenants until
        ``max_jobs`` or the band drains.  Deadline-carrying tenants are
        served earliest-deadline-first ahead of the round-robin order
        (tenants rotate to the back after being served).

        When the band's most urgent job has less than ``tight_slack_s``
        of slack left, that job is returned ALONE: coalescing it into a
        large super-batch would make it inherit the merge's execution time
        and miss a deadline it could still meet.
        """
        deadline = (time.perf_counter() + timeout) if timeout else None

        def _has_work() -> bool:
            if band is None:
                return bool(self._total)
            return bool(self._bands[band])

        shed: list[Job] = []
        try:
            with self._lock:
                while not _has_work():
                    if deadline is None:
                        return []
                    left = deadline - time.perf_counter()
                    if left <= 0 or self._closed:
                        return []
                    self._not_empty.wait(left)
                now = time.perf_counter()
                shed = self._shed_expired_locked(now)
                self._age_locked(now)
                chosen = (band if band is not None
                          else self._select_band_locked())
                if chosen is None or not self._bands[chosen]:
                    return []
                tenants = self._bands[chosen]

                # EDF tie-break inside the WFQ-chosen band: serve tenants
                # by their most urgent queued deadline; deadline-free
                # tenants keep their round-robin order (sort is stable and
                # their key is +inf)
                order = list(tenants)
                if self.deadline_aware and self._deadline_total:
                    order.sort(key=lambda t: min(
                        (j.slack(now) for j in tenants[t]),
                        default=float("inf")))
                    head = tenants.get(order[0])
                    most_urgent = min(
                        (j.slack(now) for j in head), default=float("inf")
                        ) if head else float("inf")
                    if (tight_slack_s is not None and band is None
                            and most_urgent <= tight_slack_s):
                        # pop the tight job alone — never into a merge
                        return self._take_locked(tenants, order[0], head, 1,
                                                 now)
                # extension pops must leave tight jobs queued (they will
                # pop alone at the NEXT round's tight check instead)
                exclude = tight_slack_s if band is not None else None

                out: list[Job] = []
                for tenant in order:
                    if len(out) >= max_jobs:
                        break
                    q = tenants.get(tenant)
                    if not q:
                        continue
                    take = min(max_per_tenant, len(q), max_jobs - len(out))
                    got = self._take_locked(tenants, tenant, q, take, now,
                                            exclude_tight_s=exclude)
                    out.extend(got)
                    # rotate: a served tenant still queued goes to the back
                    if got and tenant in tenants:
                        tenants.move_to_end(tenant)
                return out
        finally:
            self._resolve_shed(shed)

    def cancel(self, job_id: int) -> bool:
        """Remove a still-queued job; returns False once dispatched."""
        with self._lock:
            for tenants in self._bands.values():
                for tenant, q in list(tenants.items()):
                    for job in q:
                        if job.id == job_id:
                            q.remove(job)
                            self._total -= 1
                            if job.deadline_t is not None:
                                self._deadline_total -= 1
                            self._tenant_total[tenant] -= 1
                            if not self._tenant_total[tenant]:
                                del self._tenant_total[tenant]
                            if not q:
                                del tenants[tenant]
                            job.future._set_cancelled()
                            return True
        return False

    # ------------------------------------------------------------------
    def pending(self) -> int:
        with self._lock:
            return self._total

    def pending_by_band(self) -> dict[int, int]:
        with self._lock:
            return {b: sum(len(q) for q in tenants.values())
                    for b, tenants in self._bands.items()}

    def has_work_above(self, band: int) -> bool:
        """True when a job is queued in a strictly more urgent band —
        the cooperative-preemption trigger for a running super-batch."""
        with self._lock:
            return any(self._bands[b] for b in self._bands if b < band)

    def close(self) -> list[Job]:
        """Stop admitting; drain and return whatever is still queued."""
        with self._lock:
            self._closed = True
            rest = [j for tenants in self._bands.values()
                    for q in tenants.values() for j in q]
            for tenants in self._bands.values():
                tenants.clear()
            self._tenant_total.clear()
            self._total = 0
            self._deadline_total = 0
            self._not_empty.notify_all()
            return rest

    def reopen(self) -> None:
        """Accept submissions again after ``close`` (service restart)."""
        with self._lock:
            self._closed = False

    def kick(self) -> None:
        """Wake a blocked ``pop_round`` (used on shutdown)."""
        with self._lock:
            self._not_empty.notify_all()
