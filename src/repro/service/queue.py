"""Admission-controlled, priority-stratified, tenant-fair job queue.

Three properties the service needs that a plain FIFO lacks:

* **admission control** — ``push`` rejects (raises :class:`AdmissionError`)
  once global or per-tenant queue depth limits are hit, so a runaway agent
  sheds load at the edge instead of OOMing the service;
* **priority stratification** — jobs land in one of three bands
  (:class:`~repro.service.priority.Priority`); ``pop_round`` picks the band
  to serve by weighted fair queuing (credit accrual proportional to
  configurable weights), so latency-sensitive INTERACTIVE probes do not sit
  behind another agent's bulk sweep, while BATCH/SCAVENGER retain a
  configurable fraction of throughput.  Each round serves exactly one band,
  keeping coalesced super-batches priority-homogeneous (a prerequisite for
  coherent preemption decisions);
* **fairness within a band** — jobs live in per-tenant FIFOs and a round
  drains them round-robin with a per-tenant cap, so a tenant flooding the
  queue cannot starve another tenant of the same priority.

Starvation-proofing: a queued job is *aged* — promoted one band for every
``aging_s`` seconds it has waited — so even a SCAVENGER job under sustained
INTERACTIVE load (or with a weight-0 band) eventually reaches the top band
and is served by ordinary round-robin there.

``requeue`` re-admits cooperatively preempted jobs at the *front* of their
tenant FIFO, bypassing admission limits (they were already admitted once).
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

from ..core.fusion import PipelineBatch
from .priority import DEFAULT_WEIGHTS, Priority
from .session import PipelineFuture


class AdmissionError(RuntimeError):
    """Job rejected at submission time (queue depth / tenant quota)."""


@dataclass
class Job:
    id: int
    tenant: str
    batch: PipelineBatch
    future: PipelineFuture
    priority: Priority = Priority.BATCH
    submit_t: float = field(default_factory=time.perf_counter)
    # set at first dispatch; a failure-isolation retry must not re-measure
    # (the second measurement would include the failed run's execution time)
    dispatch_wait_s: Optional[float] = None
    # current effective band (≤ priority once aging promotes the job)
    band: int = -1
    # cooperative-preemption state: times this job's super-batch yielded,
    # and intermediates completed before the yield (sig → outputs tuple) so
    # the re-run loses no finished work
    preemptions: int = 0
    salvage: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.band < 0:
            self.band = int(self.priority)


class FairQueue:
    """Priority-stratified weighted-fair queue with per-tenant round-robin.

    ``priority_aware=False`` collapses every job into the BATCH band,
    reproducing the original priority-blind round-robin scheduler (used as
    the baseline in ``benchmarks/e2e_agentic.py --mixed-priority``).
    """

    def __init__(self,
                 max_queued_total: int = 1024,
                 max_queued_per_tenant: int = 256,
                 weights: Optional[dict] = None,
                 aging_s: Optional[float] = 5.0,
                 priority_aware: bool = True):
        self.max_queued_total = max_queued_total
        self.max_queued_per_tenant = max_queued_per_tenant
        self.weights = {Priority(k): int(v)
                        for k, v in (weights or DEFAULT_WEIGHTS).items()}
        self.aging_s = aging_s
        self.priority_aware = priority_aware
        # band → (tenant → FIFO); OrderedDict gives intra-band round-robin
        self._bands: dict[int, "OrderedDict[str, deque[Job]]"] = {
            int(p): OrderedDict() for p in Priority}
        self._credits: dict[int, float] = {int(p): 0.0 for p in Priority}
        self._tenant_total: dict[str, int] = {}
        self._total = 0
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._closed = False

    # ------------------------------------------------------------------
    def push(self, job: Job) -> None:
        with self._lock:
            if self._closed:
                raise AdmissionError("service is shutting down")
            if self._total >= self.max_queued_total:
                raise AdmissionError(
                    f"queue full ({self._total}/{self.max_queued_total})")
            n_tenant = self._tenant_total.get(job.tenant, 0)
            if n_tenant >= self.max_queued_per_tenant:
                raise AdmissionError(
                    f"tenant {job.tenant!r} over quota "
                    f"({n_tenant}/{self.max_queued_per_tenant})")
            if not self.priority_aware:
                job.band = int(Priority.BATCH)
            band = self._bands[job.band]
            band.setdefault(job.tenant, deque()).append(job)
            self._tenant_total[job.tenant] = n_tenant + 1
            self._total += 1
            self._not_empty.notify()

    def requeue(self, jobs: Sequence[Job]) -> None:
        """Re-admit preempted jobs at the front of their tenant FIFO.

        Bypasses depth limits — the jobs were admitted once already and
        rejecting them now would lose accepted work.  After the queue is
        closed the caller must fail the jobs instead."""
        with self._lock:
            if self._closed:
                raise AdmissionError("service is shutting down")
            for job in reversed(list(jobs)):
                if not self.priority_aware:
                    job.band = int(Priority.BATCH)
                band = self._bands[job.band]
                band.setdefault(job.tenant, deque()).appendleft(job)
                band.move_to_end(job.tenant, last=False)
                self._tenant_total[job.tenant] = \
                    self._tenant_total.get(job.tenant, 0) + 1
                self._total += 1
            self._not_empty.notify_all()

    # ------------------------------------------------------------------
    def _age_locked(self, now: float) -> None:
        """Promote jobs one band per ``aging_s`` seconds waited."""
        if not self.aging_s or not self.priority_aware:
            return
        for b in (int(Priority.SCAVENGER), int(Priority.BATCH)):
            tenants = self._bands[b]
            for tenant in list(tenants):
                q = tenants[tenant]
                keep: deque = deque()
                for job in q:
                    target = max(0, int(job.priority)
                                 - int((now - job.submit_t) / self.aging_s))
                    if target < b:
                        job.band = b - 1   # one band per aging step
                        dst = self._bands[b - 1]
                        dst.setdefault(job.tenant, deque()).append(job)
                    else:
                        keep.append(job)
                if keep:
                    tenants[tenant] = keep
                else:
                    del tenants[tenant]

    def _select_band_locked(self) -> Optional[int]:
        """Weighted-fair band choice (surplus round-robin over credits)."""
        nonempty = [b for b in sorted(self._bands) if self._bands[b]]
        if not nonempty:
            return None
        if not self.priority_aware:
            return nonempty[0]
        weighted = [b for b in nonempty if self.weights.get(Priority(b), 0) > 0]
        candidates = weighted or nonempty
        if len(candidates) == 1:
            return candidates[0]
        for b in candidates:
            self._credits[b] += self.weights.get(Priority(b), 0)
        chosen = max(candidates, key=lambda b: (self._credits[b], -b))
        self._credits[chosen] -= sum(self.weights.get(Priority(b), 0)
                                     for b in candidates)
        return chosen

    def pop_round(self, max_jobs: int, max_per_tenant: int = 1,
                  timeout: Optional[float] = None,
                  band: Optional[int] = None) -> list[Job]:
        """One fair scheduling round, confined to a single priority band.

        Blocks up to ``timeout`` for work, ages waiting jobs, selects a band
        by weighted fair queuing (or uses ``band`` when the caller is
        extending an in-progress coalescing window — super-batches must stay
        priority-homogeneous), then takes ≤ ``max_per_tenant`` jobs from
        each of the band's tenants in round-robin order (tenants rotate to
        the back after being served) until ``max_jobs`` or the band drains.
        """
        deadline = (time.perf_counter() + timeout) if timeout else None

        def _has_work() -> bool:
            if band is None:
                return bool(self._total)
            return bool(self._bands[band])

        with self._lock:
            while not _has_work():
                if deadline is None:
                    return []
                left = deadline - time.perf_counter()
                if left <= 0 or self._closed:
                    return []
                self._not_empty.wait(left)
            now = time.perf_counter()
            self._age_locked(now)
            chosen = band if band is not None else self._select_band_locked()
            if chosen is None or not self._bands[chosen]:
                return []
            tenants = self._bands[chosen]
            out: list[Job] = []
            served = 0
            n_tenants = len(tenants)
            while served < n_tenants and len(out) < max_jobs and tenants:
                tenant, q = next(iter(tenants.items()))
                take = min(max_per_tenant, len(q), max_jobs - len(out))
                for _ in range(take):
                    job = q.popleft()
                    out.append(job)
                    self._total -= 1
                    self._tenant_total[tenant] -= 1
                    if not self._tenant_total[tenant]:
                        del self._tenant_total[tenant]
                # rotate: served tenant goes to the back; drop empty queues
                tenants.move_to_end(tenant)
                if not q:
                    del tenants[tenant]
                served += 1
            return out

    def cancel(self, job_id: int) -> bool:
        """Remove a still-queued job; returns False once dispatched."""
        with self._lock:
            for tenants in self._bands.values():
                for tenant, q in list(tenants.items()):
                    for job in q:
                        if job.id == job_id:
                            q.remove(job)
                            self._total -= 1
                            self._tenant_total[tenant] -= 1
                            if not self._tenant_total[tenant]:
                                del self._tenant_total[tenant]
                            if not q:
                                del tenants[tenant]
                            job.future._set_cancelled()
                            return True
        return False

    # ------------------------------------------------------------------
    def pending(self) -> int:
        with self._lock:
            return self._total

    def pending_by_band(self) -> dict[int, int]:
        with self._lock:
            return {b: sum(len(q) for q in tenants.values())
                    for b, tenants in self._bands.items()}

    def has_work_above(self, band: int) -> bool:
        """True when a job is queued in a strictly more urgent band —
        the cooperative-preemption trigger for a running super-batch."""
        with self._lock:
            return any(self._bands[b] for b in self._bands if b < band)

    def close(self) -> list[Job]:
        """Stop admitting; drain and return whatever is still queued."""
        with self._lock:
            self._closed = True
            rest = [j for tenants in self._bands.values()
                    for q in tenants.values() for j in q]
            for tenants in self._bands.values():
                tenants.clear()
            self._tenant_total.clear()
            self._total = 0
            self._not_empty.notify_all()
            return rest

    def reopen(self) -> None:
        """Accept submissions again after ``close`` (service restart)."""
        with self._lock:
            self._closed = False

    def kick(self) -> None:
        """Wake a blocked ``pop_round`` (used on shutdown)."""
        with self._lock:
            self._not_empty.notify_all()
