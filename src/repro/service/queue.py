"""Admission-controlled, tenant-fair job queue.

Two properties the service needs that a plain FIFO lacks:

* **admission control** — ``push`` rejects (raises :class:`AdmissionError`)
  once global or per-tenant queue depth limits are hit, so a runaway agent
  sheds load at the edge instead of OOMing the service;
* **fairness** — jobs live in per-tenant FIFOs and ``pop_round`` drains them
  round-robin with a per-tenant cap per round, so a tenant flooding the
  queue cannot starve another: every round, each backlogged tenant gets at
  most ``max_per_tenant`` slots and every tenant with work gets at least
  one chance per cycle.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Optional

from ..core.fusion import PipelineBatch
from .session import PipelineFuture


class AdmissionError(RuntimeError):
    """Job rejected at submission time (queue depth / tenant quota)."""


@dataclass
class Job:
    id: int
    tenant: str
    batch: PipelineBatch
    future: PipelineFuture
    submit_t: float = field(default_factory=time.perf_counter)
    # set at first dispatch; a failure-isolation retry must not re-measure
    # (the second measurement would include the failed run's execution time)
    dispatch_wait_s: Optional[float] = None


class FairQueue:
    def __init__(self,
                 max_queued_total: int = 1024,
                 max_queued_per_tenant: int = 256):
        self.max_queued_total = max_queued_total
        self.max_queued_per_tenant = max_queued_per_tenant
        self._tenants: "OrderedDict[str, deque[Job]]" = OrderedDict()
        self._total = 0
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._closed = False

    # ------------------------------------------------------------------
    def push(self, job: Job) -> None:
        with self._lock:
            if self._closed:
                raise AdmissionError("service is shutting down")
            if self._total >= self.max_queued_total:
                raise AdmissionError(
                    f"queue full ({self._total}/{self.max_queued_total})")
            q = self._tenants.setdefault(job.tenant, deque())
            if len(q) >= self.max_queued_per_tenant:
                raise AdmissionError(
                    f"tenant {job.tenant!r} over quota "
                    f"({len(q)}/{self.max_queued_per_tenant})")
            q.append(job)
            self._total += 1
            self._not_empty.notify()

    def pop_round(self, max_jobs: int, max_per_tenant: int = 1,
                  timeout: Optional[float] = None) -> list[Job]:
        """One fair scheduling round.

        Blocks up to ``timeout`` for work, then takes ≤ ``max_per_tenant``
        jobs from each tenant in round-robin order (tenants rotate to the
        back after being served) until ``max_jobs`` or the queue is empty.
        """
        with self._lock:
            if not self._total and timeout:
                self._not_empty.wait(timeout)
            out: list[Job] = []
            if not self._total:
                return out
            served = 0
            n_tenants = len(self._tenants)
            while served < n_tenants and len(out) < max_jobs and self._total:
                tenant, q = next(iter(self._tenants.items()))
                take = min(max_per_tenant, len(q), max_jobs - len(out))
                for _ in range(take):
                    out.append(q.popleft())
                    self._total -= 1
                # rotate: served tenant goes to the back; drop empty queues
                self._tenants.move_to_end(tenant)
                if not q:
                    del self._tenants[tenant]
                served += 1
            return out

    def cancel(self, job_id: int) -> bool:
        """Remove a still-queued job; returns False once dispatched."""
        with self._lock:
            for tenant, q in list(self._tenants.items()):
                for job in q:
                    if job.id == job_id:
                        q.remove(job)
                        self._total -= 1
                        if not q:
                            del self._tenants[tenant]
                        job.future._set_cancelled()
                        return True
        return False

    # ------------------------------------------------------------------
    def pending(self) -> int:
        with self._lock:
            return self._total

    def close(self) -> list[Job]:
        """Stop admitting; drain and return whatever is still queued."""
        with self._lock:
            self._closed = True
            rest = [j for q in self._tenants.values() for j in q]
            self._tenants.clear()
            self._total = 0
            self._not_empty.notify_all()
            return rest

    def reopen(self) -> None:
        """Accept submissions again after ``close`` (service restart)."""
        with self._lock:
            self._closed = False

    def kick(self) -> None:
        """Wake a blocked ``pop_round`` (used on shutdown)."""
        with self._lock:
            self._not_empty.notify_all()
