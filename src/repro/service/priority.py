"""Priority classes for the execution service.

Agent workloads are heterogeneous: an RL- or AIDE-driven agent interleaves
cheap latency-sensitive probes (a single candidate it is blocked on) with
bulk sweeps it merely wants finished eventually.  The service therefore
stratifies jobs into three bands:

* :attr:`Priority.INTERACTIVE` — latency-sensitive; the agent is blocked on
  the result (e.g. the refinement of the current best AIDE node).
* :attr:`Priority.BATCH` — the default; ordinary throughput work.
* :attr:`Priority.SCAVENGER` — bulk background sweeps; runs in otherwise
  idle capacity and is the first to be preempted.

Scheduling across bands is *weighted fair queuing*, not strict priority:
each band holding work accrues credit proportional to its weight and the
band with the most credit is served next, so lower bands retain a
configurable fraction of throughput even under sustained interactive load
(``DEFAULT_WEIGHTS`` gives roughly 12:3:1).  A band with weight 0 is served
only when every weighted band is empty (strict background).

Starvation-proofing is separate from the weights: a job that has waited
longer than ``aging_s`` is promoted one band (and again after another
``aging_s``), so even a weight-0 scavenger job eventually reaches the
interactive band and is served by plain round-robin there.  See
``docs/SCHEDULING.md`` for the full semantics and guarantees.
"""

from __future__ import annotations

from enum import IntEnum


class Priority(IntEnum):
    """Job priority band; lower value = more urgent."""

    INTERACTIVE = 0
    BATCH = 1
    SCAVENGER = 2


#: Default weighted-fair-queuing weights (credit accrual per scheduling
#: decision).  Roughly: under full contention, 12/16 of rounds go to
#: INTERACTIVE, 3/16 to BATCH, 1/16 to SCAVENGER.
DEFAULT_WEIGHTS: dict[Priority, int] = {
    Priority.INTERACTIVE: 12,
    Priority.BATCH: 3,
    Priority.SCAVENGER: 1,
}
