"""Per-job lifecycle traces.

A trace is an append-only list of *hops*.  Each hop is a plain 5-tuple

    (event, t, shard, slack, detail)

- ``event`` — one of the lowercase constants below (``SUBMITTED`` ...),
- ``t`` — wall-clock ``time.time()`` stamp (shared across worker processes
  on one host; clamped monotone *within* a trace so replay ordering never
  inverts on clock jitter),
- ``shard`` — shard id string, ``""`` when not shard-bound yet,
- ``slack`` — remaining deadline budget in seconds at stamp time, or
  ``None`` for deadline-free jobs,
- ``detail`` — small JSON-safe dict of hop-specific fields (backend mix,
  plan-cache hits, failover attempt, shed reason, ...).

Tuples (not a class) because hops cross the fabric wire inside
``JobEnvelope.hops`` / ``FabricJobReport.hops`` and must survive the
pickled codec and JSONL round-trips unchanged.
"""

from __future__ import annotations

import time
from typing import Optional

# lifecycle events, in rough pipeline order
SUBMITTED = "submitted"
ANALYZED = "analyzed"      # pre-flight static analysis verdict at admission
ADMITTED = "admitted"
QUEUED = "queued"
COALESCED = "coalesced"
DISPATCHED = "dispatched"
PREEMPTED = "preempted"
REQUEUED = "requeued"
ROUTED = "routed"
FAILOVER = "failover"
COMPLETED = "completed"
FAILED = "failed"
SHED = "shed"
CANCELLED = "cancelled"
# not a job-lifecycle hop: a controller actuation (closed-loop retune of
# admission/weights), logged under the synthetic job key "control" so
# the event log replays scheduling-policy changes alongside job timelines
RETUNED = "retuned"

#: every known event, in canonical lifecycle order (used by replay + tests)
EVENTS = (SUBMITTED, ANALYZED, ADMITTED, QUEUED, COALESCED, DISPATCHED,
          PREEMPTED, REQUEUED, ROUTED, FAILOVER, RETUNED, COMPLETED,
          FAILED, SHED, CANCELLED)

#: events that terminate a trace — exactly one may appear, and only last
TERMINAL = (COMPLETED, FAILED, SHED, CANCELLED)


def make_hop(event: str, shard: str = "", slack: Optional[float] = None,
             t: Optional[float] = None, **detail) -> tuple:
    """Build one wire-ready hop tuple."""
    if t is None:
        t = time.time()
    if slack is not None:
        slack = float(slack)
    return (event, float(t), str(shard), slack, dict(detail))


class JobTrace:
    """Mutable per-job hop log.

    Created by a :class:`~repro.service.observability.events.TraceSink`;
    ``stamp`` appends a hop (with within-trace monotone time clamp) and
    emits it to the sink's JSONL log when one is configured.
    """

    __slots__ = ("key", "tenant", "hops", "_sink")

    def __init__(self, key: str, tenant: str, hops=(), sink=None):
        self.key = key
        self.tenant = tenant
        self.hops = [tuple(h) for h in hops]
        self._sink = sink

    def stamp(self, event: str, shard: str = "",
              slack: Optional[float] = None, **detail) -> tuple:
        hop = make_hop(event, shard=shard, slack=slack, **detail)
        if self.hops and hop[1] < self.hops[-1][1]:
            # never let clock jitter order a later hop before an earlier one
            hop = (hop[0], self.hops[-1][1]) + hop[2:]
        self.hops.append(hop)
        if self._sink is not None:
            self._sink.emit_hop(self.key, self.tenant, hop)
        return hop

    def as_hops(self) -> tuple:
        """Immutable wire/report form: tuple of hop tuples."""
        return tuple(self.hops)

    @property
    def terminal(self) -> Optional[str]:
        for ev, *_rest in reversed(self.hops):
            if ev in TERMINAL:
                return ev
        return None

    def __len__(self) -> int:
        return len(self.hops)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        path = "→".join(h[0] for h in self.hops)
        return f"JobTrace({self.key!r}, {path})"
