"""Structured JSONL event log + the per-process trace sink.

``TraceLog`` appends one JSON object per hop to
``<trace_dir>/events-<component>-<pid>.jsonl`` and flushes per line, so a
SIGKILLed worker's already-stamped hops (e.g. the ``dispatched`` hop of
the job it died holding) survive on disk and are recoverable by
:mod:`repro.service.observability.replay`.

``TraceSink`` owns live :class:`JobTrace` objects for one component
(client, service shard, proc worker), moves finished traces into a
bounded ring, and fans every stamped hop out to the JSONL log.
"""

from __future__ import annotations

import json
import os
import threading
from collections import OrderedDict
from typing import Optional

from .trace import JobTrace

#: completed traces kept in memory per sink
COMPLETED_RING = 256


def hop_record(key: str, tenant: str, hop) -> dict:
    """JSON-safe record for one hop (the JSONL line schema)."""
    event, t, shard, slack, detail = hop
    return {"job": key, "tenant": tenant, "event": event, "t": t,
            "shard": shard, "slack": slack, "detail": dict(detail)}


def record_hop(rec: dict) -> tuple:
    """Inverse of :func:`hop_record` — rebuild the hop tuple."""
    return (rec["event"], rec["t"], rec.get("shard", ""),
            rec.get("slack"), dict(rec.get("detail", ())))


class TraceLog:
    """Append-only JSONL writer, one file per process per component."""

    def __init__(self, trace_dir: str, component: str):
        os.makedirs(trace_dir, exist_ok=True)
        self.path = os.path.join(
            trace_dir, f"events-{component}-{os.getpid()}.jsonl")
        self._lock = threading.Lock()
        self._fh = open(self.path, "a", encoding="utf-8")

    def append(self, rec: dict) -> None:
        line = json.dumps(rec, separators=(",", ":"), default=str)
        with self._lock:
            if self._fh.closed:
                return
            self._fh.write(line + "\n")
            self._fh.flush()  # survive kill -9 mid-job

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


class TraceSink:
    """Registry of live/finished traces for one component.

    Disabled sinks (``enabled=False`` and no ``trace_dir``) hand back
    ``None`` from :meth:`begin` so call sites stay zero-overhead via a
    plain ``if trace is not None`` guard.
    """

    def __init__(self, trace_dir: Optional[str] = None,
                 component: str = "service", enabled: bool = False):
        self.enabled = bool(enabled or trace_dir)
        self.component = component
        self.log = TraceLog(trace_dir, component) if trace_dir else None
        self._lock = threading.Lock()
        self._live: dict = {}
        self._done: OrderedDict = OrderedDict()

    # -- lifecycle --------------------------------------------------------
    def begin(self, key: str, tenant: str, hops=()) -> Optional[JobTrace]:
        """Open a trace.  ``hops`` seeds it with upstream history (e.g. the
        client-side hops an envelope carried over the wire); seed hops are
        NOT re-emitted to the JSONL log — they were logged at origin."""
        if not self.enabled:
            return None
        trace = JobTrace(key, tenant, hops=hops, sink=self)
        with self._lock:
            self._live[key] = trace
        return trace

    def finish(self, trace: Optional[JobTrace]) -> None:
        if trace is None:
            return
        with self._lock:
            self._live.pop(trace.key, None)
            self._done[trace.key] = trace
            while len(self._done) > COMPLETED_RING:
                self._done.popitem(last=False)

    def store(self, key: str, tenant: str, hops) -> Optional[JobTrace]:
        """Adopt an already-complete reassembled trace (client side, after
        a ``FabricJobReport`` arrives) without re-emitting its hops."""
        if not self.enabled:
            return None
        trace = JobTrace(key, tenant, hops=hops, sink=None)
        with self._lock:
            self._live.pop(key, None)
            self._done[key] = trace
            while len(self._done) > COMPLETED_RING:
                self._done.popitem(last=False)
        return trace

    # -- reads ------------------------------------------------------------
    def get(self, key: str) -> Optional[JobTrace]:
        with self._lock:
            return self._live.get(key) or self._done.get(key)

    def recent(self, n: int = 20) -> list:
        with self._lock:
            return list(self._done.values())[-n:]

    # -- raw emission (router-side hops with no JobTrace object) ----------
    def emit_hop(self, key: str, tenant: str, hop) -> None:
        if self.log is not None:
            self.log.append(hop_record(key, tenant, hop))

    def close(self) -> None:
        if self.log is not None:
            self.log.close()
