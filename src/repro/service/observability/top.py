"""Live text view over fabric/service telemetry snapshots.

``render(snapshot)`` turns one ``telemetry.global_snapshot()`` dict
(which, since the observability PR, embeds per-shard windowed stats from
worker heartbeats) into a small fixed-width dashboard: per-shard queue
depth, plan-cache hit rate, windowed throughput/attainment/p99, and any
autoscale/proc events.  It is pure string formatting — the same renderer
backs ``examples/agentic_search.py --live`` and the CLI:

    python -m repro.service.observability.top --snapshot snap.json
    python -m repro.service.observability.top --demo
"""

from __future__ import annotations

import argparse
import json


def _bar(frac: float, width: int = 10) -> str:
    frac = max(0.0, min(1.0, frac))
    fill = int(round(frac * width))
    return "#" * fill + "." * (width - fill)


def _fmt_windows(win: dict) -> str:
    return (f"thr {win.get('throughput_per_s', 0.0):7.1f}/s  "
            f"att {win.get('attainment', 1.0):.2f} "
            f"[{_bar(win.get('attainment', 1.0))}]  "
            f"p50 {win.get('dispatch_p50_s', 0.0) * 1e3:7.1f}ms  "
            f"p99 {win.get('dispatch_p99_s', 0.0) * 1e3:7.1f}ms  "
            f"depth≤{win.get('queue_depth_max', 0)}")


def _cache_rate(row: dict) -> str:
    pc = row.get("plan_cache") or {}
    hits, misses = pc.get("hits", 0), pc.get("misses", 0)
    total = hits + misses
    return f"{hits / total:.2f}" if total else "  --"


def render(snapshot: dict) -> str:
    """Format one global telemetry snapshot as a live-view frame."""
    # Fabric/service snapshots keep lifecycle counters in the windowed
    # block rather than at the top level; fall back there so the header
    # reflects live traffic, not zeros.
    win = snapshot.get("windows") or {}
    lines = ["stratum top — "
             f"{snapshot.get('jobs_submitted', win.get('submitted', 0))}"
             " submitted / "
             f"{snapshot.get('jobs_completed', win.get('completed', 0))}"
             " done / "
             f"""{snapshot.get('jobs_preempted',
                               snapshot.get('preemptions',
                                            win.get('preempted', 0)))}"""
             " preempted / "
             f"{snapshot.get('jobs_cancelled', 0)} cancelled"]
    dl = snapshot.get("deadline") or {}
    if dl.get("jobs"):
        lines.append(f"deadline SLO: {dl.get('met', 0)}/{dl['jobs']} met "
                     f"(attainment {dl.get('attainment', 0.0):.2f}, "
                     f"shed {dl.get('shed', 0)})")
    if win:
        lines.append("windowed: " + _fmt_windows(win))

    # compile-side telemetry: a service snapshot nests the PlanCache
    # snapshot under "plan_cache"; the fabric merge flattens summed
    # counters to "plan_cache_*" keys
    pc = snapshot.get("plan_cache") or {}
    flat = {k[len("plan_cache_"):]: v for k, v in snapshot.items()
            if k.startswith("plan_cache_")}
    cc = pc or flat
    if cc:
        hits = cc.get("hits", 0)
        misses = cc.get("misses", 0)
        total = hits + misses
        rate = cc.get("hit_rate", hits / total if total else 0.0)
        lines.append(
            f"compile: plan$ {rate:.2f} "
            f"({cc.get('entries', 0)} entries)  "
            f"async {cc.get('async_compiles', 0)} "
            f"(inflight {cc.get('inflight', 0)})  "
            f"spec hits {cc.get('speculative_hits', 0)}  "
            f"compile {cc.get('compile_time_s', 0.0):.2f}s")

    shards = snapshot.get("per_shard") or {}
    if shards:
        lines.append(f"{'shard':<10} {'state':<8} {'depth':>5} "
                     f"{'inflight':>8} {'plan$':>6}  windowed")
        for sid in sorted(shards):
            row = shards[sid]
            swin = row.get("windows")
            lines.append(
                f"{sid:<10} {row.get('state', 'live'):<8} "
                f"{row.get('queue_depth', 0):>5} "
                f"{row.get('inflight', 0):>8} "
                f"{_cache_rate(row):>6}  "
                f"{_fmt_windows(swin) if swin else '--'}")

    ctl = snapshot.get("control") or {}
    if ctl:
        adm = ctl.get("admission") or {}
        wts = ctl.get("weights") or {}
        if "max_queued_total" in adm:
            gate = (f"gate {adm.get('max_queued_total', '?')}"
                    f"/{adm.get('configured_max_queued_total', '?')}"
                    + (" GATED" if adm.get("gated") else ""))
        else:       # fabric-merged block carries counts, not one gate
            gate = (f"{ctl.get('gated_shards', 0)}"
                    f"/{ctl.get('shards_reporting', 0)} shards gated")
        lines.append(
            f"control: {ctl.get('retunes', 0)} retunes "
            f"(admission -{adm.get('shrinks', 0)}/+{adm.get('regrows', 0)}, "
            f"weights +{wts.get('boosts', 0)}/-{wts.get('decays', 0)}) "
            f"{gate}")

    proc = snapshot.get("proc") or {}
    if proc:
        lines.append(f"proc: {proc.get('workers', 0)} workers, "
                     f"{proc.get('spawns', 0)} spawns, "
                     f"{proc.get('worker_failures', 0)} failures, "
                     f"handoff {proc.get('handoff_entries_shipped', 0)}")
        scale = proc.get("autoscale")
        if scale:
            lines.append(f"autoscale: {scale}")
    return "\n".join(lines)


def demo_snapshot() -> dict:
    """Synthetic snapshot for --demo and renderer smoke tests."""
    win = {"throughput_per_s": 42.5, "attainment": 0.93,
           "dispatch_p50_s": 0.012, "dispatch_p99_s": 0.087,
           "queue_depth_max": 7}
    return {
        "jobs_submitted": 120, "jobs_completed": 113, "jobs_preempted": 4,
        "jobs_cancelled": 1,
        "deadline": {"jobs": 60, "met": 56, "attainment": 0.93, "shed": 2},
        "windows": win,
        "plan_cache_hits": 49, "plan_cache_misses": 14,
        "plan_cache_entries": 9, "plan_cache_hit_rate": 0.78,
        "plan_cache_async_compiles": 7, "plan_cache_inflight": 1,
        "plan_cache_speculative_hits": 3,
        "plan_cache_compile_time_s": 1.37,
        "per_shard": {
            "shard0": {"state": "live", "queue_depth": 3, "inflight": 1,
                       "plan_cache": {"hits": 37, "misses": 5},
                       "windows": dict(win)},
            "shard1": {"state": "retired", "queue_depth": 0, "inflight": 0,
                       "plan_cache": {"hits": 12, "misses": 9},
                       "windows": dict(win)},
        },
        "proc": {"workers": 2, "spawns": 3, "worker_failures": 1,
                 "handoff_entries_shipped": 18,
                 "autoscale": {"target": 2, "reason": "backlog"}},
        "control": {"retunes": 5,
                    "admission": {"configured_max_queued_total": 1024,
                                  "max_queued_total": 256, "gated": True,
                                  "shrinks": 2, "regrows": 1},
                    "weights": {"factors": {0: 2.0}, "boosts": 1,
                                "decays": 1}},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service.observability.top",
        description="render a telemetry snapshot as a live text view")
    ap.add_argument("--snapshot", help="path to a JSON global_snapshot dump")
    ap.add_argument("--demo", action="store_true",
                    help="render a synthetic snapshot")
    args = ap.parse_args(argv)
    if args.snapshot:
        with open(args.snapshot, encoding="utf-8") as fh:
            snap = json.load(fh)
    elif args.demo:
        snap = demo_snapshot()
    else:
        ap.error("one of --snapshot or --demo is required")
        return 2
    print(render(snap))
    return 0


if __name__ == "__main__":  # pragma: no cover - CLI entry
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # e.g. `... | head`
        raise SystemExit(0)
