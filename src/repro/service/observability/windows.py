"""Windowed throughput/attainment collector.

A ring buffer of fixed-width time windows (cf. the dashboard
``collector/throughput.rs`` idiom from ROADMAP): each window accumulates
submit/complete/preempt/shed counters, deadline outcomes, max queue depth
and raw dispatch latencies; ``snapshot()`` aggregates the ring into a
JSON-safe dict with throughput, attainment and nearest-rank p50/p99.

Snapshots from many shards merge with :func:`merge_window_snapshots`
(used by ``FabricTelemetry`` and ``merge_tenant_snapshots``): counters
sum, depth maxes, and percentiles are recomputed from the concatenated
(capped) latency samples.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from typing import Optional

#: cap on latency samples kept per window / shipped per snapshot, so
#: heartbeat frames and merges stay bounded under floods
MAX_SAMPLES = 512

_COUNTERS = ("submitted", "completed", "preempted", "shed",
             "deadline_jobs", "deadline_met")


def _sum_by_band(rows) -> dict:
    """Sum per-band deadline outcomes across windows/snapshots.

    Accepts both the in-window ``{band: [jobs, met]}`` form and the
    snapshot ``{band: {"deadline_jobs": .., "deadline_met": ..}}`` form;
    band keys are normalized to int (heartbeat/JSON round-trips turn
    them into strings)."""
    out: dict = {}
    for row in rows:
        if not row:
            continue
        for k, v in row.items():
            if isinstance(v, dict):
                jobs = v.get("deadline_jobs", 0)
                met = v.get("deadline_met", 0)
            else:
                jobs, met = v[0], v[1]
            agg = out.setdefault(int(k), [0, 0])
            agg[0] += jobs
            agg[1] += met
    return {b: {"deadline_jobs": j, "deadline_met": m,
                "attainment": (m / j) if j else 1.0}
            for b, (j, m) in out.items()}


def percentile(samples, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a sequence."""
    s = sorted(samples)
    if not s:
        return 0.0
    rank = math.ceil(q / 100.0 * len(s)) - 1
    return float(s[max(0, min(len(s) - 1, rank))])


def _new_window() -> dict:
    w = {k: 0 for k in _COUNTERS}
    w["queue_depth_max"] = 0
    w["latency"] = []
    # per-band deadline outcomes: band int -> [jobs, met] — feeds the WFQ
    # weight rebalancer (control/), which needs attainment per band, not
    # just the global rate
    w["by_band"] = {}
    return w


class ThroughputCollector:
    """Ring buffer of fixed-width windows over service activity.

    Thread-safe; every ``record_*`` hook first rolls the ring forward to
    the current window (clamped so an idle gap never spins more than
    ``n_windows`` catch-up steps).
    """

    def __init__(self, window_s: float = 1.0, n_windows: int = 32,
                 clock=time.monotonic):
        if window_s <= 0:
            raise ValueError("window_s must be > 0")
        if n_windows < 1:
            raise ValueError("n_windows must be >= 1")
        self.window_s = float(window_s)
        self.n_windows = int(n_windows)
        self._clock = clock
        self._lock = threading.Lock()
        self._epoch = clock()
        self._index = 0                       # index of the open window
        self._closed = deque(maxlen=self.n_windows)
        self._current = _new_window()

    # -- ring mechanics ---------------------------------------------------
    def _roll(self) -> None:
        idx = int((self._clock() - self._epoch) / self.window_s)
        if idx <= self._index:
            return
        steps = idx - self._index
        if steps > self.n_windows:
            # long idle gap: the old current window and any intermediate
            # empties would all fall off the ring anyway — just blank it
            for _ in range(self.n_windows):
                self._closed.append(_new_window())
        else:
            self._closed.append(self._current)
            for _ in range(steps - 1):
                self._closed.append(_new_window())
        self._current = _new_window()
        self._index = idx

    # -- record hooks -----------------------------------------------------
    def record_submit(self) -> None:
        with self._lock:
            self._roll()
            self._current["submitted"] += 1

    def record_dispatch(self, latency_s: float, queue_depth: int = 0) -> None:
        with self._lock:
            self._roll()
            w = self._current
            if len(w["latency"]) < MAX_SAMPLES:
                w["latency"].append(float(latency_s))
            if queue_depth > w["queue_depth_max"]:
                w["queue_depth_max"] = int(queue_depth)

    def record_completion(self, n: int = 1) -> None:
        with self._lock:
            self._roll()
            self._current["completed"] += int(n)

    def record_preemption(self, n: int = 1) -> None:
        with self._lock:
            self._roll()
            self._current["preempted"] += int(n)

    def record_shed(self, n: int = 1) -> None:
        with self._lock:
            self._roll()
            self._current["shed"] += int(n)

    def record_deadline_outcome(self, met: bool,
                                band: Optional[int] = None) -> None:
        with self._lock:
            self._roll()
            self._current["deadline_jobs"] += 1
            if met:
                self._current["deadline_met"] += 1
            if band is not None:
                row = self._current["by_band"].setdefault(int(band), [0, 0])
                row[0] += 1
                if met:
                    row[1] += 1

    # -- read side --------------------------------------------------------
    def snapshot(self) -> dict:
        """Aggregate the ring (closed windows + the open one)."""
        with self._lock:
            self._roll()
            windows = list(self._closed) + [self._current]
            return self._aggregate(windows)

    def _aggregate(self, windows) -> dict:
        out = {k: sum(w[k] for w in windows) for k in _COUNTERS}
        out["queue_depth_max"] = max(
            (w["queue_depth_max"] for w in windows), default=0)
        samples: list = []
        for w in windows:
            samples.extend(w["latency"])
        samples = samples[-MAX_SAMPLES:]
        span_s = len(windows) * self.window_s
        out["window_s"] = self.window_s
        out["n_windows"] = len(windows)
        out["span_s"] = span_s
        out["throughput_per_s"] = out["completed"] / span_s if span_s else 0.0
        out["attainment"] = (out["deadline_met"] / out["deadline_jobs"]
                             if out["deadline_jobs"] else 1.0)
        out["dispatch_p50_s"] = percentile(samples, 50)
        out["dispatch_p99_s"] = percentile(samples, 99)
        out["latency_samples"] = samples
        by_band = _sum_by_band(w.get("by_band") for w in windows)
        if by_band:
            out["by_band"] = by_band
        out["per_window"] = [
            {k: w[k] for k in _COUNTERS} | {
                "queue_depth_max": w["queue_depth_max"],
                "dispatch_p50_s": percentile(w["latency"], 50),
                "dispatch_p99_s": percentile(w["latency"], 99),
            }
            for w in windows]
        return out


def merge_window_snapshots(snaps) -> Optional[dict]:
    """Merge per-shard ``ThroughputCollector.snapshot()`` dicts.

    Counters and throughput sum, queue depth maxes, attainment is
    recomputed from the summed deadline outcomes, and p50/p99 come from
    the concatenated (capped) latency samples.  Returns ``None`` when no
    snapshot in ``snaps`` is present.
    """
    snaps = [s for s in snaps if s]
    if not snaps:
        return None
    out = {k: sum(s.get(k, 0) for s in snaps) for k in _COUNTERS}
    out["queue_depth_max"] = max(s.get("queue_depth_max", 0) for s in snaps)
    samples: list = []
    for s in snaps:
        samples.extend(s.get("latency_samples", ()))
    samples = samples[-MAX_SAMPLES:]
    out["window_s"] = snaps[0].get("window_s", 1.0)
    out["n_windows"] = max(s.get("n_windows", 0) for s in snaps)
    out["span_s"] = max(s.get("span_s", 0.0) for s in snaps)
    out["throughput_per_s"] = sum(s.get("throughput_per_s", 0.0)
                                  for s in snaps)
    out["attainment"] = (out["deadline_met"] / out["deadline_jobs"]
                         if out["deadline_jobs"] else 1.0)
    out["dispatch_p50_s"] = percentile(samples, 50)
    out["dispatch_p99_s"] = percentile(samples, 99)
    out["latency_samples"] = samples
    by_band = _sum_by_band(s.get("by_band") for s in snaps)
    if by_band:
        out["by_band"] = by_band
    return out
