"""Postmortem replay of a JSONL trace directory.

Every process that participated in a run (client, in-process shards,
proc-fabric workers) wrote its own ``events-<component>-<pid>.jsonl``
under the shared ``trace_dir``.  Replay merges them all, reassembles one
per-job timeline (hops sorted by stamp time, de-duplicated on the full
hop tuple — the same hop logged by two components counts once), and
derives per-shard gantt summaries of dispatch→completion occupancy.

    python -m repro.service.observability.replay /tmp/traces [--job KEY]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

from .trace import DISPATCHED, FAILOVER, PREEMPTED, TERMINAL


def load_events(trace_dir: str) -> list:
    """All hop records from every JSONL file under ``trace_dir``.

    A torn final line (process killed mid-write) is skipped, never fatal.
    """
    records = []
    for path in sorted(glob.glob(os.path.join(trace_dir, "*.jsonl"))):
        component = os.path.basename(path)
        with open(path, encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn tail of a killed writer
                rec["source"] = component
                records.append(rec)
    return records


def reassemble(records) -> dict:
    """Per-job timelines: ``{job_key: [hop_record, ...]}`` sorted by time.

    Identical hops logged by more than one component collapse to one.
    """
    jobs = defaultdict(list)
    seen = set()
    for rec in records:
        ident = (rec["job"], rec["event"], rec["t"], rec.get("shard", ""),
                 rec.get("slack"))
        if ident in seen:
            continue
        seen.add(ident)
        jobs[rec["job"]].append(rec)
    for hops in jobs.values():
        hops.sort(key=lambda r: r["t"])
    return dict(jobs)


def job_timeline(timelines: dict, key: str) -> list:
    return timelines.get(key, [])


def shard_gantt(timelines: dict) -> dict:
    """Per-shard dispatch spans: ``{shard: [(job, t0, t1, outcome), ...]}``.

    A span opens at each ``dispatched`` hop and closes at the next
    preempted/terminal hop of the same job; a span left open (worker
    killed mid-job) closes at the job's last known stamp with outcome
    ``"lost"``.
    """
    gantt = defaultdict(list)
    for key, hops in timelines.items():
        open_span = None  # (shard, t0)
        for rec in hops:
            ev = rec["event"]
            if ev == DISPATCHED:
                if open_span is not None:
                    shard, t0 = open_span
                    gantt[shard].append((key, t0, rec["t"], "lost"))
                open_span = (rec.get("shard", ""), rec["t"])
            elif open_span is not None and (ev == PREEMPTED
                                            or ev in TERMINAL):
                shard, t0 = open_span
                gantt[shard].append((key, t0, rec["t"], ev))
                open_span = None
        if open_span is not None:
            shard, t0 = open_span
            gantt[shard].append((key, t0, hops[-1]["t"], "lost"))
    for spans in gantt.values():
        spans.sort(key=lambda s: s[1])
    return dict(gantt)


def summarize(timelines: dict) -> dict:
    """Run-level rollup for the CLI header."""
    outcomes = defaultdict(int)
    n_failover = 0
    for hops in timelines.values():
        events = [r["event"] for r in hops]
        n_failover += events.count(FAILOVER)
        terminal = next((e for e in reversed(events) if e in TERMINAL),
                        "open")
        outcomes[terminal] += 1
    return {"jobs": len(timelines), "outcomes": dict(outcomes),
            "failovers": n_failover}


def format_timeline(key: str, hops) -> str:
    lines = [f"job {key}"]
    t0 = hops[0]["t"] if hops else 0.0
    for rec in hops:
        slack = rec.get("slack")
        slack_s = f" slack={slack:+.3f}s" if slack is not None else ""
        shard = f" @{rec['shard']}" if rec.get("shard") else ""
        detail = rec.get("detail") or {}
        extra = " ".join(f"{k}={v}" for k, v in sorted(detail.items()))
        lines.append(f"  +{rec['t'] - t0:8.3f}s {rec['event']:<10}"
                     f"{shard}{slack_s}{'  ' + extra if extra else ''}")
    return "\n".join(lines)


def format_gantt(gantt: dict) -> str:
    lines = []
    for shard in sorted(gantt):
        spans = gantt[shard]
        busy = sum(t1 - t0 for _, t0, t1, _ in spans)
        lines.append(f"shard {shard or '?'}: {len(spans)} spans, "
                     f"{busy:.3f}s busy")
        for job, t0, t1, outcome in spans:
            lines.append(f"  {job}  {t1 - t0:8.3f}s  → {outcome}")
    return "\n".join(lines) or "(no dispatch spans)"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service.observability.replay",
        description="reconstruct per-job timelines and per-shard gantt "
                    "summaries from a trace_dir")
    ap.add_argument("trace_dir")
    ap.add_argument("--job", help="print the full timeline of one job key")
    ap.add_argument("--gantt", action="store_true",
                    help="print per-shard dispatch spans")
    args = ap.parse_args(argv)

    timelines = reassemble(load_events(args.trace_dir))
    summary = summarize(timelines)
    print(f"{summary['jobs']} jobs, outcomes {summary['outcomes']}, "
          f"{summary['failovers']} failovers")
    if args.job:
        print(format_timeline(args.job, job_timeline(timelines, args.job)))
    elif args.gantt:
        print(format_gantt(shard_gantt(timelines)))
    else:
        for key in sorted(timelines):
            hops = timelines[key]
            path = "→".join(r["event"] for r in hops)
            print(f"  {key}: {path}")
    return 0


if __name__ == "__main__":  # pragma: no cover - CLI entry
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # e.g. `... | head`
        raise SystemExit(0)
