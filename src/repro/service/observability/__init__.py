"""Per-job lifecycle tracing, windowed stats, event log, replay, live view.

See ``docs/OBSERVABILITY.md``.  Enable with
``StratumConfig.make(..., trace=True)`` (in-memory traces on every
``JobReport``) or ``trace_dir="/path"`` (plus a durable JSONL event log
replayable via ``python -m repro.service.observability.replay``).
"""

from .events import TraceLog, TraceSink, hop_record, record_hop
from .trace import (ADMITTED, ANALYZED, CANCELLED, COALESCED, COMPLETED,
                    DISPATCHED, EVENTS, FAILED, FAILOVER, PREEMPTED, QUEUED,
                    REQUEUED, RETUNED, ROUTED, SHED, SUBMITTED, TERMINAL,
                    JobTrace, make_hop)
from .windows import (MAX_SAMPLES, ThroughputCollector,
                      merge_window_snapshots, percentile)

__all__ = [
    "JobTrace", "make_hop", "EVENTS", "TERMINAL",
    "SUBMITTED", "ANALYZED", "ADMITTED", "QUEUED", "COALESCED", "DISPATCHED",
    "PREEMPTED", "REQUEUED", "ROUTED", "FAILOVER", "RETUNED", "COMPLETED",
    "FAILED", "SHED", "CANCELLED",
    "TraceSink", "TraceLog", "hop_record", "record_hop",
    "ThroughputCollector", "merge_window_snapshots", "percentile",
    "MAX_SAMPLES",
]
