"""The stratum execution service — a persistent, multi-tenant runtime.

Decouples agent *planning* from pipeline *execution* (paper §3): agents
hold :class:`~repro.service.session.Session` handles and submit batches
without blocking; the service side runs

    submit → admission control → priority-stratified fair queue → coalescer
           → optimizer (cross-agent CSE) → memory gate → Runtime
           → result demux → futures + per-tenant telemetry

Key properties:

* **priority-aware fair scheduling** — jobs carry a
  :class:`~repro.service.priority.Priority`; the queue serves bands by
  weighted fair queuing (INTERACTIVE ≫ BATCH ≫ SCAVENGER by default) with
  round-robin and a per-tenant cap inside each band, and ages long-waiting
  jobs upward so nothing starves (see ``docs/SCHEDULING.md``);
* **cooperative preemption** — a running low-priority super-batch polls the
  queue at wave boundaries and yields when more urgent work is waiting: its
  jobs are requeued at the front of their band carrying every completed
  intermediate (*salvage*), so the re-run redoes no finished work.  A job
  yields at most ``max_preemptions_per_job`` times, then runs to completion;
* **deadline-aware scheduling** — jobs may carry ``deadline_s``: inside the
  WFQ-chosen band, earliest-deadline-first breaks ties, jobs whose slack
  fell below ``deadline_tight_slack_s`` dispatch alone (never coalesced
  into a large super-batch), and jobs already past their deadline are shed
  with :class:`~repro.service.queue.DeadlineExceeded`; attainment is
  tracked per tenant in telemetry;
* **cross-agent work sharing** — jobs gathered in one round are merged into
  a super-batch before optimization, so CSE dedups identical sub-DAGs
  emitted by *different* agents, and all tenants share one thread-safe
  :class:`IntermediateCache` with per-tenant charge accounting and quota
  arbitration (an over-quota tenant's entries are evicted first);
* **global memory budget** — a super-batch only starts executing once its
  planned peak memory fits under the service budget alongside the other
  in-flight super-batches;
* **failure isolation** — an :class:`ExecutionError` fails only the
  coalesced jobs whose DAG contains the failing op; innocent-bystander jobs
  from the same super-batch are re-executed without the poisoned peer.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional, Sequence

from ..core.analysis import (AnalysisError, AnalysisReport, analyze,
                             validate_wiring)
from ..core.api import ALL_FEATURES, Stratum
from ..core.backends import make_backends
from ..core.cache import IntermediateCache
from ..core.fusion import PipelineBatch
from ..core.plan_cache import PlanCache
from ..core.runtime import ExecutionError, ExecutionPreempted, Runtime
from .coalesce import SuperBatch, coalesce, cross_agent_dedup, reachable_sigs
from .control import ControlPolicy, ServiceController
from .observability import (ANALYZED, CANCELLED, COALESCED, COMPLETED,
                            DISPATCHED, FAILED, PREEMPTED, SHED, SUBMITTED,
                            ThroughputCollector, TraceSink)
from .priority import Priority
from .queue import AdmissionError, FairQueue, Job
from .session import PipelineFuture, Session
from .telemetry import ServiceTelemetry


@dataclass
class ServiceConfig:
    memory_budget_bytes: int = 8 << 30
    cache_fraction: float = 0.10
    spill_dir: Optional[str] = None
    platform: str = ""
    enable: Sequence[str] = ALL_FEATURES
    hardware_threads: int = 0
    jit_cache_dir: Optional[str] = None
    # admission control
    max_queued_total: int = 1024
    max_queued_per_tenant: int = 256
    # pre-flight static analysis at admission (docs/ANALYSIS.md): when on,
    # every submit() runs the wiring/shape/lint analyzer and statically
    # invalid pipelines raise AnalysisError BEFORE taking a queue slot.
    # Per-submit SubmitOptions(verify=...) overrides this default either
    # way.  Clean verdicts are cached by structural signature, so an
    # agent's refinement stream pays the analyzer once per DAG shape.
    admission_analysis: bool = False
    # coalescing / fairness
    coalesce_window_s: float = 0.02
    coalesce_max_jobs: int = 16
    max_jobs_per_tenant_per_round: int = 2
    # priority scheduling (docs/SCHEDULING.md)
    priority_aware: bool = True          # False → priority-blind round-robin
    priority_weights: Optional[dict] = None   # Priority → WFQ weight
    aging_s: Optional[float] = 5.0       # starvation aging; None disables
    preemption: bool = True              # cooperative wave-boundary yields
    # liveness: every dispatch completes ≥1 wave before it may yield again,
    # so even a generous cap cannot livelock a low-priority job — the cap
    # only bounds resume overhead (re-optimize + salvage replay per yield)
    max_preemptions_per_job: int = 8
    # deadline-aware scheduling (docs/SCHEDULING.md): EDF tie-break inside
    # priority bands, shedding of expired jobs (futures fail with
    # DeadlineExceeded), and tight-deadline jobs dispatched alone instead
    # of coalesced; False records deadlines but schedules blind
    deadline_aware: bool = True
    # slack below which a deadline job refuses coalescing and runs alone
    deadline_tight_slack_s: float = 0.25
    # cap a compiled segment's summed est_time so a jitted program (which
    # has no internal yield points) can delay an interactive/deadline
    # preempt by at most one bounded slice; None = unbounded segments
    segment_time_budget_s: Optional[float] = None
    # shared-cache cross-tenant arbitration
    cache_arbitration: str = "quota"     # "quota" | "lru"
    cache_tenant_quota_fraction: float = 0.5
    # compiled plan-segment backends: jax-homogeneous segments execute as
    # one jitted program, cached per shard by structural signature — so
    # the thousands of structurally identical DAGs an agentic search
    # emits compile once; False → per-op dispatch only (bench baseline)
    compiled_segments: bool = True
    plan_cache_entries: int = 256
    # compiled-segment "next gear" (docs/ARCHITECTURE.md §7), off by
    # default: compile_async moves trace+jit onto a bounded background
    # thread (first touch of a new structural signature dispatches per-op
    # instead of blocking); batch_variants traces homogeneous
    # hyperparameter-variant groups as ONE vmapped solve; a positive
    # speculative_depth sizes the low-priority warm-up lane that
    # Session.precompile feeds with predicted-next plans
    compile_async: bool = False
    batch_variants: bool = False
    speculative_depth: int = 0
    # concurrency
    n_executors: int = 2
    # identity when the service runs as one shard of a sharded fabric
    # (src/repro/service/fabric/); "" for a standalone service
    shard_id: str = ""
    # observability (docs/OBSERVABILITY.md): trace=True keeps per-job hop
    # logs in memory and returns them on every JobReport; trace_dir also
    # appends each hop to a per-process JSONL event log replayable with
    # `python -m repro.service.observability.replay`
    trace: bool = False
    trace_dir: Optional[str] = None
    # windowed throughput/attainment collector (ring of fixed-width
    # windows, surfaced under telemetry global_snapshot()["windows"])
    window_s: float = 1.0
    n_windows: int = 32
    # closed-loop control (docs/SCHEDULING.md §5): a ControlPolicy enables
    # the feedback controller that retunes admission limits and WFQ
    # weights from the windowed collector; None (default) keeps every
    # knob at its configured constant — the dispatch loop then pays
    # exactly one None check per tick
    control: Optional[ControlPolicy] = None


@dataclass
class JobReport:
    """Per-job view of a (possibly merged) execution."""
    tenant: str
    job_id: int
    queue_wait_s: float
    coalesced_with: int          # other jobs in the same super-batch
    ops_shared_cross_agent: int  # this job's ops shared with another tenant
    cache_hits: int
    per_backend: dict
    stratum: object              # the super-batch StratumReport-ish payload
    run: object = None           # super-batch RunReport (convenience alias)
    priority: Priority = Priority.BATCH
    preemptions: int = 0         # times this job's super-batch yielded
    ops_salvaged: int = 0        # ops restored from preemption salvage
    deadline_s: object = None    # the job's SLO (None = no deadline)
    deadline_met: object = None  # None without a deadline, else bool
    tags: tuple = ()             # opaque caller tags, echoed back
    trace: tuple = ()            # lifecycle hop log (empty unless tracing)


class StratumService:
    """Persistent multi-tenant execution service over one optimizing
    runtime.  Thread-safe; one instance serves many concurrent agents."""

    def __init__(self, config: Optional[ServiceConfig] = None,
                 autostart: bool = True, **overrides):
        if config is None:
            config = ServiceConfig(**overrides)
        elif overrides:
            raise ValueError("pass either config or keyword overrides")
        self.config = config
        self.cache: Optional[IntermediateCache] = None
        if "cache" in config.enable:
            self.cache = IntermediateCache(
                budget_bytes=int(config.memory_budget_bytes
                                 * config.cache_fraction),
                spill_dir=config.spill_dir,
                arbitration=config.cache_arbitration,
                tenant_quota_fraction=config.cache_tenant_quota_fraction)
        # compiled-plan cache, one per shard: every tenant's structurally
        # identical plans share compiled segments, and signature-locality
        # routing on the fabric turns into compiled-plan locality
        self.plan_cache: Optional[PlanCache] = None
        if config.compiled_segments:
            self.plan_cache = PlanCache(
                capacity=config.plan_cache_entries,
                compile_async=config.compile_async,
                speculative_depth=config.speculative_depth)
        self._backends = make_backends(self.plan_cache,
                                       compiled=config.compiled_segments,
                                       batch_variants=config.batch_variants)
        # the optimizer: compile-only use of the existing session object,
        # sharing the service cache (Stratum(cache=...) injection)
        self._optimizer = Stratum(
            memory_budget_bytes=config.memory_budget_bytes,
            platform=config.platform,
            enable=config.enable,
            hardware_threads=config.hardware_threads,
            jit_cache_dir=config.jit_cache_dir,
            cache=self.cache,
            compiled_segments=config.compiled_segments,
            plan_cache=self.plan_cache,
            segment_time_budget_s=config.segment_time_budget_s)
        self.queue = FairQueue(
            max_queued_total=config.max_queued_total,
            max_queued_per_tenant=config.max_queued_per_tenant,
            weights=config.priority_weights,
            aging_s=config.aging_s,
            priority_aware=config.priority_aware,
            deadline_aware=config.deadline_aware)
        self.windows = ThroughputCollector(window_s=config.window_s,
                                           n_windows=config.n_windows)
        self.telemetry = ServiceTelemetry(cache=self.cache,
                                          plan_cache=self.plan_cache,
                                          windows=self.windows)
        # per-job lifecycle traces (no-op object when tracing is off)
        self.traces = TraceSink(
            trace_dir=config.trace_dir,
            component=f"shard-{config.shard_id}" if config.shard_id
            else "service",
            enabled=config.trace)
        self.queue.on_shed = self._on_deadline_shed
        # closed-loop controller (control/): retunes admission + WFQ
        # weights from the windowed collector; None when control is off
        self.controller: Optional[ServiceController] = None
        if config.control is not None:
            self.controller = ServiceController(
                config.control, queue=self.queue, windows=self.windows,
                trace_sink=self.traces, shard_id=config.shard_id)
            self.telemetry.control_provider = self.controller.snapshot
        # admission-analysis verdict cache: structural signatures of
        # batches that analyzed clean.  Only OK verdicts are cached —
        # rejections re-analyze so the error carries exact provenance.
        # Guarded by _verdict_lock (submit runs on many caller threads).
        self._verdict_ok: "OrderedDict" = OrderedDict()
        self._verdict_max = 512
        self._verdict_lock = threading.Lock()
        self._job_ids = itertools.count()
        self._running = False
        self._dispatcher: Optional[threading.Thread] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._slots = threading.Semaphore(config.n_executors)
        # mirrors the semaphore so preempt checks can ask "is any executor
        # idle?" without touching semaphore internals.  Unlocked READS are
        # fine (a stale value delays/spares one yield by one poll); writes
        # go through _adjust_free_slots — a bare `+=` is a non-atomic
        # read-modify-write and concurrent finishes would drift the counter
        # permanently, silently disabling preemption
        self._free_slots = config.n_executors
        self._free_slots_lock = threading.Lock()
        # global memory gate across concurrent super-batches
        self._mem_cond = threading.Condition()
        self._mem_inflight = 0
        # in-flight job accounting for drain on stop()
        self._inflight_cond = threading.Condition()
        self._inflight_jobs = 0
        if autostart:
            self.start()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "StratumService":
        if self._running:
            return self
        self.queue.reopen()     # stop() closed admissions; accept again
        self._running = True
        self._pool = ThreadPoolExecutor(
            max_workers=self.config.n_executors,
            thread_name_prefix="stratum-exec")
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="stratum-dispatch", daemon=True)
        self._dispatcher.start()
        return self

    def stop(self, drain: bool = True) -> None:
        if drain and self._running:
            # only a live dispatcher can drain the queue; with autostart=False
            # and no start(), draining would spin forever
            with self._inflight_cond:
                while self.queue.pending() or self._inflight_jobs:
                    self._inflight_cond.wait(timeout=0.1)
        self._running = False
        self.queue.kick()
        if self._dispatcher is not None:
            self._dispatcher.join(timeout=10)
        for job in self.queue.close():
            job.future._set_exception(
                AdmissionError("service stopped before job ran"))
            self.telemetry.record_job_failed(job.tenant)
            if job.trace is not None:
                job.trace.stamp(FAILED, shard=self.shard_id,
                                reason="service stopped")
                self.traces.finish(job.trace)
        if self._pool is not None:
            self._pool.shutdown(wait=True)
        if self.plan_cache is not None:
            # drop queued background compiles and join the compile worker
            # (bounded) — a proc-fabric worker must not be held open past
            # SIGTERM by an inflight trace+jit.  Idempotent; no-op when
            # compile_async is off.
            self.plan_cache.close()
        self.traces.close()

    def __enter__(self) -> "StratumService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- tenant API --------------------------------------------------------
    def session(self, tenant: str) -> Session:
        return Session(self, tenant)

    # -- shard introspection (used by the fabric's router/telemetry) -------
    @property
    def shard_id(self) -> str:
        return self.config.shard_id

    def queue_depth(self) -> int:
        """Jobs admitted but not yet dispatched."""
        return self.queue.pending()

    def inflight(self) -> int:
        """Jobs dispatched and currently executing."""
        with self._inflight_cond:
            return self._inflight_jobs

    def submit(self, tenant: str, batch: PipelineBatch,
               priority: Priority = Priority.BATCH,
               affinity: Optional[str] = None,
               deadline_s: Optional[float] = None,
               tags: Sequence[str] = (),
               trace_key: Optional[str] = None,
               trace_hops: Sequence[tuple] = (),
               verify: Optional[bool] = None) -> PipelineFuture:
        # ``affinity`` is a sharded-fabric routing hint; a standalone
        # service has exactly one place to run the job, so it is accepted
        # (keeping Session portable across backends) and ignored.
        # ``trace_key``/``trace_hops`` let a fabric transport continue a
        # trace begun client-side: the key is the envelope id and the hops
        # are the history the envelope carried over the wire
        del affinity
        priority = Priority(priority)
        job_id = next(self._job_ids)
        future = PipelineFuture(job_id, tenant, priority)
        trace = self.traces.begin(trace_key or f"j{job_id}", tenant,
                                  hops=trace_hops)

        def _cancel(jid: int) -> bool:
            ok = self.queue.cancel(jid)
            if ok:
                self.telemetry.record_job_cancelled(tenant)
                if trace is not None:
                    trace.stamp(CANCELLED, shard=self.shard_id)
                    self.traces.finish(trace)
            return ok

        future._cancel_hook = _cancel
        job = Job(id=job_id, tenant=tenant, batch=batch, future=future,
                  priority=priority, deadline_s=deadline_s,
                  tags=tuple(tags), trace=trace)
        if trace is not None and not trace_hops:
            # a seeded trace (fabric continuation) was already stamped
            # SUBMITTED client-side
            trace.stamp(SUBMITTED, shard=self.shard_id,
                        slack=self._slack(job), priority=priority.name)
        do_verify = (verify if verify is not None
                     else self.config.admission_analysis)
        if do_verify:
            try:
                self._admission_analysis(tenant, batch, trace)
            except AnalysisError:
                if trace is not None:
                    trace.stamp(FAILED, shard=self.shard_id,
                                reason="analysis")
                    self.traces.finish(trace)
                raise
        try:
            self.queue.push(job)           # may raise AdmissionError
        except AdmissionError:
            if trace is not None:
                trace.stamp(FAILED, shard=self.shard_id, reason="admission")
                self.traces.finish(trace)
            raise
        self.telemetry.record_submit(tenant, priority)
        return future

    # -- pre-flight static analysis (docs/ANALYSIS.md) ---------------------
    @staticmethod
    def _batch_structural_key(batch: PipelineBatch):
        return tuple(ref.op.structural_signature + f":{ref.index}"
                     for ref in batch.fused_sinks())

    def _admission_analysis(self, tenant: str, batch: PipelineBatch,
                            trace) -> None:
        """Run the pre-flight analyzer; raise AnalysisError on a statically
        invalid batch.  Clean verdicts are cached by structural signature
        (shape analysis depends on structure, not tunable values or seeds)
        so agent refinement streams pay the analyzer once per DAG shape."""
        try:
            skey = self._batch_structural_key(batch)
        except Exception:  # noqa: BLE001 — e.g. cyclic DAG; analyze below
            skey = None    # will produce the real structured finding
        if skey is not None:
            with self._verdict_lock:
                cached = skey in self._verdict_ok
                if cached:
                    self._verdict_ok.move_to_end(skey)
            if cached:
                self.telemetry.record_analysis(
                    tenant, rejected=False, cached=True)
                if trace is not None:
                    trace.stamp(ANALYZED, shard=self.shard_id, cached=True)
                return
        report = analyze(
            batch, platform=self.config.platform,
            memory_budget_bytes=self.config.memory_budget_bytes,
            lowering="lowering" in self.config.enable,
            feasibility=False)
        self.telemetry.record_analysis(
            tenant, rejected=not report.ok,
            n_warnings=len(report.warnings),
            rules=[f.rule for f in report.findings
                   if f.severity != "info"],
            time_s=report.analysis_time_s)
        if not report.ok:
            raise AnalysisError(report.errors)
        if skey is not None:
            with self._verdict_lock:
                self._verdict_ok[skey] = True
                self._verdict_ok.move_to_end(skey)
                while len(self._verdict_ok) > self._verdict_max:
                    self._verdict_ok.popitem(last=False)
        if trace is not None:
            trace.stamp(ANALYZED, shard=self.shard_id,
                        warnings=len(report.warnings),
                        analysis_ms=round(report.analysis_time_s * 1e3, 3))

    def analyze(self, batch: PipelineBatch, *,
                feasibility: bool = True) -> AnalysisReport:
        """Full static analysis of ``batch`` against this service's
        configuration — wiring, shape inference, lint and (by default)
        compile-feasibility classification.  Jax segments that probe clean
        are marked pre-verified on this service's execution backend, so
        their first real dispatch skips the execute-time eval_shape probe.
        Never executes or queues anything."""
        jax_be = self._backends.get("jax") if feasibility else None
        allowed = (("python", "jax", "pallas")
                   if "selection" in self.config.enable else ("python",))
        return analyze(
            batch, platform=self.config.platform,
            memory_budget_bytes=self.config.memory_budget_bytes,
            lowering="lowering" in self.config.enable,
            feasibility=feasibility, allowed_backends=allowed,
            segment_time_budget_s=self.config.segment_time_budget_s,
            jax_backend=jax_be)

    def precompile(self, tenant: str, batch: PipelineBatch) -> dict:
        """Speculative warm-up: optimize+plan ``batch`` WITHOUT queueing
        or executing it, and enqueue its jax segments on the plan cache's
        low-priority compile lane, so a likely-next submission of the same
        structure finds its programs warm.  The planning pass runs inline
        on the caller's thread (it is pure optimizer work — no queue slot,
        no admission, no telemetry side effects beyond the plan-cache
        stats); the compiles run on the background executor.  Returns a
        status-count dict, ``{}`` when ``compile_async`` is off."""
        del tenant                       # hints are not tenant-accounted
        if self.plan_cache is None or self.plan_cache.executor is None:
            return {}
        jax_be = self._backends.get("jax")
        if jax_be is None:
            return {}
        counts: dict = {}
        _s, sel, p, _c, _rw, _n, _t = self._optimizer.compile_batch(batch)
        for seg in p.segments:
            if seg.kind != "jax":
                continue
            status = jax_be.precompile_segment(seg, sel, cache=self.cache)
            counts[status] = counts.get(status, 0) + 1
        return counts

    @staticmethod
    def _slack(job: Job, now: Optional[float] = None) -> Optional[float]:
        """Remaining deadline budget for a hop stamp; None = no deadline."""
        if job.deadline_t is None:
            return None
        return job.deadline_t - (time.perf_counter() if now is None else now)

    def _on_deadline_shed(self, job: Job) -> None:
        """Queue hook: a deadline-expired job was shed (its future already
        failed with DeadlineExceeded)."""
        self.telemetry.record_deadline_shed(job.tenant,
                                            band=int(job.priority))
        self.telemetry.record_job_failed(job.tenant)
        if job.trace is not None:
            job.trace.stamp(SHED, shard=self.shard_id,
                            slack=self._slack(job))
            self.traces.finish(job.trace)

    # -- dispatch ----------------------------------------------------------
    def _dispatch_loop(self) -> None:
        cfg = self.config
        while self._running:
            # closed-loop control tick piggybacks the dispatch loop (no
            # extra thread); the loop wakes at least every ~0.2s even
            # idle, so the controller's tick_interval_s is honored.  With
            # control off this is the hot path's single None check
            if self.controller is not None:
                self.controller.maybe_tick()
            # bound in-flight super-batches so the fair queue, not the
            # executor pool's FIFO, decides ordering under load
            if not self._slots.acquire(timeout=0.1):
                continue
            self._adjust_free_slots(-1)
            tight = (cfg.deadline_tight_slack_s if cfg.deadline_aware
                     else None)
            jobs = self.queue.pop_round(
                max_jobs=cfg.coalesce_max_jobs,
                max_per_tenant=cfg.max_jobs_per_tenant_per_round,
                timeout=0.1, tight_slack_s=tight)
            if not jobs:
                self._adjust_free_slots(+1)
                self._slots.release()
                continue
            # coalescing window: briefly gather more concurrent submissions
            # from the SAME band — super-batches stay priority-homogeneous,
            # so a cheap interactive probe is never welded to a bulk sweep.
            # A tight-deadline job skips the window entirely: it was popped
            # alone and every waited millisecond is deadline slack spent
            now = time.perf_counter()
            if not (cfg.deadline_aware
                    and any(j.slack(now) <= cfg.deadline_tight_slack_s
                            for j in jobs)):
                deadline = now + cfg.coalesce_window_s
                while len(jobs) < cfg.coalesce_max_jobs:
                    left = deadline - time.perf_counter()
                    if left <= 0:
                        break
                    more = self.queue.pop_round(
                        max_jobs=cfg.coalesce_max_jobs - len(jobs),
                        max_per_tenant=cfg.max_jobs_per_tenant_per_round,
                        timeout=left, band=jobs[0].band,
                        tight_slack_s=tight)
                    if not more:
                        # nothing mergeable: the window timed out, or the
                        # band holds only tight-slack jobs the extension
                        # excludes — looping again would busy-spin on the
                        # queue lock until the window closes
                        break
                    jobs.extend(more)
            with self._inflight_cond:
                self._inflight_jobs += len(jobs)
            self._pool.submit(self._execute_guarded, jobs)

    def _adjust_free_slots(self, delta: int) -> None:
        with self._free_slots_lock:
            self._free_slots += delta

    def _execute_guarded(self, jobs: list) -> None:
        try:
            self._execute_jobs(jobs, allow_retry=True, is_retry=False)
        finally:
            self._adjust_free_slots(+1)
            self._slots.release()
            with self._inflight_cond:
                self._inflight_jobs -= len(jobs)
                self._inflight_cond.notify_all()

    # -- memory gate -------------------------------------------------------
    def _acquire_mem(self, need: int) -> None:
        with self._mem_cond:
            while (self._mem_inflight
                   and self._mem_inflight + need
                   > self.config.memory_budget_bytes):
                self._mem_cond.wait()
            self._mem_inflight += need

    def _release_mem(self, need: int) -> None:
        with self._mem_cond:
            self._mem_inflight -= need
            self._mem_cond.notify_all()

    # -- execution ---------------------------------------------------------
    def _fail_jobs(self, jobs: Sequence[Job], exc: BaseException) -> None:
        for job in jobs:
            job.future._set_exception(exc)
            self.telemetry.record_job_failed(job.tenant)
            if job.trace is not None:
                job.trace.stamp(FAILED, shard=self.shard_id,
                                slack=self._slack(job),
                                error=type(exc).__name__)
                self.traces.finish(job.trace)

    def _preempt_check_for(self, live: Sequence[Job], band: int):
        """Install a wave-boundary yield hook — only for super-batches that
        are not already top-band and have preemption budget left."""
        cfg = self.config
        if (not cfg.preemption or not cfg.priority_aware
                or band <= int(Priority.INTERACTIVE)):
            return None
        if max(j.preemptions for j in live) >= cfg.max_preemptions_per_job:
            return None     # yielded enough; now run to completion
        # yield only when the urgent work cannot be placed on an idle
        # executor anyway — otherwise preemption would just waste progress
        return lambda: (self._free_slots <= 0
                        and self.queue.has_work_above(band))

    def _requeue_preempted(self, live: list, job_sigs: list,
                           preempted: ExecutionPreempted) -> None:
        for job, sigs in zip(live, job_sigs):
            job.preemptions += 1
            # each job carries exactly its own reachable completed
            # intermediates; re-coalescing merges them back losslessly
            job.salvage = {s: v for s, v in preempted.salvage.items()
                           if s in sigs}
            self.telemetry.record_preemption(job.tenant)
            if job.trace is not None:
                job.trace.stamp(PREEMPTED, shard=self.shard_id,
                                slack=self._slack(job),
                                salvaged=len(job.salvage))
        try:
            self.queue.requeue(live)
        except AdmissionError as e:     # service shutting down mid-yield
            self._fail_jobs(live, e)

    def _isolate_invalid(self, live: list, err: AnalysisError,
                         allow_retry: bool) -> None:
        """A coalesced super-batch failed compile-time static validation.
        Re-validate each job's own pipelines so only the offending jobs
        fail — each with its OWN findings, not the merged batch's — and
        innocent coalesced bystanders re-run without the poisoned peer."""
        if len(live) == 1:
            self._fail_jobs(live, err)
            return
        good = []
        for job in live:
            try:
                errs = [f for f in validate_wiring(job.batch.fused_sinks())
                        if f.severity == "error"]
            except Exception:  # noqa: BLE001 — unvalidatable == invalid
                errs = []
                self._fail_jobs([job], err)
                continue
            if errs:
                self._fail_jobs([job], AnalysisError(errs))
            else:
                good.append(job)
        if len(good) == len(live):
            # nothing attributable (the defect only exists merged) —
            # fall back to failing the whole batch with the merged error
            self._fail_jobs(live, err)
            return
        if good:
            if allow_retry:
                self._execute_jobs(good, allow_retry=False, is_retry=True)
            else:
                self._fail_jobs(good, err)

    def _execute_jobs(self, jobs: list, allow_retry: bool,
                      is_retry: bool = False) -> None:
        now = time.perf_counter()
        live = [j for j in jobs if j.future._mark_running()]
        if not live:
            return
        depth = self.queue.pending()
        for job in live:
            # measure queue wait once, at first dispatch — a failure-isolation
            # retry must not re-record it (the second measurement would
            # include the failed run's execution time)
            if job.dispatch_wait_s is None:
                job.dispatch_wait_s = now - job.submit_t
                self.telemetry.record_dispatch(job.tenant,
                                               job.dispatch_wait_s,
                                               job.priority, depth=depth)
            if job.trace is not None:
                slack = self._slack(job, now)
                if len(live) > 1:
                    job.trace.stamp(COALESCED, shard=self.shard_id,
                                    slack=slack, n_jobs=len(live))
                job.trace.stamp(DISPATCHED, shard=self.shard_id,
                                slack=slack,
                                wait_s=round(job.dispatch_wait_s or 0.0, 6),
                                retry=is_retry, resume=job.preemptions > 0)

        merged: SuperBatch = coalesce(live)
        try:
            (sinks, sel, plan, candidates, rw, ops_submitted,
             opt_time) = self._optimizer.compile_batch(merged.batch)
        except AnalysisError as e:
            # statically invalid pipeline in the merged batch: fail only
            # the offending jobs, re-run innocent coalesced bystanders
            # (mirrors the ExecutionError isolation below)
            self._isolate_invalid(live, e, allow_retry)
            return
        except Exception as e:  # noqa: BLE001 — propagate via futures
            self._fail_jobs(live, e)
            return

        # post-optimization per-job reachable sets: used for cross-agent
        # dedup accounting, failure isolation, cache charge attribution and
        # telemetry attribution
        job_sigs = [reachable_sigs(merged.job_sinks(sinks, j))
                    for j in range(len(live))]
        deduped, shared = cross_agent_dedup(job_sigs,
                                            [j.tenant for j in live])
        if not is_retry and not any(j.preemptions for j in live):
            # neither a failure-isolation retry nor a post-preemption
            # re-dispatch is a new super-batch for accounting purposes
            self.telemetry.record_super_batch(len(live), deduped, shared)

        # cache charge attribution: an op shared by several tenants is
        # charged to the first submitter in this round (deterministic);
        # the others' reuse shows up as cross-tenant hits instead
        sig_tenant: dict = {}
        for job, sigs in zip(live, job_sigs):
            for s in sigs:
                sig_tenant.setdefault(s, job.tenant)

        # salvage from a previous preemption of any of these jobs
        preloaded: dict = {}
        for job in live:
            preloaded.update(job.salvage)

        band = min(j.band for j in live)
        need = max(plan.est_peak_mem, 0)
        self._acquire_mem(need)
        try:
            rt = Runtime(cache=self.cache, cache_candidates=candidates,
                         parallel="parallel" in self.config.enable,
                         preloaded=preloaded,
                         preempt_check=self._preempt_check_for(live, band),
                         sig_tenant=sig_tenant,
                         backends=self._backends)
            results, run = rt.execute(sinks, plan, sel)
        except ExecutionPreempted as p:
            self._release_mem(need)
            self._requeue_preempted(live, job_sigs, p)
            return
        except ExecutionError as e:
            self._release_mem(need)
            bad_sig = e.op.signature
            bad = [j for j, sigs in zip(live, job_sigs) if bad_sig in sigs]
            good = [j for j in live if j not in bad]
            if not bad:          # can't attribute → fail the whole batch
                self._fail_jobs(live, e)
                return
            self._fail_jobs(bad, e)
            if good:
                if allow_retry:
                    # innocent bystanders: re-run without the poisoned peer
                    self._execute_jobs(good, allow_retry=False,
                                       is_retry=True)
                else:
                    self._fail_jobs(good, e)
            return
        except Exception as e:  # noqa: BLE001
            self._release_mem(need)
            self._fail_jobs(live, e)
            return
        self._release_mem(need)

        named = dict(zip(merged.batch.names, results))
        per_job = merged.split_results(named)
        for j, (job, job_results) in enumerate(zip(live, per_job)):
            hits = sum(1 for s in job_sigs[j]
                       if run.sig_source.get(s) == "cache")
            salvaged = sum(1 for s in job_sigs[j]
                           if run.sig_source.get(s) == "salvage")
            backends: dict = {}
            for s in job_sigs[j]:
                src = run.sig_source.get(s)
                if src and src not in ("cache", "salvage"):
                    backends[src] = backends.get(src, 0) + 1
            deadline_met = None
            if job.deadline_t is not None:
                deadline_met = time.perf_counter() <= job.deadline_t
                self.telemetry.record_deadline_outcome(
                    job.tenant, deadline_met, band=int(job.priority))
            trace_hops: tuple = ()
            if job.trace is not None:
                job.trace.stamp(
                    COMPLETED, shard=self.shard_id, slack=self._slack(job),
                    backends=dict(backends), cache_hits=hits,
                    salvaged=salvaged,
                    plan_cache_hits=getattr(run, "plan_cache_hits", 0),
                    plan_cache_misses=getattr(run, "plan_cache_misses", 0),
                    plan_cache_fallback_rounds=getattr(
                        run, "plan_cache_fallback_rounds", 0),
                    deadline_met=deadline_met)
                self.traces.finish(job.trace)
                trace_hops = job.trace.as_hops()
            report = JobReport(
                tenant=job.tenant, job_id=job.id,
                queue_wait_s=job.dispatch_wait_s or 0.0,
                coalesced_with=len(live) - 1,
                ops_shared_cross_agent=shared.get(job.tenant, 0),
                cache_hits=hits, per_backend=backends,
                stratum=rw, run=run,
                priority=job.priority, preemptions=job.preemptions,
                ops_salvaged=salvaged, deadline_s=job.deadline_s,
                deadline_met=deadline_met, tags=job.tags,
                trace=trace_hops)
            self.telemetry.record_job_done(job.tenant, job_sigs[j],
                                           run.sig_source)
            job.salvage = {}    # release pinned intermediates
            job.future._set_result(job_results, report)
