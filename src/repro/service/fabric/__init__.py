"""repro.service.fabric — sharded execution fabric.

Scales the multi-tenant execution service past a single server: N
:class:`~repro.service.server.StratumService` shards behind a
:class:`ShardRouter` that consistent-hashes the pipeline-signature space,
with a serializable :class:`JobEnvelope`/:class:`ResultEnvelope` submission
boundary (explicit wire codec + :class:`Transport` abstraction), ring-based
rebalancing, crash failover that requeues in-flight envelopes onto ring
successors, and fabric-level telemetry aggregation.  See
``docs/ARCHITECTURE.md`` (fabric section) and ``docs/API.md``.

    from repro.service.fabric import ShardedStratum

    with ShardedStratum(n_shards=4, memory_budget_bytes=2 << 30) as fabric:
        results, report = fabric.session("agent-0").submit(batch).result()
"""

from .envelope import (CancelEnvelope, CodecError, FabricJobReport,
                       JobEnvelope, ResultEnvelope, decode_cancel,
                       decode_job, decode_result, encode_cancel, encode_job,
                       encode_result, routing_key_for)
from .fabric import ShardedStratum, StratumFabric
from .proc import ProcConfig, ProcStratumFabric
from .ring import ConsistentHashRing
from .router import NoShardsError, ShardRouter
from .telemetry import FabricTelemetry
from .transport import LocalTransport, Transport, TransportError

__all__ = [
    "CancelEnvelope", "CodecError", "ConsistentHashRing", "FabricJobReport",
    "FabricTelemetry", "JobEnvelope", "LocalTransport", "NoShardsError",
    "ProcConfig", "ProcStratumFabric", "ResultEnvelope", "ShardRouter",
    "ShardedStratum", "StratumFabric", "Transport", "TransportError",
    "decode_cancel", "decode_job", "decode_result", "encode_cancel",
    "encode_job", "encode_result", "routing_key_for",
]
