"""Transports carry encoded envelopes between the router and one shard.

A :class:`Transport` moves *bytes*, not objects: the router hands it an
encoded :class:`~.envelope.JobEnvelope` frame and receives encoded
:class:`~.envelope.ResultEnvelope` frames on the ``on_result`` callback it
registered.  Nothing above the codec is shared between the client side and
the shard side, which is what lets the shard move out-of-process later
(socket/RPC transports slot in here) without touching the router, the
session layer or the envelope schema.

:class:`LocalTransport` is the in-process implementation: the shard is a
:class:`~repro.service.server.StratumService` living in this process, but
every submission still round-trips ``encode_job → bytes → decode_job`` and
every reply ``encode_result → bytes → decode_result`` — the serialization
seam is exercised on every message (and asserted by the round-trip tests),
not just promised.

``LocalTransport.kill()`` simulates a shard host dying: the transport stops
accepting sends and — crucially — never delivers replies for jobs already
in flight, which is exactly the silence a crashed remote peer produces.
The router's failover path (requeue onto ring successors) is tested against
this behaviour.
"""

from __future__ import annotations

import threading
from abc import ABC, abstractmethod
from typing import Callable, Optional

from ...core.analysis import AnalysisError
from ..queue import AdmissionError
from .envelope import (ResultEnvelope, decode_cancel, decode_job,
                       encode_result, FabricJobReport)


class TransportError(ConnectionError):
    """The peer shard is unreachable (dead, draining or closed)."""


def result_envelope_for(future, envelope_id: str, tenant: str,
                        shard_id: str, attempt: int) -> ResultEnvelope:
    """Terminal :class:`ResultEnvelope` for a *resolved* shard-local
    future — the one reply shape every transport sends, whether the shard
    lives in this process (:class:`LocalTransport`) or behind a socket
    (the proc fabric's worker).  The shard-side ``JobReport`` is flattened
    into the wire-safe :class:`FabricJobReport`."""
    try:
        results, report = future.result(timeout=0)
        wire_report = FabricJobReport(
            tenant=tenant, envelope_id=envelope_id,
            shard_id=shard_id,
            queue_wait_s=getattr(report, "queue_wait_s", 0.0),
            coalesced_with=getattr(report, "coalesced_with", 0),
            ops_shared_cross_agent=getattr(report,
                                           "ops_shared_cross_agent", 0),
            cache_hits=getattr(report, "cache_hits", 0),
            ops_salvaged=getattr(report, "ops_salvaged", 0),
            preemptions=getattr(report, "preemptions", 0),
            attempt=attempt,
            deadline_s=getattr(report, "deadline_s", None),
            deadline_met=getattr(report, "deadline_met", None),
            tags=tuple(getattr(report, "tags", ()) or ()),
            per_backend=dict(getattr(report, "per_backend", {}) or {}),
            hops=tuple(getattr(report, "trace", ()) or ()))
        return ResultEnvelope(envelope_id=envelope_id, tenant=tenant,
                              shard_id=shard_id, ok=True,
                              results=results, report=wire_report,
                              attempt=attempt)
    except BaseException as e:  # noqa: BLE001 — includes CancelledError
        return ResultEnvelope(envelope_id=envelope_id, tenant=tenant,
                              shard_id=shard_id, ok=False, error=e,
                              attempt=attempt)


class Transport(ABC):
    """One bidirectional byte channel between the router and one shard."""

    @abstractmethod
    def send_job(self, data: bytes) -> None:
        """Deliver one encoded JobEnvelope frame to the shard.

        Raises :class:`TransportError` when the shard is unreachable (the
        router treats that as a dead shard and fails over) and may raise
        :class:`~repro.service.queue.AdmissionError` synchronously when an
        in-process shard applies backpressure."""

    @abstractmethod
    def set_on_result(self, cb: Callable[[bytes], None]) -> None:
        """Register the callback receiving encoded ResultEnvelope frames."""

    def send_cancel(self, data: bytes) -> bool:
        """Deliver one encoded CancelEnvelope frame to the shard.

        Returns True when the shard *synchronously* confirmed removal of
        the still-queued job (possible in-process); a remote transport
        returns False and delivers the confirmation — a ResultEnvelope
        carrying ``CancelledError`` — asynchronously like any reply.
        Transports predating cancellation simply don't override this, and
        the router degrades to abandoning the local future only."""
        raise NotImplementedError("transport does not support cancellation")

    @abstractmethod
    def close(self) -> None:
        """Orderly shutdown (drain-friendly); further sends raise."""


class LocalTransport(Transport):
    """In-process shard transport wrapping one :class:`StratumService`.

    All traffic crosses the wire codec in both directions; per-message
    byte counts are kept so tests and telemetry can assert the boundary
    is actually exercised.
    """

    def __init__(self, service, shard_id: str):
        self.service = service
        self.shard_id = shard_id
        self._on_result: Optional[Callable[[bytes], None]] = None
        self._lock = threading.Lock()
        self._dead = False
        self._closed = False
        # envelope_id -> (shard-local PipelineFuture, attempt), kept so a
        # CancelEnvelope can reach into the shard's queue; entries leave
        # on the terminal reply
        self._inflight: dict[str, tuple] = {}       # guarded-by: _lock
        self.jobs_received = 0
        self.results_sent = 0
        self.cancels_received = 0
        self.cancels_honored = 0
        self.bytes_in = 0
        self.bytes_out = 0

    # -- Transport interface ----------------------------------------------
    def set_on_result(self, cb: Callable[[bytes], None]) -> None:
        self._on_result = cb

    def send_job(self, data: bytes) -> None:
        with self._lock:
            if self._dead or self._closed:
                raise TransportError(f"shard {self.shard_id!r} unreachable")
            self.jobs_received += 1
            self.bytes_in += len(data)
        env = decode_job(data)     # the serialization seam, server side
        try:
            future = self.service.submit(env.tenant, env.batch,
                                         priority=env.priority,
                                         deadline_s=env.deadline_s,
                                         tags=env.tags,
                                         trace_key=env.envelope_id,
                                         trace_hops=env.hops)
        except (AdmissionError, AnalysisError):
            # in-process shard: backpressure and pre-flight analysis
            # rejections propagate synchronously so Session.submit keeps
            # its documented raises-at-submit contract.  (A remote
            # transport cannot do this and would deliver the rejection
            # via a ResultEnvelope instead.)
            raise
        except Exception as e:     # noqa: BLE001 — anything else at submit
            self._reply(ResultEnvelope(
                envelope_id=env.envelope_id, tenant=env.tenant,
                shard_id=self.shard_id, ok=False, error=e,
                attempt=env.attempt))
            return
        envelope_id, tenant, attempt = env.envelope_id, env.tenant, env.attempt
        with self._lock:
            self._inflight[envelope_id] = (future, attempt)
        future.add_done_callback(
            lambda f: self._complete(f, envelope_id, tenant, attempt))

    def send_cancel(self, data: bytes) -> bool:
        """Shard-aware cancellation: decode, find the local future, remove
        the job from this shard's fair queue if still queued.  The queue
        removal fires the future's done callback with ``CancelledError``,
        which travels back as an ordinary ResultEnvelope — the client-side
        router resolves the fabric future as *cancelled* on receipt."""
        with self._lock:
            if self._dead or self._closed:
                raise TransportError(f"shard {self.shard_id!r} unreachable")
            self.cancels_received += 1
            self.bytes_in += len(data)
        env = decode_cancel(data)      # the serialization seam, server side
        with self._lock:
            entry = self._inflight.get(env.envelope_id)
        if entry is None:
            return False               # already answered (or never arrived)
        future, attempt = entry
        if env.attempt != attempt:
            return False               # stale cancel for a superseded try
        honored = bool(future.cancel())
        if honored:
            with self._lock:
                self.cancels_honored += 1
        return honored

    def close(self) -> None:
        with self._lock:
            self._closed = True

    # -- crash simulation --------------------------------------------------
    def kill(self) -> None:
        """Hard-kill the shard: drop the connection AND silence every
        in-flight reply, like a crashed remote host."""
        with self._lock:
            self._dead = True

    # -- shard-side completion path ---------------------------------------
    def _complete(self, future, envelope_id: str, tenant: str,
                  attempt: int) -> None:
        with self._lock:
            self._inflight.pop(envelope_id, None)
        self._reply(result_envelope_for(future, envelope_id, tenant,
                                        self.shard_id, attempt))

    def _reply(self, env: ResultEnvelope) -> None:
        data = encode_result(env)  # the serialization seam, shard side
        with self._lock:
            if self._dead:         # crashed hosts don't answer
                return
            self.results_sent += 1
            self.bytes_out += len(data)
            cb = self._on_result
        if cb is not None:
            cb(data)
