"""Routes job envelopes to shards over the consistent-hash ring.

The :class:`ShardRouter` is the fabric's client-side brain: it owns the
ring, one :class:`~.transport.Transport` per shard, and the *pending table*
— envelope_id → (envelope, future, shard) for every job whose reply has not
arrived.  Three flows meet here:

* **submit** — hash the envelope's routing key on the ring, record it
  pending, send the encoded frame.  A send that raises
  :class:`~.transport.TransportError` marks the shard dead and retries on
  the ring successor, so a submission never observes a half-dead fabric;
* **result** — decode the frame, pop the pending entry (first reply wins;
  duplicates from failover races are dropped), resolve the future —
  a ``CancelledError`` reply (the shard honored a CancelEnvelope)
  resolves it as *cancelled*, not failed;
* **cancel** — ``cancel(envelope_id)`` encodes a
  :class:`~.envelope.CancelEnvelope` to the owning shard, whose transport
  removes the still-queued job from the shard's fair queue (shard-aware
  cancellation: the admission slot and dispatch capacity free up, instead
  of only abandoning the local future);
* **membership** — ``add_shard`` extends the ring (only ~K/N keys remap,
  see ``ring.py``), ``drain_shard`` removes a shard from the ring, waits
  for its in-flight replies, then closes it; ``fail_shard`` removes it
  *and requeues its entire pending set* onto each envelope's ring
  successor with a bumped ``attempt`` — at-least-once delivery, which is
  sound here because pipelines are deterministic DAGs keyed by content
  signature (a re-run reproduces the same values and re-uses any cached
  intermediates that survived).

The router also keeps the fabric-level counters telemetry aggregates:
per-shard envelopes routed, signature-locality hits (a routing key seen
again on the shard that served it before — the measure of how well the
ring preserves cache/CSE locality), failover requeues and membership
changes.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Optional

# locality tracking is a statistics aid, not a correctness structure —
# bound it so a long-lived fabric's memory doesn't grow with every unique
# routing key ever seen
_LOCALITY_KEYS_MAX = 65536

from concurrent.futures import CancelledError

from ..observability import FAILOVER, ROUTED, make_hop
from ..session import PipelineFuture
from .envelope import (CancelEnvelope, JobEnvelope, decode_result,
                       encode_cancel, encode_job)
from .ring import ConsistentHashRing
from .transport import Transport, TransportError


class NoShardsError(RuntimeError):
    """Every shard is dead or the fabric was never given any."""


class _Pending:
    __slots__ = ("envelope", "future", "shard_id")

    def __init__(self, envelope: JobEnvelope, future: PipelineFuture,
                 shard_id: str):
        self.envelope = envelope
        self.future = future
        self.shard_id = shard_id


class ShardRouter:
    def __init__(self, vnodes: int = 64):
        self._ring = ConsistentHashRing(vnodes=vnodes)
        self._transports: dict[str, Transport] = {}    # guarded-by: _lock
        self._pending: dict[str, _Pending] = {}          # guarded-by: _lock
        self._last_shard_for_key: "OrderedDict[str, str]" = OrderedDict()  # guarded-by: _lock
        self._lock = threading.RLock()
        self._drained = threading.Condition(self._lock)
        # fabric-level counters (read by FabricTelemetry)
        self.envelopes_routed: dict[str, int] = {}
        self.locality_lookups = 0
        self.locality_hits = 0
        self.failover_requeues = 0
        self.shards_failed = 0
        self.shards_added = 0
        self.shards_drained = 0
        self.reply_codec_errors = 0
        self.cancels_sent = 0
        self.cancels_confirmed = 0
        # client-side TraceSink (set by StratumFabric when tracing is on):
        # routed/failover hops are stamped onto envelopes here, and
        # reassembled traces from result replies are stored through it
        self.trace_sink = None

    # -- membership --------------------------------------------------------
    def add_shard(self, shard_id: str, transport: Transport) -> None:
        with self._lock:
            if shard_id in self._transports:
                raise ValueError(f"shard {shard_id!r} already registered")
            transport.set_on_result(self._on_result)
            self._transports[shard_id] = transport
            self._ring.add(shard_id)
            self.envelopes_routed.setdefault(shard_id, 0)
            self.shards_added += 1

    def shard_ids(self) -> list[str]:
        with self._lock:
            return self._ring.nodes()

    def successor_of(self, shard_id: str) -> Optional[str]:
        """The next distinct live shard clockwise of ``shard_id`` on the
        ring — where the bulk of a departing shard's keys remap, and so
        the right recipient for its warm cache entries on scale-down."""
        with self._lock:
            for node in self._ring.successors(shard_id,
                                              exclude={shard_id}):
                return node
        return None

    def fail_shard(self, shard_id: str) -> int:
        """Declare ``shard_id`` dead: silence its transport, take it off
        the ring, requeue its pending work onto ring successors.  Returns
        the number of requeued envelopes."""
        with self._lock:
            transport = self._transports.pop(shard_id, None)
            if transport is None:
                return 0
            # silence the "crashed" host before anything else: a dead peer
            # must not answer for work about to be requeued elsewhere.
            # Bumping attempts under the same lock closes the window where
            # a just-arriving stale reply would still compare equal.
            if hasattr(transport, "kill"):
                transport.kill()
            if shard_id in self._ring:
                self._ring.remove(shard_id)
            self.shards_failed += 1
            orphans = [p for p in self._pending.values()
                       if p.shard_id == shard_id]
            for p in orphans:
                p.envelope.attempt += 1
        for p in orphans:
            self._stamp_env(p.envelope, FAILOVER, shard=shard_id,
                            attempt=p.envelope.attempt)
            self._route(p, is_requeue=True)
        return len(orphans)

    def drain_shard(self, shard_id: str, timeout: float = 30.0) -> None:
        """Graceful removal: stop routing new work to the shard, wait for
        its in-flight replies, then close the transport.  In-flight work
        finishes where it is — nothing is re-executed."""
        with self._lock:
            if shard_id not in self._transports:
                raise KeyError(f"unknown shard {shard_id!r}")
            if shard_id in self._ring:
                self._ring.remove(shard_id)     # new keys remap elsewhere
            deadline = time.monotonic() + timeout
            while any(p.shard_id == shard_id
                      for p in self._pending.values()):
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError(
                        f"shard {shard_id!r} still has in-flight work "
                        f"after {timeout}s")
                self._drained.wait(left)
            transport = self._transports.pop(shard_id)
            self.shards_drained += 1
        transport.close()

    # -- submit / result ---------------------------------------------------
    def submit(self, envelope: JobEnvelope,
               future: Optional[PipelineFuture] = None) -> PipelineFuture:
        if future is None:
            future = PipelineFuture(envelope.envelope_id, envelope.tenant,
                                    envelope.priority)
            # shard-aware cancellation: future.cancel() sends a
            # CancelEnvelope to the owning shard instead of only
            # abandoning the local handle
            eid = envelope.envelope_id
            future._cancel_hook = lambda _jid: self.cancel(eid)
        pending = _Pending(envelope, future, shard_id="")
        self._route(pending, is_requeue=False)
        return future

    def cancel(self, envelope_id: str) -> bool:
        """Ask the shard owning ``envelope_id`` to drop the still-queued
        job.  Returns True when the shard synchronously confirmed removal
        (in-process transports); the future itself resolves as cancelled
        via the CancelledError reply either way.  False when the job is
        unknown, already dispatched, or the transport cannot cancel."""
        with self._lock:
            pending = self._pending.get(envelope_id)
            if pending is None:
                return False
            transport = self._transports.get(pending.shard_id)
            if transport is None:
                return False
            data = encode_cancel(CancelEnvelope(
                envelope_id=envelope_id, tenant=pending.envelope.tenant,
                attempt=pending.envelope.attempt))
            self.cancels_sent += 1
        # outside the lock: an in-process shard replies synchronously and
        # the reply path (_on_result) re-enters the router lock
        try:
            confirmed = bool(transport.send_cancel(data))
        except (TransportError, NotImplementedError):
            return False
        if confirmed:
            with self._lock:
                self.cancels_confirmed += 1
        return confirmed

    def _stamp_env(self, env: JobEnvelope, event: str, shard: str = "",
                   **detail) -> None:
        """Append a client-side hop to a *traced* envelope (no-op when the
        envelope carries no hops, i.e. tracing is off)."""
        if not env.hops:
            return
        slack = None
        if env.deadline_t is not None:
            slack = env.deadline_t - time.perf_counter()
        hop = make_hop(event, shard=shard, slack=slack, **detail)
        if hop[1] < env.hops[-1][1]:
            hop = (hop[0], env.hops[-1][1]) + hop[2:]
        env.hops = env.hops + (hop,)
        if self.trace_sink is not None:
            self.trace_sink.emit_hop(env.envelope_id, env.tenant, hop)

    def _route(self, pending: _Pending, is_requeue: bool) -> None:
        env = pending.envelope
        if env.deadline_t is not None:
            # re-derive the REMAINING budget at every (re-)encode: queueing
            # and failover time already spent must not extend the SLO on
            # the shard that finally runs the job.  May go negative — the
            # shard then sheds immediately and the DeadlineExceeded reply
            # resolves the future
            env.deadline_s = env.deadline_t - time.perf_counter()
        try:
            data = encode_job(env)     # before any pending registration:
        except Exception as e:         # an unencodable batch must not leak
            pending.future._set_exception(e)   # a forever-pending entry
            return
        while True:
            with self._lock:
                try:
                    shard_id = self._ring.route(env.routing_key)
                except LookupError:
                    self._pending.pop(env.envelope_id, None)
                    self._drained.notify_all()
                    break
                transport = self._transports[shard_id]
                pending.shard_id = shard_id
                self._pending[env.envelope_id] = pending
                self.envelopes_routed[shard_id] = \
                    self.envelopes_routed.get(shard_id, 0) + 1
                if is_requeue:
                    self.failover_requeues += 1
                else:
                    # locality is defined over *repeat* keys only (docs:
                    # "with a stable ring this is 1.0") — a key's first
                    # appearance has no prior shard to agree with and
                    # must not dilute the rate
                    last = self._last_shard_for_key.get(env.routing_key)
                    if last is not None:
                        self.locality_lookups += 1
                        if last == shard_id:
                            self.locality_hits += 1
                    self._last_shard_for_key[env.routing_key] = shard_id
                    self._last_shard_for_key.move_to_end(env.routing_key)
                    while len(self._last_shard_for_key) \
                            > _LOCALITY_KEYS_MAX:
                        self._last_shard_for_key.popitem(last=False)
            if env.hops:
                # tracing is on (the client seeded a SUBMITTED hop): stamp
                # the placement decision and re-encode so the hop log the
                # shard receives includes it
                self._stamp_env(env, ROUTED, shard=shard_id,
                                attempt=env.attempt, requeue=is_requeue)
                data = encode_job(env)
            try:
                transport.send_job(data)
                return
            except TransportError:
                # shard died between routing and send: declare it, which
                # also requeues anything else pending there, then retry
                # this envelope on the shrunken ring
                self.fail_shard(shard_id)
                with self._lock:
                    # retry ONLY while the pending entry still points at
                    # the dead shard.  Re-homed (a concurrent fail_shard
                    # requeued it) or gone entirely (that requeue already
                    # completed or failed the future) means another path
                    # owns this envelope's fate — dispatching it again
                    # would execute the job twice and re-resolve a future
                    # the caller may already have observed
                    cur = self._pending.get(env.envelope_id)
                    if cur is None or cur.shard_id != shard_id:
                        return
                continue
            except Exception as e:   # noqa: BLE001
                # any other send failure — AdmissionError backpressure
                # from an in-process shard, or a decode bug past the
                # encode: never leak a forever-pending entry.  Surface it
                # synchronously to the submitting caller (the documented
                # Session.submit contract for AdmissionError); a failover
                # requeue has no caller on the stack, so there it
                # resolves the future instead (raising out of
                # fail_shard's orphan loop would also abandon the
                # remaining orphans)
                with self._lock:
                    self._pending.pop(env.envelope_id, None)
                    self._drained.notify_all()
                if is_requeue:
                    pending.future._set_exception(e)
                    return
                raise
        pending.future._set_exception(
            NoShardsError("no live shards on the ring"))

    def _on_result(self, data: bytes) -> None:
        try:
            env = decode_result(data)
        except Exception:  # noqa: BLE001 — corrupted reply frame
            # the envelope id is unrecoverable, so no specific future can
            # be failed; count it rather than raise into the transport's
            # callback chain (which swallows exceptions, silently hanging
            # the tenant).  A remote transport's retry layer sits below
            # this; for LocalTransport corruption means a codec bug.
            with self._lock:
                self.reply_codec_errors += 1
            return
        with self._lock:
            pending = self._pending.get(env.envelope_id)
            if pending is not None \
                    and env.attempt < pending.envelope.attempt:
                return      # stale reply from a shard declared dead
            self._pending.pop(env.envelope_id, None)
            self._drained.notify_all()
        if pending is None:         # duplicate reply after a failover race
            return
        if env.ok:
            hops = tuple(getattr(env.report, "hops", ()) or ())
            if hops and self.trace_sink is not None:
                # the shard's reply carries the full reassembled trace
                # (client seed hops + shard lifecycle hops): keep it
                # queryable client-side without re-emitting to the log
                self.trace_sink.store(env.envelope_id, env.tenant, hops)
            pending.future._set_result(env.results, env.report)
        elif isinstance(env.error, CancelledError):
            # the shard honored a CancelEnvelope: resolve as *cancelled*
            # (result() raises CancelledError, cancelled() is True) rather
            # than as a job failure
            pending.future._set_cancelled()
        else:
            pending.future._set_exception(env.error)

    # -- introspection -----------------------------------------------------
    def pending_count(self, shard_id: Optional[str] = None) -> int:
        with self._lock:
            if shard_id is None:
                return len(self._pending)
            return sum(1 for p in self._pending.values()
                       if p.shard_id == shard_id)

    def locality_hit_rate(self) -> float:
        with self._lock:
            if not self.locality_lookups:
                return 0.0
            return self.locality_hits / self.locality_lookups
