"""The sharded execution fabric — N service shards behind one front door.

:class:`StratumFabric` (alias :data:`ShardedStratum`) scales the
multi-tenant execution service past one server: it runs ``n_shards``
independent :class:`~repro.service.server.StratumService` instances — each
with its own fair queue, coalescer, memory gate and intermediate cache —
behind a :class:`~.router.ShardRouter` that consistent-hashes the
pipeline-signature space.  Identical sub-DAGs from different agents hash to
the same shard, so the single-server wins (cross-agent CSE, shared-cache
hits, cache-quota arbitration) stay effective *per shard* while aggregate
queue, compute and cache capacity grow with the shard count.

Every submission crosses the serializable envelope boundary
(``envelope.py``) over a per-shard :class:`~.transport.Transport`; with
:class:`~.transport.LocalTransport` the shards share this process, but the
only thing that crosses the seam is bytes — the prerequisite for moving
shards out-of-process.

Lifecycle: ``add_shard`` grows the ring (≈K/N keys remap), ``drain_shard``
retires a shard gracefully (in-flight work finishes, new work re-routes),
and ``fail_shard`` models a crash — the dead shard's in-flight envelopes
are requeued onto ring successors, losing nothing (deterministic pipelines
make the resulting at-least-once execution safe).

    fabric = ShardedStratum(n_shards=4, memory_budget_bytes=2 << 30)
    session = fabric.session("agent-0")
    results, report = session.submit(batch).result()
    print(fabric.telemetry.report())
    fabric.stop()
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import replace
from typing import Optional

from ..observability import SUBMITTED, TraceSink, make_hop
from ..priority import Priority
from ..server import ServiceConfig, StratumService
from ..session import PipelineFuture, Session
from .envelope import (JobEnvelope, next_envelope_id, routing_key_for,
                       ROUTING_POLICIES)
from .router import ShardRouter
from .telemetry import FabricTelemetry
from .transport import LocalTransport

_fabric_ids = itertools.count()


class StratumFabric:
    """N consistent-hash service shards behind a message boundary."""

    def __init__(self, n_shards: int = 2,
                 config: Optional[ServiceConfig] = None,
                 routing: str = "sources",
                 vnodes: int = 64,
                 autostart: bool = True,
                 **overrides):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if routing not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {routing!r}")
        if config is None:
            config = ServiceConfig(**overrides)
        elif overrides:
            raise ValueError("pass either config or keyword overrides")
        self.config = config
        self.routing = routing
        self._client_id = f"fabric{next(_fabric_ids)}"
        self._lock = threading.Lock()
        self._shard_seq = itertools.count()
        self._shards: dict[str, StratumService] = {}     # live shards
        self.router = ShardRouter(vnodes=vnodes)
        self.telemetry = FabricTelemetry(self.router, self._shards_snapshot)
        # client-side trace sink: seeds every traced envelope's hop log and
        # keeps the reassembled traces the shards send back
        self.traces = TraceSink(
            trace_dir=config.trace_dir,
            component=f"client-{self._client_id}",
            enabled=config.trace)
        self.router.trace_sink = self.traces
        self._stopped = False
        for _ in range(n_shards):
            self.add_shard(autostart=autostart)

    # -- membership --------------------------------------------------------
    def add_shard(self, autostart: bool = True) -> str:
        """Bring up one more shard and join it to the ring.  Only ~K/N of
        the routing-key space remaps onto it (see ``ring.py``), so existing
        shards keep their cache/CSE locality."""
        with self._lock:
            shard_id = f"shard-{next(self._shard_seq)}"
            svc = StratumService(
                config=replace(self.config, shard_id=shard_id),
                autostart=autostart)
            self._shards[shard_id] = svc
        self.router.add_shard(shard_id, LocalTransport(svc, shard_id))
        return shard_id

    def start(self) -> "StratumFabric":
        """Start every shard created with ``autostart=False``."""
        with self._lock:
            shards = list(self._shards.values())
        for svc in shards:
            svc.start()
        return self

    def drain_shard(self, shard_id: str, timeout: float = 30.0) -> None:
        """Gracefully retire a shard: new work re-routes immediately,
        in-flight work completes where it is, then the shard stops."""
        self.router.drain_shard(shard_id, timeout=timeout)
        with self._lock:
            svc = self._shards.pop(shard_id)
        self.telemetry.retire(shard_id, svc)
        svc.stop()

    def fail_shard(self, shard_id: str) -> int:
        """Declare a shard dead (crash model).  The router silences the
        transport and requeues its pending envelopes onto ring successors;
        returns how many were requeued."""
        requeued = self.router.fail_shard(shard_id)
        with self._lock:
            svc = self._shards.pop(shard_id, None)
        if svc is not None:
            self.telemetry.retire(shard_id, svc)
            # best-effort teardown of the crashed host's threads; its
            # transport is already silenced so no replies can leak out
            svc.stop(drain=False)
        return requeued

    def shard_ids(self) -> list[str]:
        return self.router.shard_ids()

    def _shards_snapshot(self) -> dict:
        with self._lock:
            return dict(self._shards)

    # -- tenant API (Session-compatible backend) ---------------------------
    def session(self, tenant: str) -> Session:
        return Session(self, tenant)

    def submit(self, tenant: str, batch,
               priority: Priority = Priority.BATCH,
               affinity: Optional[str] = None,
               deadline_s: Optional[float] = None,
               tags=()) -> PipelineFuture:
        """Wrap ``batch`` in a :class:`JobEnvelope` and route it.  The
        routing key is derived from the batch's signature space unless
        ``affinity`` overrides it (pinning related submissions together).
        ``deadline_s``/``tags`` cross the wire with the envelope; the
        owning shard schedules EDF within the band, sheds expired work
        (the future then raises DeadlineExceeded) and echoes deadline
        attainment in the FabricJobReport."""
        if self._stopped:
            raise RuntimeError("fabric is stopped")
        key = affinity if affinity is not None \
            else routing_key_for(batch, self.routing)
        env = JobEnvelope(
            envelope_id=next_envelope_id(self._client_id),
            tenant=tenant, priority=int(Priority(priority)),
            routing_key=key, batch=batch,
            deadline_s=deadline_s,
            deadline_t=(None if deadline_s is None
                        else time.perf_counter() + deadline_s),
            tags=tuple(tags))
        if self.traces.enabled:
            # a non-empty hop log marks the envelope as traced everywhere
            # downstream (router stamps, wire codec, shard-side TraceSink)
            hop = make_hop(SUBMITTED, slack=deadline_s, tenant=tenant,
                           priority=Priority(priority).name)
            env.hops = (hop,)
            self.traces.emit_hop(env.envelope_id, tenant, hop)
        return self.router.submit(env)

    # -- lifecycle ---------------------------------------------------------
    def stop(self) -> None:
        if self._stopped:
            return
        self._stopped = True
        with self._lock:
            # keep the dict populated: telemetry stays readable after stop
            shards = list(self._shards.values())
        for svc in shards:
            svc.stop()
        self.traces.close()

    def __enter__(self) -> "StratumFabric":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


#: Docs-friendly name for the sharded front door.
ShardedStratum = StratumFabric
