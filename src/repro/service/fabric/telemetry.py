"""Fabric-level telemetry: per-shard state plus cross-shard aggregation.

A sharded fabric multiplies the observability problem: each shard keeps its
own :class:`~repro.service.telemetry.ServiceTelemetry` ledger, and the
router keeps the placement-side counters (envelopes per shard, locality,
failovers).  :class:`FabricTelemetry` joins both without copying state —
snapshots are taken live from the shards — and exposes the same
``snapshot()`` / ``global_snapshot()`` / ``report()`` surface as a single
service, so :class:`~repro.service.session.Session.telemetry` and existing
dashboards work unchanged against the fabric.

The interesting fabric-only number is the **signature-locality hit rate**:
of all routed envelopes whose routing key had been seen before, the
fraction that landed on the same shard as last time.  With a stable ring
this is 1.0; it degrades exactly by the keys remapped during membership
changes, so it doubles as a live measure of how much cache/CSE locality a
rebalance or failover cost.
"""

from __future__ import annotations

from ..control import merge_control_snapshots
from ..observability import merge_window_snapshots
from ..telemetry import merge_tenant_snapshots


class FabricTelemetry:
    """Aggregated view over the router and every live shard service.

    ``shards`` is a zero-argument callable returning a *copied* dict of
    live shards (taken under the fabric's lock) — the live dict mutates
    during failover/rebalance, and iterating it directly from a
    monitoring thread would race those membership changes."""

    def __init__(self, router, shards, extra=None) -> None:
        self._router = router
        self._shards = shards     # () -> dict shard_id -> StratumService
        # optional zero-argument callable merged into global_snapshot():
        # lets a fabric variant (the out-of-process fabric adds worker
        # pids, autoscale and warm-hand-off counters under a "proc" key)
        # extend the snapshot without subclassing the aggregation
        self._extra = extra
        # final ledgers of failed/drained shards: fabric-wide counters must
        # stay monotone — a shard's history doesn't vanish with the shard
        self._retired: dict = {}  # shard_id -> (tenant_snap, per_shard row)

    def retire(self, shard_id: str, svc) -> None:
        """Freeze a departing shard's ledger before the fabric drops it."""
        g = svc.telemetry.global_snapshot()
        row = {
            "retired": True,
            "queue_depth": 0,
            "inflight": 0,
            "envelopes_routed":
                self._router.envelopes_routed.get(shard_id, 0),
            "pending_replies": 0,
            "super_batches": g["super_batches"],
            "jobs_coalesced": g["jobs_coalesced"],
            "ops_deduped_cross_agent": g["ops_deduped_cross_agent"],
            "preemptions": g["preemptions"],
        }
        if "plan_cache" in g:
            row["plan_cache"] = g["plan_cache"]
        if "windows" in g:
            # last windowed snapshot the shard produced, frozen as-is
            row["windows"] = g["windows"]
        if "control" in g:
            # actuation counters stay monotone across scale-down/failover
            row["control"] = g["control"]
        self._retired[shard_id] = (svc.telemetry.snapshot(), row)

    # -- per-tenant view (Session.telemetry compatibility) -----------------
    def snapshot(self) -> dict:
        snaps = [snap for snap, _ in self._retired.values()]
        snaps += [svc.telemetry.snapshot()
                  for svc in self._shards().values()]
        return merge_tenant_snapshots(snaps)

    # -- fabric-wide view --------------------------------------------------
    def per_shard(self) -> dict:
        r = self._router
        out: dict[str, dict] = {sid: dict(row)
                                for sid, (_, row) in self._retired.items()}
        for shard_id, svc in self._shards().items():
            g = svc.telemetry.global_snapshot()
            out[shard_id] = {
                "queue_depth": svc.queue_depth(),
                "inflight": svc.inflight(),
                "envelopes_routed": r.envelopes_routed.get(shard_id, 0),
                "pending_replies": r.pending_count(shard_id),
                "super_batches": g["super_batches"],
                "jobs_coalesced": g["jobs_coalesced"],
                "ops_deduped_cross_agent": g["ops_deduped_cross_agent"],
                "preemptions": g["preemptions"],
            }
            if "cache_cross_tenant_hits" in g:
                out[shard_id]["cache_cross_tenant_hits"] = \
                    g["cache_cross_tenant_hits"]
            if "plan_cache" in g:
                out[shard_id]["plan_cache"] = g["plan_cache"]
            if "windows" in g:
                out[shard_id]["windows"] = g["windows"]
            if "control" in g:
                out[shard_id]["control"] = g["control"]
        return out

    def global_snapshot(self) -> dict:
        per_shard = self.per_shard()
        r = self._router
        totals = {
            "n_shards": sum(1 for s in per_shard.values()
                            if not s.get("retired")),
            "envelopes_routed": sum(s["envelopes_routed"]
                                    for s in per_shard.values()),
            "signature_locality_hit_rate": r.locality_hit_rate(),
            "failover_requeues": r.failover_requeues,
            "shards_failed": r.shards_failed,
            "shards_added": r.shards_added,
            "shards_drained": r.shards_drained,
            "reply_codec_errors": r.reply_codec_errors,
            "cancels_sent": r.cancels_sent,
            "cancels_confirmed": r.cancels_confirmed,
            "super_batches": sum(s["super_batches"]
                                 for s in per_shard.values()),
            "jobs_coalesced": sum(s["jobs_coalesced"]
                                  for s in per_shard.values()),
            "ops_deduped_cross_agent": sum(s["ops_deduped_cross_agent"]
                                           for s in per_shard.values()),
            "preemptions": sum(s["preemptions"]
                               for s in per_shard.values()),
        }
        # compiled-plan reuse fabric-wide: signature-locality routing means
        # repeat structures land on the shard already holding the compile,
        # so this rate is the fabric's compiled-plan locality measure
        # deadline attainment fabric-wide: derived from the merged tenant
        # ledgers (which include retired shards' frozen snapshots), so the
        # rate stays monotone across failover/rebalance
        tenants = self.snapshot()
        d_jobs = sum(s.get("deadline_jobs", 0) for s in tenants.values())
        d_met = sum(s.get("deadline_met", 0) for s in tenants.values())
        d_shed = sum(s.get("deadline_shed", 0) for s in tenants.values())
        totals["deadline"] = {
            "jobs": d_jobs,
            "met": d_met,
            "shed": d_shed,
            "attainment": (d_met / d_jobs) if d_jobs else 1.0,
        }
        pc_rows = [s["plan_cache"] for s in per_shard.values()
                   if "plan_cache" in s]
        if pc_rows:
            hits = sum(r["hits"] for r in pc_rows)
            misses = sum(r["misses"] for r in pc_rows)
            totals["plan_cache_hits"] = hits
            totals["plan_cache_misses"] = misses
            totals["plan_cache_entries"] = sum(r["entries"] for r in pc_rows)
            totals["plan_cache_hit_rate"] = (
                hits / (hits + misses) if hits + misses else 0.0)
            # async-compile lane fabric-wide (``.get``: retired shards'
            # frozen rows may predate these fields)
            totals["plan_cache_async_compiles"] = sum(
                r.get("async_compiles", 0) for r in pc_rows)
            totals["plan_cache_inflight"] = sum(
                r.get("inflight", 0) for r in pc_rows)
            totals["plan_cache_speculative_hits"] = sum(
                r.get("speculative_hits", 0) for r in pc_rows)
            totals["plan_cache_compile_time_s"] = sum(
                r.get("compile_time_s", 0.0) for r in pc_rows)
        # windowed throughput/attainment fabric-wide: counters sum, depth
        # maxes, percentiles recombine from each shard's capped samples
        win_rows = [s["windows"] for s in per_shard.values()
                    if s.get("windows")]
        if win_rows:
            totals["windows"] = merge_window_snapshots(win_rows)
        # closed-loop controller state fabric-wide: actuation counters sum
        # (retired shards' frozen blocks included, so they stay monotone)
        ctl_rows = [s["control"] for s in per_shard.values()
                    if s.get("control")]
        if ctl_rows:
            totals["control"] = merge_control_snapshots(ctl_rows)
        if self._extra is not None:
            try:
                totals.update(self._extra() or {})
            except Exception:  # noqa: BLE001 — extras must never break obs
                pass
        totals["per_shard"] = per_shard
        return totals

    def report(self) -> str:
        g = self.global_snapshot()
        lines = [
            f"fabric: {g['n_shards']} shard(s), "
            f"{g['envelopes_routed']} envelopes routed, "
            f"locality={g['signature_locality_hit_rate']:.2f}, "
            f"failover_requeues={g['failover_requeues']}",
        ]
        for shard_id in sorted(g["per_shard"]):
            s = g["per_shard"][shard_id]
            lines.append(
                f"  {shard_id}: routed={s['envelopes_routed']} "
                f"queue={s['queue_depth']} inflight={s['inflight']} "
                f"super_batches={s['super_batches']} "
                f"deduped={s['ops_deduped_cross_agent']}")
        for tenant, s in sorted(self.snapshot().items()):
            lines.append(
                f"  {tenant}: jobs={s['jobs_completed']}/"
                f"{s['jobs_submitted']} wait={s['queue_wait_s']:.3f}s "
                f"cache_hits={s['cache_hits']}")
        return "\n".join(lines)
