"""The fabric's serializable submission boundary.

Everything that crosses from a :class:`~repro.service.session.Session` into
the fabric — and back — is a *message*, not a shared object graph:

* :class:`JobEnvelope` — one submitted :class:`PipelineBatch` plus its
  routing metadata (tenant, priority, routing key, envelope id) and
  submission options (remaining ``deadline_s``, opaque ``tags``);
* :class:`ResultEnvelope` — the terminal reply: either ``results`` (host
  numpy arrays keyed by the batch's sink names) plus a plain-field
  :class:`FabricJobReport`, or a transported error.

The wire codec (``encode_job``/``decode_job``/``encode_result``/
``decode_result``) frames a pickled payload with a magic, a version byte
and a blake2b checksum, and performs two normalizations that make the
boundary a real process-isolation seam rather than an in-process formality:

* **DAG re-identification** — a decoded batch's ops are rebuilt with fresh
  ``uid``s.  Uids are process-local; two envelopes decoded on the same
  shard could otherwise carry colliding uids from different origin
  processes, corrupting uid-keyed passes (consumer maps, schedulers) when
  the shard coalesces them into one super-batch.  Content signatures are
  unaffected (they hash op name/spec/seed/inputs, never uids), so CSE and
  cache keys survive the trip bit-exactly.
* **result hosting** — result values are converted to host ``numpy``
  arrays, so no device buffer handle ever crosses the boundary.

Routing keys: :func:`routing_key_for` digests the batch's signature space.
Policy ``"sources"`` (default) keys on the SOURCE-op signatures — all work
over one dataset lands on one shard, keeping cross-agent CSE and the
shard's intermediate cache effective; ``"batch"`` keys on the full sink
signature set — only identical batches co-locate, spreading load wider.
"""

from __future__ import annotations

import hashlib
import itertools
import pickle
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from ...core.dag import SOURCE, rebuild, toposort
from ...core.fusion import PipelineBatch

_MAGIC = b"STRF"
_VERSION = 1
_JOB_KIND = 0x01
_RESULT_KIND = 0x02
_CANCEL_KIND = 0x03

ROUTING_POLICIES = ("sources", "batch")


class CodecError(ValueError):
    """Malformed, corrupted or version-incompatible wire frame."""


# ---------------------------------------------------------------------------
# envelopes
# ---------------------------------------------------------------------------

_envelope_counter = itertools.count()


def next_envelope_id(client: str = "local") -> str:
    return f"{client}-{next(_envelope_counter)}"


@dataclass
class JobEnvelope:
    """One batch submission crossing the Session → fabric boundary."""
    envelope_id: str
    tenant: str
    priority: int                 # int value of service.priority.Priority
    routing_key: str
    batch: PipelineBatch
    attempt: int = 0              # bumped by failover requeues
    # deadline SLO: ``deadline_s`` is the REMAINING budget at encode time
    # (absolute clocks don't cross process boundaries); ``deadline_t`` is
    # the client-local absolute instant — it never crosses the wire, the
    # router uses it to re-derive a shrunken deadline_s when a failover
    # re-encodes the envelope
    deadline_s: Optional[float] = None
    deadline_t: Optional[float] = None
    tags: tuple = ()
    # compact lifecycle hop log (observability/trace.py tuples) carried
    # over the wire when tracing is on, so a trace survives failover and
    # the owning shard can seed its JobTrace with the client-side history;
    # () when tracing is off — costs nothing on the hot path
    hops: tuple = ()


@dataclass
class CancelEnvelope:
    """Client-side request to remove a still-queued job from its shard.

    Crossing the wire (rather than only abandoning the local future) is
    what makes cancellation *shard-aware*: the owning shard's fair queue
    drops the job, freeing its admission slot and dispatch capacity.  A
    job already dispatched is not preempted — the shard simply ignores
    the cancel and the ordinary ResultEnvelope resolves the future."""
    envelope_id: str
    tenant: str
    attempt: int = 0              # must match the in-flight attempt


@dataclass
class FabricJobReport:
    """Plain-field, wire-safe per-job report (the sharded analogue of
    :class:`~repro.service.server.JobReport`)."""
    tenant: str
    envelope_id: str
    shard_id: str
    queue_wait_s: float = 0.0
    coalesced_with: int = 0
    ops_shared_cross_agent: int = 0
    cache_hits: int = 0
    ops_salvaged: int = 0
    preemptions: int = 0
    attempt: int = 0
    deadline_s: Optional[float] = None
    deadline_met: Optional[bool] = None
    tags: tuple = ()
    per_backend: dict = field(default_factory=dict)
    # full reassembled lifecycle trace (client hops + shard hops) when the
    # submission was traced; () otherwise
    hops: tuple = ()


@dataclass
class ResultEnvelope:
    """Terminal reply for one :class:`JobEnvelope`."""
    envelope_id: str
    tenant: str
    shard_id: str
    ok: bool
    results: Optional[dict[str, Any]] = None
    report: Optional[FabricJobReport] = None
    error: Optional[BaseException] = None
    attempt: int = 0       # echoes the JobEnvelope attempt this answers


# ---------------------------------------------------------------------------
# routing keys
# ---------------------------------------------------------------------------

def routing_key_for(batch: PipelineBatch, policy: str = "sources") -> str:
    """Digest of the batch's signature space, per routing policy."""
    if policy not in ROUTING_POLICIES:
        raise ValueError(f"unknown routing policy {policy!r}; "
                         f"expected one of {ROUTING_POLICIES}")
    if policy == "sources":
        sigs = sorted({op.signature for op in toposort(batch.sinks)
                       if op.op_class == SOURCE})
        if not sigs:      # sourceless batch (constants/UDFs): key on sinks
            sigs = sorted(r.signature for r in batch.sinks)
    else:
        sigs = sorted(r.signature for r in batch.sinks)
    h = hashlib.blake2b(digest_size=16)
    for s in sigs:
        h.update(s.encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------

def _frame(kind: int, payload: bytes) -> bytes:
    digest = hashlib.blake2b(payload, digest_size=16).digest()
    return (_MAGIC + bytes((_VERSION, kind)) + digest + payload)


def _unframe(data: bytes, kind: int) -> bytes:
    if len(data) < 22 or data[:4] != _MAGIC:
        raise CodecError("not a fabric wire frame")
    if data[4] != _VERSION:
        raise CodecError(f"wire version {data[4]} != {_VERSION}")
    if data[5] != kind:
        raise CodecError(f"frame kind {data[5]:#x}, expected {kind:#x}")
    digest, payload = data[6:22], data[22:]
    if hashlib.blake2b(payload, digest_size=16).digest() != digest:
        raise CodecError("checksum mismatch: frame corrupted in transit")
    return payload


def frame_kind(data: bytes) -> int:
    """The kind byte of a wire frame, after validating magic + version.

    Multiplexed byte channels (the out-of-process transport carries jobs,
    results, cancels and control frames on one stream) peek this to
    dispatch a frame without committing to a decoder; the per-kind
    ``decode_*`` function still re-validates everything including the
    checksum."""
    if len(data) < 22 or data[:4] != _MAGIC:
        raise CodecError("not a fabric wire frame")
    if data[4] != _VERSION:
        raise CodecError(f"wire version {data[4]} != {_VERSION}")
    return data[5]


def _host(value: Any) -> Any:
    """Device-independent representation: arrays to host numpy."""
    if isinstance(value, (tuple, list)):
        return type(value)(_host(v) for v in value)
    if isinstance(value, dict):
        return {k: _host(v) for k, v in value.items()}
    if hasattr(value, "shape") and hasattr(value, "dtype"):
        return np.asarray(value)
    return value


def encode_job(env: JobEnvelope) -> bytes:
    payload = pickle.dumps(
        {"envelope_id": env.envelope_id, "tenant": env.tenant,
         "priority": int(env.priority), "routing_key": env.routing_key,
         "attempt": env.attempt,
         "deadline_s": env.deadline_s, "tags": list(env.tags),
         "hops": [tuple(h) for h in env.hops],
         "sinks": list(env.batch.sinks), "names": list(env.batch.names)},
        protocol=pickle.HIGHEST_PROTOCOL)
    return _frame(_JOB_KIND, payload)


def decode_job(data: bytes) -> JobEnvelope:
    payload = _unframe(data, _JOB_KIND)
    try:
        d = pickle.loads(payload)
    except Exception as e:  # noqa: BLE001 — surface as a codec failure
        raise CodecError(f"job payload does not deserialize: {e!r}") from e
    # fresh uids for every op: uid collisions across origin processes would
    # corrupt uid-keyed passes once the shard coalesces decoded batches
    sinks = rebuild(d["sinks"], lambda op, ins: op.with_inputs(ins))
    return JobEnvelope(envelope_id=d["envelope_id"], tenant=d["tenant"],
                       priority=d["priority"], routing_key=d["routing_key"],
                       batch=PipelineBatch(sinks, d["names"]),
                       attempt=d["attempt"],
                       deadline_s=d.get("deadline_s"),
                       tags=tuple(d.get("tags", ())),
                       hops=tuple(tuple(h) for h in d.get("hops", ())))


def encode_cancel(env: CancelEnvelope) -> bytes:
    payload = pickle.dumps(
        {"envelope_id": env.envelope_id, "tenant": env.tenant,
         "attempt": env.attempt},
        protocol=pickle.HIGHEST_PROTOCOL)
    return _frame(_CANCEL_KIND, payload)


def decode_cancel(data: bytes) -> CancelEnvelope:
    payload = _unframe(data, _CANCEL_KIND)
    try:
        d = pickle.loads(payload)
    except Exception as e:  # noqa: BLE001
        raise CodecError(f"cancel payload does not deserialize: {e!r}") from e
    return CancelEnvelope(envelope_id=d["envelope_id"], tenant=d["tenant"],
                          attempt=d.get("attempt", 0))


def _encode_error(error: BaseException) -> bytes:
    """Pickle a wire-crossing error, degrading as little as possible.

    An :class:`~repro.core.runtime.ExecutionError` whose *cause* (or an op
    spec payload) doesn't pickle is re-raised with the cause stringified —
    keeping ``.op``/``.cause`` attributes intact for the tenant — before
    falling all the way back to an opaque ``RuntimeError``."""
    try:
        return pickle.dumps(error, protocol=pickle.HIGHEST_PROTOCOL)
    except Exception:  # noqa: BLE001 — unpicklable cause/op payloads
        pass
    from ...core.runtime import ExecutionError
    if isinstance(error, ExecutionError):
        try:
            return pickle.dumps(
                ExecutionError(error.op, RuntimeError(repr(error.cause))),
                protocol=pickle.HIGHEST_PROTOCOL)
        except Exception:  # noqa: BLE001 — the op itself doesn't pickle
            pass
    return pickle.dumps(
        RuntimeError(f"{type(error).__name__}: {error}"),
        protocol=pickle.HIGHEST_PROTOCOL)


def encode_result(env: ResultEnvelope) -> bytes:
    error: Optional[bytes] = None
    if env.error is not None:
        error = _encode_error(env.error)
    payload = pickle.dumps(
        {"envelope_id": env.envelope_id, "tenant": env.tenant,
         "shard_id": env.shard_id, "ok": env.ok,
         "results": _host(env.results) if env.results is not None else None,
         "report": env.report, "error": error, "attempt": env.attempt},
        protocol=pickle.HIGHEST_PROTOCOL)
    return _frame(_RESULT_KIND, payload)


def decode_result(data: bytes) -> ResultEnvelope:
    payload = _unframe(data, _RESULT_KIND)
    try:
        d = pickle.loads(payload)
    except Exception as e:  # noqa: BLE001
        raise CodecError(f"result payload does not deserialize: {e!r}") from e
    error = None
    if d["error"] is not None:
        try:
            error = pickle.loads(d["error"])
        except Exception as e:  # noqa: BLE001 — keep the failure visible
            error = RuntimeError(f"shard error (opaque on the wire): {e!r}")
    return ResultEnvelope(envelope_id=d["envelope_id"], tenant=d["tenant"],
                          shard_id=d["shard_id"], ok=d["ok"],
                          results=d["results"], report=d["report"],
                          error=error, attempt=d.get("attempt", 0))
