"""Stream framing + control-plane codec for the out-of-process fabric.

A socket is a byte *stream*: one ``send`` can arrive as many ``recv``s and
many ``send``s can coalesce into one.  The proc fabric therefore wraps
every envelope-codec frame (``envelope.py``: magic + version + kind +
blake2b checksum + pickled payload) in a 4-byte big-endian length prefix,
and reassembles on the receiving side with :class:`FrameDecoder` — an
incremental decoder that tolerates arbitrary fragmentation (a frame fed
one byte at a time decodes identically) and interleaving (job, result,
cancel, heartbeat frames mixed on one stream come out in order).

The length prefix also bounds memory: a frame longer than
``max_frame_bytes`` raises :class:`FrameError` *before* any buffering of
its body, so a corrupted length word (or a hostile peer) cannot balloon
the receiver.  Payload corruption *inside* a frame is the envelope
codec's job — its checksum rejects the frame while the length prefix
keeps the stream in sync, so the next frame still decodes.

Control frames reuse the envelope codec's framing (same magic/version/
checksum discipline) with kinds above 0x10, carrying small pickled dicts:

====================  ====== ==============================================
kind                  value  direction / payload
====================  ====== ==============================================
``HELLO``             0x10   worker → supervisor: ``{shard_id, pid}`` on
                             connect (and on reconnect)
``CONFIG``            0x11   supervisor → worker: pickled ``ServiceConfig``
                             + proc options; the worker builds its service
                             from this
``HEARTBEAT``         0x12   worker → supervisor: liveness + queue depth,
                             inflight count and telemetry snapshots (the
                             autoscaler's sensor inputs)
``DRAIN``             0x13   supervisor → worker: finish queued work,
                             flush replies, exit 0
``BYE``               0x14   worker → supervisor: orderly goodbye
``HANDOFF_REQ``       0x15   supervisor → draining worker: export your
                             hottest cache entries
``HANDOFF_DATA``      0x16   draining worker → supervisor: ``(sig,
                             spill_bytes)`` pairs (the existing spill
                             format, pickled host arrays)
``HANDOFF_PUT``       0x17   supervisor → successor worker: ingest these
                             entries into your cache
====================  ====== ==============================================
"""

from __future__ import annotations

import pickle
import struct

from ..envelope import CodecError, _frame, _unframe, frame_kind

__all__ = [
    "CONTROL_KINDS", "FrameDecoder", "FrameError", "HELLO", "CONFIG",
    "HEARTBEAT", "DRAIN", "BYE", "HANDOFF_REQ", "HANDOFF_DATA",
    "HANDOFF_PUT", "MAX_FRAME_BYTES", "decode_control", "encode_control",
    "frame_kind", "write_frame",
]

# control-plane frame kinds (envelope kinds 0x01-0x03 carry the data plane)
HELLO = 0x10
CONFIG = 0x11
HEARTBEAT = 0x12
DRAIN = 0x13
BYE = 0x14
HANDOFF_REQ = 0x15
HANDOFF_DATA = 0x16
HANDOFF_PUT = 0x17

CONTROL_KINDS = frozenset((HELLO, CONFIG, HEARTBEAT, DRAIN, BYE,
                           HANDOFF_REQ, HANDOFF_DATA, HANDOFF_PUT))

_LEN = struct.Struct(">I")
MAX_FRAME_BYTES = 256 << 20      # 256 MiB: far above any sane envelope


class FrameError(ConnectionError):
    """Unrecoverable framing-layer failure (oversized/absurd length word).

    Unlike a payload checksum mismatch — which poisons one frame while
    the length prefix keeps the stream aligned — a bad length word means
    the receiver no longer knows where frames begin; the only safe
    recovery is dropping the connection."""


def write_frame(sock, frame: bytes) -> None:
    """Send one length-prefixed frame.  Callers serialize writes (one
    lock per socket) so concurrent senders cannot interleave prefixes."""
    sock.sendall(_LEN.pack(len(frame)) + frame)


class FrameDecoder:
    """Incremental length-prefixed frame reassembler.

    ``feed(data)`` consumes any fragmentation the transport produced and
    returns every *complete* frame body (the envelope-codec frame, prefix
    stripped) in arrival order; partial bytes are buffered for the next
    feed.  Raises :class:`FrameError` on a length word exceeding
    ``max_frame_bytes`` — the stream is unrecoverable past that point.
    """

    def __init__(self, max_frame_bytes: int = MAX_FRAME_BYTES):
        self.max_frame_bytes = int(max_frame_bytes)
        self._buf = bytearray()
        self.frames_out = 0
        self.bytes_in = 0

    def pending_bytes(self) -> int:
        return len(self._buf)

    def feed(self, data: bytes) -> list[bytes]:
        self._buf += data
        self.bytes_in += len(data)
        out: list[bytes] = []
        while True:
            if len(self._buf) < _LEN.size:
                break
            (length,) = _LEN.unpack_from(self._buf, 0)
            if length > self.max_frame_bytes:
                raise FrameError(
                    f"frame length {length} exceeds limit "
                    f"{self.max_frame_bytes} — stream out of sync or peer "
                    f"misbehaving")
            if len(self._buf) < _LEN.size + length:
                break
            frame = bytes(self._buf[_LEN.size:_LEN.size + length])
            del self._buf[:_LEN.size + length]
            out.append(frame)
            self.frames_out += 1
        return out


# ---------------------------------------------------------------------------
# control-plane codec
# ---------------------------------------------------------------------------

def encode_control(kind: int, obj: dict) -> bytes:
    if kind not in CONTROL_KINDS:
        raise ValueError(f"not a control frame kind: {kind:#x}")
    return _frame(kind, pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL))


def decode_control(data: bytes) -> tuple[int, dict]:
    kind = frame_kind(data)
    if kind not in CONTROL_KINDS:
        raise CodecError(f"not a control frame: kind {kind:#x}")
    payload = _unframe(data, kind)
    try:
        return kind, pickle.loads(payload)
    except CodecError:
        raise
    except Exception as e:  # noqa: BLE001 — surface as a codec failure
        raise CodecError(
            f"control payload does not deserialize: {e!r}") from e
