"""repro.service.fabric.proc — the out-of-process shard fabric.

Moves the sharded execution fabric across real process boundaries: each
shard is a :class:`~repro.service.server.StratumService` hosted in its own
worker process (``python -m repro.service.fabric.proc.worker``) behind a
length-prefixed framed byte channel over a localhost socket, so K shards
actually use K cores instead of sharing one GIL.  The pieces:

* :mod:`frames`     — stream framing (4-byte length prefix + the existing
  checksummed envelope codec) with incremental partial-read reassembly,
  plus the supervisor↔worker control-frame codec (hello/config/heartbeat/
  drain/handoff);
* :mod:`transport`  — :class:`ProcTransport`, the socket-backed
  :class:`~repro.service.fabric.transport.Transport` carrying the
  *unchanged* Job/Result/Cancel envelopes, with a client-side admission
  window that preserves ``Session.submit``'s synchronous
  ``AdmissionError`` contract;
* :mod:`worker`     — the shard worker entrypoint: one service per
  process, decode → execute → reply, heartbeats, graceful SIGTERM drain;
* :mod:`supervisor` — spawns and monitors workers (handshake, heartbeat
  health checks, crash/hang detection, reconnect grace) and reaps them;
* :mod:`autoscale`  — the elastic control loop: spawn shards under
  queue-depth/deadline pressure, drain idle shards with a warm cache
  hand-off to the ring successor;
* :mod:`fabric`     — :class:`ProcStratumFabric`, the drop-in
  :class:`~repro.service.fabric.fabric.StratumFabric` over processes
  (``StratumClient`` reaches it via ``processes=True``).

A crashed worker (real ``kill -9``) is detected by socket EOF or
heartbeat timeout and routed into the existing ``fail_shard`` requeue
machinery — zero job loss, deadline budgets re-derived at requeue.
"""

from .autoscale import Autoscaler, AutoscalePolicy
from .fabric import ProcStratumFabric
from .frames import (FrameDecoder, FrameError, decode_control,
                     encode_control, write_frame)
from .supervisor import ProcConfig, WorkerSupervisor
from .transport import ProcTransport

__all__ = [
    "Autoscaler", "AutoscalePolicy", "FrameDecoder", "FrameError",
    "ProcConfig", "ProcStratumFabric", "ProcTransport", "WorkerSupervisor",
    "decode_control", "encode_control", "write_frame",
]
