"""The out-of-process sharded fabric — process-per-shard, same front door.

:class:`ProcStratumFabric` subclasses the in-process
:class:`~repro.service.fabric.fabric.StratumFabric` and changes exactly
one thing about each shard: it lives in its own worker process, reached
through a :class:`~.transport.ProcTransport` instead of a
:class:`~repro.service.fabric.transport.LocalTransport`.  Everything
above the transport — the router, the envelope codec, failover requeue,
shard-aware cancellation, telemetry aggregation, ``Session`` — is
inherited unchanged, which is the point of the serializable submission
boundary the fabric was built on.

What the subclass adds:

* ``add_shard`` spawns a worker via the :class:`WorkerSupervisor` and
  registers a :class:`_ShardProxy` (heartbeat-fed ``StratumService``
  stand-in) where the base class would register a local service;
* worker failures detected by the supervisor (crash, hang, socket loss)
  are wired straight into the inherited ``fail_shard`` — the same requeue
  machinery that handles a simulated in-process crash handles a real
  ``kill -9``;
* ``scale_down`` drains a shard *warm*: the departing worker exports its
  hottest cache entries (existing spill format) and the supervisor ships
  them to the shard's ring successor before the process exits;
* optional elastic autoscaling (:class:`~.autoscale.Autoscaler`) between
  ``autoscale=(min, max)`` bounds.

    fabric = ProcStratumFabric(n_shards=4, autoscale=(1, 8))
    results, report = fabric.session("agent-0").submit(batch).result()
    fabric.stop()
"""

from __future__ import annotations

from dataclasses import replace
from typing import Optional, Tuple

from ...server import ServiceConfig
from ..fabric import StratumFabric
from ..telemetry import FabricTelemetry
from .autoscale import AutoscalePolicy, Autoscaler
from .supervisor import ProcConfig, WorkerSupervisor


class ProcStratumFabric(StratumFabric):
    """N worker processes behind the same ring, router and Session API."""

    def __init__(self, n_shards: int = 2,
                 config: Optional[ServiceConfig] = None,
                 routing: str = "sources",
                 vnodes: int = 64,
                 autostart: bool = True,
                 autoscale: Optional[Tuple[int, int]] = None,
                 proc: Optional[ProcConfig] = None,
                 **overrides):
        self.proc_config = proc or ProcConfig()
        self.supervisor = WorkerSupervisor(self.proc_config,
                                           on_failure=self._on_worker_failure)
        self.autoscaler: Optional[Autoscaler] = None
        policy: Optional[AutoscalePolicy] = None
        if autoscale is not None:
            lo, hi = autoscale
            policy = AutoscalePolicy(min_shards=int(lo), max_shards=int(hi))
            n_shards = min(max(n_shards, policy.min_shards),
                           policy.max_shards)
        # base __init__ drives our add_shard override n_shards times, so
        # the supervisor must exist before it runs
        super().__init__(n_shards=n_shards, config=config, routing=routing,
                         vnodes=vnodes, autostart=autostart, **overrides)
        # same aggregation, plus the proc-only extras (worker pids,
        # hand-off and autoscale counters) merged into global_snapshot()
        self.telemetry = FabricTelemetry(self.router, self._shards_snapshot,
                                         extra=self._proc_extras)
        if policy is not None:
            self.autoscaler = Autoscaler(self, policy).start()

    # -- membership ----------------------------------------------------------
    def add_shard(self, shard_id: Optional[str] = None,
                  autostart: bool = True) -> str:
        """Spawn one worker process and join its shard to the ring.
        ``autostart`` is accepted for base-class compatibility; a worker
        always starts its service on boot."""
        del autostart
        with self._lock:
            if shard_id is None:
                shard_id = f"shard-{next(self._shard_seq)}"
        proxy = self.supervisor.spawn(
            shard_id, replace(self.config, shard_id=shard_id))
        with self._lock:
            self._shards[shard_id] = proxy
        self.router.add_shard(shard_id, proxy._handle.transport)
        return shard_id

    def start(self) -> "ProcStratumFabric":
        return self                 # workers autostart; nothing to do

    def shards(self) -> dict:
        """Copied snapshot of live shard proxies (autoscaler sensor)."""
        return self._shards_snapshot()

    def newest_shard(self) -> Optional[str]:
        """Most recently added live shard — the scale-down victim (its
        departure remaps the fewest long-lived keys)."""
        with self._lock:
            if len(self._shards) < 2:
                return None
            return next(reversed(self._shards))

    # -- elastic scale-down with warm hand-off -------------------------------
    def scale_down(self, shard_id: str, handoff: bool = True,
                   timeout: float = 30.0) -> None:
        """Retire ``shard_id`` gracefully, first shipping its hottest
        cache entries to its ring successor (existing spill format), so
        signatures that remap there start warm instead of recomputing."""
        if handoff:
            successor = self.router.successor_of(shard_id)
            if successor is not None:
                entries = self.supervisor.request_handoff(shard_id)
                if entries:
                    self.supervisor.deliver_handoff(successor, entries)
        self.drain_shard(shard_id, timeout=timeout)

    # -- supervisor events ----------------------------------------------------
    def _on_worker_failure(self, shard_id: str, reason: str) -> None:
        """A worker crashed or hung (supervisor health check): route it
        into the inherited failover path — requeue its pending envelopes
        onto ring successors.  Zero jobs are lost; at-least-once re-runs
        are safe because pipelines are deterministic, signature-keyed
        DAGs."""
        del reason
        if self._stopped:
            return
        self.fail_shard(shard_id)

    # -- lifecycle -------------------------------------------------------------
    def stop(self) -> None:
        if self._stopped:
            return
        if self.autoscaler is not None:
            self.autoscaler.stop()
        super().stop()              # graceful_stop per live worker
        self.supervisor.shutdown()

    # -- telemetry extras ------------------------------------------------------
    def _proc_extras(self) -> dict:
        extras = {
            "proc": {
                "workers": self.supervisor.live_workers(),
                "spawns": self.supervisor.spawns,
                "worker_failures": len(self.supervisor.failures),
                "handoff_entries_shipped":
                    self.supervisor.handoff_entries_shipped,
            }
        }
        if self.autoscaler is not None:
            extras["proc"]["autoscale"] = self.autoscaler.stats()
        return extras
